"""CI smoke: GarblerEndpoint ↔ EvaluatorEndpoint end-to-end over loopback
TCP on a tiny model, plus a multi-client PitGateway pass (two concurrent
sessions, one killed mid-session), with a hard timeout so a deadlocked
socket fails the build fast instead of hanging the runner.

    PYTHONPATH=src python scripts/net_smoke.py [--timeout 180] \\
        [--trace trace.json]

``--trace PATH`` records the whole smoke (both parties + the gateway
pass) with ``repro.obs`` and exports a Chrome trace_event JSON —
validated in CI by ``scripts/trace_check.py`` and uploaded as an
artifact.
"""

import argparse
import signal
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=int, default=180,
                    help="hard wall-clock limit (SIGALRM) in seconds")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export a Chrome trace_event JSON of the smoke")
    args = ap.parse_args()

    def die(signum, frame):
        print(f"FAIL: net smoke exceeded {args.timeout}s — deadlocked "
              f"socket or runaway exchange", flush=True)
        sys.stdout.flush()
        import os

        os._exit(2)

    signal.signal(signal.SIGALRM, die)
    signal.alarm(args.timeout)

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro import obs

    if args.trace:
        obs.enable()

    from repro.config import PrivacyConfig
    from repro.core.engine import PrivateTransformer, random_weights
    from repro.net import GarblerEndpoint, PitNetServer, TcpListener, \
        TcpTransport

    D, HEADS, DFF, S = 8, 2, 16, 4
    rng = np.random.default_rng(0)
    weights = random_weights(rng, D, DFF, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=6)
    model = PrivateTransformer(pcfg, D, HEADS, DFF, weights, seed=0)

    t0 = time.perf_counter()
    srv = PitNetServer(model, S, impl="ref")
    lst = TcpListener()
    loop = srv.serve_tcp(lst, timeout=120)
    cli = GarblerEndpoint(TcpTransport.connect("127.0.0.1", lst.port),
                          seed=7, impl="ref", timeout=120)
    assert loop.wait_accepted(1, timeout=30), "server never accepted"

    cli.preprocess(1)
    x = rng.normal(0, 1, (S, D))
    y = cli.run(x)

    sess = model.compile_session(S, impl="ref", wire_version=2)
    y_ref = sess.run(x, sess.preprocess(1)[0])
    assert np.array_equal(y, y_ref), "wire output != in-process session"
    assert cli.shared.negotiated_version == 2, "hello did not land on v2"
    led = cli.shared.ledger
    st = sess.stats
    assert led.offline.by_tag == dict(st.channel_offline.by_tag), \
        "offline wire ledger != metered oracle"
    assert led.online.by_tag == dict(st.channel_online.by_tag), \
        "online wire ledger != metered oracle"
    lsum = led.summary()
    assert lsum["rounds_after_coalescing"] < lsum["raw_messages"], \
        "v2 coalescing did not reduce the wire round count"
    err = float(np.abs(y - model.forward_float(x)).max())
    assert err < 0.25, f"accuracy drifted: {err}"

    cli.close()
    lst.close()
    print(f"net smoke OK in {time.perf_counter() - t0:.1f}s: loopback-TCP "
          f"wire v2 output bit-identical, ledger == oracle "
          f"({led.offline.total / 1e6:.1f} MB offline / "
          f"{led.online.total / 1e6:.2f} MB online, "
          f"{lsum['rounds_after_coalescing']} coalesced rounds vs "
          f"{lsum['raw_messages']} metered msgs), max|err|={err:.4f}",
          flush=True)

    # -- gateway: 2 concurrent sessions behind one accept loop, one
    # killed mid-session with a bundle outstanding --------------------
    from repro.serve import PitGateway, gateway_client

    t1 = time.perf_counter()
    gw = PitGateway(model, S, impl="ref", max_sessions=4, pool_cap=4)
    glst = TcpListener()
    gloop = gw.serve_listener(glst, accept_timeout=0.2, timeout=120)
    e1 = gateway_client("127.0.0.1", glst.port, seed=1, timeout=120)
    e2 = gateway_client("127.0.0.1", glst.port, seed=2, timeout=120)
    e1.preprocess(2)  # one to run, one to strand on the kill
    e2.preprocess(1)
    assert np.array_equal(e1.run(x), y_ref), "gateway session 1 diverged"
    e1.offline.transport.close()  # kill: no bye, bundle outstanding
    e1.online.transport.close()
    deadline = time.monotonic() + 30
    while gw.stats()["sessions_active"] != 1:
        assert time.monotonic() < deadline, "victim session never reclaimed"
        time.sleep(0.05)
    assert np.array_equal(e2.run(x), y_ref), "survivor session diverged"
    gst = gw.stats()
    assert gst["bundles_returned"] == 1, gst["bundles_returned"]
    cache = gst["garbling_cache"]
    e2.close()
    gloop.stop()
    gw.close()
    glst.close()
    print(f"gateway smoke OK in {time.perf_counter() - t1:.1f}s: "
          f"2 sessions muxed, mid-session kill returned "
          f"{gst['bundles_returned']} bundle, shared cache "
          f"{cache['slabs']} slabs / {cache['hits']} hits", flush=True)
    if args.trace:
        tr = obs.current()
        tr.export(args.trace)
        rep = tr.report()
        print(f"trace: {len(tr.finished_spans())} spans / "
              f"{len(rep)} span paths -> {args.trace}", flush=True)
    signal.alarm(0)
    return 0


if __name__ == "__main__":
    sys.exit(main())

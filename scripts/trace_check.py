#!/usr/bin/env python
"""Validate a Chrome trace_event JSON emitted by ``repro.obs``.

Checks (CI gate for the net-smoke trace artifact):

1. well-formed: a JSON object with a ``traceEvents`` list, every event
   carrying name/ph/ts/pid/tid, ``ts`` numeric and non-negative;
2. balanced: B/E duration events pair up per (pid, tid) as a proper
   stack, with matching names (``i`` instant events are exempt);
3. no secret-looking attribute keys or payload-like values: ``args``
   must be scalars (sizes/tags/counts), and no key may look like key /
   seed / label / mask / delta / secret material. This is the artifact-
   side mirror of the ``secretflow`` span-sink rule.

Exit codes: 0 clean, 1 findings, 2 unreadable/malformed input.
"""

from __future__ import annotations

import json
import re
import sys

REQUIRED_FIELDS = ("name", "ph", "ts", "pid", "tid")
KNOWN_PHASES = {"B", "E", "i", "X", "M"}
#: attribute keys that suggest secret material in a trace
SECRET_KEY_RE = re.compile(
    r"(^|_)(key|seed|label|labels|mask|masks|delta|secret|sk|payload|"
    r"r1|wire_zero|input_zero)($|_)", re.IGNORECASE)
SCALARS = (int, float, str, bool, type(None))
#: longer string values are payload-shaped, not a tag/name
MAX_STR_ATTR = 200


def check_events(doc) -> list:
    problems = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["top level must be an object with a 'traceEvents' list"]
    stacks = {}
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"event {i}"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        missing = [f for f in REQUIRED_FIELDS if f not in ev]
        if missing:
            problems.append(f"{where}: missing fields {missing}")
            continue
        where = f"event {i} ({ev['name']!r})"
        if ev["ph"] not in KNOWN_PHASES:
            problems.append(f"{where}: unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            problems.append(f"{where}: bad ts {ev['ts']!r}")
        args = ev.get("args", {})
        if not isinstance(args, dict):
            problems.append(f"{where}: args must be an object")
            args = {}
        for k, v in args.items():
            if SECRET_KEY_RE.search(str(k)):
                problems.append(
                    f"{where}: secret-looking attribute key {k!r}")
            if not isinstance(v, SCALARS):
                problems.append(
                    f"{where}: non-scalar attribute {k!r} "
                    f"({type(v).__name__}) — payload-shaped")
            elif isinstance(v, str) and len(v) > MAX_STR_ATTR:
                problems.append(
                    f"{where}: oversized string attribute {k!r} "
                    f"({len(v)} chars) — payload-shaped")
        if ev["ph"] == "B":
            stacks.setdefault((ev["pid"], ev["tid"]), []).append(
                (ev["name"], i))
        elif ev["ph"] == "E":
            stack = stacks.setdefault((ev["pid"], ev["tid"]), [])
            if not stack:
                problems.append(f"{where}: E without a matching B")
            else:
                name, bi = stack.pop()
                if name != ev["name"]:
                    problems.append(
                        f"{where}: E closes {name!r} opened at event {bi}")
    for (pid, tid), stack in stacks.items():
        for name, bi in stack:
            problems.append(
                f"unclosed B event {bi} ({name!r}) on pid={pid} tid={tid}")
    return problems


def main(argv) -> int:
    if len(argv) != 2:
        print("usage: trace_check.py TRACE.json", file=sys.stderr)
        return 2
    try:
        with open(argv[1]) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"trace_check: cannot read {argv[1]}: {e}", file=sys.stderr)
        return 2
    problems = check_events(doc)
    if problems:
        for p in problems:
            print(f"trace_check: {p}")
        print(f"trace_check: {len(problems)} problem(s) in {argv[1]}")
        return 1
    n = len(doc["traceEvents"])
    print(f"trace_check: ok ({n} events, {argv[1]})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

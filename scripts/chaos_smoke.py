"""CI chaos smoke: seeded fault schedules against a resilient client on
a loopback-TCP PitGateway. Every seed must either complete bit-identical
to the in-process session or fail with a typed error — no hangs (SIGALRM
hard limit), no bundle reuse (the prepped == consumed + outstanding +
returned + burned identity is checked after every seed), and no secret
bytes on error/CONTROL frames (class-name-only audit of everything that
crossed a faulty transport).

    PYTHONPATH=src python scripts/chaos_smoke.py [--seeds 8] \\
        [--timeout 360]
"""

import argparse
import re
import signal
import sys
import time

#: error CONTROL frames carry a class name plus a fixed parenthetical,
#: never str(e) / payload bytes / tracebacks (the secretflow discipline)
ERROR_WHITELIST = re.compile(
    r"^[A-Za-z_][A-Za-z0-9_]* \((idle deadline exceeded|"
    r"request deadline exceeded|see evaluator-side log)\)$")

ALLOWED = {"ok", "BundlePoolEmpty", "TransportClosed", "TransportTimeout",
           "SessionLost"}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=8,
                    help="number of seeded fault schedules to sweep")
    ap.add_argument("--timeout", type=int, default=360,
                    help="hard wall-clock limit (SIGALRM) in seconds")
    args = ap.parse_args()

    def die(signum, frame):
        print(f"FAIL: chaos smoke exceeded {args.timeout}s — a faulted "
              f"session hung instead of failing typed", flush=True)
        sys.stdout.flush()
        import os

        os._exit(2)

    signal.signal(signal.SIGALRM, die)
    signal.alarm(args.timeout)

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.config import PrivacyConfig
    from repro.core.engine import PrivateTransformer, random_weights
    from repro.net import (Deadlines, FaultPlan, ResilientClient,
                           RetryPolicy, TcpListener, TcpTransport,
                           TransportClosed)
    from repro.net import wire as W
    from repro.serve import BundlePoolEmpty, PitGateway

    D, HEADS, DFF, S = 8, 2, 16, 4
    rng = np.random.default_rng(0)
    weights = random_weights(rng, D, DFF, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=6)
    model = PrivateTransformer(pcfg, D, HEADS, DFF, weights, seed=0)
    x = rng.normal(0, 1, (S, D))
    sess = model.compile_session(S, impl="ref")
    y_ref = sess.run(x, sess.preprocess(1)[0])
    dl = Deadlines.uniform(20.0)

    t0 = time.perf_counter()
    violations = []
    outcomes = {}
    for seed in range(args.seeds):
        gw = PitGateway(model, S, impl="ref", max_sessions=4, pool_cap=4,
                        lease_s=30.0)
        lst = TcpListener()
        loop = gw.serve_listener(lst, accept_timeout=0.1, deadlines=dl)
        plan = FaultPlan(seed=seed, faulty_conns=2, n_faults=1,
                         first_op=2, horizon=40, stall_s=0.05,
                         record_frames=True)
        port = lst.port
        cli = ResilientClient(
            lambda: plan.wrap(TcpTransport.connect("127.0.0.1", port)),
            seed=seed,
            policy=RetryPolicy(attempts=6, base_s=0.01, max_s=0.05,
                               seed=seed),
            deadlines=dl)
        t_seed = time.perf_counter()
        try:
            cli.preprocess(1)
            y = cli.run(x)
            outcome = "ok" if np.array_equal(y, y_ref) else "DIVERGED"
        except BundlePoolEmpty:
            outcome = "BundlePoolEmpty"
        except TransportClosed as e:
            outcome = type(e).__name__
        except Exception as e:  # untyped escape = a resilience bug
            outcome = f"UNTYPED:{type(e).__name__}"
        finally:
            try:
                cli.close()
            except (TransportClosed, OSError):
                pass
        outcomes[seed] = outcome
        if outcome not in ALLOWED:
            violations.append(f"seed {seed}: outcome {outcome}")

        st = gw.stats()
        if st["bundles_prepped"] != (st["bundles_consumed"]
                                     + st["bundles_outstanding"]
                                     + st["bundles_returned"]
                                     + st["bundles_burned"]):
            violations.append(f"seed {seed}: bundle identity violated "
                              f"({st['bundles_prepped']} prepped != "
                              f"{st['bundles_consumed']}c + "
                              f"{st['bundles_outstanding']}o + "
                              f"{st['bundles_returned']}r + "
                              f"{st['bundles_burned']}b)")
        audited = 0
        for ft in plan.transports:
            for _direction, fr in ft.frame_log:
                try:
                    msg = W.decode_frame(fr)
                except Exception:
                    continue  # torn frames are undecodable by design
                if msg.kind != W.KIND_CONTROL:
                    continue
                audited += 1
                if msg.tag == "error" and not (
                        isinstance(msg.payload, str)
                        and ERROR_WHITELIST.match(msg.payload)):
                    violations.append(
                        f"seed {seed}: non-whitelisted error frame")
        faults = ["%s@%d.%d" % (k, c, o) for c, o, k in plan.injected()]
        print(f"seed {seed}: {outcome} in "
              f"{time.perf_counter() - t_seed:.1f}s "
              f"(faults {','.join(faults) or 'none'}, "
              f"reconnects {cli.stats()['reconnects']}, "
              f"burned {st['bundles_burned']}, resumed "
              f"{st['sessions_resumed']}, {audited} frames audited)",
              flush=True)
        loop.stop()
        gw.close()
        lst.close()

    n_ok = sum(1 for v in outcomes.values() if v == "ok")
    if n_ok == 0:
        violations.append("no seed completed — the sweep proved nothing")
    if violations:
        print("FAIL: " + "; ".join(violations), flush=True)
        return 1
    print(f"chaos smoke OK in {time.perf_counter() - t0:.1f}s: "
          f"{args.seeds} seeded schedules, {n_ok} bit-identical, "
          f"{args.seeds - n_ok} typed failures, identity + frame "
          f"hygiene held on every seed", flush=True)
    signal.alarm(0)
    return 0


if __name__ == "__main__":
    sys.exit(main())

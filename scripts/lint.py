#!/usr/bin/env python
"""Repo lint entry point — see ``repro.analysis.cli`` for the flags.

Usage (from the repo root):

    python scripts/lint.py --all --baseline analysis/baseline.json
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())

"""Quick single-device smoke of every reduced arch: fwd/train/prefill/decode."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig, get_config, reduced_config
from repro.launch.steps import build_train_step, build_prefill_step, build_decode_step, abstract_state
from repro.models.transformer import init_params, init_caches, forward

ARCHS = [
    "olmoe-1b-7b", "llama4-scout-17b-a16e", "llama3.2-1b", "deepseek-67b",
    "qwen3-1.7b", "smollm-360m", "musicgen-medium", "xlstm-125m",
    "zamba2-2.7b", "internvl2-26b", "bert-base-pit",
]


def make_batch(cfg, B, S, kind, rng):
    out = {}
    if cfg.input_mode == "embeddings":
        if kind == "decode":
            out["embeddings"] = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)), jnp.float32)
        else:
            out["embeddings"] = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    elif cfg.input_mode == "tokens+image":
        n = cfg.num_image_tokens
        if kind == "decode":
            out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        else:
            out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - n)), jnp.int32)
            out["image_embeds"] = jnp.asarray(rng.standard_normal((B, n, cfg.d_model)), jnp.float32)
    else:
        s = 1 if kind == "decode" else S
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)), jnp.int32)
    if kind == "train":
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return out


def main():
    rng = np.random.default_rng(0)
    failures = []
    for arch in ARCHS:
        cfg = reduced_config(get_config(arch))
        B, S = 2, 64
        try:
            params = init_params(cfg, jax.random.PRNGKey(0))
            # train step
            tc = TrainConfig(microbatches=1)
            step, _, _, _ = build_train_step(cfg, tc)
            state = {"params": params, "opt": __import__("repro.train.optimizer", fromlist=["init_opt_state"]).init_opt_state(params), "step": jnp.int32(0)}
            batch = make_batch(cfg, B, S, "train", rng)
            state2, metrics = jax.jit(step)(state, batch)
            loss = float(metrics["loss"])
            assert np.isfinite(loss), f"loss not finite: {loss}"
            # prefill + decode
            if cfg.causal:
                pbatch = make_batch(cfg, B, S, "prefill", rng)
                logits, caches = forward(cfg, params, pbatch, mode="prefill")
                assert logits.shape == (B, cfg.padded_vocab)
                assert np.isfinite(np.asarray(logits)).all()
                dbatch = make_batch(cfg, B, S, "decode", rng)
                # grow caches to capacity S+4
                caches2 = init_caches(cfg, B, S + 4, dtype=jnp.dtype(cfg.dtype))
                logits2, caches3 = forward(cfg, params, dbatch, mode="decode", caches=caches2)
                assert logits2.shape == (B, cfg.padded_vocab)
                assert np.isfinite(np.asarray(logits2)).all()
            print(f"PASS {arch:26s} loss={loss:.4f}")
        except Exception as e:
            import traceback
            traceback.print_exc()
            print(f"FAIL {arch}: {e}")
            failures.append(arch)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("all smoke passed")


if __name__ == "__main__":
    main()

"""End-to-end driver: serve a (reduced) BERT-style encoder privately with
batched requests — the paper's deployment scenario.

The server owns the weights, each client owns its input embeddings. For
every request batch the engine runs the full APINT pipeline: DELPHI linear
layers (HE offline), Beaver attention products, garbled softmax/GeLU, the
APINT LayerNorm offload — and reports per-request latency plus the
offline/online communication ledger.

    PYTHONPATH=src python examples/serve_private_bert.py [--requests 3]
"""

import argparse
import time

import numpy as np

from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--no-offload", action="store_true",
                    help="PRIMER-style baseline (full LayerNorm in GC)")
    args = ap.parse_args()

    rng = np.random.default_rng(1)
    weights = random_weights(rng, args.d, 2 * args.d, args.layers)
    pcfg = PrivacyConfig(
        he_poly_n=256, he_num_primes=3, he_t_bits=40, frac_bits=7,
        layernorm_offload=not args.no_offload,
    )
    server = PrivateTransformer(pcfg, args.d, 2, 2 * args.d, weights, seed=0)
    print(f"server up: d={args.d} layers={args.layers} "
          f"LN-offload={not args.no_offload} t={server.p.t} "
          f"gc_word={server.p.k}b\n")

    for i in range(args.requests):
        x = rng.normal(0, 1, (args.seq, args.d))  # client-private input
        t0 = time.time()
        y_priv = server.forward_private(x)
        dt = time.time() - t0
        y_ref = server.forward_float(x)
        err = np.abs(y_priv - y_ref).max()
        print(f"request {i}: {dt:6.1f}s  max|priv-float|={err:.4f}")

    st = server.p.stats
    print("\n--- ledger ---")
    print(f"offline: {st.channel_offline.total / 1e6:8.2f} MB "
          f"(LAN model: {st.channel_offline.time_s():.2f}s)")
    print(f"online : {st.channel_online.total / 1e6:8.2f} MB "
          f"(LAN model: {st.channel_online.time_s():.2f}s)")
    print(f"GC work: {st.gc_instances_ands:.3e} AND evaluations")
    for name, v in st.per_fn.items():
        print(f"  {name:26s} and/inst={v['and']:>7d} instances={v['instances']}")


if __name__ == "__main__":
    main()

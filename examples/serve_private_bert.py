"""End-to-end driver: serve a (reduced) BERT-style encoder privately with
the compile → preprocess → run lifecycle — the paper's deployment scenario.

The server owns the weights, each client owns its input embeddings. The
engine compiles one ``PiTSession`` per sequence-length bucket, runs ALL
offline work (garbling, HE mask products, Beaver triples) for a whole
batch of future requests in one preprocessing pass, then serves every
request online-only from the bundle pool. The offline/online latency and
communication tables come straight from the session's phase ledgers — the
phase boundary itself, not accumulated timer deltas.

``--net pipe|tcp`` runs the same deployment as an actual **two-party
exchange** (``repro.net``): a ``PitNetServer`` hosts the weights behind a
dedicated offline endpoint pair and an online pair; the client garbles,
streams tables/HE frames over the wire, and serves requests with bundle
refill pipelined against online traffic. Wire frames carry the metered
``Channel`` sizes by construction; byte-equality with the in-process
oracle is *asserted* in ``tests/test_net.py`` and the CI TCP smoke.

    PYTHONPATH=src python examples/serve_private_bert.py [--requests 3]
    PYTHONPATH=src python examples/serve_private_bert.py --net tcp

``--trace PATH`` records the whole serve (compile/preprocess/run spans,
per-op protocol spans, wire send/recv when ``--net``) with ``repro.obs``
and exports a Chrome trace_event JSON plus a per-span-path summary.
"""

import argparse
from time import perf_counter

import numpy as np

from repro import obs
from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from repro.serve import PrivateRequest, PrivateServeEngine


def serve_in_process(model, args, rng):
    engine = PrivateServeEngine(model, buckets=(args.seq,),
                                pool_target=args.requests)

    # ---- offline: one preprocessing batch for the whole request wave ----
    t0 = perf_counter()
    level = engine.preprocess(args.seq, args.requests)
    t_pre = perf_counter() - t0
    print(f"preprocess: {args.requests} bundles in {t_pre:6.1f}s "
          f"(pool level {level})")

    # ---- online: every request served from the same preprocessing batch -
    requests = [
        PrivateRequest(x=rng.normal(0, 1, (args.seq, args.d)))
        for _ in range(args.requests)
    ]
    for i, r in enumerate(requests):
        t0 = perf_counter()
        engine.serve([r])
        dt = perf_counter() - t0
        err = np.abs(r.result - model.forward_float(r.x)).max()
        print(f"request {i}: online {dt:6.1f}s  max|priv-float|={err:.4f}")

    st = engine.stats(args.seq)
    print("\n--- phase ledger (from the session phase boundary) ---")
    print(f"offline: {st.offline.channel.total / 1e6:8.2f} MB "
          f"in {st.offline.t_s:6.1f}s "
          f"(LAN model: {st.offline.channel.time_s():.2f}s)")
    print(f"online : {st.online.channel.total / 1e6:8.2f} MB "
          f"in {st.online.t_s:6.1f}s "
          f"(LAN model: {st.online.channel.time_s():.2f}s)")
    print(f"GC work: {st.gc_instances_ands:.3e} AND evaluations")
    for name, v in st.per_fn.items():
        print(f"  {name:26s} and/inst={v['and']:>7d} instances={v['instances']}")
    cores = engine.schedule_info(args.seq)
    busy = sum(1 for c in cores if c)
    print(f"\ncoarse schedule: {sum(len(c) for c in cores)} GC unit ops "
          f"over {busy}/{len(cores)} cores")


def serve_two_party(model, args, rng):
    """The same wave over real endpoints: pipelined offline/online pairs."""
    from repro.net import (InProcPipe, PitNetServer, TcpListener,
                           TcpTransport)
    from repro.serve import NetPrivateServeEngine

    srv = PitNetServer(model, args.seq, impl="ref")
    if args.net == "tcp":
        lst = TcpListener()
        loop = srv.serve_tcp(lst, timeout=600, max_conns=2)
        off_c = TcpTransport.connect("127.0.0.1", lst.port)
        on_c = TcpTransport.connect("127.0.0.1", lst.port)
        loop.wait_accepted(2, timeout=60)
        print(f"two-party over loopback TCP (port {lst.port})")
    else:
        off_c, off_s = InProcPipe.make_pair()
        on_c, on_s = InProcPipe.make_pair()
        srv.serve_transport(off_s, timeout=600, name="pit-eval-offline")
        srv.serve_transport(on_s, timeout=600, name="pit-eval-online")
        print("two-party over InProcPipe")

    eng = NetPrivateServeEngine(off_c, on_c, pool_target=args.requests,
                                seed=1, impl="ref", timeout=600)
    t0 = perf_counter()
    eng.preprocess(args.requests)
    t_pre = perf_counter() - t0
    print(f"preprocess (wire): {args.requests} bundles in {t_pre:6.1f}s "
          f"(pool level {eng.pool_size()})")

    refill = eng.refill_async(1)  # pipelined: streams while we serve
    for i in range(args.requests):
        x = rng.normal(0, 1, (args.seq, args.d))
        t0 = perf_counter()
        y = eng.run(x)
        dt = perf_counter() - t0
        err = np.abs(y - model.forward_float(x)).max()
        print(f"request {i}: online {dt:6.1f}s  max|priv-float|={err:.4f}  "
              f"refill-in-flight={refill.is_alive()}")
    refill.join(timeout=600)

    led = eng.ledger
    print("\n--- wire ledger (PROTO payloads at metered-oracle sizes) ---")
    print(f"offline: {led.offline.total / 1e6:8.2f} MB "
          f"(LAN model: {led.offline.time_s():.2f}s)")
    print(f"online : {led.online.total / 1e6:8.2f} MB "
          f"(LAN model: {led.online.time_s():.2f}s)")
    print(f"overhead: sim sideband {led.sim_bytes / 1e6:.2f} MB, control "
          f"{led.control_bytes / 1e3:.1f} KB, dir flips {led.dir_flips}")
    eng.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--no-offload", action="store_true",
                    help="PRIMER-style baseline (full LayerNorm in GC)")
    ap.add_argument("--net", choices=("off", "pipe", "tcp"), default="off",
                    help="off: in-process session; pipe/tcp: real two-party "
                         "endpoints with pipelined offline/online pairs")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="export a Chrome trace_event JSON of the serve")
    args = ap.parse_args()

    if args.trace:
        obs.enable()

    rng = np.random.default_rng(1)
    weights = random_weights(rng, args.d, 2 * args.d, args.layers)
    pcfg = PrivacyConfig(
        he_poly_n=256, he_num_primes=3, he_t_bits=40, frac_bits=7,
        layernorm_offload=not args.no_offload,
    )
    model = PrivateTransformer(pcfg, args.d, 2, 2 * args.d, weights, seed=0)
    print(f"server up: d={args.d} layers={args.layers} "
          f"LN-offload={not args.no_offload} t={model.p.t} "
          f"gc_word={model.p.k}b  bucket S={args.seq}\n")

    if args.net == "off":
        serve_in_process(model, args, rng)
    else:
        serve_two_party(model, args, rng)

    if args.trace:
        tr = obs.current()
        tr.export(args.trace)
        print(f"\n--- trace: {len(tr.finished_spans())} spans -> "
              f"{args.trace} ---")
        for path, agg in tr.report().items():
            print(f"  {path:44s} n={agg['count']:<4d} "
                  f"total={agg['total_s']:.3f}s mean={agg['mean_s']:.4f}s")


if __name__ == "__main__":
    main()

"""End-to-end driver: serve a (reduced) BERT-style encoder privately with
the compile → preprocess → run lifecycle — the paper's deployment scenario.

The server owns the weights, each client owns its input embeddings. The
engine compiles one ``PiTSession`` per sequence-length bucket, runs ALL
offline work (garbling, HE mask products, Beaver triples) for a whole
batch of future requests in one preprocessing pass, then serves every
request online-only from the bundle pool. The offline/online latency and
communication tables come straight from the session's phase ledgers — the
phase boundary itself, not accumulated timer deltas.

    PYTHONPATH=src python examples/serve_private_bert.py [--requests 3]
"""

import argparse
from time import perf_counter

import numpy as np

from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from repro.serve import PrivateRequest, PrivateServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--no-offload", action="store_true",
                    help="PRIMER-style baseline (full LayerNorm in GC)")
    args = ap.parse_args()

    rng = np.random.default_rng(1)
    weights = random_weights(rng, args.d, 2 * args.d, args.layers)
    pcfg = PrivacyConfig(
        he_poly_n=256, he_num_primes=3, he_t_bits=40, frac_bits=7,
        layernorm_offload=not args.no_offload,
    )
    model = PrivateTransformer(pcfg, args.d, 2, 2 * args.d, weights, seed=0)
    engine = PrivateServeEngine(model, buckets=(args.seq,),
                                pool_target=args.requests)
    print(f"server up: d={args.d} layers={args.layers} "
          f"LN-offload={not args.no_offload} t={model.p.t} "
          f"gc_word={model.p.k}b  bucket S={args.seq}\n")

    # ---- offline: one preprocessing batch for the whole request wave ----
    t0 = perf_counter()
    level = engine.preprocess(args.seq, args.requests)
    t_pre = perf_counter() - t0
    print(f"preprocess: {args.requests} bundles in {t_pre:6.1f}s "
          f"(pool level {level})")

    # ---- online: every request served from the same preprocessing batch -
    requests = [
        PrivateRequest(x=rng.normal(0, 1, (args.seq, args.d)))
        for _ in range(args.requests)
    ]
    for i, r in enumerate(requests):
        t0 = perf_counter()
        engine.serve([r])
        dt = perf_counter() - t0
        err = np.abs(r.result - model.forward_float(r.x)).max()
        print(f"request {i}: online {dt:6.1f}s  max|priv-float|={err:.4f}")

    st = engine.stats(args.seq)
    print("\n--- phase ledger (from the session phase boundary) ---")
    print(f"offline: {st.offline.channel.total / 1e6:8.2f} MB "
          f"in {st.offline.t_s:6.1f}s "
          f"(LAN model: {st.offline.channel.time_s():.2f}s)")
    print(f"online : {st.online.channel.total / 1e6:8.2f} MB "
          f"in {st.online.t_s:6.1f}s "
          f"(LAN model: {st.online.channel.time_s():.2f}s)")
    print(f"GC work: {st.gc_instances_ands:.3e} AND evaluations")
    for name, v in st.per_fn.items():
        print(f"  {name:26s} and/inst={v['and']:>7d} instances={v['instances']}")
    cores = engine.schedule_info(args.seq)
    busy = sum(1 for c in cores if c)
    print(f"\ncoarse schedule: {sum(len(c) for c in cores)} GC unit ops "
          f"over {busy}/{len(cores)} cores")


if __name__ == "__main__":
    main()

"""Quickstart: the APINT privacy plane in ~60 lines.

1. Build a GC-friendly circuit (i-BERT softmax row) and inspect the XFBQ
   AND-gate savings.
2. Run it privately: secret-share a row, garble (client), evaluate
   (server), reveal — and check against the cleartext softmax.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.config import PrivacyConfig
from repro.core import secret_sharing as SS
from repro.core.circuits import nonlinear as NL
from repro.core.protocol import PiTProtocol


def main():
    # --- circuit generation (§3.2) -------------------------------------
    for style in ("conventional", "xfbq"):
        net = NL.softmax_circuit(8, k=37, frac=12, style=style).build()
        print(f"softmax8 [{style:12s}]  AND={net.and_count:7d} "
              f"XOR={net.xor_count:7d} depth={net.stats()['depth']}")

    # --- private evaluation (the APINT protocol) ------------------------
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=6)
    proto = PiTProtocol(pcfg, seed=0)
    rng = np.random.default_rng(0)

    rows = rng.normal(0.0, 1.5, (4, 8))  # four independent rows (coarse-
    # grained instances: one per accelerator core / data-parallel shard)
    enc = SS.encode_fx(rows, 2 * proto.frac, proto.t)
    client_share, server_share = SS.share(rng, enc, proto.t)

    oc, os_ = proto.softmax_rows(client_share, server_share, 8,
                                 in_scale=2 * proto.frac)
    got = proto.reveal(oc, os_)
    want = np.exp(rows - rows.max(1, keepdims=True))
    want /= want.sum(1, keepdims=True)

    print(f"\nprivate softmax max|err| = {np.abs(got - want).max():.4f}")
    st = proto.stats
    print(f"GC: {st.gc_instances_ands} AND-gate evaluations "
          f"({st.gc_and_gates} per instance x 4 rows)")
    print(f"offline comm {st.channel_offline.total / 1e6:.2f} MB "
          f"(tables + labels + HE), online {st.channel_online.total / 1e3:.1f} KB (OT)")
    assert np.abs(got - want).max() < 0.05
    print("OK")


if __name__ == "__main__":
    main()

"""Train a language model on the synthetic pipeline with checkpoint/resume.

Default preset is CPU-sized; `--arch smollm-360m --full` uses the real
360M config (for actual hardware). Demonstrates the fault-tolerance path:
Ctrl-C mid-run, re-launch with the same command, training resumes from the
last checkpoint bitwise-exactly.

    PYTHONPATH=src python examples/train_lm.py --steps 20
"""

import argparse

from repro.config import TrainConfig, get_config, reduced_config
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (needs real hardware)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg, num_layers=4, d_model=128, head_dim=32,
                             d_ff=256 if cfg.d_ff else 0)
    tc = TrainConfig(
        total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
        checkpoint_every=10, checkpoint_dir=args.ckpt, learning_rate=1e-3,
    )
    tr = Trainer(cfg, tc, global_batch=args.batch, seq_len=args.seq)
    start = tr.init_or_resume(resume=True)
    print(f"training {cfg.name} from step {start} "
          f"({cfg.num_params() / 1e6:.1f}M params)")
    out = tr.run(args.steps - start)
    losses = out["losses"]
    if losses:
        print(f"steps {start}..{out['final_step']}: "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if tr.watchdog.flagged:
        print("straggler steps:", tr.watchdog.flagged)


if __name__ == "__main__":
    main()

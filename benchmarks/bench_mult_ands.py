"""Fig. 5(b): AND-gate counts of 64-bit multipliers.

conventional schoolbook vs XFBQ (without / with Q-error correction).
"""

from __future__ import annotations

from repro.core.circuits import arith
from repro.core.circuits.builder import CircuitBuilder
from benchmarks.common import emit, timeit


def counts(k: int):
    out = {}
    for style, qe in [("conventional", False), ("xfbq", False),
                      ("xfbq", True)]:
        cb = CircuitBuilder()
        a = cb.g_input_word(k)
        b = cb.e_input_word(k)
        cb.output(arith.mul(cb, a, b, style=style, qerror_terms=qe))
        out[(style, qe)] = cb.build().and_count
    return out


def main():
    for k in (16, 32, 64):
        c = counts(k)
        base = c[("conventional", False)]
        us = timeit(lambda: counts(8), n=1)
        emit(
            f"fig5b_mult{k}_conventional", us, f"ANDs={base}"
        )
        emit(
            f"fig5b_mult{k}_xfbq", us,
            f"ANDs={c[('xfbq', False)]}"
            f";reduction={100 * (1 - c[('xfbq', False)] / base):.1f}%"
            f";paper=45.5%",
        )
        emit(
            f"fig5b_mult{k}_xfbq_qerr", us,
            f"ANDs={c[('xfbq', True)]}"
            f";reduction={100 * (1 - c[('xfbq', True)] / base):.1f}%"
            f";paper=38.9%",
        )


if __name__ == "__main__":
    main()

"""Fig. 11(b): system energy (on-chip + external-memory-access) for HAAC
vs APINT while evaluating the nonlinear functions."""

from __future__ import annotations

from repro.accel.energy import energy_report
from repro.accel.sim import AccelConfig, simulate_core
from repro.core.circuits import nonlinear as NL
from repro.sched import schedulers as SC
from repro.sched.speculation import speculate
from benchmarks.common import emit

CAP = 1024
PAPER = {"softmax": 4.9, "gelu": 3.6, "layernorm": 5.7}


def main():
    nets = {
        "softmax": NL.softmax_circuit(8, k=24, frac=8).build(),
        "gelu": NL.gelu_circuit(k=21, frac=10).build(),
        "layernorm": NL.layernorm_full_circuit(8, k=24, frac=8).build(),
    }
    for name, net in nets.items():
        other = net.num_gates - net.and_count
        sr = SC.segment_reorder(net, CAP // 2)
        fine = SC.fine_grained_order(net, CAP // 2)
        haac = simulate_core(
            net, speculate(net, sr, CAP, policy="haac"),
            AccelConfig(coalesced=False), AccelConfig().dram_burst_latency,
        )
        apint = simulate_core(
            net, speculate(net, fine, CAP, policy="apint"),
            AccelConfig(coalesced=True), AccelConfig().dram_burst_latency,
        )
        e_haac = energy_report(haac, net.and_count, other)
        e_apint = energy_report(apint, net.and_count, other)
        ratio = e_haac["total_uj"] / e_apint["total_uj"]
        emit(
            f"fig11b_{name}", 0.0,
            f"haac_uj={e_haac['total_uj']:.1f}(ema {100*e_haac['ema_fraction']:.0f}%)"
            f";apint_uj={e_apint['total_uj']:.1f}(ema {100*e_apint['ema_fraction']:.0f}%)"
            f";saving={ratio:.2f}x;paper={PAPER[name]}x",
        )


if __name__ == "__main__":
    main()

"""Fig. 10: latency / stall / OoRW / DRAM-access breakdown for GC
evaluation of the nonlinear functions across scheduling + speculation +
accelerator variants (HAAC baseline -> +coarse -> +fine -> APINT).

Netlists are reduced-size rows (row 8 at 24b instead of 128 at 37b) so the
cycle simulation stays CPU-tractable; the derived metrics are the paper's
*relative* claims, which are size-stable.
"""

from __future__ import annotations

from repro.accel.sim import AccelConfig, simulate_core
from repro.core.circuits import nonlinear as NL
from repro.sched import schedulers as SC
from repro.sched.speculation import speculate
from benchmarks.common import emit

CAP = 1024  # wire-memory capacity (labels) for the reduced netlists


def run_function(name: str, net):
    sr = SC.segment_reorder(net, CAP // 2)
    fine = SC.fine_grained_order(net, CAP // 2)
    variants = [
        ("haac", sr, "haac", False),
        ("coarse", sr, "haac", True),
        ("fine", fine, "haac", True),
        ("apint", fine, "apint", True),
    ]
    res = {}
    for vname, order, policy, coal in variants:
        prog = speculate(net, order, CAP, policy=policy)
        cfg = AccelConfig(coalesced=coal)
        res[vname] = simulate_core(net, prog, cfg, cfg.dram_burst_latency)
    base = res["haac"]
    ap = res["apint"]
    for vname, r in res.items():
        emit(
            f"fig10_{name}_{vname}", 0.0,
            f"cycles={r.cycles};pipe_stall={r.pipeline_stall_cycles}"
            f";mem_stall={r.memory_stall_cycles};oorw={r.oorw_count}"
            f";dram_accesses={r.dram_accesses}",
        )
    emit(
        f"fig10_{name}_summary", 0.0,
        f"speedup_vs_haac={base.cycles / ap.cycles:.2f}x"
        f";mem_stall_reduction={100 * (1 - ap.memory_stall_cycles / max(base.memory_stall_cycles, 1)):.1f}%"
        f";paper_speedup={'5.0x softmax / 2.2x gelu / 3.9x layernorm'}"
        f";paper_memstall=86.1-99.4%",
    )
    return res


def main():
    nets = {
        "softmax": NL.softmax_circuit(8, k=24, frac=8).build(),
        "gelu": NL.gelu_circuit(k=21, frac=10).build(),
        "layernorm": NL.layernorm_full_circuit(8, k=24, frac=8).build(),
    }
    out = {}
    for name, net in nets.items():
        out[name] = run_function(name, net)
    return out


if __name__ == "__main__":
    main()

"""GC online-path microbench: gates/s of the device-resident executor
vs the per-level numpy loop, on protocol softmax-row netlists.

This is the repo's perf gate for the hottest online code in hybrid PiT —
:func:`repro.core.garble.evaluate` — the path every ``session.run`` /
``PrivateServeEngine.serve`` request takes. Two implementations of the
same bit-exact walk are raced:

  ref   per-level numpy loop (gather -> XOR/INV/Half-Gate batches ->
        scatter, one Python round trip per topological level)
  auto  device-resident executor (:mod:`repro.core.gc_exec`): the whole
        netlist compiled into ONE jitted scan through the fused level
        kernel

Two softmax-row configurations are swept:

* ``softmax8 @ 40-bit shares`` — the production share modulus
  (``bench_protocol``'s config), from the single-request latency point
  (I=1, where the executor's latency-regime plan applies) up to
  preprocessing-scale batches. The recorded headline (>= 5x gates/s
  over the numpy loop) is this config's online-latency point — the
  metric APINT optimizes — where the numpy loop is pure per-level
  dispatch overhead and the compiled walk replaces ~2100 Python round
  trips with one launch; large batches are bandwidth-bound on both
  sides and win ~2-3x.
* ``softmax2 @ 12-bit shares`` — a quantized row (aggressive word-width
  reduction is APINT's own direction, XFBQ/Fig. 5), recorded as the
  secondary config.

``python benchmarks/bench_gc_eval.py`` runs both sweeps and writes
``BENCH_gc_eval.json`` at the repo root (keeping the previously
committed speedups per point as ``prev`` for comparison); ``--smoke``
(CI and ``benchmarks/run.py``) runs the quantized row at the I=4 online
point plus a preprocessing-scale I=64 garble-parity point and asserts
parity + sane speedups on both paths. :func:`check` (``run.py
--check``) re-measures a small subset and fails on a >20% speedup
regression against the committed JSON.

Every point embeds the executor plan's :meth:`LevelPlan.stats` so the
liveness-compaction and packed-table wins are visible per netlist.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

#: (row_len, frac_bits, he_t_bits, he_poly_n, he_num_primes)
PROD = {"label": "softmax8 @ 40-bit shares",
        "row_len": 8, "frac": 6, "t_bits": 40, "poly_n": 256, "primes": 3}
QUANT = {"label": "softmax2 @ 12-bit shares (quantized row)",
         "row_len": 2, "frac": 4, "t_bits": 12, "poly_n": 64, "primes": 2}


def _net(cfg):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.config import PrivacyConfig
    from repro.core.protocol import PiTProtocol

    pcfg = PrivacyConfig(he_poly_n=cfg["poly_n"],
                         he_num_primes=cfg["primes"],
                         he_t_bits=cfg["t_bits"], frac_bits=cfg["frac"],
                         layernorm_offload=True)
    return PiTProtocol(pcfg, seed=0).softmax_net(cfg["row_len"],
                                                 cfg["frac"])


def _active_labels(net, gc, rng):
    from repro.core import garble as G

    I = gc.num_instances
    bits = rng.integers(0, 2, (I, len(net.garbler_inputs)
                               + len(net.evaluator_inputs)))
    wire_ids = np.concatenate([
        np.asarray(net.garbler_inputs, np.int64),
        np.asarray(net.evaluator_inputs, np.int64)])
    labels = np.asarray(G.encode_inputs(gc, wire_ids, bits))
    cw, cl = G.const_wires_labels(gc)
    return (np.concatenate([wire_ids, cw]),
            np.concatenate([labels, np.asarray(cl)], axis=1))


def _block(x):
    import jax

    jax.tree_util.tree_map(lambda a: a.block_until_ready(), x)
    return x


def _median(times):
    return sorted(times)[len(times) // 2]


def _point(net, instances: int, device_impl: str, reps: int, rounds: int):
    """One (netlist, I) measurement: eval + garble, ref vs device.

    Median of ``rounds`` timing rounds of ``reps`` calls each — the box
    this runs on is noisy and a single average is not reproducible.
    """
    import jax

    from repro.core import garble as G

    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    gc = G.garble(net, key, instances, impl="ref")
    active = _active_labels(net, gc, rng)
    out_ref = G.evaluate(net, gc.tables, active, impl="ref")
    out_dev = _block(G.evaluate(net, gc.tables, active, impl=device_impl))
    assert np.array_equal(np.asarray(out_ref), np.asarray(out_dev)), \
        "device executor diverged from the numpy oracle"

    t_ref, t_dev, t_gref, t_gdev = [], [], [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps):
            G.evaluate(net, gc.tables, active, impl="ref")
        t_ref.append((time.perf_counter() - t0) / reps)
        t0 = time.perf_counter()
        for _ in range(reps):
            _block(G.evaluate(net, gc.tables, active, impl=device_impl))
        t_dev.append((time.perf_counter() - t0) / reps)
    gdev = G.garble(net, key, instances, impl=device_impl)
    _block(gdev.tables)
    assert np.array_equal(np.asarray(gc.tables), np.asarray(gdev.tables))
    for _ in range(max(rounds // 2, 1)):
        t0 = time.perf_counter()
        G.garble(net, key, instances, impl="ref")
        t_gref.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _block(G.garble(net, key, instances, impl=device_impl).tables)
        t_gdev.append(time.perf_counter() - t0)

    tr, td = _median(t_ref), _median(t_dev)
    tgr, tgd = _median(t_gref), _median(t_gdev)
    gps = net.num_gates * instances
    return {
        "instances": instances,
        "eval": {
            "ref_ms": round(tr * 1e3, 1),
            "device_ms": round(td * 1e3, 1),
            "ref_mgates_per_s": round(gps / tr / 1e6, 2),
            "device_mgates_per_s": round(gps / td / 1e6, 2),
            "speedup": round(tr / td, 2),
        },
        "garble": {
            "ref_ms": round(tgr * 1e3, 1),
            "device_ms": round(tgd * 1e3, 1),
            "speedup": round(tgr / tgd, 2),
        },
    }


def _prev_points(label):
    """Committed speedups per (label, instances) from BENCH_gc_eval.json."""
    path = Path(__file__).resolve().parents[1] / "BENCH_gc_eval.json"
    if not path.exists():
        return {}
    try:
        committed = json.loads(path.read_text())
    except json.JSONDecodeError:
        return {}
    out = {}
    for c in committed.get("configs", []):
        if c.get("label") != label:
            continue
        for p in c.get("points", []):
            out[p["instances"]] = {
                "eval_speedup": p["eval"]["speedup"],
                "garble_speedup": p["garble"]["speedup"],
            }
    return out


def run_config(cfg, instance_counts, rounds=4, write=print):
    from repro.core.netlist import compile_level_plan
    from repro.kernels.dispatch import resolve_impl

    device_impl = resolve_impl("auto")
    net = _net(cfg)
    prev = _prev_points(cfg["label"])
    points = []
    for inst in instance_counts:
        reps = 3 if inst <= 16 else 1
        r = rounds if inst <= 256 else 2
        pt = _point(net, inst, device_impl, reps, r)
        plan = compile_level_plan(net, instances=inst)
        # plan stats: store rows before/after the liveness pass, real vs
        # padded table rows — the reuse wins, per netlist and regime
        pt["plan"] = plan.stats()
        gplan = compile_level_plan(net, instances=inst, garbling=True)
        if gplan is not plan:  # AND-rich throughput: garble-width plan
            pt["plan_garble"] = gplan.stats()
        if inst in prev:
            pt["prev"] = prev[inst]  # committed trajectory, for diffing
        points.append(pt)
        e = pt["eval"]
        write(f"gc_eval[{net.name}@{cfg['t_bits']}b]_I{inst},"
              f"{e['device_ms'] * 1e3:.0f},"
              f"eval {e['device_mgates_per_s']}Mg/s vs ref "
              f"{e['ref_mgates_per_s']}Mg/s = {e['speedup']}x "
              f"garble {pt['garble']['speedup']}x")
        s = pt["plan"]
        write(f"# plan[{net.name}]_I{inst}: store {s['store_rows']} rows "
              f"(naive {s['store_rows_naive']}, "
              f"{s['store_row_reduction']}x reuse), tables "
              f"{s['table_rows_real']} real / {s['table_rows_padded']} "
              f"padded lanes")
    plan = compile_level_plan(net)
    return {
        "label": cfg["label"],
        "netlist": {"name": net.name, "t_bits": cfg["t_bits"],
                    "frac_bits": cfg["frac"], "gates": net.num_gates,
                    "and": net.and_count, "depth": plan.n_levels},
        "device_impl": device_impl,
        "plan_stats": plan.stats(),
        "points": points,
    }


def full():
    def write(msg):
        print(msg, flush=True)

    prod = run_config(PROD, (1, 16, 256, 2048), rounds=6, write=write)
    quant = run_config(QUANT, (4, 16, 256), write=write)
    lat = prod["points"][0]
    thr = prod["points"][-1]

    def _garble_at(cfgres, inst):
        for p in cfgres["points"]:
            if p["instances"] == inst:
                return p["garble"]["speedup"]
        return None

    result = {
        "bench": "gc_eval",
        "configs": [prod, quant],
        "headline": {
            "config": prod["label"],
            "instances": lat["instances"],
            "eval_speedup_vs_numpy_loop": lat["eval"]["speedup"],
            "eval_mgates_per_s": lat["eval"]["device_mgates_per_s"],
            "garble_speedup": lat["garble"]["speedup"],
            "target_speedup": 5.0,
            "meets_target": lat["eval"]["speedup"] >= 5.0,
            "throughput_instances": thr["instances"],
            "throughput_eval_speedup": thr["eval"]["speedup"],
            # the garble-path overhaul's acceptance metric: offline
            # (preprocessing) garbling at I=256 on both netlists
            "garble_speedup_at_256": {
                prod["label"]: _garble_at(prod, 256),
                quant["label"]: _garble_at(quant, 256),
            },
            "garble_target_at_256": 3.0,
        },
    }
    out = Path(__file__).resolve().parents[1] / "BENCH_gc_eval.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", flush=True)
    h = result["headline"]
    print(f"# headline ({h['config']}, online latency I="
          f"{h['instances']}): {h['eval_speedup_vs_numpy_loop']}x eval / "
          f"{h['garble_speedup']}x garble — target >= "
          f"{h['target_speedup']}x: "
          f"{'PASS' if h['meets_target'] else 'FAIL'}; throughput (I="
          f"{h['throughput_instances']}): {h['throughput_eval_speedup']}x; "
          f"garble@256: {h['garble_speedup_at_256']}")
    return result


def main() -> None:
    """Smoke entry for benchmarks/run.py and CI (no JSON).

    Two quantized-row points: the I=4 online point (parity + eval
    regression floor, as before) and a preprocessing-scale I=64 point
    that exercises the throughput-regime garble path — packed table
    emission, the liveness-compacted planar store and the split-hash
    cipher — with bit-parity against the numpy oracle asserted inside
    ``_point`` and a garble speedup floor. The I=64 garble measures
    ~3-4x here; the floors (2x eval online, 1.3x garble offline) leave
    headroom for noisy CI runners while still catching a garble path
    that has fallen back behind the numpy loop.
    """
    res = run_config(QUANT, (4, 64), rounds=2)
    speedup = res["points"][0]["eval"]["speedup"]
    assert speedup >= 2.0, \
        f"device executor regressed: {speedup}x vs numpy loop (floor 2x)"
    g64 = res["points"][1]["garble"]["speedup"]
    assert g64 >= 1.3, \
        f"garble path regressed: {g64}x vs numpy loop at I=64 (floor 1.3x)"


def check() -> None:
    """Regression gate for ``benchmarks/run.py --check``.

    Re-measures a small subset of the committed trajectory (quantized
    row, online I=4 and preprocessing I=256) and fails when a freshly
    measured speedup drops more than 20% below the committed
    ``BENCH_gc_eval.json`` value. Speedups are ratios of two runs on the
    same box, so they transfer across machines far better than absolute
    times — but not perfectly (core count shifts the jit-vs-numpy ratio),
    so a point that misses the 20% band still passes while it clears the
    absolute health floors below: the gate's job is to catch the garble
    path sliding back toward the numpy loop, not to fail unrelated PRs
    on a differently shaped runner.
    """
    # a point regressed >20% vs committed AND below these is a failure;
    # above them the path is unambiguously healthy on any runner
    floors = {"eval": 3.0, "garble": 2.0}
    path = Path(__file__).resolve().parents[1] / "BENCH_gc_eval.json"
    committed = json.loads(path.read_text())
    want = {}
    for c in committed["configs"]:
        if c["label"] != QUANT["label"]:
            continue
        for p in c["points"]:
            want[p["instances"]] = p
    insts = [i for i in (4, 256) if i in want]
    if not insts:
        raise AssertionError(
            "committed BENCH_gc_eval.json has no quantized points")
    res = run_config(QUANT, tuple(insts), rounds=3)
    failures = []
    for p in res["points"]:
        ref = want[p["instances"]]
        for path_ in ("eval", "garble"):
            got = p[path_]["speedup"]
            exp = ref[path_]["speedup"]
            bad = got < 0.8 * exp and got < floors[path_]
            status = ("REGRESSED" if bad else
                      "ok" if got >= 0.8 * exp else "ok (above floor)")
            print(f"# check {path_}@I{p['instances']}: {got}x vs "
                  f"committed {exp}x (floor {floors[path_]}x) -> "
                  f"{status}", flush=True)
            if bad:
                failures.append(
                    f"{path_}@I{p['instances']}: {got}x < 80% of "
                    f"committed {exp}x and < {floors[path_]}x floor")
    if failures:
        raise AssertionError(
            "gc_eval speedups regressed >20% vs committed "
            f"BENCH_gc_eval.json: {failures}")
    print("# check passed: speedups within 20% of committed "
          "(or above the health floors)", flush=True)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        main()
    elif "--check" in sys.argv:
        check()
    else:
        full()

"""GC online-path microbench: gates/s of the device-resident executor
vs the per-level numpy loop, on protocol softmax-row netlists.

This is the repo's perf gate for the hottest online code in hybrid PiT —
:func:`repro.core.garble.evaluate` — the path every ``session.run`` /
``PrivateServeEngine.serve`` request takes. Two implementations of the
same bit-exact walk are raced:

  ref   per-level numpy loop (gather -> XOR/INV/Half-Gate batches ->
        scatter, one Python round trip per topological level)
  auto  device-resident executor (:mod:`repro.core.gc_exec`): the whole
        netlist compiled into ONE jitted scan through the fused level
        kernel

Two softmax-row configurations are swept:

* ``softmax8 @ 40-bit shares`` — the production share modulus
  (``bench_protocol``'s config), from the single-request latency point
  (I=1, where the executor's latency-regime plan applies) up to
  preprocessing-scale batches. The recorded headline (>= 5x gates/s
  over the numpy loop) is this config's online-latency point — the
  metric APINT optimizes — where the numpy loop is pure per-level
  dispatch overhead and the compiled walk replaces ~2100 Python round
  trips with one launch; large batches are bandwidth-bound on both
  sides and win ~2-3x.
* ``softmax2 @ 12-bit shares`` — a quantized row (aggressive word-width
  reduction is APINT's own direction, XFBQ/Fig. 5), recorded as the
  secondary config.

``python benchmarks/bench_gc_eval.py`` runs both sweeps and writes
``BENCH_gc_eval.json`` at the repo root; ``--smoke`` (CI and
``benchmarks/run.py``) runs only the quantized row at I=4 and asserts
parity + a sane speedup.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

#: (row_len, frac_bits, he_t_bits, he_poly_n, he_num_primes)
PROD = {"label": "softmax8 @ 40-bit shares",
        "row_len": 8, "frac": 6, "t_bits": 40, "poly_n": 256, "primes": 3}
QUANT = {"label": "softmax2 @ 12-bit shares (quantized row)",
         "row_len": 2, "frac": 4, "t_bits": 12, "poly_n": 64, "primes": 2}


def _net(cfg):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.config import PrivacyConfig
    from repro.core.protocol import PiTProtocol

    pcfg = PrivacyConfig(he_poly_n=cfg["poly_n"],
                         he_num_primes=cfg["primes"],
                         he_t_bits=cfg["t_bits"], frac_bits=cfg["frac"],
                         layernorm_offload=True)
    return PiTProtocol(pcfg, seed=0).softmax_net(cfg["row_len"],
                                                 cfg["frac"])


def _active_labels(net, gc, rng):
    from repro.core import garble as G

    I = gc.num_instances
    bits = rng.integers(0, 2, (I, len(net.garbler_inputs)
                               + len(net.evaluator_inputs)))
    wire_ids = np.concatenate([
        np.asarray(net.garbler_inputs, np.int64),
        np.asarray(net.evaluator_inputs, np.int64)])
    labels = np.asarray(G.encode_inputs(gc, wire_ids, bits))
    cw, cl = G.const_wires_labels(gc)
    return (np.concatenate([wire_ids, cw]),
            np.concatenate([labels, np.asarray(cl)], axis=1))


def _block(x):
    import jax

    jax.tree_util.tree_map(lambda a: a.block_until_ready(), x)
    return x


def _median(times):
    return sorted(times)[len(times) // 2]


def _point(net, instances: int, device_impl: str, reps: int, rounds: int):
    """One (netlist, I) measurement: eval + garble, ref vs device.

    Median of ``rounds`` timing rounds of ``reps`` calls each — the box
    this runs on is noisy and a single average is not reproducible.
    """
    import jax

    from repro.core import garble as G

    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    gc = G.garble(net, key, instances, impl="ref")
    active = _active_labels(net, gc, rng)
    out_ref = G.evaluate(net, gc.tables, active, impl="ref")
    out_dev = _block(G.evaluate(net, gc.tables, active, impl=device_impl))
    assert np.array_equal(np.asarray(out_ref), np.asarray(out_dev)), \
        "device executor diverged from the numpy oracle"

    t_ref, t_dev, t_gref, t_gdev = [], [], [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps):
            G.evaluate(net, gc.tables, active, impl="ref")
        t_ref.append((time.perf_counter() - t0) / reps)
        t0 = time.perf_counter()
        for _ in range(reps):
            _block(G.evaluate(net, gc.tables, active, impl=device_impl))
        t_dev.append((time.perf_counter() - t0) / reps)
    gdev = G.garble(net, key, instances, impl=device_impl)
    _block(gdev.tables)
    assert np.array_equal(np.asarray(gc.tables), np.asarray(gdev.tables))
    for _ in range(max(rounds // 2, 1)):
        t0 = time.perf_counter()
        G.garble(net, key, instances, impl="ref")
        t_gref.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _block(G.garble(net, key, instances, impl=device_impl).tables)
        t_gdev.append(time.perf_counter() - t0)

    tr, td = _median(t_ref), _median(t_dev)
    tgr, tgd = _median(t_gref), _median(t_gdev)
    gps = net.num_gates * instances
    return {
        "instances": instances,
        "eval": {
            "ref_ms": round(tr * 1e3, 1),
            "device_ms": round(td * 1e3, 1),
            "ref_mgates_per_s": round(gps / tr / 1e6, 2),
            "device_mgates_per_s": round(gps / td / 1e6, 2),
            "speedup": round(tr / td, 2),
        },
        "garble": {
            "ref_ms": round(tgr * 1e3, 1),
            "device_ms": round(tgd * 1e3, 1),
            "speedup": round(tgr / tgd, 2),
        },
    }


def run_config(cfg, instance_counts, rounds=4, write=print):
    from repro.core.netlist import compile_level_plan
    from repro.kernels.dispatch import resolve_impl

    device_impl = resolve_impl("auto")
    net = _net(cfg)
    points = []
    for inst in instance_counts:
        reps = 3 if inst <= 16 else 1
        r = rounds if inst <= 256 else 2
        pt = _point(net, inst, device_impl, reps, r)
        plan = compile_level_plan(net, instances=inst)
        pt["plan"] = {"chunks": plan.n_chunks,
                      "and_width": plan.and_width,
                      "free_width": plan.free_width}
        points.append(pt)
        e = pt["eval"]
        write(f"gc_eval[{net.name}@{cfg['t_bits']}b]_I{inst},"
              f"{e['device_ms'] * 1e3:.0f},"
              f"eval {e['device_mgates_per_s']}Mg/s vs ref "
              f"{e['ref_mgates_per_s']}Mg/s = {e['speedup']}x "
              f"garble {pt['garble']['speedup']}x")
    plan = compile_level_plan(net)
    return {
        "label": cfg["label"],
        "netlist": {"name": net.name, "t_bits": cfg["t_bits"],
                    "frac_bits": cfg["frac"], "gates": net.num_gates,
                    "and": net.and_count, "depth": plan.n_levels},
        "device_impl": device_impl,
        "points": points,
    }


def full():
    def write(msg):
        print(msg, flush=True)

    prod = run_config(PROD, (1, 16, 256, 2048), rounds=6, write=write)
    quant = run_config(QUANT, (4, 16, 256), write=write)
    lat = prod["points"][0]
    thr = prod["points"][-1]
    result = {
        "bench": "gc_eval",
        "configs": [prod, quant],
        "headline": {
            "config": prod["label"],
            "instances": lat["instances"],
            "eval_speedup_vs_numpy_loop": lat["eval"]["speedup"],
            "eval_mgates_per_s": lat["eval"]["device_mgates_per_s"],
            "garble_speedup": lat["garble"]["speedup"],
            "target_speedup": 5.0,
            "meets_target": lat["eval"]["speedup"] >= 5.0,
            "throughput_instances": thr["instances"],
            "throughput_eval_speedup": thr["eval"]["speedup"],
        },
    }
    out = Path(__file__).resolve().parents[1] / "BENCH_gc_eval.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", flush=True)
    h = result["headline"]
    print(f"# headline ({h['config']}, online latency I="
          f"{h['instances']}): {h['eval_speedup_vs_numpy_loop']}x eval / "
          f"{h['garble_speedup']}x garble — target >= "
          f"{h['target_speedup']}x: "
          f"{'PASS' if h['meets_target'] else 'FAIL'}; throughput (I="
          f"{h['throughput_instances']}): {h['throughput_eval_speedup']}x")
    return result


def main() -> None:
    """Smoke entry for benchmarks/run.py and CI: quantized row at I=4,
    parity + a real regression floor (no JSON). The point measures
    ~5-11x here; 2x leaves headroom for noisy CI runners while still
    catching an executor that has fallen behind the numpy loop."""
    res = run_config(QUANT, (4,), rounds=2)
    speedup = res["points"][0]["eval"]["speedup"]
    assert speedup >= 2.0, \
        f"device executor regressed: {speedup}x vs numpy loop (floor 2x)"


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        main()
    else:
        full()

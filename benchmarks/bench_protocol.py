"""Fig. 8(b): end-to-end BERT-base (12L, d=768, H=12, 128 tokens) offline /
online latency model across the APINT stack, plus a *measured* offline/
online split taken directly from the PiTSession phase boundary.

The analytic table is built from measured unit costs on this machine:
  * per-function AND counts from our circuit generator at the paper's bit
    precisions (row circuits built at n=8/16, per-element costs fitted
    linearly — softmax/LN costs are affine in row length);
  * CPU Half-Gate throughput from bench_kernels (numpy engine);
  * the paper's LAN model (9.6 Gb/s, 0.165 ms);
  * the accelerator speedups from the Fig. 10 cycle model.

The measured table runs a reduced model through compile → preprocess →
run: offline numbers are whatever ``session.preprocess`` metered, online
numbers are whatever ``session.run`` metered — no ad-hoc timer deltas.

Variants: PRIMER-baseline -> +APINT protocol (LN offload) ->
+GC-friendly circuits (XFBQ) -> +APINT accelerator.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.circuits import nonlinear as NL
from benchmarks.common import NET_BW_BPS, NET_LAT_S, emit
from benchmarks.bench_kernels import halfgate_throughput

L, D, H, S, DFF = 12, 768, 12, 128, 3072
KB = 37
KG = 21
TABLE_B = 32
LABEL_B = 16
OT_B = 48  # per transferred input bit (IKNP)


def _fit_row_ands(build, ns=(8, 16)):
    """ANDs(row n) ~ a*n + b."""
    xs, ys = [], []
    for n in ns:
        ys.append(build(n).build().and_count)
        xs.append(n)
    a = (ys[1] - ys[0]) / (xs[1] - xs[0])
    b = ys[0] - a * xs[0]
    return lambda n: a * n + b


@dataclass
class Workload:
    ands: float
    g_in_bits: float  # garbler input bits (labels offline)
    e_in_bits: float  # evaluator input bits (OT online)


def bert_workload(style: str, ln_offload: bool) -> Workload:
    softmax_row = _fit_row_ands(
        lambda n: NL.softmax_circuit(n, k=KB, frac=12, style=style))
    ln_full_row = _fit_row_ands(
        lambda n: NL.layernorm_full_circuit(n, k=KB, frac=12, style=style))
    ln_red_row = _fit_row_ands(
        lambda n: NL.layernorm_reduced_circuit(n, k=KB, frac=12, style=style))
    gelu = NL.gelu_circuit(k=KG, frac=10, style=style).build().and_count

    softmax_ands = L * H * S * softmax_row(S)
    gelu_ands = L * S * DFF * gelu
    ln_row = ln_red_row(D) if ln_offload else ln_full_row(D)
    ln_ands = L * 2 * S * ln_row
    total = softmax_ands + gelu_ands + ln_ands

    # share-input words entering GC per layer (both parties, k bits each):
    words = L * (H * S * S + S * DFF + 2 * S * D)
    return Workload(ands=total, g_in_bits=words * KB, e_in_bits=words * KB)


def latency(w: Workload, garble_tput: float, eval_tput: float,
            accel_speedup: float = 1.0):
    offline_comp = w.ands / garble_tput
    offline_comm = (w.ands * TABLE_B + w.g_in_bits / 8 * LABEL_B) * 8 / NET_BW_BPS
    online_comp = w.ands / eval_tput / accel_speedup
    online_comm = w.e_in_bits * OT_B * 8 / NET_BW_BPS + 50 * NET_LAT_S
    return offline_comp + offline_comm, online_comp + online_comm


def measured_phase_split(requests: int = 2, seq: int = 4, d: int = 8):
    """Offline/online split measured at the session phase boundary.

    One preprocessing batch covers ``requests`` inferences; every run is
    online-only. Times/bytes are read from the phase ledgers that the
    compile → preprocess → run lifecycle maintains.
    """
    from repro.config import PrivacyConfig
    from repro.core.engine import PrivateTransformer, random_weights

    rng = np.random.default_rng(0)
    weights = random_weights(rng, d, 2 * d, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=6)
    model = PrivateTransformer(pcfg, d, 2, 2 * d, weights, seed=0)
    sess = model.compile_session(seq)
    bundles = sess.preprocess(requests)
    for b in bundles:
        sess.run(rng.normal(0, 1, (seq, d)), b)
    st = sess.stats
    emit(
        "phase_split_measured", st.online.t_s / requests * 1e6,
        f"requests={requests};offline_s={st.offline.t_s:.2f}"
        f";online_s_per_req={st.online.t_s / requests:.2f}"
        f";offline_MB={st.offline.channel.total / 1e6:.2f}"
        f";online_MB_per_req={st.online.channel.total / 1e6 / requests:.3f}",
    )


def main():
    g_tput = halfgate_throughput(True)
    e_tput = halfgate_throughput(False)
    variants = {
        "primer_baseline": ("conventional", False, 1.0),
        "apint_protocol": ("conventional", True, 1.0),
        "apint_circuitgen": ("xfbq", True, 1.0),
        "apint_accelerator": ("xfbq", True, 3.3),  # Fig.10 model speedup
    }
    base_off = base_on = None
    for name, (style, off, accel) in variants.items():
        w = bert_workload(style, off)
        t_off, t_on = latency(w, g_tput, e_tput, accel)
        if base_off is None:
            base_off, base_on = t_off, t_on
        emit(
            f"fig8b_{name}", t_on * 1e6,
            f"offline_s={t_off:.1f};online_s={t_on:.1f}"
            f";and_gates={w.ands:.3e}"
            f";offline_x={base_off / t_off:.2f};online_x={base_on / t_on:.2f}",
        )
    emit(
        "fig8b_paper_reference", 0.0,
        "paper_offline_x=2.2;paper_online_x=12.2",
    )
    measured_phase_split()


if __name__ == "__main__":
    main()

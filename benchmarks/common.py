"""Shared benchmark plumbing: CSV rows + the paper's network model."""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List

ROWS: List[str] = []

# the paper's LAN setup (§4.1)
NET_BW_BPS = 9.6e9
NET_LAT_S = 0.165e-3


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append(f"{name},{us_per_call:.3f},{derived}")
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def timeit(fn, n=3):
    fn()
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6  # us

"""§Roofline table: read the dry-run artifacts and print per-cell terms."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def main(dirname: str = "experiments/dryrun"):
    files = sorted(glob.glob(os.path.join(dirname, "*.json")))
    if not files:
        emit("roofline_missing", 0.0, "run launch/dryrun first")
        return
    for f in files:
        r = json.load(open(f))
        if not r.get("ok"):
            emit(f"roofline_{r['arch']}_{r['shape']}", 0.0,
                 f"FAILED:{r.get('error', '?')}")
            continue
        rl = r["roofline"]
        tag = "mp" if r["multi_pod"] else "sp"
        emit(
            f"roofline_{r['arch']}_{r['shape']}_{tag}",
            rl["step_lower_bound_s"] * 1e6,
            f"dom={rl['dominant']};compute_s={rl['compute_s']:.4f}"
            f";memory_s={rl['memory_s']:.4f}"
            f";collective_s={rl['collective_s']:.4f}"
            f";model/hlo={r['model_to_hlo_flops']:.2f}",
        )


if __name__ == "__main__":
    main()

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator.

  fig5b  — bench_mult_ands      (64-bit multiplier AND counts)
  fig9a  — bench_circuit_ands   (per-function AND reduction)
  fig8a  — bench_accuracy       (private-vs-float parity)
  fig8b  — bench_protocol       (offline/online latency stack)
  fig10  — bench_sched          (scheduling/speculation/accelerator)
  fig11b — bench_energy         (system energy HAAC vs APINT)
  kernels / roofline            (unit costs, dry-run roofline table)
  gc_eval — bench_gc_eval       (device GC executor vs numpy loop; smoke
                                 here, full sweep writes BENCH_gc_eval.json)
  net    — bench_net            (two-party runtime: transports, ledger
                                 parity, pipelined refill; full run writes
                                 BENCH_net.json)

``--check`` runs ONLY the regression gates: the gc_eval gate re-measures
a subset of the committed ``BENCH_gc_eval.json`` trajectory and fails on
a >20% speedup regression; the net gate re-derives the smoke-config wire
oracle and fails on a >20% byte — or any round-count — regression
against the committed ``BENCH_net.json``, and holds the tracing-off
cost of the ``repro.obs`` instrumentation below 1% of the smoke point
(CI runs all of it right after the bench smoke).

``--trace [PATH]`` records the whole suite with ``repro.obs`` and
exports a Chrome trace_event JSON (default ``bench_trace.json``).
"""

from __future__ import annotations

import sys
import traceback
from pathlib import Path

# script-style invocation (`python benchmarks/run.py`) puts benchmarks/
# itself on sys.path, not the repo root that the `benchmarks.*`
# namespace imports need — add it (harmless under `-m benchmarks.run`)
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def check() -> None:
    import jax

    jax.config.update("jax_enable_x64", True)
    from benchmarks import bench_gc_eval, bench_net

    bench_gc_eval.check()
    bench_net.check()


def main(trace: str | None = None) -> None:
    import jax

    jax.config.update("jax_enable_x64", True)  # privacy plane (HE uint64)
    tr = None
    if trace:
        from repro import obs

        tr = obs.enable()
    print("name,us_per_call,derived")
    from benchmarks import (
        bench_mult_ands,
        bench_circuit_ands,
        bench_kernels,
        bench_accuracy,
        bench_protocol,
        bench_sched,
        bench_energy,
        bench_roofline,
        bench_gc_eval,
        bench_net,
    )

    suites = [
        ("fig5b", bench_mult_ands),
        ("fig9a", bench_circuit_ands),
        ("kernels", bench_kernels),
        ("fig8a", bench_accuracy),
        ("fig8b", bench_protocol),
        ("fig10", bench_sched),
        ("fig11b", bench_energy),
        ("roofline", bench_roofline),
        ("gc_eval", bench_gc_eval),
        ("net", bench_net),
    ]
    failed = []
    for name, mod in suites:
        print(f"# --- {name} ({mod.__name__}) ---", flush=True)
        try:
            mod.main()
        except Exception as e:  # keep the suite running
            traceback.print_exc()
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}", flush=True)
            failed.append(name)
    if tr is not None:
        tr.export(trace)
        print(f"# wrote trace: {trace} ({len(tr.finished_spans())} spans)",
              flush=True)
    if failed:
        print(f"# FAILED suites: {failed}", flush=True)
        sys.exit(1)
    print("# all benchmark suites completed", flush=True)


if __name__ == "__main__":
    if "--check" in sys.argv:
        check()
    else:
        trace = None
        if "--trace" in sys.argv:
            i = sys.argv.index("--trace")
            nxt = sys.argv[i + 1] if len(sys.argv) > i + 1 else ""
            trace = nxt if nxt and not nxt.startswith("-") \
                else "bench_trace.json"
        main(trace)

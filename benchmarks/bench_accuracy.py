"""Fig. 8(a) analog: private-inference output parity vs float reference.

The paper runs GLUE; without task data we report numerical parity of the
full private pipeline (shares + HE + GC with the paper's approximations)
on a reduced transformer block — the quantity GLUE accuracy is downstream
of."""

from __future__ import annotations

import numpy as np

from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from benchmarks.common import emit, timeit


def main():
    rng = np.random.default_rng(3)
    d, heads, d_ff, S = 16, 2, 32, 8
    weights = random_weights(rng, d, d_ff, 1)
    x = rng.normal(0, 1, (S, d))
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=7)
    eng = PrivateTransformer(pcfg, d, heads, d_ff, weights, seed=0)
    import time

    t0 = time.time()
    got = eng.forward_private(x)
    dt = time.time() - t0
    want = eng.forward_float(x)
    mae = float(np.abs(got - want).mean())
    mx = float(np.abs(got - want).max())
    st = eng.p.stats
    emit(
        "fig8a_parity", dt * 1e6,
        f"mae={mae:.4f};max={mx:.4f};paper_glue_drop=0.09pt"
        f";online_MB={st.channel_online.total / 1e6:.2f}"
        f";offline_MB={st.channel_offline.total / 1e6:.2f}",
    )


if __name__ == "__main__":
    main()

"""Two-party runtime benchmark: rounds / bytes / wall-clock latency of
end-to-end private inference over real transports vs the metered-sim
prediction.

Measurements per transport (``InProcPipe``, loopback TCP):

* **parity** — the revealed output must be bit-identical to the
  in-process ``PiTSession.run`` path, and the per-phase wire ledger
  (payload bytes by tag) must equal the metered ``Channel`` oracle
  exactly (framing + sim-sideband overhead reported separately).
* **latency** — wall-clock offline (preprocess) and online (run), plus
  the oracle's LAN-model prediction (``Channel.time_s``: 9.6 Gb/s,
  0.165 ms) for the same byte/round counts.
* **pipelining** — with a dedicated offline endpoint pair
  (``NetPrivateServeEngine``), online serving proceeds while a
  bandwidth-shaped refill streams in the background; the benchmark
  records that the online request completed while refill traffic was in
  flight.
* **gateway** — N concurrent client sessions behind one ``PitGateway``
  accept loop: sessions served, shared-garbling-cache hits (one slab
  per distinct netlist for all clients), aggregate bundles/sec. On the
  full config this phase runs the reduced smoke model (noted in the
  JSON) so the 3-client fan-out doesn't dominate the bench wall-clock.
* **wire v1 vs v2** — endpoints negotiate wire v2 (PRG-seeded label
  streams, delta-encoded table batches, IKNP OT, round coalescing); the
  report carries both versions' oracle byte/round counts, the offline
  byte reduction, the coalesced round count
  (``rounds_after_coalescing`` < raw metered messages), per-phase
  direction-flip counts, and the LAN-model offline speedup computed
  with the *measured* post-coalescing rounds.

``python benchmarks/bench_net.py`` writes ``BENCH_net.json`` at the repo
root; ``--smoke`` (CI / ``benchmarks/run.py``) runs the tiny config and
asserts parity + ledger equality only; ``--check`` re-derives the
smoke-config oracle and fails on a >20% wire-byte regression against
the committed JSON (the net ratchet ``benchmarks/run.py --check``
runs in CI).
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

SMOKE = {"d": 8, "heads": 2, "d_ff": 16, "S": 4, "layers": 1,
         "poly_n": 256, "primes": 3, "t_bits": 40, "frac": 6}
FULL = {"d": 16, "heads": 2, "d_ff": 32, "S": 8, "layers": 1,
        "poly_n": 256, "primes": 3, "t_bits": 40, "frac": 6}
# gateway fan-out point: mux/cache behavior is model-size independent,
# so the 3 concurrent clients run the smallest valid config
GATEWAY_CFG = {"d": 8, "heads": 2, "d_ff": 16, "S": 2, "layers": 1,
               "poly_n": 256, "primes": 3, "t_bits": 40, "frac": 6}


def _model(cfg):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.config import PrivacyConfig
    from repro.core.engine import PrivateTransformer, random_weights

    rng = np.random.default_rng(0)
    weights = random_weights(rng, cfg["d"], cfg["d_ff"], cfg["layers"])
    pcfg = PrivacyConfig(he_poly_n=cfg["poly_n"], he_num_primes=cfg["primes"],
                         he_t_bits=cfg["t_bits"], frac_bits=cfg["frac"])
    return PrivateTransformer(pcfg, cfg["d"], cfg["heads"], cfg["d_ff"],
                              weights, seed=0)


def _oracle(model, cfg, x, wire_version=1):
    """In-process metered session: the byte/round/latency oracle."""
    sess = model.compile_session(cfg["S"], impl="ref",
                                 wire_version=wire_version)
    bundles = sess.preprocess(1)
    y = sess.run(x, bundles[0])
    st = sess.stats
    return y, {
        "wire_version": wire_version,
        "offline_bytes": st.channel_offline.total,
        "online_bytes": st.channel_online.total,
        "offline_msgs": st.channel_offline.rounds,
        "online_msgs": st.channel_online.rounds,
        "offline_by_tag": dict(st.channel_offline.by_tag),
        "online_by_tag": dict(st.channel_online.by_tag),
        "lan_model_offline_s": st.channel_offline.time_s(),
        "lan_model_online_s": st.channel_online.time_s(),
    }


def _endpoints(model, cfg, kind):
    """(client, server, cleanup) over the requested transport kind."""
    from repro.net import (GarblerEndpoint, InProcPipe, PitNetServer,
                           TcpListener, TcpTransport)

    srv = PitNetServer(model, cfg["S"], impl="ref")
    if kind == "inproc":
        a, b = InProcPipe.make_pair()
        srv.serve_transport(b, timeout=600)
        cli = GarblerEndpoint(a, seed=7, impl="ref", timeout=600)
        return cli, srv, lambda: cli.close()
    lst = TcpListener()
    loop = srv.serve_tcp(lst, timeout=600)
    cli = GarblerEndpoint(TcpTransport.connect("127.0.0.1", lst.port),
                          seed=7, impl="ref", timeout=600)
    loop.wait_accepted(1, timeout=60)

    def cleanup():
        cli.close()
        lst.close()

    return cli, srv, cleanup


def _point(model, cfg, kind, x, y_ref, oracle):
    cli, srv, cleanup = _endpoints(model, cfg, kind)
    try:
        t0 = time.perf_counter()
        cli.preprocess(1)
        t_off = time.perf_counter() - t0
        t0 = time.perf_counter()
        y = cli.run(x)
        t_on = time.perf_counter() - t0
        assert np.array_equal(y, y_ref), \
            f"{kind}: output diverged from the in-process session"
        led = cli.shared.ledger
        assert led.offline.by_tag == oracle["offline_by_tag"], \
            f"{kind}: offline ledger != metered oracle"
        assert led.online.by_tag == oracle["online_by_tag"], \
            f"{kind}: online ledger != metered oracle"
        proto = led.offline.total + led.online.total
        overhead = led.frame_bytes - proto - led.sim_bytes \
            - led.control_bytes
        s = led.summary()
        return {
            "transport": kind,
            "wire_version": cli.shared.negotiated_version,
            "compression": cli.shared.negotiated_compression,
            "offline_s": round(t_off, 3),
            "online_s": round(t_on, 3),
            "offline_bytes": led.offline.total,
            "online_bytes": led.online.total,
            "sim_sideband_bytes": led.sim_bytes,
            "table_resid_bytes": led.resid_bytes,
            "control_bytes": led.control_bytes,
            "framing_overhead_bytes": overhead,
            "overhead_pct_of_proto": round(
                100.0 * (led.sim_bytes + led.control_bytes + overhead)
                / max(proto, 1), 3),
            "wire_dir_flips": led.dir_flips,
            "dir_flips_offline": s["dir_flips_offline"],
            "dir_flips_online": s["dir_flips_online"],
            "rounds_after_coalescing": s["rounds_after_coalescing"],
            "raw_messages": s["raw_messages"],
            "seed_stream_segs": led.seed_stream_segs,
            "seed_stream_labels": led.seed_stream_labels,
            "delta_batches": led.delta_batches,
            # LAN model re-priced with the *measured* post-coalescing
            # round structure (the oracle's own time_s charges one
            # latency per metered message, i.e. pre-coalescing)
            "lan_model_offline_s_coalesced": round(led.offline.time_s(
                max_rounds=max(led.proto_frames_offline, 1)), 6),
            "lan_model_online_s_coalesced": round(led.online.time_s(
                max_rounds=max(led.proto_frames_online, 1)), 6),
            "ledger_matches_oracle": True,
        }
    finally:
        cleanup()


def _pipelined(model, cfg, x, y_ref):
    """Dedicated offline pair + online pair: the online run completes
    while refill traffic is in flight — deterministically, by holding the
    offline pair's *response* delivery behind a gate until serving is
    done (the refill request stream has left the client by then)."""
    import threading as th_mod

    from repro.net import InProcPipe, PitNetServer
    from repro.serve import NetPrivateServeEngine, PrivateRequest

    srv = PitNetServer(model, cfg["S"], impl="ref")
    off_c, off_s = InProcPipe.make_pair()
    on_c, on_s = InProcPipe.make_pair()
    srv.serve_transport(off_s, timeout=600, name="pit-eval-offline")
    srv.serve_transport(on_s, timeout=600, name="pit-eval-online")
    eng = NetPrivateServeEngine(off_c, on_c, pool_target=2, seed=7,
                                impl="ref", timeout=600)
    eng.preprocess(1)  # one bundle in the pool before the wave

    gate = th_mod.Event()
    off_c.recv_gate = gate  # offline responses held until serving is done
    t0 = time.perf_counter()
    refill = eng.refill_async(1)  # streams on the offline pair
    req = PrivateRequest(x=x)
    eng.serve([req])  # consumes the pooled bundle on the online pair
    t_serve = time.perf_counter() - t0
    online_during_refill = refill.is_alive()
    gate.set()
    refill.join(timeout=600)
    t_refill = time.perf_counter() - t0
    assert np.array_equal(req.result, y_ref), \
        "pipelined: output diverged from the in-process session"
    assert eng.pool_size() == 1, "refill did not land in the pool"
    assert online_during_refill, \
        "online serve did not overlap the in-flight refill"
    eng.close()
    return {
        "refill_s": round(t_refill, 3),
        "serve_s": round(t_serve, 3),
        "online_completed_while_refill_in_flight": bool(
            online_during_refill),
    }


def _gateway(model, cfg, x, y_ref, n_clients=3):
    """Multi-client gateway point: N concurrent TCP sessions behind one
    accept loop, every output bit-identical, one garbled slab per
    distinct netlist shared across all of them."""
    import threading as th_mod

    from repro.net import TcpListener
    from repro.serve import PitGateway, gateway_client

    gw = PitGateway(model, cfg["S"], impl="ref", max_sessions=n_clients,
                    pool_cap=4)
    lst = TcpListener()
    loop = gw.serve_listener(lst, accept_timeout=0.2, timeout=600)
    outs = [None] * n_clients
    t0 = time.perf_counter()

    def client(i):
        eng = gateway_client("127.0.0.1", lst.port, seed=100 + i,
                             timeout=600)
        try:
            eng.preprocess(1)
            outs[i] = eng.run(x)
        finally:
            eng.close()

    threads = [th_mod.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=600)
    elapsed = time.perf_counter() - t0
    for i, y in enumerate(outs):
        assert y is not None and np.array_equal(y, y_ref), \
            f"gateway: session {i} diverged from the in-process session"
    st = gw.stats()
    cache = st["garbling_cache"]
    assert cache["slabs"] == cache["distinct_netlists"], \
        "gateway: more than one garbled slab per distinct netlist"
    loop.stop()
    gw.close()
    lst.close()
    return {
        "clients": n_clients,
        "sessions_served": st["sessions_admitted"],
        "sessions_shed": st["sessions_shed"],
        "bundles_consumed": st["bundles_consumed"],
        "aggregate_bundles_per_s": round(st["bundles_consumed"]
                                         / max(elapsed, 1e-9), 3),
        "elapsed_s": round(elapsed, 3),
        "shared_cache_slabs": cache["slabs"],
        "shared_cache_hits": cache["hits"],
        "shared_cache_misses": cache["misses"],
    }


def _ot_bytes(oracle):
    """Total OT traffic (extension batches + one-time base exchange)."""
    return sum(v for phase in ("offline_by_tag", "online_by_tag")
               for t, v in oracle[phase].items()
               if t.startswith("ot:") or t == "ot-base")


def run(cfg, write=print):
    model = _model(cfg)
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (cfg["S"], cfg["d"]))
    y_v1, oracle_v1 = _oracle(model, cfg, x, wire_version=1)
    y_ref, oracle = _oracle(model, cfg, x, wire_version=2)
    assert np.array_equal(y_v1, y_ref), \
        "wire-version knob changed the in-process output"

    points = []
    for kind in ("inproc", "tcp"):
        pt = _point(model, cfg, kind, x, y_ref, oracle)
        points.append(pt)
        write(f"net[{kind}],{pt['online_s'] * 1e6:.0f},"
              f"v{pt['wire_version']} "
              f"offline {pt['offline_bytes'] / 1e6:.2f}MB/"
              f"{pt['offline_s']}s online {pt['online_bytes'] / 1e6:.2f}MB/"
              f"{pt['online_s']}s rounds {pt['rounds_after_coalescing']}"
              f"(raw {pt['raw_messages']}) "
              f"overhead {pt['overhead_pct_of_proto']}% ledger==oracle")

    # v1 → v2 wire comparison (byte totals from the two oracles, round
    # structure from the measured inproc point)
    inp = points[0]
    v1_ot, v2_ot = _ot_bytes(oracle_v1), _ot_bytes(oracle)
    comparison = {
        "v1_offline_bytes": oracle_v1["offline_bytes"],
        "v2_offline_bytes": oracle["offline_bytes"],
        "offline_bytes_reduction_x": round(
            oracle_v1["offline_bytes"] / max(oracle["offline_bytes"], 1), 3),
        "v1_lan_model_offline_s": round(
            oracle_v1["lan_model_offline_s"], 6),
        "v2_lan_model_offline_s_coalesced":
            inp["lan_model_offline_s_coalesced"],
        "lan_model_offline_speedup_x": round(
            oracle_v1["lan_model_offline_s"]
            / max(inp["lan_model_offline_s_coalesced"], 1e-12), 3),
        "v1_ot_bytes": v1_ot,
        "v2_ot_bytes": v2_ot,
        "ot_bytes_ratio_v2_over_v1": round(v2_ot / max(v1_ot, 1), 3),
    }
    write(f"net[v2-vs-v1],0,offline "
          f"{comparison['offline_bytes_reduction_x']}x fewer bytes, "
          f"LAN-model offline {comparison['lan_model_offline_speedup_x']}x "
          f"faster, IKNP-OT/sim-OT bytes "
          f"{comparison['ot_bytes_ratio_v2_over_v1']}x")

    pipe = _pipelined(model, cfg, x, y_ref)
    write(f"net[pipelined],{pipe['serve_s'] * 1e6:.0f},"
          f"online-during-refill="
          f"{pipe['online_completed_while_refill_in_flight']}")

    # gateway fan-out: always the reduced config — 3 concurrent
    # full-size clients would dominate the bench wall-clock without
    # measuring anything new
    gmodel = _model(GATEWAY_CFG)
    grng = np.random.default_rng(1)
    gx = grng.normal(0, 1, (GATEWAY_CFG["S"], GATEWAY_CFG["d"]))
    gy, _ = _oracle(gmodel, GATEWAY_CFG, gx, wire_version=2)
    gw = _gateway(gmodel, GATEWAY_CFG, gx, gy)
    gw["model"] = (f"reduced (d={GATEWAY_CFG['d']}, S={GATEWAY_CFG['S']}) "
                   f"for bench wall-clock")
    write(f"net[gateway],{gw['elapsed_s'] * 1e6:.0f},"
          f"{gw['sessions_served']} sessions "
          f"{gw['aggregate_bundles_per_s']} bundles/s "
          f"cache {gw['shared_cache_slabs']} slabs/"
          f"{gw['shared_cache_hits']} hits")
    return {"config": cfg, "oracle": oracle, "oracle_v1": oracle_v1,
            "wire_comparison": comparison, "points": points,
            "pipelined": pipe, "gateway": gw}


def _tracer_overhead():
    """Cost of the ``repro.obs`` instrumentation on the smoke point.

    Two numbers matter:

    * ``traced_overhead_pct`` — wall-clock of one traced in-process
      smoke-oracle run vs an untraced one (machine-relative,
      informational: single runs, so noise dominates small deltas).
    * ``tracing_off_overhead_pct`` — the gated number: (span+instant
      call sites hit during the smoke run) x (measured per-call cost of
      a disabled ``obs.span()``/``close()`` pair) as a fraction of the
      untraced wall-clock. This is deterministic up to the microbench
      and is what ``--check`` holds below 1%.
    """
    from repro import obs

    model = _model(SMOKE)
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (SMOKE["S"], SMOKE["d"]))
    _oracle(model, SMOKE, x, wire_version=2)  # warm JIT / HE caches

    prev = obs.install(obs.NULL_TRACER)
    try:
        t0 = time.perf_counter()
        _oracle(model, SMOKE, x, wire_version=2)
        untraced_s = time.perf_counter() - t0

        tr = obs.Tracer()
        obs.install(tr)
        t0 = time.perf_counter()
        _oracle(model, SMOKE, x, wire_version=2)
        traced_s = time.perf_counter() - t0
        events = len(tr.finished_spans()) + len(tr.finished_instants())

        obs.install(obs.NULL_TRACER)
        n = 100_000
        t0 = time.perf_counter_ns()
        for _ in range(n):
            obs.span("x").close()
        null_span_ns = (time.perf_counter_ns() - t0) / n
    finally:
        obs.install(prev)

    off_pct = 100.0 * events * null_span_ns * 1e-9 / max(untraced_s, 1e-9)
    return {
        "smoke_untraced_s": round(untraced_s, 4),
        "smoke_traced_s": round(traced_s, 4),
        "traced_overhead_pct": round(
            100.0 * (traced_s - untraced_s) / max(untraced_s, 1e-9), 2),
        "trace_events": events,
        "null_span_ns": round(null_span_ns, 1),
        "tracing_off_overhead_pct": round(off_pct, 4),
    }


def _resilience_overhead():
    """Cost of the fault-injection wrapper with faults DISABLED.

    The resilience stack is meant to stay on in production, so an
    empty-schedule :class:`FaultyTransport` must be near-free: its hot
    path adds one locked counter increment + dict miss per transport op.
    Two numbers:

    * ``wrapped_overhead_pct`` — wall-clock of one wrapped smoke run vs
      a plain one (machine-relative, informational: single runs, noise
      dominates small deltas).
    * ``faults_off_overhead_pct`` — the gated number: (transport ops in
      the smoke run) x (measured per-op cost of the wrapper's no-fault
      bookkeeping) as a fraction of the plain wall-clock. Deterministic
      up to the microbench; ``--check`` holds it below 2%.
    """
    from repro.net import (FaultyTransport, GarblerEndpoint, InProcPipe,
                           PitNetServer)

    model = _model(SMOKE)
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (SMOKE["S"], SMOKE["d"]))
    _oracle(model, SMOKE, x, wire_version=2)  # warm JIT / HE caches

    def run_once(wrap):
        srv = PitNetServer(model, SMOKE["S"], impl="ref")
        a, b = InProcPipe.make_pair()
        srv.serve_transport(b, timeout=600)
        t = FaultyTransport(a) if wrap else a
        cli = GarblerEndpoint(t, seed=7, impl="ref", timeout=600)
        t0 = time.perf_counter()
        cli.preprocess(1)
        y = cli.run(x)
        elapsed = time.perf_counter() - t0
        ops = t.op if wrap else (a.frames_sent + a.frames_recv)
        cli.close()
        return y, elapsed, ops

    y_plain, plain_s, _ = run_once(wrap=False)
    y_wrapped, wrapped_s, ops = run_once(wrap=True)
    assert np.array_equal(y_plain, y_wrapped), \
        "an empty fault schedule changed the protocol output"

    ft = FaultyTransport(InProcPipe.make_pair()[0])
    n = 100_000
    t0 = time.perf_counter_ns()
    for _ in range(n):
        ft._next_fault()  # the whole no-fault hot path: counter + miss
    null_op_ns = (time.perf_counter_ns() - t0) / n

    off_pct = 100.0 * ops * null_op_ns * 1e-9 / max(plain_s, 1e-9)
    return {
        "smoke_plain_s": round(plain_s, 4),
        "smoke_wrapped_s": round(wrapped_s, 4),
        "wrapped_overhead_pct": round(
            100.0 * (wrapped_s - plain_s) / max(plain_s, 1e-9), 2),
        "transport_ops": ops,
        "null_op_ns": round(null_op_ns, 1),
        "faults_off_overhead_pct": round(off_pct, 4),
    }


def _smoke_oracle():
    """Byte/round counts of the smoke config at both wire versions —
    the deterministic reference ``check()`` ratchets against."""
    model = _model(SMOKE)
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (SMOKE["S"], SMOKE["d"]))
    _, o1 = _oracle(model, SMOKE, x, wire_version=1)
    _, o2 = _oracle(model, SMOKE, x, wire_version=2)
    keep = ("offline_bytes", "online_bytes", "offline_msgs", "online_msgs")
    return {"v1": {k: o1[k] for k in keep}, "v2": {k: o2[k] for k in keep}}


def full():
    result = {"bench": "net", **run(FULL, write=lambda m: print(m, flush=True))}
    result["smoke_oracle"] = _smoke_oracle()
    result["tracer_overhead"] = _tracer_overhead()
    result["resilience_overhead"] = _resilience_overhead()
    out = Path(__file__).resolve().parents[1] / "BENCH_net.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", flush=True)
    o, cmp_, pts = result["oracle"], result["wire_comparison"], \
        result["points"]
    print(f"# v2 oracle msgs: {o['offline_msgs']} offline / "
          f"{o['online_msgs']} online; offline bytes "
          f"{cmp_['v1_offline_bytes'] / 1e6:.1f}MB → "
          f"{cmp_['v2_offline_bytes'] / 1e6:.1f}MB "
          f"({cmp_['offline_bytes_reduction_x']}x); LAN-model offline "
          f"{cmp_['v1_lan_model_offline_s']:.3f}s → "
          f"{cmp_['v2_lan_model_offline_s_coalesced']:.3f}s "
          f"({cmp_['lan_model_offline_speedup_x']}x); measured online: "
          + ", ".join(f"{p['transport']}={p['online_s']}s" for p in pts))
    return result


def check() -> None:
    """Net wire ratchet (CI, via ``benchmarks/run.py --check``):
    re-derive the smoke-config oracle byte/round counts and fail on a
    >20% byte regression — or any message-count growth — against the
    committed ``BENCH_net.json``."""
    path = Path(__file__).resolve().parents[1] / "BENCH_net.json"
    ref = json.loads(path.read_text()).get("smoke_oracle")
    assert ref, f"{path} has no smoke_oracle section — rerun the full bench"
    got = _smoke_oracle()
    for ver in ("v1", "v2"):
        for key in ("offline_bytes", "online_bytes"):
            g, w = got[ver][key], ref[ver][key]
            assert g <= w * 1.2, \
                f"net ratchet: {ver} {key} regressed {w} → {g} (>20%)"
        for key in ("offline_msgs", "online_msgs"):
            g, w = got[ver][key], ref[ver][key]
            assert g <= w, \
                f"net ratchet: {ver} {key} grew {w} → {g}"
    assert got["v2"]["offline_bytes"] < got["v1"]["offline_bytes"], \
        "net ratchet: v2 no longer compresses the offline phase"
    committed = json.loads(path.read_text())
    assert "tracer_overhead" in committed, \
        f"{path} has no tracer_overhead section — rerun the full bench"
    assert "resilience_overhead" in committed, \
        f"{path} has no resilience_overhead section — rerun the full bench"
    ov = _tracer_overhead()
    assert ov["tracing_off_overhead_pct"] < 1.0, \
        (f"obs instrumentation costs "
         f"{ov['tracing_off_overhead_pct']:.3f}% of the smoke point with "
         f"tracing OFF ({ov['trace_events']} call sites x "
         f"{ov['null_span_ns']:.0f}ns null span) — must stay <1%")
    rov = _resilience_overhead()
    assert rov["faults_off_overhead_pct"] < 2.0, \
        (f"fault-injection wrapper costs "
         f"{rov['faults_off_overhead_pct']:.3f}% of the smoke point with "
         f"faults DISABLED ({rov['transport_ops']} transport ops x "
         f"{rov['null_op_ns']:.0f}ns null op) — must stay <2%")
    print(f"net check OK: smoke oracle v1 "
          f"{got['v1']['offline_bytes']}B / v2 "
          f"{got['v2']['offline_bytes']}B offline within ratchet; "
          f"tracing-off overhead {ov['tracing_off_overhead_pct']:.4f}% "
          f"(<1%); faults-off overhead "
          f"{rov['faults_off_overhead_pct']:.4f}% (<2%)", flush=True)


def main() -> None:
    """Smoke entry for benchmarks/run.py and CI: tiny config, both
    transports + the pipelined overlap check, parity/ledger asserted."""
    res = run(SMOKE)
    assert all(p["ledger_matches_oracle"] for p in res["points"])
    assert all(p["wire_version"] == 2 for p in res["points"])
    assert all(p["rounds_after_coalescing"] < p["raw_messages"]
               for p in res["points"])
    assert res["pipelined"]["online_completed_while_refill_in_flight"]
    assert res["gateway"]["sessions_served"] == res["gateway"]["clients"]


if __name__ == "__main__":
    if "--check" in sys.argv:
        check()
    elif "--smoke" in sys.argv:
        main()
    else:
        full()

"""Two-party runtime benchmark: rounds / bytes / wall-clock latency of
end-to-end private inference over real transports vs the metered-sim
prediction.

Measurements per transport (``InProcPipe``, loopback TCP):

* **parity** — the revealed output must be bit-identical to the
  in-process ``PiTSession.run`` path, and the per-phase wire ledger
  (payload bytes by tag) must equal the metered ``Channel`` oracle
  exactly (framing + sim-sideband overhead reported separately).
* **latency** — wall-clock offline (preprocess) and online (run), plus
  the oracle's LAN-model prediction (``Channel.time_s``: 9.6 Gb/s,
  0.165 ms) for the same byte/round counts.
* **pipelining** — with a dedicated offline endpoint pair
  (``NetPrivateServeEngine``), online serving proceeds while a
  bandwidth-shaped refill streams in the background; the benchmark
  records that the online request completed while refill traffic was in
  flight.
* **gateway** — N concurrent client sessions behind one ``PitGateway``
  accept loop: sessions served, shared-garbling-cache hits (one slab
  per distinct netlist for all clients), aggregate bundles/sec.

``python benchmarks/bench_net.py`` writes ``BENCH_net.json`` at the repo
root; ``--smoke`` (CI / ``benchmarks/run.py``) runs the tiny config and
asserts parity + ledger equality only.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

SMOKE = {"d": 8, "heads": 2, "d_ff": 16, "S": 4, "layers": 1,
         "poly_n": 256, "primes": 3, "t_bits": 40, "frac": 6}
FULL = {"d": 16, "heads": 2, "d_ff": 32, "S": 8, "layers": 1,
        "poly_n": 256, "primes": 3, "t_bits": 40, "frac": 6}


def _model(cfg):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.config import PrivacyConfig
    from repro.core.engine import PrivateTransformer, random_weights

    rng = np.random.default_rng(0)
    weights = random_weights(rng, cfg["d"], cfg["d_ff"], cfg["layers"])
    pcfg = PrivacyConfig(he_poly_n=cfg["poly_n"], he_num_primes=cfg["primes"],
                         he_t_bits=cfg["t_bits"], frac_bits=cfg["frac"])
    return PrivateTransformer(pcfg, cfg["d"], cfg["heads"], cfg["d_ff"],
                              weights, seed=0)


def _oracle(model, cfg, x):
    """In-process metered session: the byte/round/latency oracle."""
    sess = model.compile_session(cfg["S"], impl="ref")
    bundles = sess.preprocess(1)
    y = sess.run(x, bundles[0])
    st = sess.stats
    return y, {
        "offline_bytes": st.channel_offline.total,
        "online_bytes": st.channel_online.total,
        "offline_msgs": st.channel_offline.rounds,
        "online_msgs": st.channel_online.rounds,
        "offline_by_tag": dict(st.channel_offline.by_tag),
        "online_by_tag": dict(st.channel_online.by_tag),
        "lan_model_offline_s": st.channel_offline.time_s(),
        "lan_model_online_s": st.channel_online.time_s(),
    }


def _endpoints(model, cfg, kind):
    """(client, server, cleanup) over the requested transport kind."""
    from repro.net import (GarblerEndpoint, InProcPipe, PitNetServer,
                           TcpListener, TcpTransport)

    srv = PitNetServer(model, cfg["S"], impl="ref")
    if kind == "inproc":
        a, b = InProcPipe.make_pair()
        srv.serve_transport(b, timeout=600)
        cli = GarblerEndpoint(a, seed=7, impl="ref", timeout=600)
        return cli, srv, lambda: cli.close()
    lst = TcpListener()
    loop = srv.serve_tcp(lst, timeout=600)
    cli = GarblerEndpoint(TcpTransport.connect("127.0.0.1", lst.port),
                          seed=7, impl="ref", timeout=600)
    loop.wait_accepted(1, timeout=60)

    def cleanup():
        cli.close()
        lst.close()

    return cli, srv, cleanup


def _point(model, cfg, kind, x, y_ref, oracle):
    cli, srv, cleanup = _endpoints(model, cfg, kind)
    try:
        t0 = time.perf_counter()
        cli.preprocess(1)
        t_off = time.perf_counter() - t0
        t0 = time.perf_counter()
        y = cli.run(x)
        t_on = time.perf_counter() - t0
        assert np.array_equal(y, y_ref), \
            f"{kind}: output diverged from the in-process session"
        led = cli.shared.ledger
        assert led.offline.by_tag == oracle["offline_by_tag"], \
            f"{kind}: offline ledger != metered oracle"
        assert led.online.by_tag == oracle["online_by_tag"], \
            f"{kind}: online ledger != metered oracle"
        proto = led.offline.total + led.online.total
        overhead = led.frame_bytes - proto - led.sim_bytes \
            - led.control_bytes
        return {
            "transport": kind,
            "offline_s": round(t_off, 3),
            "online_s": round(t_on, 3),
            "offline_bytes": led.offline.total,
            "online_bytes": led.online.total,
            "sim_sideband_bytes": led.sim_bytes,
            "control_bytes": led.control_bytes,
            "framing_overhead_bytes": overhead,
            "overhead_pct_of_proto": round(
                100.0 * (led.sim_bytes + led.control_bytes + overhead)
                / max(proto, 1), 3),
            "wire_dir_flips": led.dir_flips,
            "ledger_matches_oracle": True,
        }
    finally:
        cleanup()


def _pipelined(model, cfg, x, y_ref):
    """Dedicated offline pair + online pair: the online run completes
    while refill traffic is in flight — deterministically, by holding the
    offline pair's *response* delivery behind a gate until serving is
    done (the refill request stream has left the client by then)."""
    import threading as th_mod

    from repro.net import InProcPipe, PitNetServer
    from repro.serve import NetPrivateServeEngine, PrivateRequest

    srv = PitNetServer(model, cfg["S"], impl="ref")
    off_c, off_s = InProcPipe.make_pair()
    on_c, on_s = InProcPipe.make_pair()
    srv.serve_transport(off_s, timeout=600, name="pit-eval-offline")
    srv.serve_transport(on_s, timeout=600, name="pit-eval-online")
    eng = NetPrivateServeEngine(off_c, on_c, pool_target=2, seed=7,
                                impl="ref", timeout=600)
    eng.preprocess(1)  # one bundle in the pool before the wave

    gate = th_mod.Event()
    off_c.recv_gate = gate  # offline responses held until serving is done
    t0 = time.perf_counter()
    refill = eng.refill_async(1)  # streams on the offline pair
    req = PrivateRequest(x=x)
    eng.serve([req])  # consumes the pooled bundle on the online pair
    t_serve = time.perf_counter() - t0
    online_during_refill = refill.is_alive()
    gate.set()
    refill.join(timeout=600)
    t_refill = time.perf_counter() - t0
    assert np.array_equal(req.result, y_ref), \
        "pipelined: output diverged from the in-process session"
    assert eng.pool_size() == 1, "refill did not land in the pool"
    assert online_during_refill, \
        "online serve did not overlap the in-flight refill"
    eng.close()
    return {
        "refill_s": round(t_refill, 3),
        "serve_s": round(t_serve, 3),
        "online_completed_while_refill_in_flight": bool(
            online_during_refill),
    }


def _gateway(model, cfg, x, y_ref, n_clients=3):
    """Multi-client gateway point: N concurrent TCP sessions behind one
    accept loop, every output bit-identical, one garbled slab per
    distinct netlist shared across all of them."""
    import threading as th_mod

    from repro.net import TcpListener
    from repro.serve import PitGateway, gateway_client

    gw = PitGateway(model, cfg["S"], impl="ref", max_sessions=n_clients,
                    pool_cap=4)
    lst = TcpListener()
    loop = gw.serve_listener(lst, accept_timeout=0.2, timeout=600)
    outs = [None] * n_clients
    t0 = time.perf_counter()

    def client(i):
        eng = gateway_client("127.0.0.1", lst.port, seed=100 + i,
                             timeout=600)
        try:
            eng.preprocess(1)
            outs[i] = eng.run(x)
        finally:
            eng.close()

    threads = [th_mod.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=600)
    elapsed = time.perf_counter() - t0
    for i, y in enumerate(outs):
        assert y is not None and np.array_equal(y, y_ref), \
            f"gateway: session {i} diverged from the in-process session"
    st = gw.stats()
    cache = st["garbling_cache"]
    assert cache["slabs"] == cache["distinct_netlists"], \
        "gateway: more than one garbled slab per distinct netlist"
    loop.stop()
    gw.close()
    lst.close()
    return {
        "clients": n_clients,
        "sessions_served": st["sessions_admitted"],
        "sessions_shed": st["sessions_shed"],
        "bundles_consumed": st["bundles_consumed"],
        "aggregate_bundles_per_s": round(st["bundles_consumed"]
                                         / max(elapsed, 1e-9), 3),
        "elapsed_s": round(elapsed, 3),
        "shared_cache_slabs": cache["slabs"],
        "shared_cache_hits": cache["hits"],
        "shared_cache_misses": cache["misses"],
    }


def run(cfg, write=print):
    model = _model(cfg)
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (cfg["S"], cfg["d"]))
    y_ref, oracle = _oracle(model, cfg, x)

    points = []
    for kind in ("inproc", "tcp"):
        pt = _point(model, cfg, kind, x, y_ref, oracle)
        points.append(pt)
        write(f"net[{kind}],{pt['online_s'] * 1e6:.0f},"
              f"offline {pt['offline_bytes'] / 1e6:.2f}MB/"
              f"{pt['offline_s']}s online {pt['online_bytes'] / 1e6:.2f}MB/"
              f"{pt['online_s']}s overhead {pt['overhead_pct_of_proto']}% "
              f"ledger==oracle")
    pipe = _pipelined(model, cfg, x, y_ref)
    write(f"net[pipelined],{pipe['serve_s'] * 1e6:.0f},"
          f"online-during-refill="
          f"{pipe['online_completed_while_refill_in_flight']}")
    gw = _gateway(model, cfg, x, y_ref)
    write(f"net[gateway],{gw['elapsed_s'] * 1e6:.0f},"
          f"{gw['sessions_served']} sessions "
          f"{gw['aggregate_bundles_per_s']} bundles/s "
          f"cache {gw['shared_cache_slabs']} slabs/"
          f"{gw['shared_cache_hits']} hits")
    return {"config": cfg, "oracle": oracle, "points": points,
            "pipelined": pipe, "gateway": gw}


def full():
    result = {"bench": "net", **run(FULL, write=lambda m: print(m, flush=True))}
    out = Path(__file__).resolve().parents[1] / "BENCH_net.json"
    out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"# wrote {out}", flush=True)
    o, pts = result["oracle"], result["points"]
    print(f"# oracle msgs: {o['offline_msgs']} offline / "
          f"{o['online_msgs']} online; LAN-model prediction "
          f"{o['lan_model_offline_s']:.3f}s / {o['lan_model_online_s']:.3f}s; "
          f"measured online: "
          + ", ".join(f"{p['transport']}={p['online_s']}s" for p in pts))
    return result


def main() -> None:
    """Smoke entry for benchmarks/run.py and CI: tiny config, both
    transports + the pipelined overlap check, parity/ledger asserted."""
    res = run(SMOKE)
    assert all(p["ledger_matches_oracle"] for p in res["points"])
    assert res["pipelined"]["online_completed_while_refill_in_flight"]
    assert res["gateway"]["sessions_served"] == res["gateway"]["clients"]


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        main()
    else:
        full()

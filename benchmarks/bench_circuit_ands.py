"""Fig. 9(a): per-function AND reduction from GC-friendly circuit
generation, at the paper's bit precisions (37b softmax/layernorm, 21b
GeLU). Row length 16 (per-element costs are linear in row length; the
derived column includes the BERT-base extrapolation to 128)."""

from __future__ import annotations

from repro.core.circuits import nonlinear as NL
from benchmarks.common import emit

N_ROW = 16
BERT_ROW = 128
PAPER = {"softmax": 48.1, "gelu": 33.7, "layernorm": 45.6}


def main():
    builders = {
        "softmax": lambda s: NL.softmax_circuit(N_ROW, k=37, frac=12, style=s),
        "gelu": lambda s: NL.gelu_circuit(k=21, frac=10, style=s),
        "layernorm": lambda s: NL.layernorm_full_circuit(
            N_ROW, k=37, frac=12, style=s),
    }
    for name, build in builders.items():
        conv = build("conventional").build()
        xfbq = build("xfbq").build()
        red = 100 * (1 - xfbq.and_count / conv.and_count)
        scale = BERT_ROW / N_ROW if name != "gelu" else 1.0
        emit(
            f"fig9a_{name}", 0.0,
            f"ANDs_conv={conv.and_count};ANDs_xfbq={xfbq.and_count}"
            f";reduction={red:.1f}%;paper={PAPER[name]}%"
            f";bert128_ANDs~={int(xfbq.and_count * scale)}",
        )


if __name__ == "__main__":
    main()

"""GC/HE kernel micro-benchmarks (CPU). The measured throughputs feed the
end-to-end protocol latency model (bench_protocol)."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.halfgate import ref_np as HN
from repro.kernels.ntt import ref as NR
from benchmarks.common import emit, timeit

_CACHE = {}


def halfgate_throughput(garbling: bool = True, n: int = 1 << 18) -> float:
    """AND gates per second on this CPU (numpy path used by the engine)."""
    key = ("hg", garbling, n)
    if key in _CACHE:
        return _CACHE[key]
    rng = np.random.default_rng(0)
    a0 = rng.integers(0, 2**32, (n, 4), dtype=np.uint32)
    b0 = rng.integers(0, 2**32, (n, 4), dtype=np.uint32)
    r = rng.integers(0, 2**32, (n, 4), dtype=np.uint32)
    tw = np.arange(n, dtype=np.uint32)
    if garbling:
        fn = lambda: HN.garble_and_gates(a0, b0, r, tw)
    else:
        _, tg, te = HN.garble_and_gates(a0, b0, r, tw)
        fn = lambda: HN.eval_and_gates(a0, b0, tg, te, tw)
    us = timeit(fn, n=3)
    _CACHE[key] = n / (us / 1e6)
    return _CACHE[key]


def main():
    for garbling in (True, False):
        tput = halfgate_throughput(garbling)
        emit(
            f"kernel_halfgate_{'garble' if garbling else 'eval'}",
            (1 << 18) / tput * 1e6,
            f"and_gates_per_s={tput:.3e}",
        )
    # NTT (BFV path, 30-bit prime, N=2048)
    n = 2048
    q = NR.find_ntt_primes(30, 1, n)[0]
    a = jnp.asarray(
        np.random.default_rng(0).integers(0, q, (8, n)).astype(np.uint64))
    f = jax.jit(lambda x: NR.ntt_forward(x, q, n))
    f(a).block_until_ready()
    us = timeit(lambda: f(a).block_until_ready(), n=5)
    emit("kernel_ntt2048_x8", us, f"ntts_per_s={8 / (us / 1e6):.1f}")
    # label_select
    from repro.kernels.label_select import ref as LR

    g = 1 << 18
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    w0 = jax.random.bits(ks[0], (g, 4), dtype=jnp.uint32)
    r = jax.random.bits(ks[1], (g, 4), dtype=jnp.uint32)
    bits = jax.random.bits(ks[2], (g,), dtype=jnp.uint32) & 1
    sel = jax.jit(LR.select_labels)
    sel(w0, r, bits).block_until_ready()
    us = timeit(lambda: sel(w0, r, bits).block_until_ready(), n=5)
    emit("kernel_label_select", us, f"labels_per_s={g / (us / 1e6):.3e}")


if __name__ == "__main__":
    main()

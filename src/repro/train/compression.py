"""Gradient compression: int8 ring reduce-scatter/all-gather with error
feedback.

The all-reduce of data-parallel gradients dominates cross-pod traffic; the
classic remedy is to quantize the payload and carry the quantization error
into the next step (error feedback keeps convergence). Implemented as an
explicit ring over ``lax.ppermute`` inside shard_map so the wire format is
truly int8 (+ one f32 scale per tensor chunk) — a 4x wire reduction vs f32.

``compressed_psum(x, axis, mesh)`` is a drop-in for ``lax.psum`` on the
named data axis; ``ErrorFeedback`` holds per-leaf residuals.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map


def _quant(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def _ring_allreduce_int8(x, axis_name: str):
    """Inside shard_map: reduce-scatter + all-gather rings, int8 payload."""
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n, -1)

    # reduce-scatter ring: after n-1 steps, rank r holds the full sum of
    # chunk (r+1) mod n
    def rs_step(s, acc_chunks):
        send_idx = (idx - s) % n
        payload, scale = _quant(acc_chunks[send_idx])
        payload = jax.lax.ppermute(
            payload, axis_name, [(i, (i + 1) % n) for i in range(n)]
        )
        scale = jax.lax.ppermute(
            scale, axis_name, [(i, (i + 1) % n) for i in range(n)]
        )
        recv_idx = (idx - s - 1) % n
        return acc_chunks.at[recv_idx].add(_dequant(payload, scale))

    acc = chunks
    for s in range(n - 1):
        acc = rs_step(s, acc)
    mine = (idx + 1) % n
    my_chunk, my_scale = _quant(acc[mine])

    # all-gather ring of the reduced chunks
    out = jnp.zeros_like(acc)
    out = out.at[mine].set(_dequant(my_chunk, my_scale))
    payload, scale, src = my_chunk, my_scale, mine
    for s in range(n - 1):
        payload = jax.lax.ppermute(
            payload, axis_name, [(i, (i + 1) % n) for i in range(n)]
        )
        scale = jax.lax.ppermute(
            scale, axis_name, [(i, (i + 1) % n) for i in range(n)]
        )
        src = (src - 1) % n
        out = out.at[src].set(_dequant(payload, scale))
    res = out.reshape(-1)
    if pad:
        res = res[:-pad]
    return res.reshape(x.shape)


def compressed_psum(x, axis_name: str, mesh):
    """int8 ring all-reduce of a replicated-along-axis array."""
    fn = partial(_ring_allreduce_int8, axis_name=axis_name)
    other = tuple(a for a in mesh.axis_names if a != axis_name)
    spec = P()  # replicated input/output w.r.t. all axes
    return shard_map(
        fn, mesh=mesh, in_specs=spec, out_specs=spec, check=False
    )(x)


class ErrorFeedback:
    """Per-leaf residual accumulator: g' = Q(g + e); e = (g + e) − g'."""

    def __init__(self, params_like):
        self.residual = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_like
        )

    def apply(self, grads, reduce_fn):
        def one(g, e):
            x = g.astype(jnp.float32) + e
            q, scale = _quant(x)
            sent = _dequant(q, scale)
            new_e = x - sent
            return reduce_fn(sent), new_e

        pairs = jax.tree_util.tree_map(one, grads, self.residual)
        reduced = jax.tree_util.tree_map(
            lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple)
        )
        self.residual = jax.tree_util.tree_map(
            lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple)
        )
        return reduced

"""Fault tolerance for long-running multi-pod jobs.

  * PreemptionGuard — SIGTERM/SIGINT set a flag; the train loop checkpoints
    and exits cleanly at the next step boundary.
  * StragglerWatchdog — per-step wall-time EWMA + k·sigma flagging; on a
    real fleet the hook triggers backup-worker re-dispatch; here it logs
    and counts (exercised in tests with injected delays).
  * elastic_info — derive the mesh a restarted job can support from the
    visible device count (checkpoints reshard on restore).
"""

from __future__ import annotations

import math
import signal
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax

from repro.utils import get_logger

log = get_logger("repro.fault")


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:  # not main thread (tests)
                pass

    def _handler(self, signum, frame):
        log.warning("preemption signal %s received; will checkpoint and exit",
                    signum)
        self.requested = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


@dataclass
class StragglerWatchdog:
    """Flags steps slower than mean + k·sigma (EWMA estimates)."""

    k: float = 4.0
    alpha: float = 0.05
    warmup: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            # prime estimates
            self._mean = (self._mean * (self._n - 1) + dt) / self._n
            self._var = max(self._var, (dt - self._mean) ** 2)
            return False
        sigma = math.sqrt(max(self._var, 1e-12))
        is_straggler = dt > self._mean + self.k * sigma + 1e-9
        if is_straggler:
            self.flagged.append(step)
            log.warning(
                "straggler: step %d took %.4fs (mean %.4fs, sigma %.4fs)",
                step, dt, self._mean, sigma,
            )
            if self.on_straggler:
                self.on_straggler(step, dt, self._mean)
        else:  # don't pollute stats with straggler samples
            d = dt - self._mean
            self._mean += self.alpha * d
            self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        return is_straggler


def elastic_info() -> dict:
    n = jax.device_count()
    model = 16 if n % 16 == 0 and n >= 16 else 1
    return {
        "devices": n,
        "mesh": (n // model, model),
        "axes": ("data", "model"),
    }

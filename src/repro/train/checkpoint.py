"""Checkpointing: atomic, resharding-aware, optionally async.

Layout: <dir>/step_<N>/ containing one .npy per leaf (paths flattened with
'__') + manifest.json (step, config name, tree structure, shapes). Writes
go to a tmp dir + atomic rename so a preemption mid-write never corrupts
the latest checkpoint. Restore re-shards onto whatever mesh the restarted
job has (elastic scaling: the loader only needs the logical tree).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}__"))
        return out
    return {prefix[:-2]: tree}


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("__")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return root


def save(ckpt_dir: str, step: int, state, *, meta: Optional[Dict] = None,
         keep: int = 3) -> str:
    flat = _flatten(state)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "meta": meta or {}}
    for path, leaf in flat.items():
        arr = np.asarray(leaf)
        manifest["leaves"][path] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
        np.save(os.path.join(tmp, path + ".npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        (int(d.split("_")[1]), d)
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
    )
    for _, d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None, *,
            shardings=None) -> Tuple[int, Any]:
    """Returns (step, state). With `shardings` (a matching pytree of
    NamedSharding), leaves are placed sharded — onto whatever mesh the
    *current* process holds (elastic restart)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints in {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for path in manifest["leaves"]:
        flat[path] = np.load(os.path.join(d, path + ".npy"))
    state = _unflatten(flat)
    if shardings is not None:
        state = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )
    return step, state


class AsyncCheckpointer:
    """Overlap checkpoint writes with training (one in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state, meta=None):
        self.wait()
        host_state = jax.tree_util.tree_map(np.asarray, state)  # snapshot

        def _run():
            save(self.ckpt_dir, step, host_state, meta=meta, keep=self.keep)

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

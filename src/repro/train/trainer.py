"""Training loop: step compilation, checkpoint/resume, preemption handling,
straggler watchdog. Deterministic end to end (synthetic data is a counter
hash; resume reproduces the uninterrupted run bitwise — tested)."""

from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.data import SyntheticLMData
from repro.launch.steps import build_train_step
from repro.models.transformer import init_params
from repro.train import checkpoint as CK
from repro.train.fault import PreemptionGuard, StragglerWatchdog
from repro.train.optimizer import init_opt_state
from repro.utils import get_logger

log = get_logger("repro.trainer")


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tc: TrainConfig,
        *,
        global_batch: int = 8,
        seq_len: int = 128,
        mesh=None,
        shape: Optional[ShapeConfig] = None,
    ):
        self.cfg, self.tc = cfg, tc
        self.data = SyntheticLMData(cfg, global_batch, seq_len, seed=tc.seed)
        step_fn, in_sh, out_sh, rules = build_train_step(cfg, tc, mesh, shape)
        kwargs = {}
        if in_sh is not None:
            kwargs = dict(in_shardings=in_sh, out_shardings=out_sh)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0,), **kwargs)
        self.ckpt = CK.AsyncCheckpointer(tc.checkpoint_dir)
        self.watchdog = StragglerWatchdog()
        self.state = None
        self.step = 0

    def init_or_resume(self, resume: bool = True):
        latest = CK.latest_step(self.tc.checkpoint_dir) if resume else None
        if latest is not None:
            self.step, self.state = CK.restore(self.tc.checkpoint_dir, latest)
            self.state = jax.tree_util.tree_map(jnp.asarray, self.state)
            log.info("resumed from step %d", self.step)
        else:
            params = init_params(self.cfg, jax.random.PRNGKey(self.tc.seed))
            self.state = {
                "params": params,
                "opt": init_opt_state(params),
                "step": jnp.int32(0),
            }
        return self.step

    def run(self, num_steps: int, *, with_guard: bool = True) -> Dict:
        guard = PreemptionGuard() if with_guard else None
        metrics_hist = []
        end = self.step + num_steps
        while self.step < end:
            batch = {k: jnp.asarray(v) for k, v in
                     self.data.batch_at(self.step).items()}
            t0 = time.time()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            self.watchdog.observe(self.step, dt)
            metrics_hist.append(metrics)
            self.step += 1
            if self.step % max(self.tc.checkpoint_every, 1) == 0:
                self.ckpt.save(self.step, self.state, meta={"cfg": self.cfg.name})
            if guard is not None and guard.requested:
                log.warning("preempted: checkpointing at step %d", self.step)
                self.ckpt.save(self.step, self.state)
                break
        self.ckpt.wait()
        if guard is not None:
            guard.restore()
        return {
            "final_step": self.step,
            "losses": [m["loss"] for m in metrics_hist],
            "metrics": metrics_hist,
        }

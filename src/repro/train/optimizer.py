"""AdamW with linear-warmup cosine decay, implemented directly on pytrees.

Optimizer state shards exactly like the parameters (same tree structure),
so FSDP sharding of params automatically shards m/v — ZeRO-style.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def lr_schedule(tc: TrainConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - tc.warmup_steps) / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def _decay_mask(path: str) -> bool:
    """Weight decay on matrices only (no norms / biases / vectors)."""
    leaf = path.split("/")[-1]
    return leaf not in ("scale", "bias", "a_log", "dt_bias", "d_skip", "m", "v")


def _tree_map_with_path(fn, *trees):
    def rec(prefix, *ts):
        if isinstance(ts[0], dict):
            return {k: rec(prefix + "/" + str(k), *[t[k] for t in ts]) for k in ts[0]}
        return fn(prefix, *ts)

    return rec("", *trees)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    tc: TrainConfig, params, grads, opt_state, step
) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_opt_state, metrics). All f32."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(tc, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - tc.beta1 ** t
    bc2 = 1.0 - tc.beta2 ** t

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = tc.beta1 * m + (1 - tc.beta1) * g
        v_new = tc.beta2 * v + (1 - tc.beta2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + tc.eps)
        if _decay_mask(path) and p.ndim >= 2:
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    out = _tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params,
        grads,
        opt_state["m"],
        opt_state["v"],
    )
    new_params = jax.tree_util.tree_map(
        lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_m = jax.tree_util.tree_map(
        lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_v = jax.tree_util.tree_map(
        lambda x: x[2], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v}, metrics

"""Sequence-chunked cross-entropy so (B, S, V) logits are never resident.

The unembed + logsumexp for each sequence chunk runs under
``jax.checkpoint`` so the backward pass recomputes chunk logits instead of
saving them — peak memory is one (B, S/nc, V_shard) buffer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.sharding import shard


def chunked_lm_loss(cfg: ModelConfig, out_head, hidden, labels, *, z_coef=1e-4,
                    num_chunks: int = 0):
    """hidden (B,S,d) -> (mean_nll, metrics). labels: int32, -1 = ignored.

    Labels index the *unpadded* vocab; padded logits rows can never win.
    """
    B, S, d = hidden.shape
    if num_chunks <= 0:
        num_chunks = max(1, S // 1024)
    while S % num_chunks != 0:
        num_chunks -= 1
    sc = S // num_chunks
    table = out_head["table"]

    hs = hidden.reshape(B, num_chunks, sc, d)
    ls = labels.reshape(B, num_chunks, sc)

    def chunk_loss(h, lab):
        logits = jnp.einsum(
            "bsd,vd->bsv", h, table.astype(h.dtype),
            preferred_element_type=jnp.float32,
        )
        logits = shard(logits, "batch", None, "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lab, 0)
        picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = logz - picked
        mask = (lab >= 0).astype(jnp.float32)
        zl = z_coef * jnp.square(logz)
        return jnp.sum((nll + zl) * mask), jnp.sum(mask)

    chunk_loss = jax.checkpoint(chunk_loss)

    def body(carry, xs):
        tot, cnt = carry
        h, lab = xs
        s, c = chunk_loss(h, lab)
        return (tot + s, cnt + c), None

    hs_t = jnp.moveaxis(hs, 1, 0)  # (nc, B, sc, d)
    ls_t = jnp.moveaxis(ls, 1, 0)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs_t, ls_t))
    mean = tot / jnp.maximum(cnt, 1.0)
    return mean, {"tokens": cnt}

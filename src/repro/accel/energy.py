"""Energy model (Fig. 11 analog): on-chip vs external-memory-access energy.

Constants follow the paper's sources: HBM2 ≈ 3.9 pJ/bit [22]; 28nm SRAM
read/write ≈ 0.08 pJ/bit (TSMC N28 compiler class); Half-Gate unit energy
derived from 4 cipher evaluations ≈ 60 pJ; FreeXOR ≈ 1 pJ.
"""

from __future__ import annotations

from typing import Dict

from repro.accel.sim import SimResult

HBM_PJ_PER_BIT = 3.9
SRAM_PJ_PER_BIT = 0.08
HALFGATE_PJ = 60.0
FREEXOR_PJ = 1.0
LABEL_BITS = 128


def energy_report(res: SimResult, and_gates: int, other_gates: int) -> Dict:
    ema_pj = res.dram_bytes * 8 * HBM_PJ_PER_BIT
    sram_pj = res.compute_cycles * 3 * LABEL_BITS * SRAM_PJ_PER_BIT
    core_pj = and_gates * HALFGATE_PJ + other_gates * FREEXOR_PJ
    total = ema_pj + sram_pj + core_pj
    return {
        "ema_uj": ema_pj / 1e6,
        "onchip_uj": (sram_pj + core_pj) / 1e6,
        "total_uj": total / 1e6,
        "ema_fraction": ema_pj / total if total else 0.0,
    }

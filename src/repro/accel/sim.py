"""Cycle-level model of the APINT / HAAC GC accelerators (§3.4, Fig. 10).

16 cores, each a pipelined PE (Half-Gate 18 cy eval / 21 cy garble, FreeXOR
1 cy), a Wire Memory (128 KiB = 8192 labels), an OoRW prefetch buffer and a
shared DRAM channel. Per instruction the model accounts:

  * pipeline stalls — waiting for an in-flight producer (wire dependency);
  * memory stalls   — waiting for an OoRW or a garbled-table line from DRAM.

DRAM: bandwidth-shared bus (bytes/cycle) with a fixed per-burst latency.
Coarse-grained scheduling makes the per-core streams identical, so the 16
concurrent requests of one instruction slot coalesce into one burst
(row-locality); without it every request pays the burst overhead alone —
this reproduces the paper's bandwidth-utilization argument (Fig. 6).

The model is parameterized, not RTL; EXPERIMENTS.md validates the paper's
*relative* claims (stall reductions, OoRW/DRAM counts, energy ratios).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR
from repro.sched.speculation import SpecProgram

HALFGATE_EVAL_CY = 18
HALFGATE_GARBLE_CY = 21
FREEXOR_CY = 1
TABLE_BYTES = 32
LABEL_BYTES = 16


@dataclass
class AccelConfig:
    num_cores: int = 16
    wire_mem_kb: int = 128
    dram_bytes_per_cycle: float = 64.0  # HBM2-class @ compute clock
    dram_burst_latency: int = 24  # cycles per independent burst
    coalesced: bool = True  # coarse-grained: aligned cross-core requests
    garbling: bool = False

    @property
    def capacity_wires(self) -> int:
        return self.wire_mem_kb * 1024 // LABEL_BYTES


@dataclass
class SimResult:
    cycles: int = 0
    compute_cycles: int = 0
    pipeline_stall_cycles: int = 0
    memory_stall_cycles: int = 0
    dram_bytes: int = 0
    oorw_count: int = 0
    dram_accesses: int = 0
    per_core_cycles: List[int] = field(default_factory=list)

    def merge_parallel(self, other: "SimResult") -> "SimResult":
        out = SimResult()
        out.cycles = max(self.cycles, other.cycles)
        out.compute_cycles = self.compute_cycles + other.compute_cycles
        out.pipeline_stall_cycles = (
            self.pipeline_stall_cycles + other.pipeline_stall_cycles
        )
        out.memory_stall_cycles = (
            self.memory_stall_cycles + other.memory_stall_cycles
        )
        out.dram_bytes = self.dram_bytes + other.dram_bytes
        out.oorw_count = self.oorw_count + other.oorw_count
        out.dram_accesses = self.dram_accesses + other.dram_accesses
        out.per_core_cycles = self.per_core_cycles + other.per_core_cycles
        return out


def _gate_cycles(op: int, garbling: bool) -> int:
    if op == OP_AND:
        return HALFGATE_GARBLE_CY if garbling else HALFGATE_EVAL_CY
    return FREEXOR_CY


def program_compute_cycles(net: Netlist, garbling: bool = False) -> int:
    """Pure PE compute cycles of one instruction stream over ``net``.

    The stall-free floor every schedule is measured against — and the
    accelerator-side twin of ``repro.sched.schedulers.schedule_cost``
    (same per-op latency table: 21 cy garble / 18 cy eval Half-Gate with
    a dense 2-row table write per AND, 1 cy FreeXOR/INV). The regression
    test in ``test_sched`` pins the two models to each other so the
    scheduler can never cost a netlist differently than the simulator
    executes it.
    """
    n_and = int(np.sum(net.op == OP_AND))
    and_cy = HALFGATE_GARBLE_CY if garbling else HALFGATE_EVAL_CY
    return n_and * and_cy + (net.num_gates - n_and) * FREEXOR_CY


def simulate_core(
    net: Netlist,
    prog: SpecProgram,
    cfg: AccelConfig,
    dram_penalty_per_burst: float,
) -> SimResult:
    """One core walking one instruction stream."""
    order = prog.order
    ready_at: Dict[int, float] = {}
    t = 0.0
    res = SimResult()
    bw = cfg.dram_bytes_per_cycle * (
        1.0 if not cfg.coalesced else 1.0 / cfg.num_cores
    )
    # per-core effective bandwidth share: coalesced -> 1/num_cores of the
    # bus but zero extra burst latency; uncoalesced -> full bus contention
    # modeled as burst latency per request (dram_penalty_per_burst).
    for pos in range(len(order)):
        g = int(order[pos])
        op = int(net.op[g])
        # pipeline: wait for producers
        dep_t = 0.0
        for w in (int(net.in0[g]), int(net.in1[g])):
            dep_t = max(dep_t, ready_at.get(w, 0.0))
        stall_pipe = max(0.0, dep_t - t)
        # memory: OoRW fetches + table line for AND gates
        mem_bytes = 0
        bursts = 0
        if prog.is_oorw_read0[pos]:
            mem_bytes += LABEL_BYTES
            bursts += 1
            res.oorw_count += 1
        if prog.is_oorw_read1[pos]:
            mem_bytes += LABEL_BYTES
            bursts += 1
            res.oorw_count += 1
        if op == OP_AND and not cfg.garbling:
            mem_bytes += TABLE_BYTES  # table streamed in
            bursts += 1
        if op == OP_AND and cfg.garbling:
            mem_bytes += TABLE_BYTES  # table streamed out
            bursts += 1
        if prog.live[pos]:
            mem_bytes += LABEL_BYTES
            bursts += 1
        mem_cycles = mem_bytes / max(bw, 1e-9)
        if not cfg.coalesced:
            mem_cycles += bursts * dram_penalty_per_burst
        # prefetching hides table/OoRW latency while compute proceeds;
        # the visible stall is the excess of memory time over compute time
        comp = _gate_cycles(op, cfg.garbling)
        issue = t + stall_pipe
        visible_mem = max(0.0, mem_cycles - comp - stall_pipe)
        t = issue + 1  # pipelined issue
        done = issue + comp + visible_mem
        ready_at[int(net.out[g])] = done
        res.compute_cycles += 1
        res.pipeline_stall_cycles += int(stall_pipe)
        res.memory_stall_cycles += int(visible_mem)
        res.dram_bytes += mem_bytes
        res.dram_accesses += bursts
        t = max(t, done - comp)  # next issue can overlap the tail
    res.cycles = int(t + max(ready_at.values(), default=0) - t)
    res.cycles = int(max(t, max(ready_at.values(), default=t)))
    res.per_core_cycles = [res.cycles]
    return res


def simulate(
    nets: Sequence[Netlist],
    progs: Sequence[SpecProgram],
    cfg: AccelConfig,
) -> SimResult:
    """Synchronous multi-core run: cores process their streams in parallel;
    total latency = max core latency (they share DRAM via the bw model)."""
    assert len(nets) == len(progs)
    per_core: List[SimResult] = []
    for net, prog in zip(nets, progs):
        per_core.append(
            simulate_core(net, prog, cfg, cfg.dram_burst_latency)
        )
    total = SimResult()
    for r in per_core:
        total = total.merge_parallel(r)
    return total

from repro.accel.sim import AccelConfig, simulate, SimResult
from repro.accel.energy import energy_report

__all__ = ["AccelConfig", "simulate", "SimResult", "energy_report"]

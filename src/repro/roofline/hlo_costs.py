"""Trip-count-corrected cost model over optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so with
scan-over-layers the reported flops/bytes are ~L× too small. The optimized
HLO annotates every while with ``backend_config={"known_trip_count":{"n":N}}``.
This module:

  1. splits the HLO module into computations,
  2. builds the while-call graph and propagates trip-count multipliers
     (nested scans multiply: microbatch × layer × flash-chunk),
  3. counts, per computation and weighted by multiplier:
       * dot flops (2 · |out| · contracted_size) — the dominant term,
       * an HBM traffic estimate: for every non-fusion-interior op,
         operand bytes + result bytes (tensors are counted once per
         read and once per write — the standard fusion-boundary model),
       * collective operand/result/wire bytes per kind.

Fusion subcomputations are skipped (their interior never touches HBM);
condition computations are ignored (O(1) work per iteration).
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_DOT_RE = re.compile(r"\bdot\(")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _nelems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _nelems(dims) * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(text: str) -> Dict[str, Tuple[str, List[str]]]:
    comps: Dict[str, Tuple[str, List[str]]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = (line, [])
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur][1].append(line)
    return comps


def _entry_name(text: str) -> Optional[str]:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            if m:
                return m.group(2)
    return None


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_PARAM_HDR_RE = re.compile(r"%([\w\.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^,)]*))")


def _operand_span(line: str):
    """Span of the top-level argument list of the op on this line."""
    eq = line.find("=")
    if eq < 0:
        return None
    paren = line.find("(", eq)
    if paren < 0:
        return None
    depth = 0
    for i in range(paren, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return paren + 1, i
    return paren + 1, len(line)


def _line_shapes(line: str, symtab: Dict[str, List[Tuple[str, str]]]):
    """(result shapes, operand shapes) for an instruction line.

    Optimized HLO prints operands as bare %names — shapes are resolved
    through ``symtab`` (built from the defining lines of the computation).
    """
    eq = line.find("=")
    if eq < 0:
        return [], []
    span = _operand_span(line)
    paren = span[0] - 1 if span else len(line)
    res = _SHAPE_RE.findall(line[eq:paren])
    opnds: List[Tuple[str, str]] = []
    if span:
        for name in _NAME_RE.findall(line[span[0]: span[1]]):
            opnds.extend(symtab.get(name, []))
    return res, opnds


def _build_symtab(header: str, lines: List[str]) -> Dict[str, List[Tuple[str, str]]]:
    """%name -> [(dtype, dims), ...] from defs + computation parameters."""
    tab: Dict[str, List[Tuple[str, str]]] = {}
    for m in _PARAM_HDR_RE.finditer(header):
        tab[m.group(1)] = _SHAPE_RE.findall(m.group(2))
    for line in lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        eq = line.find("=")
        paren = line.find("(", eq)
        if paren < 0:
            paren = len(line)
        tab[dm.group(1)] = _SHAPE_RE.findall(line[eq:paren])
    return tab


def analyze_hlo(text: str) -> Dict:
    comps = _split_computations(text)
    entry = _entry_name(text)

    # which computations are fusion interiors / while conditions
    fusion_comps = set()
    cond_comps = set()
    while_edges: Dict[str, List[Tuple[str, int]]] = {}
    for name, (_, lines) in comps.items():
        for line in lines:
            for m in _CALLS_RE.finditer(line):
                fusion_comps.add(m.group(1))
            cm = _COND_RE.search(line)
            if cm:
                cond_comps.add(cm.group(1))
            bm = _BODY_RE.search(line)
            if bm:
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                while_edges.setdefault(name, []).append((bm.group(1), trip))

    # propagate multipliers from entry
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        mult[name] = mult.get(name, 0.0) + m
        for child, trip in while_edges.get(name, []):
            visit(child, m * trip)

    if entry:
        visit(entry, 1.0)
    else:  # fallback: everything counted once
        for name in comps:
            mult.setdefault(name, 1.0)

    flops = 0.0
    traffic = 0.0
    coll: Dict[str, Dict[str, float]] = {}
    coll_total = {"operand_bytes": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0,
                  "count": 0.0}

    for name, (header, lines) in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0 or name in fusion_comps or name in cond_comps:
            continue
        symtab = _build_symtab(header, lines)
        for line in lines:
            res, opnds = _line_shapes(line, symtab)
            if not res and not opnds:
                continue
            rb = sum(_shape_bytes(d, s) for d, s in res)
            ob = sum(_shape_bytes(d, s) for d, s in opnds)
            traffic += m * (rb + ob)
            if _DOT_RE.search(line):
                out_elems = sum(_nelems(s) for _, s in res)
                cm = _LHS_CONTRACT_RE.search(line)
                contracted = 1
                if cm and cm.group(1).strip() and opnds:
                    lhs_dims = opnds[0][1].split(",")
                    for idx in cm.group(1).split(","):
                        contracted *= int(lhs_dims[int(idx)])
                flops += m * 2.0 * out_elems * contracted
            cmatch = _COLLECTIVE_RE.search(line)
            if cmatch and "-done(" not in line:
                kind = cmatch.group(1)
                if kind == "all-reduce":
                    wire = 2.0 * ob
                elif kind == "all-gather":
                    wire = float(rb)
                else:
                    wire = float(ob)
                agg = coll.setdefault(
                    kind,
                    {"count": 0.0, "operand_bytes": 0.0, "result_bytes": 0.0,
                     "wire_bytes": 0.0},
                )
                agg["count"] += m
                agg["operand_bytes"] += m * ob
                agg["result_bytes"] += m * rb
                agg["wire_bytes"] += m * wire
                coll_total["count"] += m
                coll_total["operand_bytes"] += m * ob
                coll_total["result_bytes"] += m * rb
                coll_total["wire_bytes"] += m * wire

    return {
        "dot_flops": flops,
        "traffic_bytes": traffic,
        "collectives": {"per_kind": coll, "total": coll_total},
        "num_computations": len(comps),
        "num_whiles": sum(len(v) for v in while_edges.values()),
    }

from repro.roofline.analysis import (
    parse_collectives,
    roofline_terms,
    model_flops,
    HW,
)

__all__ = ["parse_collectives", "roofline_terms", "model_flops", "HW"]

"""Analytic per-device HBM traffic model (true dtypes).

The container compiles on the CPU backend, which *emulates bf16 in f32*
(whole cache/activation buffers get `convert`ed) — so HLO-derived byte
counts overstate bf16 models by up to 2x vs the TPU target. The roofline
memory term therefore comes from this first-principles model; the
HLO-parsed traffic is reported alongside as the "CPU-compile upper bound".

Assumptions (documented per term):
  * bf16 compute / f32 master + Adam (train), bf16 weights (serve)
  * full remat: block activations recomputed in bwd; only the per-layer
    (B,S,d) stash is stored between fwd and bwd
  * flash attention: score tiles stay in VMEM (no HBM score traffic)
  * FSDP gathers land once per device per pass (fwd, bwd-recompute,
    bwd-grad) at bf16
"""

from __future__ import annotations

from typing import Dict

from repro.config import ModelConfig, ShapeConfig
from repro.roofline.analysis import active_params


def _devices(multi_pod: bool):
    return 512 if multi_pod else 256, 16  # total, model-axis size


def traffic_train(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
                  microbatches: int = 1) -> Dict[str, float]:
    D, M = _devices(multi_pod)
    N = float(cfg.num_params())
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.num_layers
    tokens_dev = B * S / (D / M)  # per model-replica data shard
    # optimizer: read p,m,v,g (4x4B) + write p,m,v (3x4B) on the shard
    opt = N / D * (7 * 4)
    # grads: written once (f32) per step (accumulation stays in registers
    # per microbatch scan iteration — written once per microbatch)
    grads = N / D * 4 * microbatches
    # weights: each device reads its gathered bf16 copy 3x (fwd, recompute,
    # grad pass); gathered footprint = N / M per device
    weights = 3 * (N / M) * 2 * microbatches
    # activation stash: (B,S,d) per layer, sharded over data x model
    stash_bytes = L * tokens_dev / microbatches * d * 2 / M * microbatches
    stash = 2 * stash_bytes  # write fwd + read bwd
    # recompute intermediates (qkv/h/gate...) ~6x the stash, write+read
    recompute = 6 * 2 * stash_bytes
    # logits chunks: (B,S,V/M) f32 write+read, fwd+bwd
    logits = 4 * tokens_dev * cfg.padded_vocab / M * 4 / 1  # 2 passes x w+r
    total = opt + grads + weights + stash + recompute + logits
    return {
        "opt": opt, "grads": grads, "weights": weights, "stash": stash,
        "recompute": recompute, "logits": logits, "total": total,
    }


def traffic_prefill(cfg: ModelConfig, shape: ShapeConfig, *,
                    multi_pod: bool) -> Dict[str, float]:
    D, M = _devices(multi_pod)
    N = float(active_params(cfg))
    B, S = shape.global_batch, shape.seq_len
    tokens_dev = B * S / (D / M)
    acts = cfg.num_layers * tokens_dev * cfg.d_model * 2 / M * 8  # interms
    weights = (N / M) * 2  # one bf16 pass
    kv = (cfg.num_layers * B * S * cfg.num_kv_heads * cfg.head_dim * 2 * 2
          / D)  # cache write
    total = acts + weights + kv
    return {"weights": weights, "acts": acts, "kv_write": kv, "total": total}


def traffic_decode(cfg: ModelConfig, shape: ShapeConfig, *,
                   multi_pod: bool) -> Dict[str, float]:
    D, M = _devices(multi_pod)
    N = float(active_params(cfg))
    B, T = shape.global_batch, shape.seq_len
    # every parameter shard read once per token step (bf16)
    weights = N / D * 2 * (D / M)  # each model-replica reads its TP slice
    # KV cache read fully + one-token write
    if cfg.uses_attention:
        layers_attn = (cfg.num_layers if cfg.family != "hybrid"
                       else cfg.num_layers // max(cfg.attn_every, 1))
        kv = layers_attn * B * T * cfg.num_kv_heads * cfg.head_dim * 2 * 2 / D
    else:
        kv = 0.0
    # recurrent state read+write (f32)
    state = 0.0
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * cfg.d_model if cfg.family == "hybrid" else 2 * cfg.d_model
        h = d_in // 64 if cfg.family == "hybrid" else cfg.num_heads
        p = d_in // max(h, 1)
        n = cfg.ssm_state if cfg.family == "hybrid" else p
        state = 2 * cfg.num_layers * B * h * n * p * 4 / D
    total = weights + kv + state
    return {"weights": weights, "kv_read": kv, "state": state, "total": total}


def traffic(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
            microbatches: int = 1) -> Dict[str, float]:
    if shape.kind == "train":
        return traffic_train(cfg, shape, multi_pod=multi_pod,
                             microbatches=microbatches)
    if shape.kind == "prefill":
        return traffic_prefill(cfg, shape, multi_pod=multi_pod)
    return traffic_decode(cfg, shape, multi_pod=multi_pod)

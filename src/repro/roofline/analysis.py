"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs_global  / (chips * peak_FLOP/s)
    memory     = HLO_bytes_global  / (chips * HBM_bw)
    collective = collective_bytes_global / (chips * link_bw)

``cost_analysis`` on an SPMD-partitioned module reports *per-device*
flops/bytes, so global = per_device * chips, and each term conveniently
reduces to per_device / peak. Collective bytes are not in cost_analysis:
we parse the optimized HLO (``compiled.as_text()``) and sum operand and
result sizes of every collective op. Two variants are recorded:

  * ``operand_bytes`` — literal sum of operand sizes (task-spec formula);
  * ``wire_bytes``    — per-op estimate of bytes actually moved per device
      (all-reduce ~ 2x operand for ring RS+AG; all-gather ~ result size;
      reduce-scatter ~ operand; all-to-all / permute ~ operand),
      which is what the roofline table uses (documented in EXPERIMENTS.md).
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List

from repro.config import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class HW:
    """TPU v5e-like target (task-mandated constants)."""

    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # bytes/s / chip
    ici_bw: float = 50e9  # bytes/s / link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\w+\[[\d,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> Dict:
    """Per-device collective byte accounting from optimized HLO text."""
    per_op: Dict[str, Dict[str, float]] = {}
    ops: List[Dict] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        if "-done(" in line:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        # result shapes appear before the op name, operands after
        pre = line[: m.end()]
        res_shapes = _SHAPE_RE.findall(pre)
        opnd_shapes = shapes[len(res_shapes):]
        res_b = sum(_shape_bytes(d, s) for d, s in res_shapes)
        opnd_b = sum(_shape_bytes(d, s) for d, s in opnd_shapes)
        if kind == "all-reduce":
            wire = 2 * opnd_b
        elif kind == "all-gather":
            wire = res_b
        else:  # reduce-scatter / all-to-all / collective-permute
            wire = opnd_b
        ops.append({"kind": kind, "operand_bytes": opnd_b,
                    "result_bytes": res_b, "wire_bytes": wire})
        agg = per_op.setdefault(kind, {"count": 0, "operand_bytes": 0,
                                       "result_bytes": 0, "wire_bytes": 0})
        agg["count"] += 1
        agg["operand_bytes"] += opnd_b
        agg["result_bytes"] += res_b
        agg["wire_bytes"] += wire
    total = {
        "operand_bytes": sum(o["operand_bytes"] for o in ops),
        "wire_bytes": sum(o["wire_bytes"] for o in ops),
        "count": len(ops),
    }
    return {"per_kind": per_op, "total": total}


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
    hw: HW = HW(),
) -> Dict[str, float]:
    t_c = flops_per_device / hw.peak_flops
    t_m = bytes_per_device / hw.hbm_bw
    t_x = wire_bytes_per_device / hw.ici_bw
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "step_lower_bound_s": bound,
        # fraction of the bound that is useful compute (roofline fraction)
        "compute_fraction_of_bound": t_c / bound if bound > 0 else 0.0,
    }


def active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: only routed experts)."""
    n = cfg.num_params()
    if cfg.num_experts:
        per_mlp = (3 if cfg.gated_mlp else 2) * cfg.d_model * cfg.d_ff
        total_exp = cfg.num_layers * cfg.num_experts * per_mlp
        active_exp = cfg.num_layers * cfg.num_experts_per_token * per_mlp
        n = n - total_exp + active_exp
    return float(n)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference).

    Embedding-table flops excluded (standard convention); attention
    quadratic term reported separately in benchmarks where relevant.
    """
    n = active_params(cfg)
    n -= cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens

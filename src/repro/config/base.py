"""Config dataclasses for the model plane and the privacy plane.

The config system is deliberately explicit (frozen dataclasses + a registry)
rather than string-keyed dicts: every architecture in ``repro.configs`` is a
plain Python file declaring one ``ModelConfig`` and registering it.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. ``family`` selects the block stack:

    * ``dense``  — pre-norm GQA attention + (G)MLP blocks
    * ``moe``    — attention + mixture-of-experts FFN
    * ``ssm``    — xLSTM (mLSTM/sLSTM) recurrent blocks, no attention
    * ``hybrid`` — Mamba2 blocks with a periodically applied *shared*
                   attention block (Zamba2)
    * ``audio``  — dense decoder over precomputed codec-frame embeddings
    * ``vlm``    — dense decoder over [patch-embeddings ; token-embeddings]
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // num_heads
    causal: bool = True
    qk_norm: bool = False
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "silu"  # silu | gelu (gated MLP unless gated_mlp=False)
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_impl: str = "dense"  # dense (einsum dispatch) | a2a (shard_map EP)
    moe_combine: str = "psum"  # psum | psum_scatter (into seq-parallel stash)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM / hybrid ---
    ssm_state: int = 0  # Mamba2 N
    ssm_chunk: int = 256  # SSD chunk length
    ssm_expand: int = 2  # Mamba2 expansion factor
    ssm_conv: int = 4  # short conv width
    attn_every: int = 0  # hybrid: shared attn applied every N ssm blocks
    shared_attn_lora_rank: int = 0  # zamba2 per-invocation LoRA rank
    slstm_every: int = 0  # xlstm: every Nth block is sLSTM (rest mLSTM)

    # --- frontends (audio / vlm): stubs provide precomputed embeddings ---
    input_mode: str = "tokens"  # tokens | embeddings | tokens+image
    num_image_tokens: int = 0

    # --- numerics / compilation ---
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"  # master parameter dtype
    remat: str = "block"  # none | block  (jax.checkpoint around each block)
    scan_layers: bool = True
    attn_chunk: int = 512  # kv-chunk for flash-style attention scan
    logit_dtype: str = "float32"

    # --- privacy plane: which nonlinear ops are garbled ---
    gc_softmax_bits: int = 37
    gc_layernorm_bits: int = 37
    gc_act_bits: int = 21
    gc_frac_bits: int = 12

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0 or self.num_kv_heads == 0, (
            f"{self.name}: num_heads={self.num_heads} not a multiple of "
            f"num_kv_heads={self.num_kv_heads}"
        )

    # vocab padded so the embedding table shards over the model axis
    @property
    def padded_vocab(self) -> int:
        return _ceil_to(self.vocab_size, 128)

    @property
    def uses_attention(self) -> bool:
        return self.family in ("dense", "moe", "audio", "vlm", "hybrid")

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode (500k) is supported."""
        return self.family in ("ssm", "hybrid")

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, h = self.d_model, self.d_ff, self.padded_vocab, self.num_heads
        hd, kv = self.head_dim, self.num_kv_heads
        emb = v * d
        head = 0 if self.tie_embeddings else v * d
        per_attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        if self.gated_mlp:
            per_mlp = 3 * d * f
        else:
            per_mlp = 2 * d * f
        n = emb + head
        if self.family in ("dense", "audio", "vlm"):
            n += self.num_layers * (per_attn + per_mlp + 2 * d)
        elif self.family == "moe":
            n += self.num_layers * (
                per_attn + self.num_experts * per_mlp + d * self.num_experts + 2 * d
            )
        elif self.family == "ssm":
            # xLSTM rough: mLSTM block ~ (2*expand+2)*d^2-ish; use init-time count instead
            n += self.num_layers * (4 * d * d + 2 * d)
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            per_mamba = d * (2 * d_in + 2 * self.ssm_state * 1) + d_in * d + d_in * 2
            n += self.num_layers * (per_mamba + 2 * d)
            n += per_attn + per_mlp  # one shared block
        return n


# ---------------------------------------------------------------------------
# Input shapes (assigned per task spec)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def assigned_shapes(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The shape cells this arch actually runs.

    ``long_500k`` needs sub-quadratic attention: only ssm/hybrid run it
    (skip documented in DESIGN.md §5). All assigned archs are decoder-style,
    so decode shapes always run.
    """
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(s)
    return tuple(out)


# ---------------------------------------------------------------------------
# Mesh / train / privacy configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    microbatches: int = 1  # gradient accumulation factor
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    grad_compression_bits: int = 0  # 0 = off, 8 = int8 error-feedback ring
    # cast f32 master params to compute dtype *before* the FSDP all-gather
    # (halves gather bytes); "float32" reproduces the gather-then-cast
    # baseline for the perf iteration log.
    param_gather_dtype: str = "bfloat16"


@dataclass(frozen=True)
class PrivacyConfig:
    """Knobs for the APINT privacy plane."""

    protocol: str = "apint"  # apint | primer_baseline
    mult_style: str = "xfbq"  # xfbq | conventional
    xfbq_qerror_terms: bool = False  # include Q-error correction terms
    layernorm_offload: bool = True  # APINT Fig.4 LayerNorm reduction
    scheduler: str = "fine"  # df | fr | sr | coarse | fine
    speculation: bool = True
    num_cores: int = 16
    wire_memory_kb: int = 128
    he_poly_n: int = 2048
    he_num_primes: int = 3
    he_t_bits: int = 40  # prime plaintext modulus (shares + GC word algebra)
    frac_bits: int = 12


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny config of the same family for CPU smoke tests.

    Preserves every structural feature (family, GQA ratio, MoE top-k, qk-norm,
    hybrid pattern, vocab padding behaviour) while shrinking all dims.
    """
    h = min(cfg.num_heads, 4)
    ratio = cfg.num_heads // cfg.num_kv_heads if cfg.num_kv_heads else 1
    kv = max(1, h // min(ratio, h))
    changes = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        num_heads=h,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=min(cfg.vocab_size, 512),
        attn_chunk=64,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_chunk=32,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        num_experts_per_token=(
            min(cfg.num_experts_per_token, 2) if cfg.num_experts_per_token else 0
        ),
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        slstm_every=min(cfg.slstm_every, 2) if cfg.slstm_every else 0,
        num_image_tokens=8 if cfg.num_image_tokens else 0,
        shared_attn_lora_rank=4 if cfg.shared_attn_lora_rank else 0,
        dtype="float32",
        scan_layers=cfg.scan_layers,
        name=cfg.name + "-smoke",
    )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)

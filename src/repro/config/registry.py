"""Architecture registry: ``get_config("olmoe-1b-7b")`` etc.

Importing ``repro.configs`` registers all shipped architectures.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config.base import ModelConfig

_REGISTRY: Dict[str, ModelConfig] = {}

_SHIPPED_MODULES = [
    "olmoe_1b_7b",
    "llama4_scout_17b_a16e",
    "llama3_2_1b",
    "deepseek_67b",
    "qwen3_1_7b",
    "smollm_360m",
    "musicgen_medium",
    "xlstm_125m",
    "zamba2_2_7b",
    "internvl2_26b",
    "bert_base_pit",
]


def register_config(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY and _REGISTRY[cfg.name] != cfg:
        raise ValueError(f"config {cfg.name!r} already registered with different values")
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    for mod in _SHIPPED_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown config {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)

from repro.config.base import (
    ModelConfig,
    ShapeConfig,
    MeshConfig,
    TrainConfig,
    PrivacyConfig,
    SHAPES,
    assigned_shapes,
    reduced_config,
)
from repro.config.registry import register_config, get_config, list_configs

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "MeshConfig",
    "TrainConfig",
    "PrivacyConfig",
    "SHAPES",
    "assigned_shapes",
    "reduced_config",
    "register_config",
    "get_config",
    "list_configs",
]

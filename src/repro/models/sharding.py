"""Logical-axis sharding rules with divisibility fallbacks.

The production mesh is fixed — ``(data=16, model=16)`` per pod, optionally a
leading ``pod`` axis — but head counts across the 10 assigned architectures
are not uniformly divisible by 16 (llama4: 40H, smollm: 15H, musicgen: 24H).
Model code therefore annotates *logical* names and this module resolves them
to mesh axes per (config, shape, mesh), falling back when a dim does not
divide:

  * q-heads not divisible by |model|  ->  attention shards the q-sequence
    ("attn_seq" -> model) instead of heads;
  * kv-heads not divisible            ->  decode caches shard the kv-sequence
    ("kv_seq" -> model) — always divisible for our shapes (32768, 524288);
  * batch=1 (long_500k)               ->  sequence takes the data axes.

Rules live in a module-global context set by the step builders
(``repro.launch.steps``); in unit tests no rules are active and ``shard`` is
the identity, so model code runs unmodified on one CPU device.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.config import MeshConfig, ModelConfig

Axes = Optional[Tuple[str, ...]]  # mesh axes for one logical name


@dataclass
class Rules:
    """Resolved logical-name -> mesh-axes mapping for one (cfg, shape, mesh)."""

    mesh: object  # jax.sharding.Mesh
    table: Dict[str, Axes]
    # resolved booleans model code may branch on (static at trace time)
    shard_heads: bool = False
    shard_kv_heads: bool = False
    seq_shard_attn: bool = False

    def spec(self, *names: Optional[str]) -> P:
        parts = []
        for n in names:
            if n is None:
                parts.append(None)
            else:
                ax = self.table.get(n)
                if ax is None:
                    parts.append(None)
                elif len(ax) == 1:
                    parts.append(ax[0])
                else:
                    parts.append(ax)
        return P(*parts)


_ACTIVE: Optional[Rules] = None


def active_rules() -> Optional[Rules]:
    return _ACTIVE


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = rules
    try:
        yield
    finally:
        _ACTIVE = prev


def axis_size_of(name: str) -> int:
    """Product of mesh-axis sizes a logical name maps to (1 if inactive)."""
    r = _ACTIVE
    if r is None:
        return 1
    ax = r.table.get(name)
    if not ax:
        return 1
    sizes = dict(zip(r.mesh.axis_names, r.mesh.devices.shape))
    n = 1
    for a in ax:
        n *= sizes[a]
    return n


def shard(x, *names: Optional[str]):
    """Annotate ``x`` with the sharding for logical dim ``names``.

    Identity when no rules are active (single-device tests) — model code is
    unconditional.
    """
    r = _ACTIVE
    if r is None:
        return x
    assert x.ndim == len(names), f"{x.shape} vs {names}"
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(r.mesh, r.spec(*names))
    )


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def make_rules(
    cfg: ModelConfig,
    mesh,
    *,
    kind: str,  # train | prefill | decode
    global_batch: int,
    seq_len: int,
) -> Rules:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_sz = axis_sizes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    dp_sz = 1
    for a in dp_axes:
        dp_sz *= axis_sizes[a]

    shard_heads = _divides(cfg.num_heads, model_sz)
    shard_kv_heads = _divides(cfg.num_kv_heads, model_sz)
    seq_shard_attn = not shard_heads and _divides(seq_len, model_sz) and seq_len > 1

    # batch: prefer full DP; long_500k (batch=1) gives the data axes to seq.
    if _divides(global_batch, dp_sz):
        batch_ax: Axes = dp_axes
        long_mode = False
    else:
        batch_ax = None
        long_mode = True

    table: Dict[str, Axes] = {}
    table["batch"] = batch_ax
    table["vocab"] = ("model",)
    table["d_ff"] = ("model",)
    table["expert"] = ("model",)
    table["heads"] = ("model",) if shard_heads else None
    table["kv_heads"] = ("model",) if shard_kv_heads else None
    table["attn_seq"] = ("model",) if seq_shard_attn else None
    # SSM head count differs from attention head count (mamba2: d_in/64)
    if cfg.family == "hybrid":
        ssm_h = (cfg.ssm_expand * cfg.d_model) // 64
    elif cfg.family == "ssm":
        ssm_h = cfg.num_heads
    else:
        ssm_h = 0
    table["ssm_heads"] = ("model",) if _divides(ssm_h, model_sz) else None
    # inter-block activation stash: sequence-parallel over `model` for
    # attention families, embed-parallel for recurrent families (their scan
    # runs over sequence chunks and must see the full sequence locally).
    recurrent = cfg.family in ("ssm", "hybrid")
    if kind in ("train", "prefill") and seq_len > 1:
        if not recurrent and _divides(seq_len, model_sz):
            table["act_seq"] = ("model",)
            table["act_embed"] = None
        elif recurrent and _divides(cfg.d_model, model_sz):
            table["act_seq"] = None
            table["act_embed"] = ("model",)
        else:
            table["act_seq"] = None
            table["act_embed"] = None
    else:
        table["act_seq"] = None
        table["act_embed"] = None

    # decode KV cache: shard the time dim over `model` (always divisible for
    # 32k / 500k); in long mode (batch=1) give it the data axes as well.
    kv_axes = []
    if long_mode:
        kv_axes.extend(dp_axes)
    kv_axes.append("model")
    total = 1
    for a in kv_axes:
        total *= axis_sizes[a]
    table["kv_seq"] = tuple(kv_axes) if _divides(seq_len, total) else None

    # embedding-dim of weights for FSDP: shard over data axes — training
    # only (serving re-pays the gather every step; weights are TP-sharded
    # and data-replicated there, see §Perf deepseek/llama4 decode)
    table["fsdp"] = dp_axes if (dp_axes and kind == "train") else None
    table["seq_dp"] = dp_axes if long_mode and _divides(seq_len, dp_sz) else None

    return Rules(
        mesh=mesh,
        table=table,
        shard_heads=shard_heads,
        shard_kv_heads=shard_kv_heads,
        seq_shard_attn=seq_shard_attn,
    )


# ---------------------------------------------------------------------------
# Parameter shardings (FSDP over data axes + TP over model)
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig, params, mesh, *, fsdp_params: bool = True
                ) -> Dict:
    """PartitionSpec pytree matching ``params``.

    Convention by path name (see models/*.py param layouts):
      * ...embedding "table" (V, d)            -> (model, fsdp)
      * attention wq/wo etc. (d, n)            -> (fsdp, model)
      * moe experts w* (E, d, f)               -> (model, fsdp, None)
      * norm scales / biases / small vectors   -> replicated
    Stacked-layer params have a leading L dim (replicated).

    ``fsdp_params=False`` drops the data-axis shard (TP-only): the serving
    layout — decode would otherwise re-pay the full FSDP all-gather on
    every token step (see EXPERIMENTS.md §Perf, deepseek decode).
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_sz = axis_sizes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    dp_sz = 1
    for a in dp_axes:
        dp_sz *= axis_sizes[a]
    if not fsdp_params:
        dp_axes = ()
        dp_sz = 1
    fsdp = dp_axes if dp_axes else None

    def spec_for(path: str, x) -> P:
        shape = x.shape
        name = path.split("/")[-1]
        nstack = 1 if "stacked" in path else 0  # leading layer dim(s)
        # normalize: dims after the stack prefix
        dims = shape[nstack:] if nstack else shape
        pad = (None,) * nstack

        def ok(dim_idx, sz):
            return dims[dim_idx] % sz == 0

        if name in ("scale", "bias", "a_log", "dt_bias", "d_skip") or len(dims) <= 1:
            return P(*pad, *([None] * len(dims)))
        if name == "table":  # (V, d) embedding / unembedding
            v_ok = ok(0, model_sz)
            d_ok = ok(1, dp_sz) if fsdp else False
            return P(*pad, "model" if v_ok else None, fsdp if d_ok else None)
        if name == "expert_w2":  # (E, f, d): FSDP on the *output* dim
            e_ok = ok(0, model_sz)
            d_ok = ok(2, dp_sz) if fsdp else False
            return P(*pad, "model" if e_ok else None, None, fsdp if d_ok else None)
        if name.startswith("expert"):  # (E, d, f)
            e_ok = ok(0, model_sz)
            d_ok = ok(1, dp_sz) if fsdp else False
            return P(
                *pad,
                "model" if e_ok else None,
                fsdp if d_ok else None,
                *([None] * (len(dims) - 2)),
            )
        if len(dims) == 2:  # (in, out) dense kernels
            in_ok = ok(0, dp_sz) if fsdp else False
            out_ok = ok(1, model_sz)
            # FSDP on the input dim, TP on the output dim when divisible;
            # fall back to sharding whichever side divides.
            if out_ok:
                return P(*pad, fsdp if in_ok else None, "model")
            if in_ok:
                return P(*pad, fsdp, None)
            return P(*pad, None, None)
        if len(dims) == 3:  # e.g. conv kernels (w, d, 1) or (H, ...) blocks
            return P(*pad, *([None] * len(dims)))
        return P(*pad, *([None] * len(dims)))

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}

    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(v, prefix + "/" + str(k)) for k, v in tree.items()}
        return spec_for(prefix, tree)

    del flat, specs
    return build(params)


def named_sharding_tree(cfg: ModelConfig, params, mesh, *,
                        fsdp_params: bool = True):
    specs = param_specs(cfg, params, mesh, fsdp_params=fsdp_params)
    return jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )

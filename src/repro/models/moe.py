"""Mixture-of-Experts FFN (OLMoE 64e/top-8, Llama4-Scout 16e/top-1).

Two interchangeable implementations (cfg.moe_impl):

* ``dense`` — every expert runs on every token, masked combine. O(E) flops:
  for smoke tests and tiny configs only.

* ``a2a`` — expert parallelism for the production mesh, written with
  shard_map so the communication pattern is explicit and deterministic:
  activations arrive *replicated* across the `model` axis (the natural
  layout between blocks); each model-rank routes all tokens but gathers
  into capacity buffers only for its own E/|model| experts, runs the
  expert GEMMs locally, scatter-adds its contribution and psums over
  `model`. One all-reduce of (B, S, d) per MoE layer — the same wire cost
  as a Megatron TP MLP, with zero dispatch einsum overhead (the GShard
  (G,S,E,C) dispatch tensor would dominate HLO flops at 64 experts).
  Expert weights are additionally FSDP-sharded over the data axes and
  all-gathered (in bf16, after cast) inside the shard_map body.

Router: softmax top-k with probability renormalization + load-balancing
auxiliary loss (Switch-style), capacity drop without replacement.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import dense_init, _act
from repro.utils.compat import shard_map
from repro.models.sharding import active_rules, shard


def init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s1 = 1.0 / math.sqrt(d)
    s2 = 1.0 / math.sqrt(f)
    p = {
        "router": dense_init(ks[0], d, E, scale=s1),
        "expert_w1": jax.random.normal(ks[1], (E, d, f), jnp.float32) * s1,
        "expert_w2": jax.random.normal(ks[2], (E, f, d), jnp.float32) * s2,
    }
    if cfg.gated_mlp:
        p["expert_w3"] = jax.random.normal(ks[3], (E, d, f), jnp.float32) * s1
    return p


def _router(cfg, p, x):
    """x: (B,S,d) -> (gates (B,S,k), idx (B,S,k), aux_loss scalar)."""
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"]["w"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.num_experts_per_token)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e f_e * p_e
    E = cfg.num_experts
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2), axis=(0, 1)
    ) / cfg.num_experts_per_token
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_ffn(cfg, w1, w2, w3, xs, dtype):
    """xs: (E_loc, cap, d) -> (E_loc, cap, d)."""
    h = jnp.einsum("ecd,edf->ecf", xs, w1.astype(dtype), preferred_element_type=dtype)
    h = _act(cfg, h)
    if w3 is not None:
        h = h * jnp.einsum(
            "ecd,edf->ecf", xs, w3.astype(dtype), preferred_element_type=dtype
        )
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(dtype), preferred_element_type=dtype)


# ---------------------------------------------------------------------------
# dense path (tests / tiny configs)
# ---------------------------------------------------------------------------


def _moe_dense(cfg, p, x):
    B, S, d = x.shape
    dt = x.dtype
    gates, idx, aux = _router(cfg, p, x)
    w1 = p["expert_w1"].astype(dt)
    w2 = p["expert_w2"].astype(dt)
    w3 = p.get("expert_w3")
    h = jnp.einsum("bsd,edf->bsef", x, w1, preferred_element_type=dt)
    h = _act(cfg, h)
    if w3 is not None:
        h = h * jnp.einsum("bsd,edf->bsef", x, w3.astype(dt), preferred_element_type=dt)
    y_all = jnp.einsum("bsef,efd->bsed", h, w2, preferred_element_type=dt)
    comb = jnp.sum(
        jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32)
        * gates[..., None],
        axis=2,
    )  # (B,S,E)
    y = jnp.einsum("bse,bsed->bsd", comb.astype(dt), y_all)
    return y, aux


# ---------------------------------------------------------------------------
# expert-parallel path (production)
# ---------------------------------------------------------------------------


def _moe_ep_local(cfg, mesh_axes_fsdp, cap, x, gates, idx, w1, w2, w3=None):
    """shard_map body. x/gates/idx replicated over `model`; weights sharded:
    w* (E_loc, d_fsdp_loc, f). Returns this rank's partial output (B,S,d)."""
    dt = x.dtype
    B, S, d = x.shape
    k = idx.shape[-1]
    E = cfg.num_experts
    r = jax.lax.axis_index("model")
    E_loc = w1.shape[0]

    # FSDP all-gather of this rank's expert weights (bf16 on the wire)
    if mesh_axes_fsdp:
        w1 = _fsdp_gather(w1.astype(dt), mesh_axes_fsdp, axis=1)
        w2 = _fsdp_gather(w2.astype(dt), mesh_axes_fsdp, axis=2)
        w3 = _fsdp_gather(w3.astype(dt), mesh_axes_fsdp, axis=1) if w3 is not None else None
    else:
        w1 = w1.astype(dt)
        w2 = w2.astype(dt)
        w3 = w3.astype(dt) if w3 is not None else None

    tokens = x.reshape(B * S, d)
    flat_idx = idx.reshape(B * S * k)  # expert id per assignment
    flat_gate = gates.reshape(B * S * k)
    tok_of_assign = jnp.repeat(jnp.arange(B * S, dtype=jnp.int32), k)

    local_e = flat_idx - r * E_loc  # in [0, E_loc) if ours
    mine = (local_e >= 0) & (local_e < E_loc)

    # position of each assignment within its expert's capacity buffer
    onehot = jax.nn.one_hot(jnp.where(mine, local_e, E_loc), E_loc + 1, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive cumsum
    slot = jnp.sum(pos_in_e * onehot, axis=1)  # (BSk,)
    keep = mine & (slot < cap)
    dst = jnp.where(keep, local_e * cap + slot, E_loc * cap)  # overflow row

    gathered = jnp.zeros((E_loc * cap + 1, d), dt)
    gathered = gathered.at[dst].add(jnp.take(tokens, tok_of_assign, axis=0))
    xs = gathered[:-1].reshape(E_loc, cap, d)

    ys = _expert_ffn(cfg, w1, w2, w3, xs, dt).reshape(E_loc * cap, d)
    ys = jnp.concatenate([ys, jnp.zeros((1, d), dt)], axis=0)
    contrib = jnp.take(ys, dst, axis=0) * flat_gate[:, None].astype(dt)
    y = jnp.zeros((B * S, d), dt).at[tok_of_assign].add(
        jnp.where(keep[:, None], contrib, 0)
    )
    y = y.reshape(B, S, d)
    if cfg.moe_combine == "psum_scatter":
        # combine directly into the sequence-parallel layout: a
        # reduce-scatter is half the wire bytes of the all-reduce, and the
        # inter-block stash is seq-sharded anyway (§Perf, olmoe cell).
        return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                    tiled=True)
    return jax.lax.psum(y, "model")


def _fsdp_gather(w, axes, axis):
    for ax in axes:
        w = jax.lax.all_gather(w, ax, axis=axis, tiled=True)
    return w


def _moe_ep(cfg, p, x):
    rules = active_rules()
    assert rules is not None, "a2a MoE requires active sharding rules (mesh)"
    mesh = rules.mesh
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    B, S, d = x.shape
    k = cfg.num_experts_per_token
    E = cfg.num_experts
    model_sz = axis_sizes.get("model", 1)
    assert E % model_sz == 0, (E, model_sz)

    gates, idx, aux = _router(cfg, p, x)
    gates = gates.astype(x.dtype)

    dp_sz = 1
    for a in dp_axes:
        dp_sz *= axis_sizes[a]
    b_loc = B // dp_sz if B % dp_sz == 0 else B
    tokens_loc = b_loc * S
    cap = int(tokens_loc * k * cfg.capacity_factor / E) + 1

    batch_ax = rules.table.get("batch")
    bspec = batch_ax if batch_ax is None else (
        batch_ax[0] if len(batch_ax) == 1 else batch_ax
    )
    # FSDP axes for expert weights: training only (rules carry the policy)
    # and dims must divide
    fsdp_rule = rules.table.get("fsdp")
    fsdp_ok = fsdp_rule and (cfg.d_model % dp_sz == 0)
    fsdp_axes = tuple(fsdp_rule) if fsdp_ok else ()
    fs = fsdp_axes if fsdp_axes else None
    wspec = P("model", fs, None)
    w2spec = P("model", None, fs)

    use_scatter = (
        cfg.moe_combine == "psum_scatter" and S % model_sz == 0
        and rules.table.get("act_seq") is not None
    )
    body = partial(_moe_ep_local, cfg if use_scatter else
                   dataclasses.replace(cfg, moe_combine="psum"),
                   fsdp_axes, cap)
    w3 = p.get("expert_w3")
    act_specs = (P(bspec, None, None),) * 3
    if w3 is not None:
        in_specs = act_specs + (wspec, w2spec, wspec)
        args = (x, gates, idx, p["expert_w1"], p["expert_w2"], w3)
    else:
        in_specs = act_specs + (wspec, w2spec)
        args = (x, gates, idx, p["expert_w1"], p["expert_w2"])
    out_spec = (P(bspec, "model", None) if use_scatter
                else P(bspec, None, None))
    y = shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_spec,
        check=False,
    )(*args)
    return y, aux


def moe_ffn(cfg: ModelConfig, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss)."""
    if cfg.moe_impl == "a2a" and active_rules() is not None:
        return _moe_ep(cfg, p, x)
    return _moe_dense(cfg, p, x)

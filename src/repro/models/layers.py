"""Core transformer building blocks (pure JAX, no flax).

Conventions:
  * params are nested dicts of f32 arrays; compute casts to ``cfg.dtype``.
  * norms / softmax / running attention stats are f32.
  * every activation annotates logical shardings via ``models.sharding.shard``
    (identity in single-device tests).

Attention supports three shapes of execution:
  * full (train / prefill): flash-style two-level chunking (q chunks
    vectorized, kv chunks scanned with running max/sum) — never materializes
    the S×S score matrix;
  * decode: one query token against a KV cache, scores (B, H, T);
  * GQA throughout; q-heads shard over `model` when divisible, otherwise the
    q-sequence does (see models/sharding.py).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.sharding import axis_size_of, shard

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, *, scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale
    return {"w": w}


def norm_init(cfg: ModelConfig, d: int):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x):
    """qk-norm: RMS over the head dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def linear(p, x, dtype):
    return x.astype(dtype) @ p["w"].astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig, positions):
    """positions: (...,) int32 -> (cos, sin) with shape (..., head_dim//2)."""
    hd = cfg.head_dim
    half = hd // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., hd); cos/sin broadcastable (..., hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos.astype(jnp.float32)
    sin = sin.astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * cos - x2f * sin
    o2 = x2f * cos + x1f * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, *, lora_rank: int = 0):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], d, h * hd),
        "wk": dense_init(ks[1], d, kv * hd),
        "wv": dense_init(ks[2], d, kv * hd),
        "wo": dense_init(ks[3], h * hd, d, scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.qk_norm:
        p["qn"] = {"scale": jnp.ones((hd,), jnp.float32)}
        p["kn"] = {"scale": jnp.ones((hd,), jnp.float32)}
    return p


def init_attention_lora(key, cfg: ModelConfig, rank: int):
    """Per-invocation LoRA for the zamba2 shared attention block."""
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    k1, k2 = jax.random.split(key)
    return {
        "lora_a": dense_init(k1, d, rank),
        "lora_b": {"w": jnp.zeros((rank, h * hd), jnp.float32)},
    }


def _flash_chunks(cfg, q, k, v, q_offset, causal):
    """Flash-style attention: q (B,S,H,hd); k/v (B,T,KV,hd) full.

    q is processed in parallel chunks; kv is scanned with running (m, l, acc).
    GQA expansion (KV -> H) happens per kv-chunk inside the scan body so the
    expanded buffer never exceeds one chunk, and the flattened H dim shards
    over `model` whenever H divides (the grouped (KV, q_per_kv) layout cannot
    shard for kv<16). Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    qpk = H // KV
    scale = 1.0 / math.sqrt(hd)

    # the q-chunk count must be a multiple of the attn_seq shard count so the
    # (B, nq, qc, H, hd) layout partitions exactly on nq
    seq_shards = axis_size_of("attn_seq")
    nq = seq_shards * max(1, -(-S // (cfg.attn_chunk * seq_shards)))
    while S % nq != 0:
        nq += seq_shards
    qc = S // nq
    kc = min(cfg.attn_chunk, T)
    while T % kc:  # non-power-of-two prompt lengths (serving)
        kc -= 1
    nk = T // kc
    assert S % qc == 0 and T % kc == 0, (S, qc, T, kc)

    q5 = q.reshape(B, nq, qc, H, hd)
    q5 = shard(q5, "batch", "attn_seq", None, "heads", None)
    k4 = k.reshape(B, nk, kc, KV, hd)
    v4 = v.reshape(B, nk, kc, KV, hd)

    q_pos = q_offset + jnp.arange(S, dtype=jnp.int32).reshape(nq, qc)

    def body(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        k_pos = j * kc + jnp.arange(kc, dtype=jnp.int32)
        # GQA expand for this chunk only: (B, kc, H, hd)
        kx = jnp.repeat(kj, qpk, axis=2) if qpk > 1 else kj
        vx = jnp.repeat(vj, qpk, axis=2) if qpk > 1 else vj
        kx = shard(kx, "batch", None, "heads", None)
        vx = shard(vx, "batch", None, "heads", None)
        # scores: (B, nq, qc, H, kc), f32
        s = jnp.einsum(
            "bnqhd,bkhd->bnqhk", q5, kx, preferred_element_type=jnp.float32
        )
        s = s * scale
        if causal:
            mask = q_pos[None, :, :, None, None] >= k_pos[None, None, None, None, :]
            s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bnqhk,bkhd->bnqhd", p.astype(vx.dtype), vx,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nq, qc, H), -1e30, jnp.float32)
    l0 = jnp.zeros((B, nq, qc, H), jnp.float32)
    a0 = jnp.zeros((B, nq, qc, H, hd), jnp.float32)
    if nk == 1:
        (m, l, acc), _ = body((m0, l0, a0), (k4[:, 0], v4[:, 0], jnp.int32(0)))
    else:
        ks = jnp.moveaxis(k4, 1, 0)  # (nk, B, kc, KV, hd)
        vs = jnp.moveaxis(v4, 1, 0)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (ks, vs, jnp.arange(nk, dtype=jnp.int32))
        )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _decode_attn(cfg, q, k_cache, v_cache, cache_len):
    """q: (B, 1, H, hd); caches (B, T, KV, hd); attends to [0, cache_len]."""
    B, _, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    qpk = H // KV
    scale = 1.0 / math.sqrt(hd)
    q4 = q.reshape(B, KV, qpk, hd)
    s = jnp.einsum(
        "bgph,btgh->bgpt", q4, k_cache, preferred_element_type=jnp.float32
    ) * scale
    t_pos = jnp.arange(T, dtype=jnp.int32)
    mask = t_pos[None, None, None, :] <= cache_len  # current token included
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bgpt,btgh->bgph", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attention(
    cfg: ModelConfig,
    p,
    x,
    *,
    pos_offset,
    cache: Optional[dict] = None,
    mode: str = "train",
    lora: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Returns (y, new_cache). Modes: train (no cache), prefill (build cache),
    decode (read+append cache)."""
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype

    q = linear(p["wq"], x, dt)
    if lora is not None:  # zamba2 per-invocation LoRA on the q projection
        q = q + linear(lora["lora_b"], linear(lora["lora_a"], x, dt), dt)
    k = linear(p["wk"], x, dt)
    v = linear(p["wv"], x, dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)

    if cfg.qk_norm:
        q = rms_head_norm(p["qn"]["scale"], q)
        k = rms_head_norm(p["kn"]["scale"], k)

    if cfg.rope_theta > 0:
        pos = pos_offset + jnp.arange(S, dtype=jnp.int32)
        cos, sin = rope_freqs(cfg, pos)  # (S, hd/2)
        q = apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
        k = apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])

    q = shard(q, "batch", "attn_seq", "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)

    new_cache = None
    if mode == "decode":
        assert cache is not None and S == 1
        T = cache["k"].shape[1]
        pos_idx = cache["len"]  # scalar int32: number of valid tokens
        z = jnp.zeros((), pos_idx.dtype)
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (z, pos_idx, z, z)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (z, pos_idx, z, z)
        )
        # time dim takes `model` (kv_seq); heads stay unsharded here — a spec
        # may not use a mesh axis twice.
        k_cache = shard(k_cache, "batch", "kv_seq", None, None)
        v_cache = shard(v_cache, "batch", "kv_seq", None, None)
        out = _decode_attn(cfg, q, k_cache, v_cache, pos_idx)
        new_cache = {"k": k_cache, "v": v_cache, "len": pos_idx + 1}
    else:
        out = _flash_chunks(cfg, q, k, v, pos_offset, cfg.causal)
        if mode == "prefill":
            kc = shard(k.astype(dt), "batch", "kv_seq", None, None)
            vc = shard(v.astype(dt), "batch", "kv_seq", None, None)
            new_cache = {"k": kc, "v": vc, "len": jnp.int32(S)}

    out = shard(out, "batch", "attn_seq", "heads", None)
    y = out.reshape(B, S, H * hd) @ p["wo"]["w"].astype(dt)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def _act(cfg, x):
    if cfg.activation == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": dense_init(ks[0], d, f),
        "w2": dense_init(ks[1], f, d),
    }
    if cfg.gated_mlp:
        p["w3"] = dense_init(ks[2], d, f)
    return p


def mlp(cfg: ModelConfig, p, x):
    dt = x.dtype
    h = _act(cfg, linear(p["w1"], x, dt))
    if cfg.gated_mlp:
        h = h * linear(p["w3"], x, dt)
    h = shard(h, "batch", None, "d_ff")
    return linear(p["w2"], h, dt)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(p, tokens, dtype):
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)


def unembed(p, x, dtype=jnp.float32):
    """logits = x @ table^T, in f32 for loss stability."""
    return jnp.einsum(
        "bsd,vd->bsv", x, p["table"].astype(x.dtype), preferred_element_type=dtype
    )

"""Model assembly: init / forward / caches for all 10 assigned families.

Layer stacking uses ``lax.scan`` over *super-blocks* with stacked parameters
(compile time and HLO size O(1) in depth):

  * dense/moe/audio/vlm : super-block = [attn + (mlp|moe)]         × L
  * ssm (xlstm)         : super-block = [(per-1) × mLSTM + sLSTM]  × L/per
  * hybrid (zamba2)     : super-block = [6 × mamba2 + shared-attn] × L/6
                          (shared attention weights are *not* stacked; each
                          invocation gets its own LoRA adapter, Zamba2-style)

Parameter tree convention (relied on by models/sharding.param_specs):
  {"embed": ..., "out_head": ..., "final_norm": ...,
   "stacked": <one leading stack dim on every leaf>, "shared": <unstacked>}

``forward`` returns:
  * mode="train":   (hidden (B,S,d), aux_loss)        — loss/unembed chunked in steps
  * mode="prefill": (last_logits (B,V), caches)
  * mode="decode":  (logits (B,V), caches)
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.sharding import shard

# ---------------------------------------------------------------------------
# block init/apply per family
# ---------------------------------------------------------------------------


def _init_attn_mlp_block(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": L.norm_init(cfg, cfg.d_model),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.norm_init(cfg, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = M.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    return p


def _apply_attn_mlp_block(cfg, p, x, *, pos_offset, cache, mode, lora=None):
    h, new_cache = L.attention(
        cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x),
        pos_offset=pos_offset, cache=cache, mode=mode, lora=lora,
    )
    x = x + h
    x = shard(x, "batch", "act_seq", "act_embed")
    aux = jnp.float32(0.0)
    if "moe" in p:
        h, aux = M.moe_ffn(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x))
    else:
        h = L.mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    x = x + h
    x = shard(x, "batch", "act_seq", "act_embed")
    return x, new_cache, aux


def _init_mamba_block(key, cfg):
    return {"ln": L.norm_init(cfg, cfg.d_model), "mamba": S.init_mamba2(key, cfg)}


def _apply_mamba_block(cfg, p, x, *, cache, mode):
    h, new_cache = S.mamba2(cfg, p["mamba"], L.apply_norm(cfg, p["ln"], x),
                            cache=cache, mode=mode)
    x = x + h
    x = shard(x, "batch", "act_seq", "act_embed")
    return x, new_cache


def _init_mlstm_block(key, cfg):
    return {"ln": L.norm_init(cfg, cfg.d_model), "mlstm": S.init_mlstm(key, cfg)}


def _apply_mlstm_block(cfg, p, x, *, cache, mode):
    h, new_cache = S.mlstm(cfg, p["mlstm"], L.apply_norm(cfg, p["ln"], x),
                           cache=cache, mode=mode)
    x = x + h
    x = shard(x, "batch", "act_seq", "act_embed")
    return x, new_cache


def _init_slstm_block(key, cfg):
    return {"ln_pre": L.norm_init(cfg, cfg.d_model), "slstm": S.init_slstm(key, cfg)}


def _apply_slstm_block(cfg, p, x, *, cache, mode):
    h, new_cache = S.slstm(cfg, p["slstm"], L.apply_norm(cfg, p["ln_pre"], x),
                           cache=cache, mode=mode)
    x = x + h
    x = shard(x, "batch", "act_seq", "act_embed")
    return x, new_cache


# ---------------------------------------------------------------------------
# stack layout
# ---------------------------------------------------------------------------


def _stack_info(cfg: ModelConfig):
    """(num_super, inner_counts) per family."""
    if cfg.family == "hybrid":
        per = cfg.attn_every
        assert cfg.num_layers % per == 0
        return cfg.num_layers // per, per
    if cfg.family == "ssm":
        per = cfg.slstm_every
        assert cfg.num_layers % per == 0
        return cfg.num_layers // per, per - 1  # inner mLSTM count
    return cfg.num_layers, 1


def _vmap_init(fn, keys):
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    V = cfg.padded_vocab
    params: dict = {
        "final_norm": L.norm_init(cfg, d),
        "out_head": L.init_embedding(ks[0], V, d),
    }
    if cfg.input_mode in ("tokens", "tokens+image"):
        params["embed"] = L.init_embedding(ks[1], V, d)
    if cfg.rope_theta == 0 and cfg.uses_attention:
        params["pos_embed"] = L.init_embedding(ks[2], 512, d)  # bert-style

    ns, inner = _stack_info(cfg)
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        keys = jax.random.split(ks[3], ns)
        params["stacked"] = {
            "block": _vmap_init(partial(_init_attn_mlp_block, cfg=cfg), keys)
        }
    elif cfg.family == "ssm":
        n_m = ns * inner
        params["stacked"] = {
            "mlstm": _vmap_init(
                partial(_init_mlstm_block, cfg=cfg), jax.random.split(ks[3], n_m)
            ),
            "slstm": _vmap_init(
                partial(_init_slstm_block, cfg=cfg), jax.random.split(ks[4], ns)
            ),
        }
    elif cfg.family == "hybrid":
        n_m = ns * inner
        params["stacked"] = {
            "mamba": _vmap_init(
                partial(_init_mamba_block, cfg=cfg), jax.random.split(ks[3], n_m)
            ),
            "lora": _vmap_init(
                partial(
                    L.init_attention_lora, cfg=cfg, rank=cfg.shared_attn_lora_rank
                ),
                jax.random.split(ks[4], ns),
            ),
        }
        params["shared"] = {"block": _init_attn_mlp_block(ks[5], cfg)}
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    """Empty decode caches with time capacity ``capacity``."""
    ns, inner = _stack_info(cfg)
    KV, hd = cfg.num_kv_heads, cfg.head_dim

    def attn_cache(n):
        return {
            "k": jnp.zeros((n, batch, capacity, KV, hd), dtype),
            "v": jnp.zeros((n, batch, capacity, KV, hd), dtype),
        }

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        layers = {"attn": attn_cache(ns)}
    elif cfg.family == "hybrid":
        d_in, H, P, N = S.mamba2_dims(cfg)
        layers = {
            "mamba": {
                "ssm": jnp.zeros((ns, inner, batch, H, N, P), jnp.float32),
                "conv": jnp.zeros(
                    (ns, inner, batch, cfg.ssm_conv - 1, d_in + 2 * N), dtype
                ),
            },
            "attn": attn_cache(ns),
        }
    elif cfg.family == "ssm":
        d_in, H, P = S.mlstm_dims(cfg)
        layers = {
            "mlstm": {
                "ssm": jnp.zeros((ns, inner, batch, H, P, P), jnp.float32),
                "norm": jnp.zeros((ns, inner, batch, H, P, 1), jnp.float32),
                "conv": jnp.zeros((ns, inner, batch, 3, d_in), dtype),
            },
            "slstm": {
                "c": jnp.zeros((ns, batch, cfg.d_model), jnp.float32),
                "n": jnp.full((ns, batch, cfg.d_model), 1e-6, jnp.float32),
                "h": jnp.zeros((ns, batch, cfg.d_model), jnp.float32),
                "m": jnp.zeros((ns, batch, cfg.d_model), jnp.float32),
            },
        }
    else:
        raise ValueError(cfg.family)
    return {"layers": layers, "len": jnp.int32(0)}


def _attn_layer_cache(layer_slice, length):
    if layer_slice is None:
        return None
    return {"k": layer_slice["k"], "v": layer_slice["v"], "len": length}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, params, batch, dtype):
    if cfg.input_mode == "embeddings":
        x = batch["embeddings"].astype(dtype)
    elif cfg.input_mode == "tokens+image":
        tok = L.embed(params["embed"], batch["tokens"], dtype)
        img = batch["image_embeds"].astype(dtype)
        x = jnp.concatenate([img, tok], axis=1)
    else:
        x = L.embed(params["embed"], batch["tokens"], dtype)
    if "pos_embed" in params:
        Spos = x.shape[1]
        x = x + params["pos_embed"]["table"][:Spos].astype(dtype)[None]
    return x


def forward(
    cfg: ModelConfig,
    params,
    batch: dict,
    *,
    mode: str = "train",
    caches: Optional[dict] = None,
):
    assert mode in ("train", "prefill", "decode")
    dtype = jnp.dtype(cfg.dtype)
    if mode == "decode":
        assert caches is not None
        if cfg.input_mode == "embeddings":
            x = batch["embeddings"].astype(dtype)
        else:
            x = L.embed(params["embed"], batch["tokens"], dtype)
        if "pos_embed" in params:
            x = x + jnp.take(
                params["pos_embed"]["table"].astype(dtype), caches["len"], axis=0
            )[None, None]
        pos_offset = caches["len"]
    else:
        x = _embed_inputs(cfg, params, batch, dtype)
        pos_offset = 0
    x = shard(x, "batch", "act_seq", "act_embed")

    use_remat = mode == "train" and cfg.remat == "block"

    def maybe_remat(fn):
        if use_remat:
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable
            )
        return fn

    cache_len = caches["len"] if caches is not None else None
    layer_caches = caches["layers"] if caches is not None else None

    ns, inner = _stack_info(cfg)
    aux_total = jnp.float32(0.0)
    new_layer_caches = None

    if cfg.family in ("dense", "moe", "audio", "vlm"):

        def body(carry, xs_in):
            xc, aux = carry
            bp = xs_in["p"]
            c_in = _attn_layer_cache(xs_in.get("c"), cache_len)
            xc, new_c, a = _apply_attn_mlp_block(
                cfg, bp, xc, pos_offset=pos_offset, cache=c_in, mode=mode
            )
            ys = None
            if new_c is not None:
                ys = {"k": new_c["k"], "v": new_c["v"]}
            return (xc, aux + a), ys

        xs = {"p": params["stacked"]["block"]}
        if mode == "decode":
            xs["c"] = layer_caches["attn"]
        (x, aux_total), ys = jax.lax.scan(maybe_remat(body), (x, aux_total), xs)
        if mode in ("prefill", "decode"):
            new_layer_caches = {"attn": ys}

    elif cfg.family == "hybrid":
        shared = params["shared"]["block"]

        def super_body(carry, xs_in):
            xc, aux = carry
            mp = xs_in["mamba"]  # stacked (inner, ...)
            lora = xs_in["lora"]

            def inner_body(xc2, xs2):
                c_in = xs2.get("c")
                xc2, new_c = _apply_mamba_block(
                    cfg, xs2["p"], xc2, cache=c_in, mode=mode
                )
                return xc2, new_c

            inner_xs = {"p": mp}
            if mode == "decode":
                inner_xs["c"] = xs_in["mc"]
            xc, mamba_ys = jax.lax.scan(inner_body, xc, inner_xs)

            c_in = _attn_layer_cache(xs_in.get("ac"), cache_len)
            xc, new_ac, a = _apply_attn_mlp_block(
                cfg, shared, xc, pos_offset=pos_offset, cache=c_in, mode=mode,
                lora=lora,
            )
            ys = {}
            if mamba_ys is not None and mode in ("prefill", "decode"):
                ys["mamba"] = mamba_ys
            if new_ac is not None:
                ys["attn"] = {"k": new_ac["k"], "v": new_ac["v"]}
            return (xc, aux + a), (ys or None)

        xs = {"mamba": _reshape_stack(params["stacked"]["mamba"], ns, inner),
              "lora": params["stacked"]["lora"]}
        if mode == "decode":
            xs["mc"] = layer_caches["mamba"]
            xs["ac"] = layer_caches["attn"]
        (x, aux_total), ys = jax.lax.scan(maybe_remat(super_body), (x, aux_total), xs)
        if mode in ("prefill", "decode"):
            new_layer_caches = {"mamba": ys["mamba"], "attn": ys["attn"]}

    elif cfg.family == "ssm":

        def super_body(carry, xs_in):
            xc, aux = carry

            def inner_body(xc2, xs2):
                xc2, new_c = _apply_mlstm_block(
                    cfg, xs2["p"], xc2, cache=xs2.get("c"), mode=mode
                )
                return xc2, new_c

            inner_xs = {"p": xs_in["mlstm"]}
            if mode == "decode":
                inner_xs["c"] = xs_in["mc"]
            xc, mlstm_ys = jax.lax.scan(inner_body, xc, inner_xs)

            sc = xs_in.get("sc")
            xc, new_sc = _apply_slstm_block(cfg, xs_in["slstm"], xc, cache=sc, mode=mode)
            ys = {}
            if mode in ("prefill", "decode"):
                ys["mlstm"] = mlstm_ys
                ys["slstm"] = new_sc
            return (xc, aux), (ys or None)

        xs = {
            "mlstm": _reshape_stack(params["stacked"]["mlstm"], ns, inner),
            "slstm": params["stacked"]["slstm"],
        }
        if mode == "decode":
            xs["mc"] = layer_caches["mlstm"]
            xs["sc"] = layer_caches["slstm"]
        (x, aux_total), ys = jax.lax.scan(maybe_remat(super_body), (x, aux_total), xs)
        if mode in ("prefill", "decode"):
            new_layer_caches = {"mlstm": ys["mlstm"], "slstm": ys["slstm"]}

    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)

    if mode == "train":
        return x, aux_total

    new_len = (cache_len + 1) if mode == "decode" else jnp.int32(x.shape[1])
    new_caches = {"layers": new_layer_caches, "len": new_len}
    last = x[:, -1] if mode == "prefill" else x[:, 0]
    logits = jnp.einsum(
        "bd,vd->bv", last, params["out_head"]["table"].astype(last.dtype),
        preferred_element_type=jnp.float32,
    )
    logits = shard(logits, "batch", "vocab")
    return logits, new_caches


def _reshape_stack(tree, ns: int, inner: int):
    return jax.tree_util.tree_map(
        lambda a: a.reshape(ns, inner, *a.shape[1:]), tree
    )

"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Both Mamba2 and mLSTM are instances of one primitive — a gated linear RNN

    S_t = exp(logdecay_t) * S_{t-1} + B_t ⊗ X_t          S: (H, N, P)
    Y_t = C_t · S_t                                       Y: (H, P)

so we implement a single *chunkwise-parallel* kernel (`chunked_linear_rnn`):
intra-chunk contributions are computed with quadratic-in-chunk einsums
(MXU-friendly) and inter-chunk state is carried by a `lax.scan` — the
standard SSD decomposition [arXiv:2405.21060].

sLSTM has a true nonlinear recurrence (hidden state feeds the gates), so it
runs as a `lax.scan` over time with block-diagonal recurrent weights and
exponential-gating stabilizer state, faithful to [arXiv:2405.04517].
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, linear, norm_init, apply_norm
from repro.models.sharding import shard

# ---------------------------------------------------------------------------
# shared chunked linear RNN (SSD form)
# ---------------------------------------------------------------------------


def _segsum(x):
    """log-space segment sums: x (..., L) -> (..., L, L) lower-triangular
    cumulative sums  out[..., i, j] = sum_{k=j+1..i} x[..., k]  (i >= j)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(L, dtype=jnp.int32)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, out, -jnp.inf)


def chunked_linear_rnn(
    C: jnp.ndarray,  # (B, L, H, N)   "query"/output mixer
    Bm: jnp.ndarray,  # (B, L, H, N)  "key"/input mixer
    X: jnp.ndarray,  # (B, L, H, P)   values
    logdecay: jnp.ndarray,  # (B, L, H) per-step log decay (<= 0)
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, N, P)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (Y (B,L,H,P), final_state (B,H,N,P))."""
    B, L, H, N = C.shape
    P = X.shape[-1]
    cl = min(chunk, L)
    nc = L // cl
    assert L % cl == 0, (L, cl)

    f32 = jnp.float32
    Cc = shard(C.reshape(B, nc, cl, H, N), "batch", None, None, "ssm_heads", None)
    Bc = shard(Bm.reshape(B, nc, cl, H, N), "batch", None, None, "ssm_heads", None)
    Xc = shard(X.reshape(B, nc, cl, H, P), "batch", None, None, "ssm_heads", None)
    ld = logdecay.reshape(B, nc, cl, H).astype(f32)

    # intra-chunk: scores[b,c,i,j,h] = C_i · B_j * exp(sum_{j<k<=i} ld_k)
    ldt = jnp.moveaxis(ld, -1, -2)  # (B, nc, H, cl)
    seg = _segsum(ldt)  # (B, nc, H, cl, cl)
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc, preferred_element_type=f32)
    scores = shard(scores, "batch", None, "ssm_heads", None, None)
    scores = scores * jnp.exp(seg)
    y_intra = jnp.einsum(
        "bchij,bcjhp->bcihp", scores.astype(X.dtype), Xc, preferred_element_type=f32
    )

    # per-chunk end states: state_c = sum_j exp(sum_{k>j} ld) B_j X_j
    total = jnp.sum(ld, axis=2)  # (B, nc, H)
    decay_tail = jnp.exp(total[:, :, None, :] - jnp.cumsum(ld, axis=2))  # (B,nc,cl,H)
    chunk_states = jnp.einsum(
        "bcjhn,bcjh,bcjhp->bchnp",
        Bc.astype(f32),
        decay_tail,
        Xc.astype(f32),
        preferred_element_type=f32,
    )  # (B, nc, H, N, P)

    # inter-chunk scan over chunk states
    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((B, H, N, P), f32)
    )

    def body(s, inp):
        st_c, tot_c = inp  # (B,H,N,P), (B,H)
        s_out = s  # state entering this chunk
        s_next = s * jnp.exp(tot_c)[:, :, None, None] + st_c
        return s_next, s_out

    sts = jnp.moveaxis(chunk_states, 1, 0)  # (nc, B, H, N, P)
    tots = jnp.moveaxis(total, 1, 0)  # (nc, B, H)
    final, entering = jax.lax.scan(body, s0, (sts, tots))
    entering = jnp.moveaxis(entering, 0, 1)  # (B, nc, H, N, P)

    # contribution of the entering state within each chunk
    decay_in = jnp.exp(jnp.cumsum(ld, axis=2))  # (B, nc, cl, H)
    y_inter = jnp.einsum(
        "bcihn,bcih,bchnp->bcihp",
        Cc.astype(f32),
        decay_in,
        entering,
        preferred_element_type=f32,
    )
    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y.astype(X.dtype), final


def linear_rnn_step(
    C, Bm, X, logdecay, state
):  # shapes: (B,H,N), (B,H,N), (B,H,P), (B,H), (B,H,N,P)
    """Single decode step of the same recurrence."""
    f32 = jnp.float32
    s = state.astype(f32) * jnp.exp(logdecay.astype(f32))[..., None, None]
    s = s + Bm.astype(f32)[..., None] * X.astype(f32)[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", C.astype(f32), s, preferred_element_type=f32)
    return y.astype(X.dtype), s


# ---------------------------------------------------------------------------
# Mamba2 block  [arXiv:2405.21060]
# ---------------------------------------------------------------------------


def mamba2_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    head_p = 64
    H = d_in // head_p
    return d_in, H, head_p, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, H, P, N = mamba2_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "wx": dense_init(ks[0], d, d_in),
        "wz": dense_init(ks[1], d, d_in),
        "wB": dense_init(ks[2], d, N),
        "wC": dense_init(ks[3], d, N),
        "wdt": dense_init(ks[4], d, H),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),  # A = -exp(a_log)
        "d_skip": jnp.ones((H,), jnp.float32),
        "conv": {
            "w": jax.random.normal(ks[5], (cfg.ssm_conv, d_in + 2 * N), jnp.float32)
            * (1.0 / math.sqrt(cfg.ssm_conv))
        },
        "wo": dense_init(ks[6], d_in, d),
        "gn": {"scale": jnp.ones((d_in,), jnp.float32)},
    }


def _causal_conv(xbc, w, state=None):
    """xbc: (B, L, Cch); w: (W, Cch) depthwise causal conv.

    Returns (y, new_state) where state is the trailing (W-1) inputs.
    """
    B, L, Cch = xbc.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, Cch), xbc.dtype)
    xp = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)  # (B, L+W-1, C)
    out = jnp.zeros((B, L, Cch), jnp.float32)
    for i in range(W):
        out = out + xp[:, i : i + L, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, L:, :] if W > 1 else state
    return out.astype(xbc.dtype), new_state


def mamba2(cfg: ModelConfig, p, x, *, cache=None, mode="train"):
    """x: (B, L, d). cache: {"ssm": (B,H,N,P), "conv": (B,W-1,C)}."""
    B, L, d = x.shape
    d_in, H, P, N = mamba2_dims(cfg)
    dt_ = x.dtype

    xin = linear(p["wx"], x, dt_)  # (B,L,d_in)
    z = linear(p["wz"], x, dt_)
    Bv = linear(p["wB"], x, dt_)  # (B,L,N)
    Cv = linear(p["wC"], x, dt_)
    dt_pre = linear(p["wdt"], x, jnp.float32) + p["dt_bias"]  # (B,L,H)

    xbc = jnp.concatenate([xin, Bv, Cv], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv"]["w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xin, Bv, Cv = jnp.split(xbc, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt_pre)  # (B,L,H)
    A = -jnp.exp(p["a_log"])  # (H,)
    logdecay = dt * A[None, None, :]  # (B,L,H)

    xh = xin.reshape(B, L, H, P)
    xh = shard(xh, "batch", None, "ssm_heads", None)
    Bh = jnp.broadcast_to(Bv[:, :, None, :], (B, L, H, N))
    Ch = jnp.broadcast_to(Cv[:, :, None, :], (B, L, H, N))
    xs = xh * dt[..., None].astype(dt_)

    ssm_state = cache["ssm"] if cache is not None else None
    if mode == "decode":
        assert L == 1
        y, new_state = linear_rnn_step(
            Ch[:, 0], Bh[:, 0], xs[:, 0], logdecay[:, 0], ssm_state
        )
        y = y[:, None]
    else:
        y, new_state = chunked_linear_rnn(
            Ch, Bh, xs, logdecay, cfg.ssm_chunk, initial_state=ssm_state
        )

    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, L, d_in).astype(dt_)
    # gated RMSNorm (Mamba2 uses RMSNorm(y * silu(z)))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
    y = (y * p["gn"]["scale"]).astype(dt_)
    out = linear(p["wo"], y, dt_)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"ssm": new_state, "conv": new_conv}
    return out, new_cache


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block  [arXiv:2405.04517]
# ---------------------------------------------------------------------------


def mlstm_dims(cfg: ModelConfig):
    d_in = 2 * cfg.d_model  # proj factor 2
    H = cfg.num_heads
    P = d_in // H
    return d_in, H, P


def init_mlstm(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, H, P = mlstm_dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "wup": dense_init(ks[0], d, d_in),
        "wz": dense_init(ks[1], d, d_in),
        "wq": dense_init(ks[2], d_in, d_in),
        "wk": dense_init(ks[3], d_in, d_in),
        "wv": dense_init(ks[4], d_in, d_in),
        "wi": dense_init(ks[5], d_in, H),
        "wf": dense_init(ks[6], d_in, H),
        "conv": {
            "w": jax.random.normal(ks[7], (4, d_in), jnp.float32) * 0.5
        },
        "wo": dense_init(ks[8], d_in, d),
        "gn": {"scale": jnp.ones((d_in,), jnp.float32)},
    }


def mlstm(cfg: ModelConfig, p, x, *, cache=None, mode="train"):
    """mLSTM with matrix memory, run through the shared chunked linear RNN.

    Stabilization: sigmoid forget gate (log-space decay), exp input gate
    clamped at 0 — the recurrent CPU decode path matches this parallel form
    exactly (see tests/test_models_parity.py).
    """
    B, L, d = x.shape
    d_in, H, P = mlstm_dims(cfg)
    dt_ = x.dtype

    up = linear(p["wup"], x, dt_)
    z = linear(p["wz"], x, dt_)
    conv_state = cache["conv"] if cache is not None else None
    c, new_conv = _causal_conv(up, p["conv"]["w"], conv_state)
    c = jax.nn.silu(c)

    q = linear(p["wq"], c, dt_).reshape(B, L, H, P) / math.sqrt(P)
    k = linear(p["wk"], c, dt_).reshape(B, L, H, P) / math.sqrt(P)
    v = linear(p["wv"], up, dt_).reshape(B, L, H, P)

    logf = jax.nn.log_sigmoid(linear(p["wf"], c, jnp.float32))  # (B,L,H)
    logi = jnp.minimum(linear(p["wi"], c, jnp.float32), 0.0)
    i_gate = jnp.exp(logi)

    kx = k * i_gate[..., None].astype(dt_)
    ssm_state = cache["ssm"] if cache is not None else None
    norm_state = cache["norm"] if cache is not None else None
    if mode == "decode":
        assert L == 1
        h, new_state = linear_rnn_step(q[:, 0], kx[:, 0], v[:, 0], logf[:, 0], ssm_state)
        ones = jnp.ones((B, H, 1), dt_)
        nrm, new_norm = linear_rnn_step(
            q[:, 0], kx[:, 0], ones, logf[:, 0], norm_state
        )
        h, nrm = h[:, None], nrm[:, None]
    else:
        h, new_state = chunked_linear_rnn(
            q, kx, v, logf, cfg.ssm_chunk, initial_state=ssm_state
        )
        ones = jnp.ones((B, L, H, 1), dt_)
        nrm, new_norm = chunked_linear_rnn(
            q, kx, ones, logf, cfg.ssm_chunk, initial_state=norm_state
        )
    h = h / jnp.maximum(jnp.abs(nrm), 1.0).astype(h.dtype)

    h = h.reshape(B, L, d_in)
    hf = h.astype(jnp.float32)
    h = hf * jax.lax.rsqrt(jnp.mean(jnp.square(hf), -1, keepdims=True) + 1e-6)
    h = (h * p["gn"]["scale"]).astype(dt_)
    out = linear(p["wo"], h * jax.nn.silu(z), dt_)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"ssm": new_state, "norm": new_norm, "conv": new_conv}
    return out, new_cache


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block (true nonlinear recurrence -> lax.scan over time)
# ---------------------------------------------------------------------------


def slstm_dims(cfg: ModelConfig):
    H = cfg.num_heads
    P = cfg.d_model // H
    return H, P


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    H, P = slstm_dims(cfg)
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(P)
    # input projections for the 4 gates + block-diag recurrent weights
    return {
        "wgates": dense_init(ks[0], d, 4 * d),
        "r": jax.random.normal(ks[1], (4, H, P, P), jnp.float32) * s,
        "bias": jnp.zeros((4, d), jnp.float32),
        "ln": norm_init(cfg, d),
        "wup": dense_init(ks[2], d, 2 * (4 * d // 3)),
        "wdown": dense_init(ks[3], 4 * d // 3, d),
    }


def slstm(cfg: ModelConfig, p, x, *, cache=None, mode="train"):
    """x: (B, L, d). Exponential gating with stabilizer state m (faithful).

    cache: {"c","n","h": (B,d), "m": (B,H)}
    """
    B, L, d = x.shape
    H, P = slstm_dims(cfg)
    f32 = jnp.float32

    gates_in = (linear(p["wgates"], x, f32)).reshape(B, L, 4, d) + p["bias"]

    if cache is not None:
        c0, n0, h0, m0 = cache["c"], cache["n"], cache["h"], cache["m"]
    else:
        c0 = jnp.zeros((B, d), f32)
        n0 = jnp.full((B, d), 1e-6, f32)
        h0 = jnp.zeros((B, d), f32)
        m0 = jnp.zeros((B, d), f32)

    r = p["r"].astype(f32)

    def step(carry, g_t):
        c, n, h, m = carry
        hh = h.reshape(B, H, P)
        rec = jnp.einsum("ghpq,bhq->bghp", r, hh).reshape(B, 4, d)
        pre = g_t + rec
        zi = jnp.tanh(pre[:, 0])
        i_pre = pre[:, 1]  # per-cell exponential gates (B, d)
        logf = jax.nn.log_sigmoid(pre[:, 2])
        o = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(logf + m, i_pre)  # stabilizer state
        i_g = jnp.exp(i_pre - m_new)
        f_g = jnp.exp(logf + m - m_new)
        c_new = f_g * c + i_g * zi
        n_new = f_g * n + i_g
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    gs = jnp.moveaxis(gates_in, 1, 0)  # (L, B, 4, d)
    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), gs)
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B, L, d)

    # post-up/down projection (GLU, proj factor 4/3)
    y = apply_norm(cfg, p["ln"], y)
    u = linear(p["wup"], y, x.dtype)
    u1, u2 = jnp.split(u, 2, axis=-1)
    out = linear(p["wdown"], jax.nn.gelu(u1) * u2, x.dtype)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"c": c, "n": n, "h": h, "m": m}
    return out, new_cache

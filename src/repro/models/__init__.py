from repro.models.transformer import init_params, forward, init_caches

__all__ = ["init_params", "forward", "init_caches"]

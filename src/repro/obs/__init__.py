"""Tracing + metrics subsystem (host-side only; stdlib only).

Usage::

    from repro import obs
    tr = obs.enable()                  # install a recording tracer
    with obs.span("garble", netlist="softmax8", instances=64):
        ...
    tr.export("trace.json")            # chrome://tracing / Perfetto
    tr.report()                        # {path: {count, total_s, ...}}
    obs.disable()

When disabled (the default) ``obs.span()`` returns one shared no-op
span — no allocation, no clock reads.
"""
from repro.obs.tracer import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    current,
    disable,
    enable,
    install,
    instant,
    span,
    timer,
)

__all__ = [
    "NULL_TRACER", "NullTracer", "Span", "Tracer", "current", "disable",
    "enable", "install", "instant", "span", "timer",
]

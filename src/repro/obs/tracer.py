"""Lightweight thread-safe tracing + metrics.

One global :class:`Tracer` (swap with :func:`install`) records nested
spans into per-thread buffers. Spans carry wall time (perf_counter_ns),
thread id and *scalar* attributes only — sizes, tags, counts. Payloads
(arrays, bytes) are rejected at ``set()`` time so secret material can
never end up in a trace; the ``secretflow`` lint additionally flags any
tainted value reaching a span call site.

Export targets:

- ``tracer.export(path)`` — Chrome ``trace_event`` JSON, loadable in
  chrome://tracing or Perfetto (B/E duration events + instant events).
- ``tracer.report()`` — aggregated tree summary keyed by span *path*
  (``"offline/gc_offline"``): count / total_s / mean_s / max_s.

Disabled tracing is zero-cost-when-off: :data:`NULL_TRACER` returns one
shared pre-allocated no-op span, so instrumented call sites pay a single
attribute load + method call and allocate nothing.

Timing unification: call sites that need a wall-clock *measurement*
regardless of tracing (``Stats.phase``, the serve EWMAs) use
:func:`timer`, which always returns a real timing span — it records into
the trace buffer only when tracing is on, but ``elapsed_s`` is always
valid. This keeps one timing code path instead of three hand-rolled
``perf_counter()`` deltas.

Spans never enter jitted bodies: instrument host-side dispatch
boundaries only (``jit_hygiene`` stays green by construction — this
module is pure stdlib and is never imported from a kernel body).
"""
from __future__ import annotations

import json
import threading
import time
from collections import defaultdict

_SCALAR = (int, float, str, bool)


def _check_attrs(attrs):
    for k, v in attrs.items():
        if not isinstance(v, _SCALAR):
            raise TypeError(
                f"span attribute {k!r} must be a scalar "
                f"(int/float/str/bool), got {type(v).__name__}; "
                "record sizes/tags/counts, never payloads")
    return attrs


class Span:
    """A timed region. Use as a context manager or close() by hand."""

    __slots__ = ("name", "attrs", "t0_ns", "t1_ns", "_tracer", "_tid",
                 "path")

    def __init__(self, name, attrs, tracer, tid, path):
        self.name = name
        self.attrs = attrs
        self._tracer = tracer
        self._tid = tid
        self.path = path
        self.t1_ns = None
        self.t0_ns = time.perf_counter_ns()

    @property
    def elapsed_s(self) -> float:
        """Seconds since start (or total duration once closed)."""
        end = self.t1_ns if self.t1_ns is not None else time.perf_counter_ns()
        return (end - self.t0_ns) * 1e-9

    duration_s = elapsed_s

    def set(self, **attrs):
        """Attach scalar attributes (sizes/tags/counts — no payloads)."""
        self.attrs.update(_check_attrs(attrs))
        return self

    def close(self):
        if self.t1_ns is None:
            self.t1_ns = time.perf_counter_ns()
            if self._tracer is not None:
                self._tracer._finish(self)
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _NullSpan:
    """Shared no-op span: no allocation, no time reads."""

    __slots__ = ()
    name = ""
    attrs: dict = {}
    path = ""
    elapsed_s = 0.0
    duration_s = 0.0

    def set(self, **attrs):
        return self

    def close(self):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every call returns the shared no-op span."""

    enabled = False

    def span(self, name, **attrs):
        return _NULL_SPAN

    def instant(self, name, **attrs):
        return None

    def export(self, path):
        raise RuntimeError("tracing is disabled; enable() first")

    def report(self):
        return {}

    def clear(self):
        pass

    def finished_spans(self):
        return []

    def finished_instants(self):
        return []


NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer: per-thread span stacks + buffers.

    Each thread appends finished spans to its own list (list.append is
    atomic under the GIL, so the hot path takes no lock); the registry
    of per-thread buffers is guarded by a mutex touched once per thread.
    """

    enabled = True

    def __init__(self):
        self._local = threading.local()
        self._mutex = threading.Lock()
        # a LIST of per-thread buffers, not a tid-keyed dict: the OS
        # recycles thread idents, so a short-lived thread's tid can be
        # reissued and a dict entry would silently drop its spans
        self._buffers: list = []
        self._instants = []  # (name, ts_ns, tid, attrs), under _mutex
        self._epoch_ns = time.perf_counter_ns()

    def _state(self):
        st = getattr(self._local, "st", None)
        if st is None:
            tid = threading.get_ident()
            buf: list = []
            with self._mutex:
                self._buffers.append(buf)
            st = self._local.st = (tid, buf, [])  # (tid, buffer, stack)
        return st

    def span(self, name, **attrs):
        tid, _buf, stack = self._state()
        path = stack[-1].path + "/" + name if stack else name
        sp = Span(name, _check_attrs(attrs), self, tid, path)
        stack.append(sp)
        return sp

    def _finish(self, sp: Span):
        tid, buf, stack = self._state()
        # tolerate out-of-order closes (pop whatever is above sp too)
        while stack:
            top = stack.pop()
            if top is sp:
                break
        buf.append(sp)

    def instant(self, name, **attrs):
        """Zero-duration event (Chrome 'i' phase)."""
        tid = threading.get_ident()
        ev = (name, time.perf_counter_ns(), tid, _check_attrs(attrs))
        with self._mutex:
            self._instants.append(ev)

    def finished_spans(self):
        with self._mutex:
            bufs = list(self._buffers)
        out = []
        for b in bufs:
            out.extend(b[:len(b)])
        return out

    def finished_instants(self):
        with self._mutex:
            return list(self._instants)

    def clear(self):
        with self._mutex:
            for b in self._buffers:
                del b[:]
            del self._instants[:]

    # -- export ----------------------------------------------------------

    def export(self, path):
        """Write Chrome trace_event JSON (open in chrome://tracing)."""
        ep = self._epoch_ns
        events = []
        for sp in self.finished_spans():
            base = {"name": sp.name, "cat": "repro", "pid": 1,
                    "tid": sp._tid, "args": sp.attrs}
            events.append({**base, "ph": "B",
                           "ts": (sp.t0_ns - ep) / 1e3})
            events.append({**base, "ph": "E",
                           "ts": (sp.t1_ns - ep) / 1e3})
        with self._mutex:
            instants = list(self._instants)
        for name, ts_ns, tid, attrs in instants:
            events.append({"name": name, "cat": "repro", "pid": 1,
                           "tid": tid, "ph": "i", "s": "t",
                           "ts": (ts_ns - ep) / 1e3, "args": attrs})
        events.sort(key=lambda e: e["ts"])
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def report(self):
        """Aggregate finished spans by path: count/total/mean/max."""
        agg = defaultdict(lambda: [0, 0.0, 0.0])  # count, total, max
        for sp in self.finished_spans():
            a = agg[sp.path]
            d = (sp.t1_ns - sp.t0_ns) * 1e-9
            a[0] += 1
            a[1] += d
            if d > a[2]:
                a[2] = d
        return {
            path: {"count": c, "total_s": t, "mean_s": t / c, "max_s": m}
            for path, (c, t, m) in sorted(agg.items())
        }


# -- module-level current tracer -----------------------------------------

_current: "Tracer | NullTracer" = NULL_TRACER


def current():
    """The installed tracer (NULL_TRACER when tracing is off)."""
    return _current


def install(tracer):
    """Swap the global tracer; returns the previous one."""
    global _current
    prev = _current
    _current = tracer
    return prev


def enable() -> Tracer:
    """Install a fresh recording Tracer and return it."""
    tr = Tracer()
    install(tr)
    return tr


def disable():
    """Back to the no-op tracer."""
    install(NULL_TRACER)


def span(name, **attrs):
    """Open a span on the current tracer (no-op span when disabled)."""
    return _current.span(name, **attrs)


def instant(name, **attrs):
    """Zero-duration event on the current tracer."""
    return _current.instant(name, **attrs)


class _TimerSpan(Span):
    """A real timing span that is never recorded (tracing off)."""

    __slots__ = ()

    def __init__(self, name, attrs):
        super().__init__(name, attrs, None, 0, name)


def timer(name, **attrs):
    """A span whose ``elapsed_s`` is always a real measurement.

    When tracing is on this is a normal recorded span; when off it is a
    tiny unrecorded timing object. Call sites that *need* the duration
    (Stats.phase, serve EWMAs) use this so wall-clock accounting keeps
    working with tracing disabled, through one shared code path.
    """
    if _current.enabled:
        return _current.span(name, **attrs)
    return _TimerSpan(name, _check_attrs(attrs))

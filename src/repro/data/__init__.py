from repro.data.pipeline import SyntheticLMData, ByteTokenizer

__all__ = ["SyntheticLMData", "ByteTokenizer"]

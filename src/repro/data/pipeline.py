"""Deterministic synthetic LM data pipeline.

Tokens are a counter-mode hash of (stream_id, step, position) — fully
deterministic, so checkpoint/restore resumes the exact stream (bitwise
training-resume tests rely on this), and each data-parallel host slices its
own rows without coordination.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig, ShapeConfig


class ByteTokenizer:
    """UTF-8 bytes + specials; vocab 259."""

    PAD, BOS, EOS = 256, 257, 258
    vocab_size = 259

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)

    def decode(self, tokens) -> str:
        b = bytes(int(t) for t in tokens if int(t) < 256)
        return b.decode("utf-8", errors="replace")


def _hash_tokens(stream: int, step: int, rows: int, cols: int, vocab: int,
                 row_offset: int = 0) -> np.ndarray:
    """splitmix64 counter hash -> (rows, cols) int32 tokens in [0, vocab)."""
    with np.errstate(over="ignore"):  # wrapping uint64 hash, intentional
        r = np.arange(row_offset, row_offset + rows, dtype=np.uint64)[:, None]
        c = np.arange(cols, dtype=np.uint64)[None, :]
        x = (
            np.uint64(stream) * np.uint64(0x9E3779B97F4A7C15)
            + np.uint64(step) * np.uint64(0xBF58476D1CE4E5B9)
            + r * np.uint64(0x94D049BB133111EB)
            + c
        )
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
        return (x % np.uint64(vocab)).astype(np.int32)


@dataclass
class SyntheticLMData:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch for `step` (host-sliced rows)."""
        assert self.global_batch % self.num_hosts == 0
        rows = self.global_batch // self.num_hosts
        off = self.host_index * rows
        cfg = self.cfg
        out: Dict[str, np.ndarray] = {}
        if cfg.input_mode == "embeddings":
            tok = _hash_tokens(self.seed, step, rows, self.seq_len + 1, 1 << 16, off)
            emb = (tok[:, :-1, None] % 997).astype(np.float32) / 997.0
            out["embeddings"] = np.broadcast_to(
                emb, (rows, self.seq_len, cfg.d_model)
            ).astype(np.float32)
            out["labels"] = tok[:, 1:] % cfg.vocab_size
        elif cfg.input_mode == "tokens+image":
            n_img = cfg.num_image_tokens
            tok = _hash_tokens(self.seed, step, rows, self.seq_len + 1, cfg.vocab_size, off)
            out["tokens"] = tok[:, : self.seq_len - n_img]
            img = _hash_tokens(self.seed + 1, step, rows, n_img, 1 << 16, off)
            out["image_embeds"] = np.repeat(
                (img[..., None] % 499).astype(np.float32) / 499.0, cfg.d_model, -1
            )
            labels = tok[:, 1:]
            labels = np.concatenate(
                [np.full((rows, n_img), -1, np.int32),
                 labels[:, : self.seq_len - n_img]], axis=1,
            )
            out["labels"] = labels
        else:
            tok = _hash_tokens(self.seed, step, rows, self.seq_len + 1,
                               cfg.vocab_size, off)
            out["tokens"] = tok[:, :-1]
            out["labels"] = tok[:, 1:]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

"""APINT-on-JAX: privacy-preserving transformer inference framework.

Two planes:
  * privacy plane (``repro.core``, ``repro.sched``, ``repro.accel``,
    ``repro.kernels``): the APINT paper's contribution — garbled-circuit
    protocol engine, GC-friendly circuit generation, netlist scheduling and
    the accelerator model.
  * model plane (``repro.models``, ``repro.train``, ``repro.serve``,
    ``repro.launch``): the transformer substrate — 10 assigned architectures,
    pjit/shard_map distribution, training & serving at pod scale.
"""

__version__ = "1.0.0"

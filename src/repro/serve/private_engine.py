"""Private serving: sequence-length buckets + preprocessed-bundle pools.

The plaintext ``ServeEngine`` batches token requests against jitted
prefill/decode; this is its privacy-plane sibling. Each sequence length
gets its own compiled :class:`~repro.core.session.PiTSession` (shapes and
scales are resolved per bucket at compile time), and each bucket owns a
pool of single-use :class:`~repro.core.session.PreprocessedBundle`\\ s.

The pool is refillable in the background (``refill_async``) so the
offline phase — the dominant cost — overlaps idle time between request
waves; ``serve`` then only pays the online phase per request. When a
bucket's pool runs dry the engine either preprocesses on demand
(``auto_refill=True``) or raises :class:`BundlePoolEmpty` so the caller
can shed load — the production behaviour for latency-SLO serving.

Locking is per bucket: each sequence length owns an independent
session (its own protocol, RNG and stats), so refill and serving of one
bucket never stall another. Within a bucket, offline refill and online
runs still serialize — the in-process protocol shares one RNG/stats
object, and correctness beats concurrency there.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.core.session import PiTSession, PreprocessedBundle, compile
from repro.serve.errors import BundlePoolEmpty


@dataclass
class PrivateRequest:
    x: np.ndarray  # (S, d) client-private embeddings
    result: Optional[np.ndarray] = None


class PrivateServeEngine:
    def __init__(self, model, *, buckets: Sequence[int] = (),
                 pool_target: int = 2, auto_refill: bool = False,
                 num_cores: int = 16, impl: Optional[str] = None):
        """``model``: a ``PrivateTransformer`` (server-owned weights).

        ``buckets`` pre-compiles sessions for those sequence lengths;
        other lengths compile lazily on first sight. ``pool_target`` is
        the per-bucket bundle level ``maintain`` refills to. ``impl``
        defaults to ``"auto"``: every bucket's garble/evaluate runs on
        the device-resident GC executor, never the per-level numpy walk —
        and bundle refills garble through the executor's throughput
        regime (packed tables + compacted store), which is what keeps
        ``refill_async`` faster than the serve path drains the pool.
        """
        self.model = model
        self.pool_target = pool_target
        self.auto_refill = auto_refill
        self.num_cores = num_cores
        self.impl = impl
        self._sessions: Dict[int, PiTSession] = {}
        self._pools: Dict[int, Deque[PreprocessedBundle]] = {}
        self._locks: Dict[int, threading.Lock] = {}
        self._meta = threading.Lock()  # guards bucket creation + hints
        # refill-queue depth (bundles scheduled, not yet pooled) and the
        # observed per-bundle preprocessing time, per bucket — the raw
        # material for the retry-after hint a shed carries
        self._refill_pending: Dict[int, int] = {}
        self._prep_ewma_s: Dict[int, float] = {}
        for S in buckets:
            self.session(S)

    # ------------------------------------------------------------------
    # buckets & pools
    # ------------------------------------------------------------------
    def session(self, seq_len: int) -> PiTSession:
        with self._meta:
            if seq_len not in self._sessions:
                self._sessions[seq_len] = compile(
                    self.model, shape=(seq_len, self.model.d), seed=seq_len,
                    impl=self.impl)
                self._pools[seq_len] = deque()
                self._locks[seq_len] = threading.Lock()
            return self._sessions[seq_len]

    def _bucket_lock(self, seq_len: int) -> threading.Lock:
        self.session(seq_len)
        return self._locks[seq_len]

    def pool_size(self, seq_len: int) -> int:
        with self._meta:
            return len(self._pools.get(seq_len, ()))

    def _note_refill(self, seq_len: int, count: int) -> None:
        with self._meta:
            self._refill_pending[seq_len] = (
                self._refill_pending.get(seq_len, 0) + count)

    def _note_prepped(self, seq_len: int, count: int, elapsed_s: float
                      ) -> None:
        with self._meta:
            self._refill_pending[seq_len] = (
                self._refill_pending.get(seq_len, 0) - count)
            if count > 0 and elapsed_s > 0:
                per = elapsed_s / count
                prev = self._prep_ewma_s.get(seq_len)
                self._prep_ewma_s[seq_len] = (
                    per if prev is None else 0.7 * prev + 0.3 * per)

    def retry_after_hint(self, seq_len: int) -> Optional[float]:
        """When is a dry bucket expected to have a bundle again? Refill
        queue depth times observed per-bundle preprocessing time — None
        until either has been observed (no data, no guess)."""
        with self._meta:
            depth = self._refill_pending.get(seq_len, 0)
            per = self._prep_ewma_s.get(seq_len)
        if per is None:
            return None
        return round(max(depth, 1) * per, 3)

    def preprocess(self, seq_len: int, count: int) -> int:
        """Synchronously add ``count`` bundles to the bucket's pool."""
        sess = self.session(seq_len)
        self._note_refill(seq_len, count)
        elapsed = 0.0
        try:
            with self._bucket_lock(seq_len):
                # span-backed timing: the EWMA reads the span's duration
                # (one timing path with the tracer instead of a
                # hand-rolled perf_counter delta)
                with obs.timer("engine.prep", bucket=seq_len,
                               bundles=count) as sp:
                    bundles = sess.preprocess(count)
                elapsed = sp.elapsed_s
                self._pools[seq_len].extend(bundles)
                return len(self._pools[seq_len])
        finally:
            self._note_prepped(seq_len, count, elapsed)

    def maintain(self, seq_len: int) -> int:
        """Top the bucket's pool back up to ``pool_target``.

        Deficit is computed under the bucket lock so concurrent refills
        don't both see it and overshoot the target.
        """
        sess = self.session(seq_len)
        with self._bucket_lock(seq_len):
            deficit = self.pool_target - len(self._pools[seq_len])
            if deficit > 0:
                self._note_refill(seq_len, deficit)
                sp = obs.timer("engine.prep", bucket=seq_len,
                               bundles=deficit)
                try:
                    self._pools[seq_len].extend(sess.preprocess(deficit))
                finally:
                    self._note_prepped(seq_len, deficit,
                                       sp.close().elapsed_s)
            return len(self._pools[seq_len])

    def refill_async(self, seq_len: int, count: Optional[int] = None
                     ) -> threading.Thread:
        """Refill the bucket's pool on a background thread."""
        def work():
            if count is None:
                self.maintain(seq_len)
            else:
                self.preprocess(seq_len, count)

        th = threading.Thread(target=work, daemon=True,
                              name=f"pit-refill-S{seq_len}")
        th.start()
        return th

    def _take_bundle(self, seq_len: int) -> PreprocessedBundle:
        """Pop one bundle; caller must hold the bucket lock."""
        pool = self._pools[seq_len]
        if pool:
            return pool.popleft()
        if self.auto_refill:
            return self._sessions[seq_len].preprocess(1)[0]
        raise BundlePoolEmpty(
            f"no preprocessed bundle for bucket S={seq_len} "
            f"(pool empty; call preprocess/refill_async or enable "
            f"auto_refill)",
            retry_after_s=self.retry_after_hint(seq_len))

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def serve(self, requests: List[PrivateRequest]) -> List[PrivateRequest]:
        """Serve a wave of requests, bucketed by sequence length.

        Requests of the same length form one batch against one session;
        each request consumes one pooled bundle (online phase only).
        """
        by_len: Dict[int, List[PrivateRequest]] = {}
        for r in requests:
            by_len.setdefault(int(np.asarray(r.x).shape[0]), []).append(r)
        for S, batch in by_len.items():
            sess = self.session(S)
            with self._bucket_lock(S):
                for r in batch:
                    bundle = self._take_bundle(S)
                    try:
                        r.result = sess.run(r.x, bundle)
                    except Exception:
                        if not bundle.consumed:
                            # e.g. bad request shape: the (expensive)
                            # bundle is still fresh — return it to the pool
                            self._pools[S].appendleft(bundle)
                        raise
        return requests

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def stats(self, seq_len: int):
        return self.session(seq_len).stats

    def schedule_info(self, seq_len: int) -> List[List[str]]:
        """Coarse-grained GC-op → accelerator-core assignment (§3.3.1)."""
        return self.session(seq_len).plan.coarse_schedule(self.num_cores)


# ---------------------------------------------------------------------------
# pipelined two-party serving (client side)
# ---------------------------------------------------------------------------


class NetPrivateServeEngine:
    """Client-side serving engine over the two-party runtime, pipelined.

    The in-process :class:`PrivateServeEngine` serializes refill and
    serving within a bucket (one protocol object). This engine instead
    gives the offline phase a **dedicated endpoint pair**: one
    :class:`~repro.net.party.GarblerEndpoint` per transport, both backed
    by one :class:`~repro.net.party.ClientShared` bundle pool. The server
    side mirrors it with two ``EvaluatorEndpoint`` threads over one
    ``ServerShared`` store (see :class:`~repro.net.party.PitNetServer`).

    ``refill_async`` therefore streams garbled tables / HE frames /
    triples on the offline pair while ``serve`` keeps answering requests
    on the online pair — the ROADMAP PR-2 follow-up ("overlap bundle
    refill with online serving by giving each phase its own protocol
    endpoint").
    """

    def __init__(self, offline_transport, online_transport, *,
                 pool_target: int = 2, seed: int = 0, impl: str = "ref",
                 timeout: Optional[float] = None):
        from repro.net.party import ClientShared, GarblerEndpoint

        self.pool_target = pool_target
        self._shared = ClientShared(seed=seed, impl=impl)
        self.offline = GarblerEndpoint(offline_transport, shared=self._shared,
                                       timeout=timeout)
        self.online = GarblerEndpoint(online_transport, shared=self._shared,
                                      timeout=timeout)
        self.offline.handshake()
        self.online.handshake()
        self._refill_lock = threading.Lock()  # deficit computation
        self._hint_lock = threading.Lock()
        self._refill_pending = 0  # bundles scheduled, not yet pooled
        self._prep_ewma_s: Optional[float] = None

    @property
    def plan(self):
        return self._shared.plan

    @property
    def ledger(self):
        return self._shared.ledger

    def pool_size(self) -> int:
        return self._shared.pool_size()

    # -- offline pair --------------------------------------------------
    def _note_refill(self, count: int) -> None:
        with self._hint_lock:
            self._refill_pending += count

    def _note_prepped(self, count: int, elapsed_s: float) -> None:
        with self._hint_lock:
            self._refill_pending -= count
            if count > 0 and elapsed_s > 0:
                per = elapsed_s / count
                self._prep_ewma_s = (per if self._prep_ewma_s is None
                                     else 0.7 * self._prep_ewma_s
                                     + 0.3 * per)

    def retry_after_hint(self) -> Optional[float]:
        """Refill queue depth × observed per-bundle preprocessing time
        (wire round trips included); None before the first refill."""
        with self._hint_lock:
            if self._prep_ewma_s is None:
                return None
            return round(max(self._refill_pending, 1) * self._prep_ewma_s, 3)

    def _preprocess_timed(self, count: int) -> None:
        # span-backed: the prep EWMA reads the span's duration
        sp = obs.timer("engine.prep", bundles=count)
        elapsed = 0.0
        try:
            self.offline.preprocess(count)
            elapsed = sp.close().elapsed_s
        finally:
            sp.close()
            self._note_prepped(count, elapsed)

    def preprocess(self, count: int) -> int:
        self._note_refill(count)
        with self._refill_lock:
            self._preprocess_timed(count)
        return self.pool_size()

    def maintain(self) -> int:
        """Top the pool back up to ``pool_target``.

        The deficit is computed under the same lock every engine-driven
        ``preprocess`` holds, so a maintain racing an explicit-count
        refill cannot both see the low watermark and overshoot the
        target (mirrors the in-process engine's bucket-lock rule)."""
        with self._refill_lock:
            deficit = self.pool_target - self.pool_size()
            if deficit > 0:
                self._note_refill(deficit)
                self._preprocess_timed(deficit)
            return self.pool_size()

    def refill_async(self, count: Optional[int] = None) -> threading.Thread:
        """Refill on a background thread over the *offline* endpoint —
        online ``serve`` traffic keeps flowing on its own pair."""
        def work():
            if count is None:
                self.maintain()
            else:
                self.preprocess(count)

        th = threading.Thread(target=work, daemon=True, name="pit-net-refill")
        th.start()
        return th

    # -- online pair ---------------------------------------------------
    def serve(self, requests: List[PrivateRequest]) -> List[PrivateRequest]:
        for r in requests:
            bid = self._shared.take_bundle_id()
            if bid is None:
                raise BundlePoolEmpty(
                    "no preprocessed bundle in the net pool (call "
                    "preprocess/refill_async)",
                    retry_after_s=self.retry_after_hint())
            try:
                r.result = self.online.run(r.x, bundle_id=bid)
            except Exception:
                with self._shared.lock:
                    if bid in self._shared.bundles:
                        # e.g. bad request shape: rejected before any
                        # wire traffic — the (expensive) bundle is still
                        # fresh on both parties, return it to the pool
                        self._shared.order.appendleft(bid)
                raise
        return requests

    def run(self, x: np.ndarray) -> np.ndarray:
        return self.serve([PrivateRequest(x=x)])[0].result

    def close(self) -> None:
        self.offline.close()
        self.online.close()

from repro.serve.engine import ServeEngine
from repro.serve.private_engine import (
    BundlePoolEmpty,
    NetPrivateServeEngine,
    PrivateRequest,
    PrivateServeEngine,
)

__all__ = [
    "ServeEngine",
    "PrivateServeEngine",
    "NetPrivateServeEngine",
    "PrivateRequest",
    "BundlePoolEmpty",
]

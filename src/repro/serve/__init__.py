from repro.serve.engine import ServeEngine
from repro.serve.errors import BundlePoolEmpty
from repro.serve.private_engine import (
    NetPrivateServeEngine,
    PrivateRequest,
    PrivateServeEngine,
)

__all__ = [
    "ServeEngine",
    "PrivateServeEngine",
    "NetPrivateServeEngine",
    "PrivateRequest",
    "BundlePoolEmpty",
    "PitGateway",
    "gateway_client",
]

_GATEWAY_EXPORTS = ("PitGateway", "gateway_client")


def __getattr__(name):
    # the gateway sits on top of repro.net.party, which itself imports
    # repro.serve.errors — importing it eagerly here would close that
    # loop into a cycle, so it loads on first attribute access instead
    if name in _GATEWAY_EXPORTS:
        from repro.serve import gateway
        return getattr(gateway, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

from repro.serve.engine import ServeEngine
from repro.serve.private_engine import (
    BundlePoolEmpty,
    PrivateRequest,
    PrivateServeEngine,
)

__all__ = [
    "ServeEngine",
    "PrivateServeEngine",
    "PrivateRequest",
    "BundlePoolEmpty",
]

"""Serving-plane exceptions shared by the in-process engines and the
two-party runtime.

They live in their own dependency-free module so :mod:`repro.net.party`
(which must *raise* the load-shed signal when a gateway sheds over the
wire) can import it without creating an import cycle with
:mod:`repro.serve` (whose ``__init__`` imports the gateway, which
imports the endpoints).
"""

from __future__ import annotations

from typing import Optional


class BundlePoolEmpty(RuntimeError):
    """Load-shed signal: no preprocessed bundle (or no capacity) for the
    request's bucket.

    ``retry_after_s`` is the shedder's hint for when capacity is expected
    back — computed from the refill queue depth and the observed
    per-bundle preprocessing time, never a bare guess. ``scope`` says
    what was exhausted: ``"pool"`` (no bundle for a run), ``"prep"``
    (a bounded bundle pool refused more offline work) or ``"session"``
    (a gateway at its session cap refused the connection).
    """

    def __init__(self, message: str, *,
                 retry_after_s: Optional[float] = None,
                 scope: str = "pool"):
        super().__init__(message)
        self.retry_after_s = retry_after_s
        self.scope = scope

"""Batched serving: prefill + decode with KV caches.

The engine compiles one prefill function (fixed prompt length buckets) and
one decode function (batch-static), serving request batches greedily. On
the production mesh the same functions lower with the decode sharding
rules (launch/steps.build_*); here they also run eagerly on CPU for tests
and the quickstart example.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.transformer import forward, init_caches


@dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, capacity: int = 256,
                 batch: int = 4):
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.batch = batch

        def prefill(params, batch_in):
            return forward(cfg, params, batch_in, mode="prefill")

        def decode(params, batch_in, caches):
            return forward(cfg, params, batch_in, mode="decode", caches=caches)

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(2,))

    def _grow_caches(self, caches, target: int):
        """Copy prefill caches into capacity-sized buffers."""
        def grow(x):
            if x.ndim >= 3 and x.shape[2] == int(caches["len"]):
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, self.capacity - x.shape[2])
                return jnp.pad(x, pad)
            return x

        layers = caches["layers"]
        if self.cfg.uses_attention:
            layers = dict(layers)
            layers["attn"] = {
                k: jnp.pad(
                    v, [(0, 0), (0, 0), (0, self.capacity - v.shape[2]),
                        (0, 0), (0, 0)]
                )
                for k, v in layers["attn"].items()
            }
        return {"layers": layers, "len": caches["len"]}

    def generate(self, requests: List[Request], greedy: bool = True
                 ) -> List[Request]:
        """Serve a batch of same-length-prompt requests."""
        assert len(requests) <= self.batch
        reqs = list(requests)
        S = len(reqs[0].prompt)
        assert all(len(r.prompt) == S for r in reqs), "bucket by length"
        B = len(reqs)
        toks = np.stack([r.prompt for r in reqs]).astype(np.int32)
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        caches = self._grow_caches(caches, self.capacity)
        out = [[] for _ in reqs]
        cur = np.asarray(jnp.argmax(logits[:, : self.cfg.vocab_size], -1))
        for i in range(B):
            out[i].append(int(cur[i]))
        steps = max(r.max_new_tokens for r in reqs) - 1
        for _ in range(max(steps, 0)):
            batch_in = {"tokens": jnp.asarray(cur[:, None].astype(np.int32))}
            logits, caches = self._decode(self.params, batch_in, caches)
            cur = np.asarray(jnp.argmax(logits[:, : self.cfg.vocab_size], -1))
            for i in range(B):
                if len(out[i]) < reqs[i].max_new_tokens:
                    out[i].append(int(cur[i]))
        for r, o in zip(reqs, out):
            r.out_tokens = o
        return reqs

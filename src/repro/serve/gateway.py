"""Multi-client front door for the two-party serving runtime.

:class:`PitGateway` hosts one model behind a single
:class:`~repro.net.transport.TcpListener` accept loop and muxes N
concurrent client *sessions* over it. The session — not the transport —
is the unit of isolation: every admitted client token gets its own
:class:`~repro.net.party.SessionState` (a private bundle-id namespace, a
per-session :class:`~repro.net.party.WireLedger`, rate/byte accounting),
and both transports of a pipelined endpoint pair bind to the same
session because their hellos carry the same client token.

What is shared, deliberately, is the expensive part: all sessions run
over ONE :class:`~repro.net.party.ServerShared` — one compiled plan, one
protocol instance whose netlist cache is the shared garbling cache
(observable via :class:`~repro.core.session.GarblingCache`: exactly one
slab per distinct ``(netlist, instances, impl)``, however many clients
are connected), one quantized-weight store, and one preprocessing refill
pool discipline.

Admission control (the serving-plane contract):

* **session cap** — at ``max_sessions`` live sessions, a new client's
  hello is answered with a typed CONTROL ``shed`` frame carrying a
  ``retry_after_s`` hint and the connection is closed. The client sees
  :class:`~repro.serve.errors.BundlePoolEmpty` (``scope="session"``),
  never an exception string off the wire.
* **bounded bundle pools** — each session may hold at most ``pool_cap``
  outstanding bundles. A ``prep`` that would exceed it is shed the same
  way (``scope="prep"``) *before* the client garbles anything; the hint
  is computed from the gateway-wide refill queue depth times the
  observed per-bundle preprocessing time.
* **graceful teardown** — when a session's last transport drops (clean
  bye or a mid-exchange kill), its in-flight bundles are counted as
  returned and reclaimed, and the session slot frees for the next
  client. Other sessions never notice.

Resilience (the fault-tolerance contract, PR 10):

* **lease/resume** — with ``lease_s > 0``, a session whose last
  transport drops *without* a clean bye is **parked** for the lease
  window instead of reclaimed: its bundle store, ledger, and sid
  survive, and a re-hello carrying the same client token rebinds fresh
  transports to it (``epoch`` increments, the hello's ``reset_ot``
  redoes the base OT). A clean bye still reclaims immediately. Expired
  leases are garbage-collected on the next admission or stats poll and
  their bundles counted as returned.
* **burn-on-interrupt** — a run that dies mid-op burns its bundle
  (``bundles_burned``): partial label disclosure makes re-running it
  unsafe. The metrics identity under every fault is
  ``prepped == consumed + outstanding + returned + burned``.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

from repro import obs
from repro.net.party import (
    EvaluatorEndpoint,
    ServerShared,
    SessionState,
)
from repro.net.transport import AcceptLoop, Deadlines, TcpListener, \
    Transport, TransportClosed


class _SessionShed(TransportClosed):
    """Internal: hello refused at the session cap. Subclasses
    TransportClosed so the serve loop unwinds as a clean disconnect —
    the shed frame is already on the wire."""


class _GatewayEndpoint(EvaluatorEndpoint):
    """One accepted transport. Starts on a provisional session (so
    pre-hello frame bytes are metered somewhere), then binds to the
    session the hello's client token resolves to."""

    def __init__(self, transport: Transport, gateway: "PitGateway", *,
                 timeout: Optional[float] = None,
                 deadlines: Optional["Deadlines"] = None):
        super().__init__(transport, shared=gateway.shared, timeout=timeout,
                         deadlines=deadlines,
                         session=SessionState(sid=-1, client="pre-hello"))
        self.gateway = gateway
        self._bound = False

    # -- session resolution -------------------------------------------
    def _on_hello(self, payload) -> dict:
        token = payload.get("client")
        gen = int(payload.get("gen", 0) or 0)
        sess, hint = self.gateway._admit_session(token, gen=gen)
        if sess is None:
            self._send_control("shed", {"retry_after_s": hint,
                                        "scope": "session"})
            raise _SessionShed("session cap reached, connection shed")
        # fold the provisional (pre-hello) metering into the real ledger,
        # then rebind this endpoint onto the session's state
        sess.ledger.absorb(self.ledger)
        self.session = sess
        self.ledger = sess.ledger
        self._bound = True
        return {"session": sess.sid, "epoch": sess.epoch}

    def _admit_prep(self, n: int) -> Optional[float]:
        return self.gateway._admit_prep(self.session, n)

    def _handle_prep(self, payload) -> None:
        sess = self.session
        before = sess.bundles_prepped
        n = int(payload["n"])
        self.gateway._prep_begin(n)
        # span-backed timing: the gateway's prep EWMA reads the span's
        # duration instead of a hand-rolled perf_counter delta
        sp = obs.timer("gateway.prep", sid=sess.sid, bundles=n)
        try:
            super()._handle_prep(payload)
        finally:
            prepped = sess.bundles_prepped > before
            self.gateway._prep_end(n, sp.close().elapsed_s,
                                   counted=prepped)

    def _on_disconnect(self) -> None:
        if self._bound:
            self.gateway._release_endpoint(
                self.session, reason=self.disconnect_reason or "closed")


class PitGateway:
    """Serve one model to many clients from one accept loop.

    ``max_sessions`` bounds concurrently-live client sessions;
    ``pool_cap`` bounds outstanding preprocessed bundles per session
    (admission happens before the client garbles, so a shed wastes no
    offline work on either side). ``retry_floor_s`` is the minimum
    retry-after hint when no preprocessing time has been observed yet.
    """

    def __init__(self, model, seq_len: int, *, impl: str = "ref",
                 seed: int = 104729, max_sessions: int = 8,
                 pool_cap: int = 4, retry_floor_s: float = 0.05,
                 lease_s: float = 0.0,
                 shared: Optional[ServerShared] = None,
                 wire_version: Optional[int] = None,
                 compression: Optional[bool] = None):
        if shared is None:
            kw = {}
            if wire_version is not None:
                kw["wire_version"] = wire_version
            if compression is not None:
                kw["compression"] = compression
            shared = ServerShared(model, seq_len, impl=impl, seed=seed,
                                  **kw)
        self.shared = shared
        self.max_sessions = max_sessions
        self.pool_cap = pool_cap
        self.retry_floor_s = retry_floor_s
        #: resume window: a session whose last transport dropped without
        #: a clean bye keeps its state for this long, waiting for a
        #: re-hello with the same token. 0 = legacy behavior (reclaim
        #: immediately — a dropped client's bundles return at once).
        self.lease_s = lease_s
        self._lock = threading.Lock()
        self._sessions: Dict[str, SessionState] = {}  # token -> live
        self._closed: List[Dict[str, object]] = []  # summaries, torn down
        self._next_sid = 1
        self.sessions_admitted = 0
        self.sessions_shed = 0
        self.sessions_resumed = 0
        self.leases_expired = 0
        self.bundles_returned = 0
        # refill-queue instrumentation for retry-after hints
        self._prep_inflight = 0  # bundles in flight across all sessions
        self._prep_ewma_s: Optional[float] = None  # seconds per bundle
        self.endpoints: List[_GatewayEndpoint] = []
        self.threads: List[threading.Thread] = []
        self._loops: List[AcceptLoop] = []
        self._started_s = time.perf_counter()

    # ------------------------------------------------------------------
    # admission control
    # ------------------------------------------------------------------
    def _admit_session(self, token: Optional[str], *, gen: int = 0
                       ) -> Tuple[Optional[SessionState], Optional[float]]:
        """Resolve a hello's client token to a session, minting one if
        needed. Returns ``(session, None)`` on admit, ``(None, hint)``
        when the session cap sheds the connection."""
        with self._lock:
            self._gc_leases_locked()
            if token and token in self._sessions:
                # second endpoint of a pair, or a resume. A resume is a
                # re-hello on a parked session (zero live endpoints) OR
                # one carrying a new client transport generation — the
                # ``gen`` check is what makes resume accounting
                # deterministic when the fresh hellos race the dead
                # pair's teardown. Either way the session state
                # survives: new epoch, IKNP dropped (the dead pair's
                # extension counters are untrustworthy; the reset costs
                # one base OT).
                sess = self._sessions[token]
                if sess.endpoints == 0 or gen > sess.gen:
                    with sess.lock:
                        sess.epoch += 1
                        sess.resumes += 1
                        sess.gen = max(sess.gen, gen)
                        sess.lease_expires_s = None
                        sess.iknp = None
                    self.sessions_resumed += 1
                    obs.instant("gateway.session_resume", sid=sess.sid,
                                epoch=sess.epoch)
                sess.endpoints += 1
                return sess, None
            if len(self._sessions) >= self.max_sessions:
                self.sessions_shed += 1
                return None, self._retry_hint_locked(self.pool_cap)
            sid = self._next_sid
            self._next_sid += 1
            # a token-less hello (bare GarblerEndpoint predating the
            # gateway) still gets a session — keyed so it cannot collide
            token = token or f"anon-{sid}"
            sess = SessionState(sid=sid, client=token)
            sess.endpoints = 1
            self._sessions[token] = sess
            self.sessions_admitted += 1
            return sess, None

    def _admit_prep(self, sess: SessionState, n: int) -> Optional[float]:
        """Bounded per-session pool: admit ``n`` more bundles or return a
        retry-after hint."""
        with self._lock:
            if sess.outstanding() + n <= self.pool_cap:
                return None
            # _prep_begin already counted this request into the refill
            # queue depth, so the hint covers it without adding n again
            return self._retry_hint_locked(0)

    def _retry_hint_locked(self, n: int) -> float:
        """Retry-after = (refill queue depth + the refused request) times
        the observed per-bundle preprocessing time — an actual backlog
        estimate, not a constant."""
        per = self._prep_ewma_s or self.retry_floor_s
        return round(max(self.retry_floor_s,
                         (self._prep_inflight + n) * per), 3)

    # -- refill-queue instrumentation ----------------------------------
    def _prep_begin(self, n: int) -> None:
        with self._lock:
            self._prep_inflight += n

    def _prep_end(self, n: int, elapsed_s: float, *, counted: bool) -> None:
        with self._lock:
            self._prep_inflight -= n
            if counted and n > 0:
                per = elapsed_s / n
                self._prep_ewma_s = (per if self._prep_ewma_s is None
                                     else 0.7 * self._prep_ewma_s
                                     + 0.3 * per)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def serve_transport(self, transport: Transport, *,
                        timeout: Optional[float] = None,
                        deadlines: Optional[Deadlines] = None
                        ) -> threading.Thread:
        """Serve one accepted transport on its own thread (session
        resolution happens at its hello)."""
        ep = _GatewayEndpoint(transport, self, timeout=timeout,
                              deadlines=deadlines)
        self.endpoints.append(ep)
        th = threading.Thread(target=self._serve_one, args=(ep,),
                              daemon=True,
                              name=f"pit-gw-ep-{len(self.threads)}")
        th.start()
        self.threads.append(th)
        return th

    @staticmethod
    def _serve_one(ep: _GatewayEndpoint) -> None:
        try:
            ep.serve_forever()
        finally:
            # unlike the single-client server, the gateway owns the
            # accepted socket's lifetime: done (bye, kill or shed) means
            # closed, so shed clients fail fast instead of waiting out
            # their recv timeout
            try:
                ep.transport.close()
            except OSError:
                pass

    def serve_listener(self, listener: TcpListener, *,
                       accept_timeout: float = 1.0,
                       timeout: Optional[float] = None,
                       deadlines: Optional[Deadlines] = None, **shaping
                       ) -> AcceptLoop:
        """The front door: ONE accept loop on ``listener``; every
        accepted connection becomes a gateway endpoint."""
        loop = listener.accept_loop(
            lambda t: self.serve_transport(t, timeout=timeout,
                                           deadlines=deadlines),
            accept_timeout=accept_timeout, name="pit-gateway-accept",
            **shaping)
        self._loops.append(loop)
        return loop

    # ------------------------------------------------------------------
    # teardown & introspection
    # ------------------------------------------------------------------
    def _release_endpoint(self, sess: SessionState, *,
                          reason: str = "closed") -> None:
        """An endpoint bound to ``sess`` disconnected. When the last one
        drops: a clean ``bye`` (or a lease-less gateway) reclaims the
        session — outstanding bundles are returned (the client is gone;
        its ids can never be run) and the slot frees. With ``lease_s``
        set, an *unclean* drop (kill, timeout, error) parks the session
        instead: the state survives for the lease window so a
        reconnecting client can resume it."""
        with self._lock:
            sess.endpoints -= 1
            if sess.endpoints > 0:
                return
            if self.lease_s > 0 and reason != "bye":
                with sess.lock:
                    sess.lease_expires_s = time.monotonic() + self.lease_s
                obs.instant("gateway.session_park", sid=sess.sid,
                            reason=reason, lease_s=self.lease_s)
                return
            self._reclaim_locked(sess)

    def _reclaim_locked(self, sess: SessionState) -> None:
        """Tear a session down for good (caller holds the gateway lock):
        unconsumed bundles are returned, the summary is archived, and
        the token slot frees. Burned bundles stay burned — they were
        never reusable."""
        with sess.lock:
            returned = len(sess.bundles)
            sess.bundles.clear()
            sess.bundles_returned += returned
            sess.lease_expires_s = None
        self.bundles_returned += returned
        self._sessions.pop(sess.client, None)
        self._closed.append(sess.summary())

    def _gc_leases_locked(self) -> None:
        """Reclaim parked sessions whose lease expired (caller holds the
        gateway lock). Runs on every admission and stats poll, so an
        expired lease is observed without waiting for wire traffic."""
        if self.lease_s <= 0:
            return
        now = time.monotonic()
        expired = [s for s in self._sessions.values()
                   if s.endpoints == 0 and s.lease_expires_s is not None
                   and s.lease_expires_s <= now]
        for sess in expired:
            self.leases_expired += 1
            obs.instant("gateway.lease_expire", sid=sess.sid)
            self._reclaim_locked(sess)

    def stats(self) -> Dict[str, object]:
        """Gateway-wide accounting: admission counters, the shared
        garbling cache, and per-session summaries (live + torn down).

        The whole snapshot is taken under the gateway lock — admission
        counters (``sessions_admitted``/``sessions_shed``/
        ``bundles_returned``) are mutated by endpoint threads under the
        same lock, so a reader polling while sessions churn always sees
        a consistent set (hammer-tested in ``tests/test_gateway.py``).
        Per-session summaries snapshot under each session's own lock and
        ledger mutex inside it.
        """
        with self._lock:
            self._gc_leases_locked()
            active = sum(1 for s in self._sessions.values()
                         if s.endpoints > 0)
            parked = len(self._sessions) - active
            live = [s.summary() for s in self._sessions.values()]
            closed = list(self._closed)
            inflight = self._prep_inflight
            ewma = self._prep_ewma_s
            admitted = self.sessions_admitted
            sess_shed = self.sessions_shed
            resumed = self.sessions_resumed
            expired = self.leases_expired
            returned = self.bundles_returned
        sessions = closed + live
        dt = max(time.perf_counter() - self._started_s, 1e-9)
        consumed = sum(s["bundles_consumed"] for s in sessions)
        return {
            "sessions_active": active,
            "sessions_parked": parked,
            "sessions_admitted": admitted,
            "sessions_shed": sess_shed,
            "sessions_resumed": resumed,
            "leases_expired": expired,
            "prep_sheds": sum(s["sheds"] for s in sessions),
            "bundles_prepped": sum(s["bundles_prepped"] for s in sessions),
            "bundles_consumed": consumed,
            "bundles_returned": returned,
            "bundles_burned": sum(s["bundles_burned"] for s in sessions),
            "bundles_outstanding": sum(s["bundles_outstanding"]
                                       for s in sessions),
            "prep_inflight": inflight,
            "prep_ewma_s": None if ewma is None else round(ewma, 4),
            "elapsed_s": round(dt, 3),
            "bundles_per_s": round(consumed / dt, 3),
            "garbling_cache": self.shared.gc_cache.summary(),
            "sessions": sessions,
        }

    def metrics(self) -> Dict[str, object]:
        """Scrape-able counters snapshot in a stable schema.

        ``counters`` are monotonic over the gateway's lifetime (totals
        include torn-down sessions); ``gauges`` are instantaneous;
        ``spans`` are the current tracer's per-span-path aggregates
        (count/total/mean/max seconds — empty when tracing is off). The
        top-level key set is the scrape contract: keys are only ever
        added, never renamed or removed within ``pit.gateway.v1``.
        """
        st = self.stats()
        tr = obs.current()
        return {
            "schema": "pit.gateway.v1",
            "counters": {
                "sessions_admitted": st["sessions_admitted"],
                "sessions_shed": st["sessions_shed"],
                "sessions_resumed": st["sessions_resumed"],
                "leases_expired": st["leases_expired"],
                "prep_sheds": st["prep_sheds"],
                "bundles_prepped": st["bundles_prepped"],
                "bundles_consumed": st["bundles_consumed"],
                "bundles_returned": st["bundles_returned"],
                "bundles_burned": st["bundles_burned"],
                "garbling_cache_hits": st["garbling_cache"]["hits"],
                "garbling_cache_misses": st["garbling_cache"]["misses"],
            },
            "gauges": {
                "sessions_active": st["sessions_active"],
                "sessions_parked": st["sessions_parked"],
                "bundles_outstanding": st["bundles_outstanding"],
                "prep_inflight": st["prep_inflight"],
                "prep_ewma_s": st["prep_ewma_s"],
                "bundles_per_s": st["bundles_per_s"],
                "elapsed_s": st["elapsed_s"],
            },
            "spans": tr.report(),
        }

    def join(self, timeout: Optional[float] = None) -> None:
        for th in self.threads:
            th.join(timeout=timeout)

    def close(self) -> None:
        for loop in self._loops:
            loop.stop()
        for ep in self.endpoints:
            try:
                ep.transport.close()
            except OSError:
                pass


def gateway_client(host: str, port: int, *, pool_target: int = 2,
                   seed: int = 0, impl: str = "ref",
                   timeout: Optional[float] = None, **shaping):
    """Connect a pipelined client (offline + online transport pair) to a
    gateway and return a ready :class:`NetPrivateServeEngine`. Both
    transports carry the same client token, so the gateway binds them to
    one session. Raises :class:`~repro.serve.errors.BundlePoolEmpty`
    (``scope="session"``) if the gateway sheds the connection."""
    from repro.net.transport import TcpTransport
    from repro.serve.private_engine import NetPrivateServeEngine

    offline = TcpTransport.connect(host, port, **shaping)
    online = TcpTransport.connect(host, port, **shaping)
    return NetPrivateServeEngine(offline, online, pool_target=pool_target,
                                 seed=seed, impl=impl, timeout=timeout)

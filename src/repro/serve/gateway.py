"""Multi-client front door for the two-party serving runtime.

:class:`PitGateway` hosts one model behind a single
:class:`~repro.net.transport.TcpListener` accept loop and muxes N
concurrent client *sessions* over it. The session — not the transport —
is the unit of isolation: every admitted client token gets its own
:class:`~repro.net.party.SessionState` (a private bundle-id namespace, a
per-session :class:`~repro.net.party.WireLedger`, rate/byte accounting),
and both transports of a pipelined endpoint pair bind to the same
session because their hellos carry the same client token.

What is shared, deliberately, is the expensive part: all sessions run
over ONE :class:`~repro.net.party.ServerShared` — one compiled plan, one
protocol instance whose netlist cache is the shared garbling cache
(observable via :class:`~repro.core.session.GarblingCache`: exactly one
slab per distinct ``(netlist, instances, impl)``, however many clients
are connected), one quantized-weight store, and one preprocessing refill
pool discipline.

Admission control (the serving-plane contract):

* **session cap** — at ``max_sessions`` live sessions, a new client's
  hello is answered with a typed CONTROL ``shed`` frame carrying a
  ``retry_after_s`` hint and the connection is closed. The client sees
  :class:`~repro.serve.errors.BundlePoolEmpty` (``scope="session"``),
  never an exception string off the wire.
* **bounded bundle pools** — each session may hold at most ``pool_cap``
  outstanding bundles. A ``prep`` that would exceed it is shed the same
  way (``scope="prep"``) *before* the client garbles anything; the hint
  is computed from the gateway-wide refill queue depth times the
  observed per-bundle preprocessing time.
* **graceful teardown** — when a session's last transport drops (clean
  bye or a mid-exchange kill), its in-flight bundles are counted as
  returned and reclaimed, and the session slot frees for the next
  client. Other sessions never notice.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

from repro import obs
from repro.net.party import (
    EvaluatorEndpoint,
    ServerShared,
    SessionState,
)
from repro.net.transport import AcceptLoop, TcpListener, Transport, \
    TransportClosed


class _SessionShed(TransportClosed):
    """Internal: hello refused at the session cap. Subclasses
    TransportClosed so the serve loop unwinds as a clean disconnect —
    the shed frame is already on the wire."""


class _GatewayEndpoint(EvaluatorEndpoint):
    """One accepted transport. Starts on a provisional session (so
    pre-hello frame bytes are metered somewhere), then binds to the
    session the hello's client token resolves to."""

    def __init__(self, transport: Transport, gateway: "PitGateway", *,
                 timeout: Optional[float] = None):
        super().__init__(transport, shared=gateway.shared, timeout=timeout,
                         session=SessionState(sid=-1, client="pre-hello"))
        self.gateway = gateway
        self._bound = False

    # -- session resolution -------------------------------------------
    def _on_hello(self, payload) -> dict:
        token = payload.get("client")
        sess, hint = self.gateway._admit_session(token)
        if sess is None:
            self._send_control("shed", {"retry_after_s": hint,
                                        "scope": "session"})
            raise _SessionShed("session cap reached, connection shed")
        # fold the provisional (pre-hello) metering into the real ledger,
        # then rebind this endpoint onto the session's state
        sess.ledger.absorb(self.ledger)
        self.session = sess
        self.ledger = sess.ledger
        self._bound = True
        return {"session": sess.sid}

    def _admit_prep(self, n: int) -> Optional[float]:
        return self.gateway._admit_prep(self.session, n)

    def _handle_prep(self, payload) -> None:
        sess = self.session
        before = sess.bundles_prepped
        n = int(payload["n"])
        self.gateway._prep_begin(n)
        # span-backed timing: the gateway's prep EWMA reads the span's
        # duration instead of a hand-rolled perf_counter delta
        sp = obs.timer("gateway.prep", sid=sess.sid, bundles=n)
        try:
            super()._handle_prep(payload)
        finally:
            prepped = sess.bundles_prepped > before
            self.gateway._prep_end(n, sp.close().elapsed_s,
                                   counted=prepped)

    def _on_disconnect(self) -> None:
        if self._bound:
            self.gateway._release_endpoint(self.session)


class PitGateway:
    """Serve one model to many clients from one accept loop.

    ``max_sessions`` bounds concurrently-live client sessions;
    ``pool_cap`` bounds outstanding preprocessed bundles per session
    (admission happens before the client garbles, so a shed wastes no
    offline work on either side). ``retry_floor_s`` is the minimum
    retry-after hint when no preprocessing time has been observed yet.
    """

    def __init__(self, model, seq_len: int, *, impl: str = "ref",
                 seed: int = 104729, max_sessions: int = 8,
                 pool_cap: int = 4, retry_floor_s: float = 0.05,
                 shared: Optional[ServerShared] = None,
                 wire_version: Optional[int] = None,
                 compression: Optional[bool] = None):
        if shared is None:
            kw = {}
            if wire_version is not None:
                kw["wire_version"] = wire_version
            if compression is not None:
                kw["compression"] = compression
            shared = ServerShared(model, seq_len, impl=impl, seed=seed,
                                  **kw)
        self.shared = shared
        self.max_sessions = max_sessions
        self.pool_cap = pool_cap
        self.retry_floor_s = retry_floor_s
        self._lock = threading.Lock()
        self._sessions: Dict[str, SessionState] = {}  # token -> live
        self._closed: List[Dict[str, object]] = []  # summaries, torn down
        self._next_sid = 1
        self.sessions_admitted = 0
        self.sessions_shed = 0
        self.bundles_returned = 0
        # refill-queue instrumentation for retry-after hints
        self._prep_inflight = 0  # bundles in flight across all sessions
        self._prep_ewma_s: Optional[float] = None  # seconds per bundle
        self.endpoints: List[_GatewayEndpoint] = []
        self.threads: List[threading.Thread] = []
        self._loops: List[AcceptLoop] = []
        self._started_s = time.perf_counter()

    # ------------------------------------------------------------------
    # admission control
    # ------------------------------------------------------------------
    def _admit_session(self, token: Optional[str]
                       ) -> Tuple[Optional[SessionState], Optional[float]]:
        """Resolve a hello's client token to a session, minting one if
        needed. Returns ``(session, None)`` on admit, ``(None, hint)``
        when the session cap sheds the connection."""
        with self._lock:
            if token and token in self._sessions:
                sess = self._sessions[token]  # second endpoint of a pair
                sess.endpoints += 1
                return sess, None
            if len(self._sessions) >= self.max_sessions:
                self.sessions_shed += 1
                return None, self._retry_hint_locked(self.pool_cap)
            sid = self._next_sid
            self._next_sid += 1
            # a token-less hello (bare GarblerEndpoint predating the
            # gateway) still gets a session — keyed so it cannot collide
            token = token or f"anon-{sid}"
            sess = SessionState(sid=sid, client=token)
            sess.endpoints = 1
            self._sessions[token] = sess
            self.sessions_admitted += 1
            return sess, None

    def _admit_prep(self, sess: SessionState, n: int) -> Optional[float]:
        """Bounded per-session pool: admit ``n`` more bundles or return a
        retry-after hint."""
        with self._lock:
            if sess.outstanding() + n <= self.pool_cap:
                return None
            # _prep_begin already counted this request into the refill
            # queue depth, so the hint covers it without adding n again
            return self._retry_hint_locked(0)

    def _retry_hint_locked(self, n: int) -> float:
        """Retry-after = (refill queue depth + the refused request) times
        the observed per-bundle preprocessing time — an actual backlog
        estimate, not a constant."""
        per = self._prep_ewma_s or self.retry_floor_s
        return round(max(self.retry_floor_s,
                         (self._prep_inflight + n) * per), 3)

    # -- refill-queue instrumentation ----------------------------------
    def _prep_begin(self, n: int) -> None:
        with self._lock:
            self._prep_inflight += n

    def _prep_end(self, n: int, elapsed_s: float, *, counted: bool) -> None:
        with self._lock:
            self._prep_inflight -= n
            if counted and n > 0:
                per = elapsed_s / n
                self._prep_ewma_s = (per if self._prep_ewma_s is None
                                     else 0.7 * self._prep_ewma_s
                                     + 0.3 * per)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def serve_transport(self, transport: Transport, *,
                        timeout: Optional[float] = None
                        ) -> threading.Thread:
        """Serve one accepted transport on its own thread (session
        resolution happens at its hello)."""
        ep = _GatewayEndpoint(transport, self, timeout=timeout)
        self.endpoints.append(ep)
        th = threading.Thread(target=self._serve_one, args=(ep,),
                              daemon=True,
                              name=f"pit-gw-ep-{len(self.threads)}")
        th.start()
        self.threads.append(th)
        return th

    @staticmethod
    def _serve_one(ep: _GatewayEndpoint) -> None:
        try:
            ep.serve_forever()
        finally:
            # unlike the single-client server, the gateway owns the
            # accepted socket's lifetime: done (bye, kill or shed) means
            # closed, so shed clients fail fast instead of waiting out
            # their recv timeout
            try:
                ep.transport.close()
            except OSError:
                pass

    def serve_listener(self, listener: TcpListener, *,
                       accept_timeout: float = 1.0,
                       timeout: Optional[float] = None, **shaping
                       ) -> AcceptLoop:
        """The front door: ONE accept loop on ``listener``; every
        accepted connection becomes a gateway endpoint."""
        loop = listener.accept_loop(
            lambda t: self.serve_transport(t, timeout=timeout),
            accept_timeout=accept_timeout, name="pit-gateway-accept",
            **shaping)
        self._loops.append(loop)
        return loop

    # ------------------------------------------------------------------
    # teardown & introspection
    # ------------------------------------------------------------------
    def _release_endpoint(self, sess: SessionState) -> None:
        """An endpoint bound to ``sess`` disconnected. When the last one
        drops, reclaim the session: in-flight bundles are returned (the
        client is gone; its ids can never be run) and the slot frees."""
        with self._lock:
            sess.endpoints -= 1
            if sess.endpoints > 0:
                return
            with sess.lock:
                returned = len(sess.bundles)
                sess.bundles.clear()
                sess.bundles_returned += returned
            self.bundles_returned += returned
            self._sessions.pop(sess.client, None)
            self._closed.append(sess.summary())

    def stats(self) -> Dict[str, object]:
        """Gateway-wide accounting: admission counters, the shared
        garbling cache, and per-session summaries (live + torn down).

        The whole snapshot is taken under the gateway lock — admission
        counters (``sessions_admitted``/``sessions_shed``/
        ``bundles_returned``) are mutated by endpoint threads under the
        same lock, so a reader polling while sessions churn always sees
        a consistent set (hammer-tested in ``tests/test_gateway.py``).
        Per-session summaries snapshot under each session's own lock and
        ledger mutex inside it.
        """
        with self._lock:
            live = [s.summary() for s in self._sessions.values()]
            closed = list(self._closed)
            inflight = self._prep_inflight
            ewma = self._prep_ewma_s
            admitted = self.sessions_admitted
            sess_shed = self.sessions_shed
            returned = self.bundles_returned
        sessions = closed + live
        dt = max(time.perf_counter() - self._started_s, 1e-9)
        consumed = sum(s["bundles_consumed"] for s in sessions)
        return {
            "sessions_active": len(live),
            "sessions_admitted": admitted,
            "sessions_shed": sess_shed,
            "prep_sheds": sum(s["sheds"] for s in sessions),
            "bundles_prepped": sum(s["bundles_prepped"] for s in sessions),
            "bundles_consumed": consumed,
            "bundles_returned": returned,
            "bundles_outstanding": sum(s["bundles_outstanding"]
                                       for s in sessions),
            "prep_inflight": inflight,
            "prep_ewma_s": None if ewma is None else round(ewma, 4),
            "elapsed_s": round(dt, 3),
            "bundles_per_s": round(consumed / dt, 3),
            "garbling_cache": self.shared.gc_cache.summary(),
            "sessions": sessions,
        }

    def metrics(self) -> Dict[str, object]:
        """Scrape-able counters snapshot in a stable schema.

        ``counters`` are monotonic over the gateway's lifetime (totals
        include torn-down sessions); ``gauges`` are instantaneous;
        ``spans`` are the current tracer's per-span-path aggregates
        (count/total/mean/max seconds — empty when tracing is off). The
        top-level key set is the scrape contract: keys are only ever
        added, never renamed or removed within ``pit.gateway.v1``.
        """
        st = self.stats()
        tr = obs.current()
        return {
            "schema": "pit.gateway.v1",
            "counters": {
                "sessions_admitted": st["sessions_admitted"],
                "sessions_shed": st["sessions_shed"],
                "prep_sheds": st["prep_sheds"],
                "bundles_prepped": st["bundles_prepped"],
                "bundles_consumed": st["bundles_consumed"],
                "bundles_returned": st["bundles_returned"],
                "garbling_cache_hits": st["garbling_cache"]["hits"],
                "garbling_cache_misses": st["garbling_cache"]["misses"],
            },
            "gauges": {
                "sessions_active": st["sessions_active"],
                "bundles_outstanding": st["bundles_outstanding"],
                "prep_inflight": st["prep_inflight"],
                "prep_ewma_s": st["prep_ewma_s"],
                "bundles_per_s": st["bundles_per_s"],
                "elapsed_s": st["elapsed_s"],
            },
            "spans": tr.report(),
        }

    def join(self, timeout: Optional[float] = None) -> None:
        for th in self.threads:
            th.join(timeout=timeout)

    def close(self) -> None:
        for loop in self._loops:
            loop.stop()
        for ep in self.endpoints:
            try:
                ep.transport.close()
            except OSError:
                pass


def gateway_client(host: str, port: int, *, pool_target: int = 2,
                   seed: int = 0, impl: str = "ref",
                   timeout: Optional[float] = None, **shaping):
    """Connect a pipelined client (offline + online transport pair) to a
    gateway and return a ready :class:`NetPrivateServeEngine`. Both
    transports carry the same client token, so the gateway binds them to
    one session. Raises :class:`~repro.serve.errors.BundlePoolEmpty`
    (``scope="session"``) if the gateway sheds the connection."""
    from repro.net.transport import TcpTransport
    from repro.serve.private_engine import NetPrivateServeEngine

    offline = TcpTransport.connect(host, port, **shaping)
    online = TcpTransport.connect(host, port, **shaping)
    return NetPrivateServeEngine(offline, online, pool_target=pool_target,
                                 seed=seed, impl=impl, timeout=timeout)

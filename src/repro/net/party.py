"""Two-party endpoints: the compiled plan walk as real message exchanges.

Roles follow the repo's protocol convention (``core/protocol.py``): the
**client owns the input and acts as garbler**; the **server owns the
weights and acts as evaluator**. So in deployment terms:

  :class:`GarblerEndpoint`    the client process — holds ``x``, garbles
                              every netlist in the plan, drives
                              ``preprocess``/``run`` requests
  :class:`EvaluatorEndpoint`  the long-lived model server — holds the
                              weights, evaluates circuits, deals triples

Both endpoints walk the *same* compiled :class:`~repro.core.plan.Plan`
in lockstep (the server ships the plan spec in the handshake) and
execute each op's offline/online halves as framed wire messages. Every
protocol-metered message becomes a PROTO segment whose payload length is
exactly what the in-process ``ot.Channel`` meters — the simulation is
the byte oracle, and the per-tag :class:`WireLedger` can be asserted
equal to a metered ``PiTSession`` transcript (``tests/test_net.py``).

Fidelity boundary (documented, measured): the runtime is *share- and
size-faithful*, not cryptographically hardened — it inherits the repo's
honest-but-curious simulation level. Concretely: HE ciphertext frames
are identity-encrypted blocks of the exact ciphertext wire size; OT
frames carry the choice bits / chosen labels in correctly-sized IKNP
blocks; and a small **sim sideband** (SIM frames, ledgered separately as
overhead) carries what the oracle treats as implicit — GC decode
metadata, the LayerNorm-offload centered share whose HE transfer the
meter prepays offline, and the final output shares.

Outputs are bit-identical to the in-process ``PiTSession.run`` path:
every op's algebra is the same mod-t computation, and additive masks
cancel under reconstruction regardless of which party drew them.
"""

from __future__ import annotations

import itertools
import sys
import threading
import time
import traceback
import uuid
from collections import deque
from contextlib import contextmanager
from dataclasses import asdict, dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import PrivacyConfig
from repro import obs
from repro.core import garble as G
from repro.core import labels as LB
from repro.core import ot as OT
from repro.core import secret_sharing as SS
from repro.core.netlist import Netlist
from repro.core.ot import Channel
from repro.core.plan import (
    GC_KINDS,
    OpSpec,
    Plan,
    RegRef,
    compile_plan,
    plan_from_spec,
    plan_to_spec,
)
from repro.core.protocol import (
    PiTProtocol,
    _row_sum,
    _row_sum_sq,
    _rowwise_mul,
    bits_of,
    words_from_bits,
)
from repro.core.session import GarblingCache, gc_net_for
from repro.net import wire as W
from repro.net.transport import (
    Deadlines,
    Transport,
    TransportClosed,
    TransportTimeout,
)
from repro.serve.errors import BundlePoolEmpty


class NetProtocolError(RuntimeError):
    """Lockstep violation, peer error, or malformed exchange."""


class SessionRebindError(NetProtocolError):
    """A reconnecting endpoint pair landed in a different session than
    the one its client state remembers — the server reclaimed the old
    session (lease expired, or it was never parked) and minted a new
    one. The client's pooled bundles belong to the dead session and are
    unusable; a resilient caller surfaces this as ``SessionLost``."""


_bundle_ids = itertools.count(1)


# ---------------------------------------------------------------------------
# ledgers
# ---------------------------------------------------------------------------


@dataclass
class WireLedger:
    """Per-phase protocol byte ledger + overhead counters.

    ``offline``/``online`` reuse :class:`~repro.core.ot.Channel`, keyed
    by the same tags the in-process meter uses, so equality with the
    oracle's ``Stats.channel_offline/online.by_tag`` is a direct dict
    compare. ``sim_bytes``/``control_bytes`` are the sideband and
    ``dir_flips`` counts wire direction alternations over ALL frames
    (control and sim included — the hello/bye handshake contributes two
    flips, which is why this number reads higher than the PROTO-only
    round structure). ``dir_flips_offline``/``dir_flips_online`` count
    alternations over PROTO frames of one phase only — the real
    latency-bearing round structure the LAN model charges — and
    ``proto_frames_*`` count PROTO frames per phase, i.e. the v2
    post-coalescing round count (the oracle's ``rounds`` counts meter
    calls = pre-coalescing segments).

    ``seed_stream_*``/``delta_batches``/``resid_bytes`` count the v2
    compressed streams (how many label batches were replayed from seeds
    and how much table residual rode the sim sideband).

    One ledger is shared by all endpoints of a party — in the pipelined
    mode the offline and online endpoints mutate it from two threads, so
    every update happens under ``_mutex``.
    """

    offline: Channel = field(default_factory=Channel)
    online: Channel = field(default_factory=Channel)
    sim_bytes: int = 0
    control_bytes: int = 0
    frame_bytes: int = 0  # total frame bytes incl. headers, both ways
    dir_flips: int = 0
    dir_flips_offline: int = 0
    dir_flips_online: int = 0
    proto_frames_offline: int = 0
    proto_frames_online: int = 0
    seed_stream_segs: int = 0
    seed_stream_labels: int = 0
    delta_batches: int = 0
    resid_bytes: int = 0
    _last_io: int = 0  # +1 sent, -1 received
    _last_proto: Dict[int, int] = field(default_factory=dict)
    _mutex: threading.Lock = field(default_factory=threading.Lock,
                                   repr=False)

    def _channel(self, phase: int) -> Channel:
        if phase == W.PHASE_OFFLINE:
            return self.offline
        if phase == W.PHASE_ONLINE:
            return self.online
        raise NetProtocolError("PROTO frame without a phase")

    def record_segs(self, phase: int, segs: Sequence[W.Seg]) -> None:
        ch = self._channel(phase)
        with self._mutex:
            for s in segs:
                if s.dir == W.DIR_C2S:
                    ch.c2s(len(s.data), s.tag)
                else:
                    ch.s2c(len(s.data), s.tag)

    def record_io(self, outgoing: bool, nbytes: int) -> None:
        d = 1 if outgoing else -1
        with self._mutex:
            if self._last_io and d != self._last_io:
                self.dir_flips += 1
            self._last_io = d
            self.frame_bytes += nbytes

    def record_proto_frame(self, phase: int, outgoing: bool,
                           nbytes: int) -> None:
        """One PROTO frame on the wire: the post-coalescing round unit."""
        d = 1 if outgoing else -1
        with self._mutex:
            last = self._last_proto.get(phase, 0)
            if last and d != last:
                if phase == W.PHASE_OFFLINE:
                    self.dir_flips_offline += 1
                else:
                    self.dir_flips_online += 1
            self._last_proto[phase] = d
            if phase == W.PHASE_OFFLINE:
                self.proto_frames_offline += 1
            else:
                self.proto_frames_online += 1

    def add_sim(self, nbytes: int) -> None:
        with self._mutex:
            self.sim_bytes += nbytes

    def add_stream(self, labels: int) -> None:
        """One seed-stream segment replacing ``labels`` raw labels."""
        with self._mutex:
            self.seed_stream_segs += 1
            self.seed_stream_labels += int(labels)

    def add_delta_batch(self, resid_bytes: int) -> None:
        """One delta-encoded table batch with its sideband residual."""
        with self._mutex:
            self.delta_batches += 1
            self.resid_bytes += int(resid_bytes)

    def add_control(self, nbytes: int) -> None:
        with self._mutex:
            self.control_bytes += nbytes

    def absorb(self, other: "WireLedger") -> None:
        """Fold another ledger's counters into this one (a gateway
        endpoint meters its pre-hello frames on a provisional ledger,
        then transfers them to the session it resolves to)."""
        with self._mutex, other._mutex:
            for phase_ch, o_ch in ((self.offline, other.offline),
                                   (self.online, other.online)):
                for tag, n in o_ch.by_tag.items():
                    phase_ch.by_tag[tag] = phase_ch.by_tag.get(tag, 0) + n
                phase_ch.client_to_server += o_ch.client_to_server
                phase_ch.server_to_client += o_ch.server_to_client
                phase_ch.rounds += o_ch.rounds
            self.sim_bytes += other.sim_bytes
            self.control_bytes += other.control_bytes
            self.frame_bytes += other.frame_bytes
            self.dir_flips += other.dir_flips
            self.dir_flips_offline += other.dir_flips_offline
            self.dir_flips_online += other.dir_flips_online
            self.proto_frames_offline += other.proto_frames_offline
            self.proto_frames_online += other.proto_frames_online
            self.seed_stream_segs += other.seed_stream_segs
            self.seed_stream_labels += other.seed_stream_labels
            self.delta_batches += other.delta_batches
            self.resid_bytes += other.resid_bytes
            if other._last_io:
                self._last_io = other._last_io
            for phase, last in other._last_proto.items():
                if last:
                    self._last_proto[phase] = last

    def summary(self) -> Dict[str, object]:
        # consistent snapshot: endpoint threads mutate every field below
        # under ``_mutex``, so the reader must hold it too — without it a
        # poll racing a ``record_segs`` can see a frame counted in
        # ``frame_bytes`` but not yet in its phase channel
        with self._mutex:
            return {
                "offline_bytes": self.offline.total,
                "online_bytes": self.online.total,
                "sim_bytes": self.sim_bytes,
                "control_bytes": self.control_bytes,
                "frame_bytes": self.frame_bytes,
                "dir_flips": self.dir_flips,
                "dir_flips_offline": self.dir_flips_offline,
                "dir_flips_online": self.dir_flips_online,
                "proto_frames_offline": self.proto_frames_offline,
                "proto_frames_online": self.proto_frames_online,
                "rounds_after_coalescing": (self.proto_frames_offline
                                            + self.proto_frames_online),
                "raw_messages": self.offline.rounds + self.online.rounds,
                "seed_stream_segs": self.seed_stream_segs,
                "seed_stream_labels": self.seed_stream_labels,
                "delta_batches": self.delta_batches,
                "resid_bytes": self.resid_bytes,
                "offline_by_tag": dict(self.offline.by_tag),
                "online_by_tag": dict(self.online.by_tag),
            }


def _gc_geom(net: Netlist, k: int) -> Tuple[int, int, int]:
    """(n_out_words, xc_label_count, evaluator_label_count) of a netlist."""
    n_out_bits = len(net.outputs)
    xc_bits = len(net.garbler_inputs) - n_out_bits
    return n_out_bits // k, xc_bits, len(net.evaluator_inputs)


def _distinct_nets(protocol: PiTProtocol, plan: Plan, *, n: int = 1,
                   cache: Optional[GarblingCache] = None
                   ) -> Tuple[Dict[str, Netlist], Dict[str, int]]:
    """Netlists in first-appearance order + per-request instance totals.

    With ``cache`` (the server side of a multi-session gateway), netlist
    resolution routes through the shared :class:`GarblingCache`, counted
    per distinct slab — ``n`` is the bundle batch size, so the slab key
    matches the ``instances`` the garbler actually ships.
    """
    if cache is not None:
        return cache.distinct_nets(plan, n)
    nets: Dict[str, Netlist] = {}
    per_req: Dict[str, int] = {}
    for op in plan.ops:
        if op.kind in GC_KINDS:
            net = gc_net_for(protocol, op)
            per_req[net.name] = per_req.get(net.name, 0) + plan.gc_instances(op)
            nets.setdefault(net.name, net)
    return nets, per_req


def _read_reg(regs: Dict[str, np.ndarray], ref: RegRef) -> np.ndarray:
    v = regs[ref.reg]
    if ref.cols is not None:
        v = v[:, ref.cols[0]: ref.cols[1]]
    if ref.transpose:
        v = v.T.copy()
    return v


def _write_reg(regs: Dict[str, np.ndarray], shapes, ref: RegRef,
               val: np.ndarray) -> None:
    if ref.cols is None:
        regs[ref.reg] = val
        return
    if ref.reg not in regs:
        regs[ref.reg] = np.zeros(shapes[ref.reg], np.uint64)
    regs[ref.reg][:, ref.cols[0]: ref.cols[1]] = val


# ---------------------------------------------------------------------------
# endpoint base: framed send/recv with ledger + lockstep checks
# ---------------------------------------------------------------------------


_PHASE_NAMES = {W.PHASE_OFFLINE: "offline", W.PHASE_ONLINE: "online"}


def _trace_segs(phase: int, segs: Sequence[W.Seg], direction: str) -> None:
    """Mirror a ledger ``record_segs`` into the trace, one instant per
    segment, carrying the SAME (phase, tag, byte-count) the ledger
    records — so the trace reconciles against ``WireLedger.by_tag``
    exactly, segment by segment. Attributes are sizes and tags only,
    never the segment payload."""
    tr = obs.current()
    if not tr.enabled:
        return
    ph = _PHASE_NAMES.get(phase, str(phase))
    for s in segs:
        tr.instant("wire:seg", tag=s.tag, bytes=len(s.data), phase=ph,
                   dir=direction)


class _Endpoint:
    def __init__(self, transport: Transport, *, timeout: Optional[float],
                 ledger: WireLedger,
                 deadlines: Optional[Deadlines] = None):
        self.transport = transport
        self.timeout = timeout
        # per-phase recv deadlines; a bare ``timeout`` becomes the
        # uniform default for callers that predate Deadlines
        self.deadlines = deadlines if deadlines is not None \
            else Deadlines.uniform(timeout)
        self._phase_name = "idle"
        self.ledger = ledger
        self._seg_queue: Deque[Tuple[int, W.Seg]] = deque()
        # negotiated at hello; v1 until then (pre-hello traffic is v1)
        self.wire_version = W.WIRE_VERSION
        self.compression = True
        # v2 round coalescing: outgoing PROTO segs buffer here and flush
        # as ONE frame per consecutive same-phase run — before anything
        # that must hit the wire in order (CONTROL/SIM sends) and before
        # any blocking receive (so lockstep can never deadlock on a
        # buffered segment the peer is waiting for)
        self._out_buf: List[Tuple[int, W.Seg]] = []

    # -- send ----------------------------------------------------------
    def _send_control(self, tag: str, payload=None) -> None:
        self._flush()
        # CONTROL stays v1-framed: hello happens before negotiation and
        # a v1-only peer must be able to parse the handshake
        frame = W.encode_msg(W.KIND_CONTROL, tag, payload)
        self.ledger.add_control(len(frame))
        self.ledger.record_io(True, len(frame))
        self.transport.send(frame)

    def _send_sim(self, tag: str, payload, phase: int) -> None:
        self._flush()
        frame = W.encode_msg(W.KIND_SIM, tag, payload, phase=phase)
        self.ledger.add_sim(len(frame))
        self.ledger.record_io(True, len(frame))
        self.transport.send(frame)

    def _send_segs(self, segs: Sequence[W.Seg], phase: int) -> None:
        if not segs:
            return
        if self.wire_version >= 2:
            self._out_buf.extend((phase, s) for s in segs)
            return
        self._emit_proto(list(segs), phase)

    def _emit_proto(self, segs: List[W.Seg], phase: int) -> None:
        with obs.span("wire.send", phase=_PHASE_NAMES.get(phase, str(phase)),
                      segs=len(segs)) as sp:
            frame = W.encode_proto(segs, phase, version=self.wire_version)
            sp.set(bytes=len(frame))
            self.ledger.record_segs(phase, segs)
            _trace_segs(phase, segs, "send")
            self.ledger.record_proto_frame(phase, True, len(frame))
            self.ledger.record_io(True, len(frame))
            self.transport.send(frame)

    def _flush(self) -> None:
        if not self._out_buf:
            return
        buf, self._out_buf = self._out_buf, []
        with obs.span("wire.flush", segs=len(buf)):
            i = 0
            while i < len(buf):
                phase = buf[i][0]
                j = i
                while j < len(buf) and buf[j][0] == phase:
                    j += 1
                self._emit_proto([s for _, s in buf[i:j]], phase)
                i = j

    @contextmanager
    def _in_phase(self, phase: str):
        prev, self._phase_name = self._phase_name, phase
        try:
            yield
        finally:
            self._phase_name = prev

    # -- recv ----------------------------------------------------------
    def _recv_frame(self) -> W.Msg:
        self._flush()
        with obs.span("wire.recv") as sp:
            frame = self.transport.recv(
                timeout=self.deadlines.for_phase(self._phase_name))
            msg = W.decode_frame(frame)
        sp.set(bytes=len(frame), kind=msg.kind)
        self.ledger.record_io(False, len(frame))
        if msg.kind == W.KIND_PROTO:
            self.ledger.record_segs(msg.phase, msg.segs)
            _trace_segs(msg.phase, msg.segs, "recv")
            self.ledger.record_proto_frame(msg.phase, False, len(frame))
        elif msg.kind == W.KIND_SIM:
            self.ledger.add_sim(len(frame))
        else:
            self.ledger.add_control(len(frame))
            if msg.tag == "error":
                raise NetProtocolError(f"peer error: {msg.payload}")
            if msg.tag == "shed":
                # typed load-shed frame, never an exception string: the
                # peer stays healthy, we back off for the hinted time
                p = msg.payload if isinstance(msg.payload, dict) else {}
                raise BundlePoolEmpty(
                    f"peer shed load (scope={p.get('scope', 'pool')}): "
                    f"retry after {p.get('retry_after_s')}s",
                    retry_after_s=p.get("retry_after_s"),
                    scope=str(p.get("scope", "pool")))
        return msg

    def _expect_seg(self, tag: str) -> bytes:
        while not self._seg_queue:
            msg = self._recv_frame()
            if msg.kind != W.KIND_PROTO:
                raise NetProtocolError(
                    f"expected PROTO seg {tag!r}, got kind={msg.kind} "
                    f"tag={msg.tag!r}")
            self._seg_queue.extend((msg.phase, s) for s in msg.segs)
        _, seg = self._seg_queue.popleft()
        if seg.tag != tag:
            raise NetProtocolError(
                f"lockstep violation: expected seg {tag!r}, got {seg.tag!r}")
        return seg.data

    def _expect_msg(self, kind: int, tag: str):
        if self._seg_queue:
            pending = self._seg_queue[0][1].tag
            raise NetProtocolError(
                f"expected {tag!r} but PROTO seg {pending!r} is pending")
        msg = self._recv_frame()
        if msg.kind != kind or msg.tag != tag:
            raise NetProtocolError(
                f"lockstep violation: expected ({kind}, {tag!r}), got "
                f"({msg.kind}, {msg.tag!r})")
        return msg.payload

    def close(self) -> None:
        self.transport.close()


# ---------------------------------------------------------------------------
# server (evaluator) side
# ---------------------------------------------------------------------------


class SessionState:
    """One client relationship's server-side state: a private bundle-id
    namespace, its own :class:`WireLedger`, and rate/byte accounting.

    ``PitNetServer`` owns exactly one (every endpoint pair serves the
    same client); ``PitGateway`` (:mod:`repro.serve.gateway`) mints one
    per admitted client and binds each accepted transport to the session
    its hello names — bundle ids from different clients can no longer
    collide, which is what let the old server refuse a second client.
    """

    def __init__(self, sid: int = 0, client: str = "local"):
        self.sid = sid
        self.client = client
        self.lock = threading.Lock()  # bundle store
        self.bundles: Dict[int, Dict[str, dict]] = {}
        self.ledger = WireLedger()
        self.endpoints = 0  # live transports bound to this session
        self.wire_version = W.WIRE_VERSION  # negotiated at hello
        self.iknp = None  # per-session IKNP receiver state (v2, lazy)
        self.created_s = time.perf_counter()
        # resilience: lease/epoch so a reconnecting client can rebind
        # its transports to THIS session instead of getting a new one.
        # epoch counts transport generations; lease_expires_s is set
        # (monotonic clock) while the session is parked with zero live
        # endpoints awaiting a resume, None otherwise.
        self.epoch = 0
        self.resumes = 0
        self.gen = 0  # highest client transport generation seen in a
        # hello — lets a gateway detect a reconnect deterministically
        # even when the new hellos race the old endpoints' teardown
        self.lease_expires_s: Optional[float] = None
        # accounting (mutated under ``lock``)
        self.prep_requests = 0
        self.run_requests = 0
        self.run_inflight = 0  # runs started, neither consumed nor burned
        self.bundles_prepped = 0
        self.bundles_consumed = 0
        self.bundles_returned = 0
        self.bundles_burned = 0
        self.sheds = 0

    def outstanding(self) -> int:
        with self.lock:
            return len(self.bundles) + self.run_inflight

    def summary(self) -> Dict[str, object]:
        """Per-session rate/byte accounting on top of the wire ledger."""
        dt = max(time.perf_counter() - self.created_s, 1e-9)
        led = self.ledger.summary()  # snapshot under the ledger mutex
        with self.lock:
            out = {
                "sid": self.sid,
                "client": self.client,
                "wire_version": self.wire_version,
                "prep_requests": self.prep_requests,
                "run_requests": self.run_requests,
                "bundles_prepped": self.bundles_prepped,
                "bundles_consumed": self.bundles_consumed,
                "bundles_returned": self.bundles_returned,
                "bundles_burned": self.bundles_burned,
                "bundles_outstanding": len(self.bundles) + self.run_inflight,
                "epoch": self.epoch,
                "resumes": self.resumes,
                "sheds": self.sheds,
                "elapsed_s": round(dt, 3),
                "runs_per_s": round(self.run_requests / dt, 3),
                "bytes_per_s": round(led["frame_bytes"] / dt, 1),
            }
        out.update(led)
        return out


class ServerShared:
    """Weight-owner state shared by all evaluator endpoints of a server.

    Two axes of sharing: the pipelined mode runs one endpoint per
    transport (a dedicated offline pair and an online pair) over one
    bundle store, and the gateway runs N client *sessions* over one
    model/protocol. Everything here is session-invariant — the plan, the
    protocol (whose netlist cache IS the shared garbling cache, made
    observable by ``gc_cache``), quantized weights, LN parameters — while
    per-client state (bundle namespace, ledger, accounting) lives in
    :class:`SessionState`. ``session`` is the default single-client
    namespace that ``PitNetServer`` endpoints use.
    """

    def __init__(self, model, seq_len: int, *, impl: str = "ref",
                 seed: int = 104729, wire_version: int = W.WIRE_V2,
                 compression: bool = True):
        self.model = model
        self.impl = impl
        #: highest wire revision this server offers; each hello
        #: negotiates min(client, server) per session, so a v1-only
        #: peer still completes runs against a v2 server
        self.wire_version = wire_version
        self.compression = compression
        self.plan = compile_plan(model, seq_len)
        self.protocol = PiTProtocol(model.p.pcfg, seed=seed, impl=impl,
                                    wire_version=wire_version,
                                    compression=compression)
        self.gc_cache = GarblingCache(self.protocol)
        self.rng = np.random.default_rng(seed)
        self.rng_lock = threading.Lock()
        self.session = SessionState()
        self._weight_lock = threading.Lock()
        self._quantized: Dict[str, tuple] = {}
        self._ln_cache: Dict[str, dict] = {}

    # default-session views (the pre-gateway single-client API)
    @property
    def lock(self) -> threading.Lock:
        return self.session.lock

    @property
    def bundles(self) -> Dict[int, Dict[str, dict]]:
        return self.session.bundles

    @property
    def ledger(self) -> WireLedger:
        return self.session.ledger

    # -- weight access (mirrors PiTSession; locked: gateway sessions
    # race the first resolution from N endpoint threads) ---------------
    def weight_mod(self, op: OpSpec) -> np.ndarray:
        with self._weight_lock:
            if op.name not in self._quantized:
                Wt = self.model.weights[op.attrs["layer"]]
                w = getattr(Wt, op.attrs["weight"])
                scale = op.attrs.get("wscale", 1.0)
                if scale != 1.0:
                    w = w * scale
                self._quantized[op.name] = self.protocol.quantize_weight(w)
            return self._quantized[op.name][1]

    def _ln_params_locked(self, op: OpSpec) -> dict:
        if op.name not in self._ln_cache:
            p = self.protocol
            Wt = self.model.weights[op.attrs["layer"]]
            which = op.attrs["which"]
            gamma = getattr(Wt, f"{which}_g")
            beta = getattr(Wt, f"{which}_b")
            f = p.frac
            self._ln_cache[op.name] = {
                "gq_mod": SS.encode_fx(np.asarray(gamma), f, p.t),
                "bq_mod": SS.encode_fx(np.asarray(beta), f, p.t),
                "gq_raw": np.round(np.asarray(gamma, np.float64) * (1 << f)
                                   ).astype(np.int64),
                "bq_raw": np.round(np.asarray(beta, np.float64) * (1 << f)
                                   ).astype(np.int64),
            }
        return self._ln_cache[op.name]

    def ln_params(self, op: OpSpec) -> dict:
        with self._weight_lock:
            return self._ln_params_locked(op)

    def hello_payload(self) -> dict:
        p = self.protocol
        ln_gq = {
            op.name: self.ln_params(op)["gq_mod"]
            for op in self.plan.ops
            if op.kind == "layernorm" and p.pcfg.layernorm_offload
        }
        return {
            "version": self.wire_version,
            "compression": self.compression,
            "plan": plan_to_spec(self.plan),
            "pcfg": asdict(self.model.p.pcfg),
            "ln_gq": ln_gq,
        }


class EvaluatorEndpoint(_Endpoint):
    """Model-server endpoint: serves preprocess + run requests on one
    transport. Spawn one per transport over a shared :class:`ServerShared`
    for the pipelined offline/online split."""

    def __init__(self, transport: Transport, *, model=None,
                 seq_len: Optional[int] = None,
                 shared: Optional[ServerShared] = None, impl: str = "ref",
                 timeout: Optional[float] = None,
                 deadlines: Optional[Deadlines] = None,
                 session: Optional[SessionState] = None):
        if shared is None:
            if model is None or seq_len is None:
                raise ValueError("need model+seq_len or a ServerShared")
            shared = ServerShared(model, seq_len, impl=impl)
        session = session or shared.session
        super().__init__(transport, timeout=timeout, ledger=session.ledger,
                         deadlines=deadlines)
        self.shared = shared
        self.session = session
        #: why the serve loop ended: "bye" | "closed" | "timeout" |
        #: "error" | None while still serving. Session owners (the
        #: gateway) use it to tell a clean goodbye from a vanished peer.
        self.disconnect_reason: Optional[str] = None

    # ------------------------------------------------------------------
    def serve_forever(self) -> None:
        """Handle requests until the peer says bye / closes the transport.

        Errors are reported to the peer as a CONTROL ``error`` frame and
        re-raised (the endpoint thread dies loudly — a deadlocked or
        diverged session must never hang silently). ``_on_disconnect``
        runs on every exit — normal bye, peer vanishing mid-exchange, or
        an error — so session owners (the gateway) can reclaim state.
        """
        try:
            self._serve_loop()
        finally:
            self._on_disconnect()

    def _on_disconnect(self) -> None:
        """Hook: the transport is done (bye, close or error). Base
        endpoints have nothing to reclaim."""

    def _serve_loop(self) -> None:
        while True:
            try:
                msg = self._recv_frame()  # idle phase: between requests
            except TransportTimeout:
                # the peer is slow/absent but the connection is intact:
                # an idle deadline expiring is this server's decision to
                # hang up, not a peer crash — tell the peer why (class
                # name only), then release the transport
                self.disconnect_reason = "timeout"
                try:
                    self._send_control("error", "TransportTimeout "
                                                "(idle deadline exceeded)")
                except (TransportClosed, OSError):
                    pass
                self._close_quietly()
                return
            except TransportClosed:
                self.disconnect_reason = "closed"
                return
            try:
                if msg.kind != W.KIND_CONTROL:
                    raise NetProtocolError(
                        f"expected a CONTROL frame, got kind={msg.kind}")
                if msg.tag == "bye":
                    self.disconnect_reason = "bye"
                    return
                if msg.tag == "hello":
                    with self._in_phase("hello"):
                        self._handle_hello(msg.payload)
                elif msg.tag == "prep":
                    with obs.span("offline", role="evaluator",
                                  sid=self.session.sid), \
                            self._in_phase("offline"):
                        self._handle_prep(msg.payload)
                elif msg.tag == "run":
                    with obs.span("online", role="evaluator",
                                  sid=self.session.sid), \
                            self._in_phase("online"):
                        self._handle_run(msg.payload)
                else:
                    raise NetProtocolError(f"unknown request {msg.tag!r}")
            except TransportTimeout:
                # mid-request deadline: the stream may be desynced
                # (lockstep position unknown) — signal and hang up; any
                # interrupted run was already burned by _handle_run
                self.disconnect_reason = "timeout"
                try:
                    self._send_control("error", "TransportTimeout "
                                                "(request deadline exceeded)")
                except (TransportClosed, OSError):
                    pass
                self._close_quietly()
                return
            except TransportClosed:
                self.disconnect_reason = "closed"
                return
            except Exception as e:  # report, then die loudly
                # full traceback stays on THIS side only: exception reprs
                # interpolate live values (shapes, array contents, key
                # material in the worst case), so the peer gets just the
                # class name — enough to correlate with the server log
                self.disconnect_reason = "error"
                traceback.print_exc(file=sys.stderr)
                try:
                    self._send_control(
                        "error", f"{type(e).__name__} "
                                 f"(see evaluator-side log)")
                    self._drain_peer()
                except TransportClosed:
                    pass  # peer already gone — nothing left to tell it
                # close so a peer blocked mid-send fails fast
                self._close_quietly()
                raise

    def _drain_peer(self) -> None:
        """Drain the peer's in-flight stream after sending an error:
        closing a TCP socket with unread data RSTs the connection, which
        would discard the queued error frame before the peer reads it.
        Each wait respects the configured idle deadline (the old code
        hardcoded 0.5 s, silently overriding long-timeout deployments);
        a timeout or close ends the drain, and an OSError is surfaced as
        a typed close instead of being swallowed indistinguishably."""
        budget = self.deadlines.for_phase("idle")
        if budget is None or budget > 5.0:
            budget = 5.0  # a drain must stay bounded even when the
            # serve deadline is "block forever"
        while True:
            try:
                self.transport.recv(timeout=budget)
            except TransportTimeout:
                return  # peer went quiet without closing: good enough
            except OSError as e:
                if isinstance(e, TransportClosed):
                    raise
                raise TransportClosed(
                    f"drain failed: {type(e).__name__}") from e

    def _close_quietly(self) -> None:
        try:
            self.transport.close()
        except OSError:
            pass

    # ------------------------------------------------------------------
    def _handle_hello(self, payload) -> None:
        peer_v = payload.get("version")
        if not isinstance(peer_v, int) or peer_v < W.WIRE_VERSION:
            raise NetProtocolError(
                f"wire version mismatch: peer {peer_v}, "
                f"ours {W.WIRE_VERSION}..{self.shared.wire_version}")
        # pick the highest revision both ends speak: an old v1-only peer
        # advertises 1 and gets a v1 session; newer peers get v2 frames
        ver = min(peer_v, self.shared.wire_version)
        comp = (bool(payload.get("compression", True))
                and self.shared.compression and ver >= 2)
        extra = self._on_hello(payload)
        # _on_hello may have re-bound self.session (gateway resolution)
        self.wire_version = ver
        self.compression = comp
        with self.session.lock:  # readers (stats pollers) snapshot it
            self.session.wire_version = ver
            if payload.get("reset_ot"):
                # reconnect hello: the IKNP extension counters on a
                # half-dead transport pair are desynced (receiver and
                # sender advance at different message boundaries), so a
                # resuming client asks both sides to redo the base OT —
                # ~32 KiB, vs silently corrupted labels otherwise
                self.session.iknp = None
        self._send_control("hello-ok", {
            **self.shared.hello_payload(),
            **extra,
            "version": ver,
            "compression": comp,
        })

    def _on_hello(self, payload) -> dict:
        """Hook: inspect the client hello (id/token), return extra
        hello-ok fields. The gateway resolves the session here; the
        single-client server just names its one session."""
        return {"session": self.session.sid}

    def _admit_prep(self, n: int) -> Optional[float]:
        """Hook: admission control for ``n`` more bundles. Return None to
        admit, or a retry-after hint (seconds) to shed. The base server
        has no bound — bounded pools are gateway policy."""
        return None

    # ------------------------------------------------------------------
    # offline: receive the garbling stream, deal server-side material
    # ------------------------------------------------------------------
    def _handle_prep(self, payload) -> None:
        sh = self.shared
        sess = self.session
        p = sh.protocol
        plan = sh.plan
        t, k = p.t, p.k
        n = int(payload["n"])
        ids = [int(i) for i in payload["ids"]]
        hint = self._admit_prep(n)
        if hint is not None:
            # bounded pool: typed CONTROL shed with a retry-after hint —
            # the client has garbled nothing yet, so nothing is wasted
            with sess.lock:
                sess.sheds += 1
            self._send_control("shed",
                               {"retry_after_s": hint, "scope": "prep"})
            return
        with sess.lock:
            dup = sorted(set(ids) & set(sess.bundles))
            sess.prep_requests += 1
        if dup or len(set(ids)) != n:
            # refuse rather than corrupt: a client reusing ids within its
            # own session would silently swap tables under the first
            # use's labels (ids are per-session — a *different* client's
            # ids live in a different SessionState namespace)
            raise NetProtocolError(
                f"bundle ids {dup or ids} already exist in this session")
        self._send_control("prep-ok", {"n": n})
        nets, per_req = _distinct_nets(p, plan, n=n, cache=sh.gc_cache)
        v2c = self.wire_version >= 2 and self.compression

        slabs: Dict[str, dict] = {}
        if v2c:
            # the garbler coalesces every slab's segments into one frame
            # and defers the sideband: pop ALL the PROTO segs first, then
            # the resid/meta sims, mirroring the send order exactly
            heads: Dict[str, tuple] = {}
            for name, net in nets.items():
                I_tot = per_req[name] * n
                n_out, _, _ = _gc_geom(net, k)
                wire_b = self._expect_seg(f"tables:{name}")
                seed, ctr, count = W.unpack_seed_stream(
                    self._expect_seg("g-labels"))
                if ctr != 0 or count != I_tot * n_out * k:
                    raise NetProtocolError(
                        f"seed stream for {name!r} does not match the "
                        f"plan ({ctr}, {count})")
                heads[name] = (wire_b, seed, count)
            for name, net in nets.items():
                I_tot = per_req[name] * n
                n_out, _, _ = _gc_geom(net, k)
                wire_b, seed, count = heads[name]
                resid = self._expect_msg(W.KIND_SIM, f"tables-resid:{name}")
                meta = self._expect_msg(W.KIND_SIM, f"gc-meta:{name}")
                tables = W.unpack_tables_delta(wire_b, resid, I_tot,
                                               net.and_count)
                mlab = LB.stream_labels(seed, 0, count).reshape(
                    I_tot, n_out * k, 4)
                self.ledger.add_stream(count)
                self.ledger.add_delta_batch(len(resid))
                slabs[name] = {
                    "tables": tables, "mlab": mlab,
                    "perm": np.asarray(meta["perm"], np.uint32),
                    "cw": np.asarray(meta["cw"], np.int64),
                    "clab": np.asarray(meta["clab"], np.uint32),
                    "off": 0,
                }
        else:
            for name, net in nets.items():
                I_tot = per_req[name] * n
                n_out, xc_bits, _ = _gc_geom(net, k)
                tables = W.unpack_tables(self._expect_seg(f"tables:{name}"),
                                         I_tot, net.and_count)
                mlab = W.unpack_labels(self._expect_seg("g-labels"),
                                       (I_tot, n_out * k))
                meta = self._expect_msg(W.KIND_SIM, f"gc-meta:{name}")
                slabs[name] = {
                    "tables": tables, "mlab": mlab,
                    "perm": np.asarray(meta["perm"], np.uint32),
                    "cw": np.asarray(meta["cw"], np.int64),
                    "clab": np.asarray(meta["clab"], np.uint32),
                    "off": 0,
                }

        resp: List[W.Seg] = []
        new_bundles: Dict[int, Dict[str, dict]] = {}
        for bid in ids:
            parts: Dict[str, dict] = {}
            for op in plan.ops:
                if op.kind == "linear":
                    x_shape = plan.read_shape(op.reads[0])
                    r1 = W.ct_unpack(self._expect_seg("he-enc-r"), x_shape)
                    Wmod = sh.weight_mod(op)
                    wr = SS.matmul_mod(r1, Wmod.T, t)
                    with sh.rng_lock:
                        s_mask = sh.rng.integers(0, t, wr.shape,
                                                 dtype=np.uint64)
                    client_y = SS.sub_mod(wr, s_mask, t)
                    resp.append(W.Seg("he-wr", W.DIR_S2C,
                                      W.ct_pack(client_y, p._ct_bytes,
                                                p.params.n)))
                    parts[op.name] = {"s_mask": s_mask}
                elif op.kind == "beaver_matmul":
                    m, kk = plan.read_shape(op.reads[0])
                    _, nn = plan.read_shape(op.reads[1])
                    with sh.rng_lock:
                        trip = SS.deal_matmul_triple(sh.rng, m, kk, nn, t)
                    resp.append(W.Seg(
                        "beaver", W.DIR_S2C,
                        W.pack_u64(trip.a1) + W.pack_u64(trip.b1)
                        + W.pack_u64(trip.c1)))
                    parts[op.name] = {"a2": trip.a2, "b2": trip.b2,
                                      "c2": trip.c2}
                else:  # GC kinds
                    I = plan.gc_instances(op)
                    net = gc_net_for(p, op)
                    slab = slabs[net.name]
                    lo = slab["off"]
                    slab["off"] = lo + I
                    parts[op.name] = {
                        "net": net,
                        "tables": slab["tables"][lo: lo + I],
                        "mlab": slab["mlab"][lo: lo + I],
                        "perm": slab["perm"][lo: lo + I],
                        "cw": slab["cw"],
                        "clab": slab["clab"][lo: lo + I],
                    }
                    if op.kind == "layernorm" and p.pcfg.layernorm_offload:
                        I_ln, nn = op.shape
                        self._expect_seg("he-ln-r")
                        self._expect_seg("he-enc-centered")
                        with sh.rng_lock:
                            parts[op.name]["he_mask"] = sh.rng.integers(
                                0, t, I_ln, dtype=np.uint64)
            new_bundles[bid] = parts
        self._send_segs(resp, W.PHASE_OFFLINE)
        with sess.lock:
            sess.bundles.update(new_bundles)
            sess.bundles_prepped += n
        self._send_control("prep-done", {"n": n, "ids": ids})

    # ------------------------------------------------------------------
    # online: one run against one bundle
    # ------------------------------------------------------------------
    def _handle_run(self, payload) -> None:
        sess = self.session
        bid = int(payload["id"])
        with sess.lock:
            sparts = sess.bundles.pop(bid, None)
            if sparts is not None:
                sess.run_requests += 1
                sess.run_inflight += 1
        if sparts is None:
            raise NetProtocolError(
                f"bundle {bid} unknown or already consumed on the server")
        try:
            self._run_bundle(sparts, payload)
        except BaseException:
            # burn on interrupt: the online leg exchanged SOME of this
            # bundle's labels before dying — re-running it would hand
            # the peer a second active label per wire, breaking GC
            # security. The bundle is gone from the store (popped above)
            # and is accounted as burned, never returned to any pool.
            with sess.lock:
                sess.run_inflight -= 1
                sess.bundles_burned += 1
            obs.instant("net.bundle_burn", sid=sess.sid, bundle=bid)
            raise
        with sess.lock:
            sess.run_inflight -= 1
            sess.bundles_consumed += 1

    def _run_bundle(self, sparts: Dict[str, dict], payload) -> None:
        sh = self.shared
        p = sh.protocol
        plan = sh.plan
        t = p.t
        bid = int(payload["id"])
        S, d = plan.seq_len, plan.d
        regs: Dict[str, np.ndarray] = {
            "x": W.unpack_u64(self._expect_seg("input-share"), (S, d))
        }
        for op in plan.ops:
            part = sparts[op.name]
            rd = [_read_reg(regs, ref) for ref in op.reads]
            with obs.span("op:" + op.kind, op=op.name):
                if op.kind == "linear":
                    xo_c = W.unpack_u64(self._expect_seg("x-minus-r"),
                                        rd[0].shape)
                    x_open = SS.add_mod(xo_c, rd[0], t)
                    wx = SS.matmul_mod(x_open, sh.weight_mod(op).T, t)
                    out = SS.add_mod(wx, part["s_mask"], t)
                elif op.kind == "beaver_matmul":
                    Es = SS.sub_mod(rd[0], part["a2"], t)
                    Fs = SS.sub_mod(rd[1], part["b2"], t)
                    self._send_segs([W.Seg("beaver-open", W.DIR_S2C,
                                           W.pack_u64(Es) + W.pack_u64(Fs))],
                                    W.PHASE_ONLINE)
                    data = self._expect_seg("beaver-open")
                    Ec = W.unpack_u64(data[: Es.size * 8], Es.shape)
                    Fc = W.unpack_u64(data[Es.size * 8:], Fs.shape)
                    E = SS.add_mod(Ec, Es, t)
                    F = SS.add_mod(Fc, Fs, t)
                    out = SS.add_mod(
                        SS.add_mod(part["c2"],
                                   SS.matmul_mod(E, part["b2"], t), t),
                        SS.matmul_mod(part["a2"], F, t), t)
                elif op.kind == "trunc":
                    flat = rd[0].reshape(-1, 1)
                    out = self._server_gc(part, flat, None
                                          ).reshape(rd[0].shape)
                elif op.kind == "gc_apply":
                    if op.attrs["circuit"] == "softmax":
                        out = self._server_gc(part, rd[0], None)
                    else:
                        flat = rd[0].reshape(-1, 1)
                        out = self._server_gc(part, flat, None
                                              ).reshape(rd[0].shape)
                elif op.kind == "layernorm":
                    hs = rd[0]
                    for extra in rd[1:]:
                        hs = SS.add_mod(hs, extra, t)
                    out = self._server_layernorm(op, part, hs)
                else:
                    raise NetProtocolError(f"unknown op kind {op.kind!r}")
                _write_reg(regs, plan.reg_shapes, op.write, out)

        self._send_sim("reveal", {"s": regs[plan.output_reg]},
                       W.PHASE_ONLINE)
        self._send_control("run-done", {"id": bid})

    # ------------------------------------------------------------------
    def _server_gc(self, part: dict, xs: np.ndarray,
                   raw_e: Optional[np.ndarray]) -> np.ndarray:
        """Evaluator leg of one GC op: sim-OT request, receive labels,
        evaluate, decode to this party's output share."""
        import jax.numpy as jnp

        sh = self.shared
        p = sh.protocol
        t, k = p.t, p.k
        net: Netlist = part["net"]
        n_out, xc_bits, n_e = _gc_geom(net, k)
        I = xs.shape[0]

        e_bits = bits_of(xs, k, t)
        if raw_e is not None:
            rv = np.mod(np.asarray(raw_e, np.int64), 1 << k).astype(np.uint64)
            e_bits = np.concatenate([e_bits, bits_of(rv, k, 1 << k)], axis=1)
        assert e_bits.shape == (I, n_e)
        if self.wire_version >= 2:
            # real IKNP extension. One-time base OT, lazily at the
            # session's first online GC op: this endpoint (the OT
            # receiver) acts as base-OT *sender* — it sends A, the
            # garbler answers with the κ B-elements. Then per batch:
            # column matrix u out, masked label pairs back.
            sess = self.session
            g_lab_data = None
            iknp = sess.iknp
            if iknp is None:
                with sh.rng_lock:
                    iknp = OT.IknpReceiver(sh.rng)
                self._send_segs([W.Seg("ot-base", W.DIR_S2C,
                                       iknp.base_msg_a())], W.PHASE_ONLINE)
                g_lab_data = self._expect_seg("g-labels")
                iknp.absorb_base_b(self._expect_seg("ot-base"))
                sess.iknp = iknp
            u, t_cols = iknp.extend(e_bits)
            self._send_segs([W.Seg(f"ot:{net.name}", W.DIR_C2S, u)],
                            W.PHASE_ONLINE)
            if g_lab_data is None:
                g_lab_data = self._expect_seg("g-labels")
            g_lab = W.unpack_labels(g_lab_data, (I, xc_bits))
            e_lab = iknp.receive(self._expect_seg(f"ot:{net.name}"),
                                 e_bits, t_cols).reshape(I, n_e, 4)
        else:
            # sim-OT: the receiver's choice-derived messages (logical c2s
            # in the oracle's ledger; see core/ot.ot_labels)
            self._send_segs([W.Seg(f"ot:{net.name}", W.DIR_C2S,
                                   W.pack_ot_request(e_bits))],
                            W.PHASE_ONLINE)
            g_lab = W.unpack_labels(self._expect_seg("g-labels"),
                                    (I, xc_bits))
            e_lab = W.unpack_ot_response(self._expect_seg(f"ot:{net.name}"),
                                         (I, n_e))
        wire_ids = np.concatenate([
            np.asarray(net.garbler_inputs, np.int64),
            np.asarray(net.evaluator_inputs, np.int64), part["cw"]])
        labels = np.concatenate([g_lab, part["mlab"], e_lab, part["clab"]],
                                axis=1)
        out_lab = G.evaluate(net, jnp.asarray(part["tables"]),
                             (wire_ids, jnp.asarray(labels)), impl=sh.impl)
        out_bits = ((np.asarray(out_lab)[..., 0] & 1) ^ part["perm"]
                    ).astype(np.uint8)
        return words_from_bits(out_bits, k, t)

    def _server_layernorm(self, op: OpSpec, part: dict, hs: np.ndarray
                          ) -> np.ndarray:
        sh = self.shared
        p = sh.protocol
        t, f = p.t, p.frac
        I, n = hs.shape
        lp = sh.ln_params(op)
        if not p.pcfg.layernorm_offload:
            raw = np.concatenate([np.broadcast_to(lp["gq_raw"], (I, n)),
                                  np.broadcast_to(lp["bq_raw"], (I, n))],
                                 axis=1)
            return self._server_gc(part, hs, raw)
        # APINT Fig. 4 offload, evaluator legs (mirrors layernorm_online)
        inv_n = int(round((1 << f) / n))
        mu = SS.scalar_mul_mod(inv_n, _row_sum(hs, t), t)
        cxs = SS.sub_mod(SS.scalar_mul_mod(1 << f, hs, t), mu[:, None], t)
        cxc = np.asarray(self._expect_msg(W.KIND_SIM, "ln-centered"),
                         np.uint64)
        cross = np.array(
            [int(np.dot(cxc[i].astype(object), cxs[i].astype(object)) % t)
             for i in range(I)], dtype=np.uint64)
        cross_c = SS.sub_mod(cross, part["he_mask"], t)
        self._send_segs([W.Seg("he-cross", W.DIR_S2C,
                               W.ct_pack_rows(cross_c, p._ct_bytes))],
                        W.PHASE_ONLINE)
        var_s = SS.add_mod(_row_sum_sq(cxs, t),
                           SS.scalar_mul_mod(2, part["he_mask"], t), t)
        var_s = SS.scalar_mul_mod(inv_n, var_s, t)
        gxs = _rowwise_mul(lp["gq_mod"], cxs, t)
        in_s = np.concatenate([gxs, var_s[:, None]], axis=1)
        out = self._server_gc(part, in_s, None)
        return SS.add_mod(out, np.broadcast_to(lp["bq_mod"], out.shape), t)


# ---------------------------------------------------------------------------
# client (garbler) side
# ---------------------------------------------------------------------------


class ClientShared:
    """Input-owner state shared by a client's endpoints (offline + online
    pairs in the pipelined mode): protocol, plan, and the bundle pool."""

    def __init__(self, *, seed: int = 0, impl: str = "ref",
                 wire_version: int = W.WIRE_V2, compression: bool = True):
        self.seed = seed
        self.impl = impl
        #: highest wire revision this client requests at hello; the
        #: server replies with min(ours, theirs) — see ``adopt_hello``
        self.wire_version = wire_version
        self.compression = compression
        self.negotiated_version: Optional[int] = None
        self.negotiated_compression: Optional[bool] = None
        self.protocol: Optional[PiTProtocol] = None
        self.plan: Optional[Plan] = None
        self.ln_gq: Dict[str, np.ndarray] = {}
        self.rng = np.random.default_rng(seed)  # offline draws
        self.run_rng = np.random.default_rng(seed + 1)  # input shares
        self.lock = threading.Lock()  # pool + lazy init
        self.bundles: Dict[int, Dict[str, dict]] = {}
        self.order: Deque[int] = deque()
        self.ledger = WireLedger()
        self.iknp = None  # per-session IKNP sender state (v2, lazy)
        # both endpoints of a pair send the same token, so a gateway can
        # bind them to ONE session/bundle namespace (uuid: two clients
        # with the same seed must still be distinct sessions)
        self.client_token = f"c{seed}-{uuid.uuid4().hex[:12]}"
        self.session_id: Optional[int] = None

    def adopt_hello(self, payload: dict) -> None:
        sid = payload.get("session")
        ver = payload.get("version", W.WIRE_VERSION)
        comp = bool(payload.get("compression", False))
        if not isinstance(ver, int) or ver < W.WIRE_VERSION \
                or ver > self.wire_version:
            raise NetProtocolError(
                f"server negotiated wire version {ver!r}, outside our "
                f"supported range {W.WIRE_VERSION}..{self.wire_version}")
        with self.lock:
            if self.plan is not None:  # second endpoint of a pair
                if plan_to_spec(self.plan) != payload["plan"]:
                    raise NetProtocolError(
                        "offline/online endpoints saw different plans")
                if sid != self.session_id:
                    raise SessionRebindError(
                        f"endpoint landed in session {sid}, not the "
                        f"client's session {self.session_id} — either the "
                        f"hellos carried different tokens, or the server "
                        f"reclaimed the session (lease expired) and "
                        f"minted a new one")
                if ver != self.negotiated_version \
                        or comp != self.negotiated_compression:
                    raise NetProtocolError(
                        f"offline/online endpoints negotiated different "
                        f"wire formats (v{self.negotiated_version} vs "
                        f"v{ver})")
                return
            pcfg = PrivacyConfig(**payload["pcfg"])
            self.negotiated_version = ver
            self.negotiated_compression = comp
            self.protocol = PiTProtocol(pcfg, seed=self.seed,
                                        wire_version=ver, compression=comp)
            self.plan = plan_from_spec(payload["plan"])
            self.session_id = sid
            self.ln_gq = {k: np.asarray(v, np.uint64)
                          for k, v in payload["ln_gq"].items()}

    def pool_size(self) -> int:
        with self.lock:
            return len(self.order)

    def take_bundle_id(self) -> Optional[int]:
        with self.lock:
            return self.order.popleft() if self.order else None


class GarblerEndpoint(_Endpoint):
    """Client endpoint: connect, ``handshake()``, then ``preprocess(n)``
    (offline: garble + stream) and ``run(x)`` (online only)."""

    def __init__(self, transport: Transport, *,
                 shared: Optional[ClientShared] = None, seed: int = 0,
                 impl: str = "ref", timeout: Optional[float] = None,
                 deadlines: Optional[Deadlines] = None,
                 wire_version: int = W.WIRE_V2, compression: bool = True,
                 reset_ot: bool = False, gen: int = 0):
        shared = shared or ClientShared(seed=seed, impl=impl,
                                        wire_version=wire_version,
                                        compression=compression)
        super().__init__(transport, timeout=timeout, ledger=shared.ledger,
                         deadlines=deadlines)
        self.shared = shared
        #: reconnect endpoints set this so the hello asks the server to
        #: drop the session's IKNP state (see EvaluatorEndpoint hello)
        self.reset_ot = reset_ot
        #: client transport generation (0 = first pair, bumped by the
        #: resilient client on every reconnect) — rides in the hello so
        #: the server's resume accounting is timing-independent
        self.gen = gen
        self._lock = threading.Lock()  # one request at a time per endpoint

    # ------------------------------------------------------------------
    def handshake(self) -> Plan:
        """Hello exchange; raises :class:`BundlePoolEmpty` if a gateway
        at its session cap sheds the connection (typed CONTROL frame
        with a retry-after hint, not an error string), and
        :class:`SessionRebindError` if a reconnect hello lands in a
        different session than the client remembers."""
        with self._lock, self._in_phase("hello"):
            hello = {
                "version": self.shared.wire_version,
                "compression": self.shared.compression,
                "client": self.shared.client_token,
            }
            if self.reset_ot:
                hello["reset_ot"] = True
            if self.gen:
                hello["gen"] = self.gen
            self._send_control("hello", hello)
            self.shared.adopt_hello(self._expect_msg(W.KIND_CONTROL,
                                                     "hello-ok"))
            self.wire_version = self.shared.negotiated_version
            self.compression = bool(self.shared.negotiated_compression)
        return self.shared.plan

    def close(self) -> None:
        try:
            self._send_control("bye")
        except TransportClosed:
            pass
        self.transport.close()

    # ------------------------------------------------------------------
    # offline
    # ------------------------------------------------------------------
    def preprocess(self, n: int = 1) -> List[int]:
        """Garble every netlist in the plan (one batched call per distinct
        netlist across all ``n`` bundles), stream tables/labels/HE frames
        to the evaluator, and pool the client halves. Returns bundle ids."""
        if n < 1:
            raise ValueError("preprocess needs n >= 1")
        sh = self.shared
        if sh.plan is None:
            self.handshake()
        with self._lock, obs.span("offline", role="garbler", bundles=n), \
                self._in_phase("offline"):
            return self._preprocess_locked(n)

    def _preprocess_locked(self, n: int) -> List[int]:
        sh = self.shared
        p = sh.protocol
        plan = sh.plan
        t, k = p.t, p.k
        ids = [next(_bundle_ids) for _ in range(n)]
        self._send_control("prep", {"n": n, "ids": ids})
        # admission gate BEFORE any garbling: a bounded server pool sheds
        # here (BundlePoolEmpty via the CONTROL shed frame) while the
        # expensive offline work is still unstarted on both sides
        self._expect_msg(W.KIND_CONTROL, "prep-ok")

        nets, per_req = _distinct_nets(p, plan)
        v2c = self.wire_version >= 2 and self.compression
        slabs: Dict[str, tuple] = {}
        sims: List[Tuple[str, object]] = []
        for name, net in nets.items():
            I_tot = per_req[name] * n
            n_out, xc_bits, _ = _gc_geom(net, k)
            if v2c:
                # v2: masks are drawn BEFORE garbling so the mask-wire
                # active labels can be preset to the PRG stream — the
                # evaluator replays the same stream from the 32-byte
                # seed record instead of receiving raw labels, and the
                # table batch ships delta-encoded (anchor + per-instance
                # XOR head; the residual rides the sim sideband)
                masks = sh.rng.integers(0, t, (I_tot, n_out),
                                        dtype=np.uint64)
                mask_enc = SS.sub_mod(np.zeros_like(masks), masks, t)
                seed = LB.stream_seed(sh.rng)
                with obs.span("garble", netlist=name, instances=I_tot):
                    gcirc = G.garble(
                        net, p._next_key(), I_tot, impl=sh.impl,
                        seeded_inputs=(net.garbler_inputs[xc_bits:],
                                       bits_of(mask_enc, k, t), seed, 0))
                wire_b, resid = W.pack_tables_delta(gcirc.tables)
                self._send_segs([
                    W.Seg(f"tables:{name}", W.DIR_C2S, wire_b),
                    W.Seg("g-labels", W.DIR_C2S,
                          W.pack_seed_stream(seed, 0, I_tot * n_out * k)),
                ], W.PHASE_OFFLINE)
                self.ledger.add_stream(I_tot * n_out * k)
                self.ledger.add_delta_batch(len(resid))
                sims.append((f"tables-resid:{name}", resid))
            else:
                with obs.span("garble", netlist=name, instances=I_tot):
                    gcirc = G.garble(net, p._next_key(), I_tot,
                                     impl=sh.impl)
                masks = sh.rng.integers(0, t, (I_tot, n_out),
                                        dtype=np.uint64)
                mask_enc = SS.sub_mod(np.zeros_like(masks), masks, t)
                mlab = G.encode_inputs(gcirc, net.garbler_inputs[xc_bits:],
                                       bits_of(mask_enc, k, t))
                self._send_segs([
                    W.Seg(f"tables:{name}", W.DIR_C2S,
                          W.pack_tables(gcirc.tables)),
                    W.Seg("g-labels", W.DIR_C2S, W.pack_labels(mlab)),
                ], W.PHASE_OFFLINE)
            cw, clab = G.const_wires_labels(gcirc)
            meta = {
                "perm": np.asarray(gcirc.output_perm),
                "cw": np.asarray(cw), "clab": np.asarray(clab),
            }
            if v2c:
                # defer the sideband so all slab segments coalesce into
                # one offline frame; the evaluator pops every PROTO seg
                # first, then the sims, in this exact order
                sims.append((f"gc-meta:{name}", meta))
            else:
                self._send_sim(f"gc-meta:{name}", meta, W.PHASE_OFFLINE)
            slabs[name] = (gcirc, masks)
        for tag, obj in sims:
            self._send_sim(tag, obj, W.PHASE_OFFLINE)

        offsets = {name: 0 for name in nets}
        new_bundles: Dict[int, Dict[str, dict]] = {}
        for bid in ids:
            parts: Dict[str, dict] = {}
            segs: List[W.Seg] = []
            for op in plan.ops:
                if op.kind == "linear":
                    x_shape = plan.read_shape(op.reads[0])
                    r1 = sh.rng.integers(0, t, x_shape, dtype=np.uint64)
                    segs.append(W.Seg("he-enc-r", W.DIR_C2S,
                                      W.ct_pack(r1, p._ct_bytes, p.params.n)))
                    parts[op.name] = {"r1": r1}
                elif op.kind == "beaver_matmul":
                    parts[op.name] = {}
                else:  # GC kinds
                    I = plan.gc_instances(op)
                    net = gc_net_for(p, op)
                    lo = offsets[net.name]
                    offsets[net.name] = lo + I
                    gcirc, masks = slabs[net.name]
                    parts[op.name] = {
                        "gc": G.slice_instances(gcirc, lo, lo + I),
                        "masks": masks[lo: lo + I],
                    }
                    if op.kind == "layernorm" and p.pcfg.layernorm_offload:
                        I_ln, nn = op.shape
                        blocks = W.ct_blocks(I_ln * nn, p.params.n)
                        segs.append(W.Seg("he-ln-r", W.DIR_C2S,
                                          bytes(blocks * p._ct_bytes)))
                        segs.append(W.Seg("he-enc-centered", W.DIR_C2S,
                                          bytes(I_ln * p._ct_bytes)))
            self._send_segs(segs, W.PHASE_OFFLINE)
            new_bundles[bid] = parts

        # server responses arrive in the same deterministic walk order
        for bid in ids:
            for op in plan.ops:
                if op.kind == "linear":
                    new_bundles[bid][op.name]["client_y"] = W.ct_unpack(
                        self._expect_seg("he-wr"), op.shape)
                elif op.kind == "beaver_matmul":
                    m, kk = plan.read_shape(op.reads[0])
                    _, nn = plan.read_shape(op.reads[1])
                    data = self._expect_seg("beaver")
                    o1, o2 = m * kk * 8, (m * kk + kk * nn) * 8
                    new_bundles[bid][op.name] = {
                        "a1": W.unpack_u64(data[:o1], (m, kk)),
                        "b1": W.unpack_u64(data[o1:o2], (kk, nn)),
                        "c1": W.unpack_u64(data[o2:], (m, nn)),
                    }
        self._expect_msg(W.KIND_CONTROL, "prep-done")
        with sh.lock:
            sh.bundles.update(new_bundles)
            sh.order.extend(ids)
        return ids

    # ------------------------------------------------------------------
    # online
    # ------------------------------------------------------------------
    def run(self, x: np.ndarray, bundle_id: Optional[int] = None
            ) -> np.ndarray:
        """Online phase for one request; consumes one pooled bundle."""
        sh = self.shared
        if sh.plan is None:
            self.handshake()
        plan = sh.plan
        x = np.asarray(x, np.float64)
        if x.shape != (plan.seq_len, plan.d):
            raise ValueError(f"input shape {x.shape} != bucket shape "
                             f"{(plan.seq_len, plan.d)}")
        with self._lock:
            if bundle_id is None:
                bundle_id = sh.take_bundle_id()
                if bundle_id is None:
                    raise NetProtocolError(
                        "no preprocessed bundle in the pool — call "
                        "preprocess() first")
            with sh.lock:
                parts = sh.bundles.pop(bundle_id, None)
            if parts is None:
                raise NetProtocolError(
                    f"bundle {bundle_id} unknown or already consumed")
            with obs.span("online", role="garbler", bundle_id=bundle_id), \
                    self._in_phase("online"):
                return self._run_locked(x, bundle_id, parts)

    def _run_locked(self, x, bundle_id: int, parts) -> np.ndarray:
        sh = self.shared
        p = sh.protocol
        plan = sh.plan
        t, f = p.t, p.frac
        self._send_control("run", {"id": bundle_id})

        enc = SS.encode_fx(x, f, t)
        # SS.share is the approved split: xs = enc − fresh one-time mask
        # (draws the mask from run_rng exactly as the inline split did)
        xc, xs = SS.share(sh.run_rng, enc, t)
        self._send_segs([W.Seg("input-share", W.DIR_C2S, W.pack_u64(xs))],
                        W.PHASE_ONLINE)
        regs: Dict[str, np.ndarray] = {"x": xc}
        for op in plan.ops:
            part = parts[op.name]
            rd = [_read_reg(regs, ref) for ref in op.reads]
            with obs.span("op:" + op.kind, op=op.name):
                if op.kind == "linear":
                    xo = SS.sub_mod(rd[0], part["r1"], t)
                    self._send_segs([W.Seg("x-minus-r", W.DIR_C2S,
                                           W.pack_u64(xo))], W.PHASE_ONLINE)
                    out = part["client_y"]
                elif op.kind == "beaver_matmul":
                    Ec = SS.sub_mod(rd[0], part["a1"], t)
                    Fc = SS.sub_mod(rd[1], part["b1"], t)
                    self._send_segs([W.Seg("beaver-open", W.DIR_C2S,
                                           W.pack_u64(Ec) + W.pack_u64(Fc))],
                                    W.PHASE_ONLINE)
                    data = self._expect_seg("beaver-open")
                    Es = W.unpack_u64(data[: Ec.size * 8], Ec.shape)
                    Fs = W.unpack_u64(data[Ec.size * 8:], Fc.shape)
                    E = SS.add_mod(Ec, Es, t)
                    F = SS.add_mod(Fc, Fs, t)
                    out = SS.add_mod(
                        SS.add_mod(part["c1"],
                                   SS.matmul_mod(E, part["b1"], t), t),
                        SS.add_mod(SS.matmul_mod(part["a1"], F, t),
                                   SS.matmul_mod(E, F, t), t), t)
                elif op.kind == "trunc":
                    flat = rd[0].reshape(-1, 1)
                    out = self._client_gc(part, flat).reshape(rd[0].shape)
                elif op.kind == "gc_apply":
                    if op.attrs["circuit"] == "softmax":
                        out = self._client_gc(part, rd[0])
                    else:
                        flat = rd[0].reshape(-1, 1)
                        out = self._client_gc(part, flat).reshape(rd[0].shape)
                elif op.kind == "layernorm":
                    hc = rd[0]
                    for extra in rd[1:]:
                        hc = SS.add_mod(hc, extra, t)
                    out = self._client_layernorm(op, part, hc)
                else:
                    raise NetProtocolError(f"unknown op kind {op.kind!r}")
                _write_reg(regs, plan.reg_shapes, op.write, out)

        xs_out = np.asarray(
            self._expect_msg(W.KIND_SIM, "reveal")["s"], np.uint64)
        self._expect_msg(W.KIND_CONTROL, "run-done")
        v = SS.reconstruct(regs[plan.output_reg], xs_out, t)
        return SS.decode_fx(v, f, t)

    # ------------------------------------------------------------------
    def _client_gc(self, part: dict, xc: np.ndarray) -> np.ndarray:
        """Garbler leg of one GC op: send active labels for this party's
        share, answer the sim-OT request, output share = the masks."""
        sh = self.shared
        p = sh.protocol
        t, k = p.t, p.k
        gcirc: G.GarbledCircuit = part["gc"]
        net = gcirc.net
        n_out, xc_bits, n_e = _gc_geom(net, k)
        I = xc.shape[0]
        g_lab = G.encode_inputs(gcirc, net.garbler_inputs[:xc_bits],
                                bits_of(xc, k, t))
        self._send_segs([W.Seg("g-labels", W.DIR_C2S, W.pack_labels(g_lab))],
                        W.PHASE_ONLINE)
        if self.wire_version >= 2:
            # IKNP sender leg: answer the one-time base OT if this is
            # the session's first online GC op, then mask both labels of
            # every evaluator wire under the extension-matrix hash
            if sh.iknp is None:
                a_data = self._expect_seg("ot-base")
                with sh.lock:
                    snd = OT.IknpSender(sh.rng)
                    b_data = snd.base_msg_b(a_data)
                    sh.iknp = snd
                self._send_segs([W.Seg("ot-base", W.DIR_C2S, b_data)],
                                W.PHASE_ONLINE)
            u_data = self._expect_seg(f"ot:{net.name}")
            e_zero = G.input_zeros(gcirc, net.evaluator_inputs)
            y = sh.iknp.respond(u_data, I * n_e, np.asarray(e_zero),
                                np.asarray(gcirc.r)[:, None, :])
            self._send_segs([W.Seg(f"ot:{net.name}", W.DIR_S2C, y)],
                            W.PHASE_ONLINE)
        else:
            choice = W.unpack_ot_request(self._expect_seg(f"ot:{net.name}"),
                                         (I, n_e))
            e_zero = G.input_zeros(gcirc, net.evaluator_inputs)
            e_lab = OT.choose_labels(e_zero, gcirc.r[:, None, :], choice)
            self._send_segs([W.Seg(f"ot:{net.name}", W.DIR_S2C,
                                   W.pack_ot_response(e_lab))],
                            W.PHASE_ONLINE)
        return part["masks"]

    def _client_layernorm(self, op: OpSpec, part: dict, hc: np.ndarray
                          ) -> np.ndarray:
        sh = self.shared
        p = sh.protocol
        t, f = p.t, p.frac
        I, n = hc.shape
        if not p.pcfg.layernorm_offload:
            return self._client_gc(part, hc)
        inv_n = int(round((1 << f) / n))
        mu = SS.scalar_mul_mod(inv_n, _row_sum(hc, t), t)
        cxc = SS.sub_mod(SS.scalar_mul_mod(1 << f, hc, t), mu[:, None], t)
        # sim sideband: the oracle prepays the centered-share ciphertext
        # offline ("he-enc-centered"); the actual coefficients ride here
        self._send_sim("ln-centered", cxc, W.PHASE_ONLINE)
        cross_c = W.ct_unpack_rows(self._expect_seg("he-cross"), I,
                                   p._ct_bytes)
        var_c = SS.add_mod(_row_sum_sq(cxc, t),
                           SS.scalar_mul_mod(2, cross_c, t), t)
        var_c = SS.scalar_mul_mod(inv_n, var_c, t)
        gxc = _rowwise_mul(sh.ln_gq[op.name], cxc, t)
        in_c = np.concatenate([gxc, var_c[:, None]], axis=1)
        return self._client_gc(part, in_c)


# ---------------------------------------------------------------------------
# pipelined server wrapper
# ---------------------------------------------------------------------------


class PitNetServer:
    """Host a model behind N evaluator endpoints over one bundle store.

    The pipelined deployment gives the offline phase its own endpoint
    pair so ``refill_async`` traffic streams concurrently with online
    ``run`` traffic (see ``serve.private_engine.NetPrivateServeEngine``).
    """

    def __init__(self, model, seq_len: int, *, impl: str = "ref",
                 seed: int = 104729, wire_version: int = W.WIRE_V2,
                 compression: bool = True):
        self.shared = ServerShared(model, seq_len, impl=impl, seed=seed,
                                   wire_version=wire_version,
                                   compression=compression)
        self.endpoints: List[EvaluatorEndpoint] = []
        self.threads: List[threading.Thread] = []

    def serve_transport(self, transport: Transport, *,
                        timeout: Optional[float] = None,
                        deadlines: Optional[Deadlines] = None, name: str = ""
                        ) -> threading.Thread:
        ep = EvaluatorEndpoint(transport, shared=self.shared,
                               timeout=timeout, deadlines=deadlines)
        self.endpoints.append(ep)
        th = threading.Thread(target=ep.serve_forever, daemon=True,
                              name=name or f"pit-eval-{len(self.threads)}")
        th.start()
        self.threads.append(th)
        return th

    def serve_tcp(self, listener, *, accept_timeout: float = 1.0,
                  timeout: Optional[float] = None,
                  deadlines: Optional[Deadlines] = None, name: str = "",
                  max_conns: Optional[int] = None):
        """Serve every connection accepted on ``listener`` in the
        background (each becomes an evaluator endpoint over the shared
        store) until the returned :class:`~repro.net.transport.AcceptLoop`
        is stopped, the listener closes, or ``max_conns`` is reached.

        One call now serves a whole pipelined endpoint pair — callers
        sequence with ``loop.wait_accepted(n)`` instead of joining a
        one-shot accept thread (the old single-accept-per-call shape).
        ``accept_timeout`` is the stop-flag poll interval.
        """
        def handler(transport):
            self.serve_transport(transport, timeout=timeout,
                                 deadlines=deadlines, name=name)

        return listener.accept_loop(
            handler, accept_timeout=accept_timeout, max_accepts=max_conns,
            name=(name or "pit-eval") + "-accept")

    def join(self, timeout: Optional[float] = None) -> None:
        for th in self.threads:
            th.join(timeout=timeout)

    def close(self) -> None:
        for ep in self.endpoints:
            ep.close()

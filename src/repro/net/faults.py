"""Deterministic fault injection for any :class:`Transport`.

:class:`FaultyTransport` wraps a transport and injects faults from a
**seeded schedule**: every ``send``/``recv`` call on the wrapper advances
one shared *op counter*, and a :class:`Fault` scheduled at op ``k`` fires
exactly when the k-th call happens. Because the endpoints walk the
protocol in lockstep on a single thread per transport, the op sequence —
and therefore the injected fault sequence — is a pure function of the
schedule, identical on :class:`InProcPipe` and :class:`TcpTransport`.
That makes every chaos run replayable: same seed, same faults, same
outcome.

Fault kinds (the realistic failure modes of a long-lived 2PC socket):

* ``reset`` — the connection dies at op k: the inner transport is closed
  and the call raises :class:`TransportClosed`. Models a peer crash or
  an RST from a middlebox.
* ``stall`` — the peer stops sending for ``delay_s``: a recv sleeps
  and then either delivers late (``delay_s < timeout``) or raises
  :class:`TransportTimeout` (``delay_s >= timeout``); a send is just
  delayed. Models GC pauses, congestion, a wedged remote thread.
* ``torn`` — a frame is truncated mid-write and the connection dies:
  the receiver gets half a frame (a framing-level torn length-prefix),
  the sender sees :class:`TransportClosed`. The wrapper sits above the
  byte framing, so a torn frame is delivered as a *valid transport
  frame with a truncated payload* — the same decode failure on both
  transports, deterministically.
* ``dup`` — a frame is delivered (or sent) twice. Models retransmit
  bugs and at-least-once relays; the lockstep protocol must reject the
  duplicate with a typed error rather than desync.

``FaultPlan`` extends the idea across reconnects: a resilient client
that reconnects gets a fresh transport per attempt, and the plan hands
each new connection its own seeded schedule (empty after
``faulty_conns`` connections, so chaos runs terminate).
"""

from __future__ import annotations

import random
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.net.transport import Transport, TransportClosed, TransportTimeout

KINDS = ("reset", "stall", "torn", "dup")

# frames larger than this are slab payloads, not CONTROL traffic — the
# frame log keeps only small frames so hygiene checks stay cheap
_LOG_FRAME_CAP = 4096


@dataclass(frozen=True)
class Fault:
    """One scheduled fault: fires at shared op index ``op``."""

    op: int
    kind: str  # one of KINDS
    delay_s: float = 0.0  # stall duration (ignored for other kinds)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable set of faults keyed by op index."""

    faults: Tuple[Fault, ...] = ()

    @classmethod
    def from_seed(cls, seed: int, *, n_faults: int = 1, first_op: int = 2,
                  horizon: int = 64, kinds: Tuple[str, ...] = KINDS,
                  stall_s: float = 0.25) -> "FaultSchedule":
        """Derive a schedule from a seed — same seed, same schedule.

        Ops below ``first_op`` are spared so the very first hello frames
        can flow (schedules that kill op 0 only ever test "connect
        failed", which the backoff tests cover directly).
        """
        rng = random.Random(seed)
        n = min(n_faults, max(0, horizon - first_op))
        ops = sorted(rng.sample(range(first_op, horizon), n))
        faults = tuple(
            Fault(op, kind, stall_s if kind == "stall" else 0.0)
            for op, kind in ((op, rng.choice(kinds)) for op in ops))
        return cls(faults)

    def by_op(self) -> Dict[int, Fault]:
        return {f.op: f for f in self.faults}

    def __len__(self) -> int:
        return len(self.faults)


class FaultyTransport(Transport):
    """Wrap ``inner`` and inject faults from a deterministic schedule.

    Counters (`bytes_*`, `frames_*`) mirror the inner transport so
    ledger reconciliation still works; ``injected`` records every fault
    that actually fired as ``(op, kind)`` for replay assertions, and
    ``frame_log`` keeps small frames (CONTROL-sized) as
    ``(direction, bytes)`` so tests can audit what crossed the wire on
    error paths.
    """

    def __init__(self, inner: Transport, schedule: FaultSchedule = FaultSchedule(),
                 *, record_frames: bool = False):
        super().__init__()
        self.inner = inner
        self.schedule = schedule
        self.injected: List[Tuple[int, str]] = []
        self.frame_log: List[Tuple[str, bytes]] = []
        self._record = record_frames
        self._by_op = schedule.by_op()
        self._op = 0
        self._dead = False
        self._pending: "deque[bytes]" = deque()  # duplicated frames
        self._lock = threading.Lock()

    # -- counters mirror the inner transport ---------------------------
    @property
    def bytes_sent(self):  # type: ignore[override]
        return self.inner.bytes_sent

    @bytes_sent.setter
    def bytes_sent(self, v):
        pass

    @property
    def bytes_recv(self):  # type: ignore[override]
        return self.inner.bytes_recv

    @bytes_recv.setter
    def bytes_recv(self, v):
        pass

    @property
    def frames_sent(self):  # type: ignore[override]
        return self.inner.frames_sent

    @frames_sent.setter
    def frames_sent(self, v):
        pass

    @property
    def frames_recv(self):  # type: ignore[override]
        return self.inner.frames_recv

    @frames_recv.setter
    def frames_recv(self, v):
        pass

    @property
    def op(self) -> int:
        """The next op index the shared send/recv counter will assign."""
        with self._lock:
            return self._op

    def arm(self, fault: Fault) -> None:
        """Add a fault at an absolute op index on a live transport —
        tests use ``ft.arm(Fault(ft.op + k, ...))`` to land a kill a
        known number of ops into the *next* exchange."""
        with self._lock:
            self._by_op[fault.op] = fault

    # -- fault machinery ----------------------------------------------
    def _next_fault(self) -> Tuple[int, Optional[Fault]]:
        with self._lock:
            op = self._op
            self._op += 1
        return op, self._by_op.get(op)

    def _kill(self, op: int, why: str) -> None:
        self._dead = True
        try:
            self.inner.close()
        except OSError:
            pass
        raise TransportClosed(f"injected {why} at op {op}")

    def _log_frame(self, direction: str, frame: bytes) -> None:
        if self._record and len(frame) <= _LOG_FRAME_CAP:
            self.frame_log.append((direction, frame))

    # -- Transport interface -------------------------------------------
    def send(self, frame: bytes) -> None:
        op, fault = self._next_fault()
        if self._dead:
            raise TransportClosed("injected fault: transport already dead")
        if fault is not None:
            self.injected.append((op, fault.kind))
            if fault.kind == "reset":
                self._kill(op, "reset")
            if fault.kind == "stall":
                time.sleep(fault.delay_s)
            elif fault.kind == "torn":
                torn = frame[:max(1, len(frame) // 2)]
                self._log_frame("send", torn)
                self.inner.send(torn)
                self._kill(op, "torn frame")
            elif fault.kind == "dup":
                self._log_frame("send", frame)
                self.inner.send(frame)  # once here, once below
        self._log_frame("send", frame)
        self.inner.send(frame)

    def recv(self, timeout: Optional[float] = None) -> bytes:
        op, fault = self._next_fault()
        if self._dead:
            raise TransportClosed("injected fault: transport already dead")
        if fault is not None:
            self.injected.append((op, fault.kind))
            if fault.kind == "reset":
                self._kill(op, "reset")
            if fault.kind == "stall":
                if timeout is not None and fault.delay_s >= timeout:
                    # the peer is still stalled when the deadline fires
                    time.sleep(timeout)
                    raise TransportTimeout(
                        f"injected stall at op {op} outlived "
                        f"timeout={timeout}s")
                time.sleep(fault.delay_s)
            elif fault.kind == "torn":
                frame = self.inner.recv(timeout=timeout)
                torn = frame[:max(1, len(frame) // 2)]
                self._log_frame("recv", torn)
                self._dead = True
                try:
                    self.inner.close()
                except OSError:
                    pass
                return torn
            elif fault.kind == "dup":
                frame = self.inner.recv(timeout=timeout)
                self._pending.append(frame)
                self._log_frame("recv", frame)
                return frame
        if self._pending:
            frame = self._pending.popleft()  # the duplicate delivery
        else:
            frame = self.inner.recv(timeout=timeout)
        self._log_frame("recv", frame)
        return frame

    def close(self) -> None:
        self.inner.close()


@dataclass
class FaultPlan:
    """Seeded fault schedules for a whole client, across reconnects.

    Connection ``i`` (in wrap order) gets
    ``FaultSchedule.from_seed(seed * 1009 + i, ...)`` while
    ``i < faulty_conns`` and an empty schedule afterwards, so a
    reconnecting client eventually runs on clean transports and the
    chaos run terminates. All wrapped transports are kept on
    ``transports`` for post-run assertions (injected-fault logs, frame
    hygiene).
    """

    seed: int
    faulty_conns: int = 2
    n_faults: int = 1
    first_op: int = 2
    horizon: int = 64
    kinds: Tuple[str, ...] = KINDS
    stall_s: float = 0.25
    record_frames: bool = False
    transports: List[FaultyTransport] = field(default_factory=list)

    def __post_init__(self):
        self._lock = threading.Lock()
        self._conns = 0

    def schedule_for(self, conn_index: int) -> FaultSchedule:
        if conn_index >= self.faulty_conns:
            return FaultSchedule(())
        return FaultSchedule.from_seed(
            self.seed * 1009 + conn_index, n_faults=self.n_faults,
            first_op=self.first_op, horizon=self.horizon, kinds=self.kinds,
            stall_s=self.stall_s)

    def wrap(self, inner: Transport) -> FaultyTransport:
        with self._lock:
            i = self._conns
            self._conns += 1
        ft = FaultyTransport(inner, self.schedule_for(i),
                             record_frames=self.record_frames)
        self.transports.append(ft)
        return ft

    def injected(self) -> List[Tuple[int, int, str]]:
        """Every fault that fired, as (conn_index, op, kind)."""
        out = []
        for i, ft in enumerate(self.transports):
            out.extend((i, op, kind) for op, kind in ft.injected)
        return out

"""Versioned wire format for the two-party PiT runtime.

Two frame families share one header:

* **PROTO** frames carry protocol-metered traffic: a batch of raw,
  tag-addressed segments. A segment's payload length is *exactly* the
  byte count the in-process ``ot.Channel`` meters for that message (the
  simulation is the size oracle), so the per-tag wire ledger can be
  asserted equal to the metered ledger. Payloads are raw bytes with **no
  per-array metadata** — both endpoints walk the same compiled plan in
  lockstep, so every shape is known statically. This is also what makes
  the encoding deterministic ("golden bytes"): same plan + same arrays →
  same frame bytes.

* **CONTROL / SIM** frames carry a tag plus one typed payload (None,
  bool, int, float, str, bytes, list, dict, numpy array — jax arrays are
  converted). CONTROL drives the session state machine (hello,
  preprocess, run, error); SIM is the simulation sideband: data the
  metered oracle treats as implicit (garbled-circuit decode metadata,
  the final output shares) — counted separately as overhead, never in
  the protocol ledger.

Layout (all integers little-endian)::

    frame   := magic "PW" | version u8 | kind u8 | phase u8 | body
    PROTO   := nseg u32 | seg*
    seg     := dir u8 | taglen u16 | tag utf8 | len u64 | raw bytes
    CONTROL := taglen u16 | tag utf8 | obj
    SIM     := same as CONTROL

Typed object encoding (``obj``) uses a one-byte type marker; arrays are
``'A' | dtype-str | ndim u8 | dims u64* | C-order raw bytes``.

Version 2 (negotiated at hello, v1 remains fully supported) revises the
PROTO payload encodings only — the frame layout is unchanged except for
the version byte:

* **seed streams** — label streams whose receiver is *entitled* to the
  whole stream (the garbler's mask-input labels, which are active labels
  by construction) ship as a 32-byte ``(seed, counter)`` record; the
  receiver replays the PRG (:func:`repro.core.labels.stream_labels`).
* **delta-encoded table batches** — a slab of per-instance garbled
  tables ships as one full anchor instance plus 8 B/AND-gate
  per-instance delta records. The 24 B/AND residual needed to invert the
  delta code travels on the SIM sideband and is ledgered as simulation
  overhead, like every other stand-in the size oracle models
  (identity-HE blocks, the reveal sideband).
* **IKNP OT** — the sim-OT blocks are replaced by a real base-OT +
  extension-matrix exchange (:mod:`repro.core.ot`): κ=128
  Chou–Orlandi base OTs at hello-follow-up, then per-batch a 16 B/OT
  column matrix (receiver→sender) and a 32 B/OT masked-pair response.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np

WIRE_VERSION = 1
WIRE_V2 = 2
SUPPORTED_VERSIONS = (WIRE_VERSION, WIRE_V2)
MAGIC = b"PW"

KIND_CONTROL = 0
KIND_PROTO = 1
KIND_SIM = 2

PHASE_NONE = 0
PHASE_OFFLINE = 1
PHASE_ONLINE = 2

DIR_C2S = 0
DIR_S2C = 1


class WireError(ValueError):
    """Malformed or version-incompatible frame."""


# ---------------------------------------------------------------------------
# typed object codec (CONTROL / SIM payloads)
# ---------------------------------------------------------------------------


def _enc_obj(out: bytearray, obj) -> None:
    if obj is None:
        out += b"N"
    elif obj is True:
        out += b"T"
    elif obj is False:
        out += b"F"
    elif isinstance(obj, int):
        raw = obj.to_bytes((obj.bit_length() + 8) // 8 + 1, "little",
                           signed=True)
        out += b"I" + struct.pack("<H", len(raw)) + raw
    elif isinstance(obj, float):
        out += b"D" + struct.pack("<d", obj)
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out += b"S" + struct.pack("<I", len(raw)) + raw
    elif isinstance(obj, (bytes, bytearray)):
        out += b"B" + struct.pack("<Q", len(obj)) + bytes(obj)
    elif isinstance(obj, (list, tuple)):
        out += b"L" + struct.pack("<I", len(obj))
        for v in obj:
            _enc_obj(out, v)
    elif isinstance(obj, dict):
        out += b"M" + struct.pack("<I", len(obj))
        for k, v in obj.items():
            if not isinstance(k, str):
                raise WireError(f"dict keys must be str, got {type(k)}")
            kr = k.encode("utf-8")
            out += struct.pack("<H", len(kr)) + kr
            _enc_obj(out, v)
    elif isinstance(obj, np.generic):  # numpy scalar → python scalar
        _enc_obj(out, obj.item())
    else:
        a = np.ascontiguousarray(np.asarray(obj))  # numpy or jax array
        ds = a.dtype.str.encode("ascii")
        out += b"A" + struct.pack("<B", len(ds)) + ds
        out += struct.pack("<B", a.ndim)
        out += struct.pack(f"<{a.ndim}Q", *a.shape) if a.ndim else b""
        raw = a.tobytes()
        out += struct.pack("<Q", len(raw)) + raw


def _dec_obj(buf: memoryview, pos: int):
    t = bytes(buf[pos: pos + 1])
    pos += 1
    if t == b"N":
        return None, pos
    if t == b"T":
        return True, pos
    if t == b"F":
        return False, pos
    if t == b"I":
        (n,) = struct.unpack_from("<H", buf, pos)
        pos += 2
        return int.from_bytes(bytes(buf[pos: pos + n]), "little",
                              signed=True), pos + n
    if t == b"D":
        (v,) = struct.unpack_from("<d", buf, pos)
        return v, pos + 8
    if t == b"S":
        (n,) = struct.unpack_from("<I", buf, pos)
        pos += 4
        return bytes(buf[pos: pos + n]).decode("utf-8"), pos + n
    if t == b"B":
        (n,) = struct.unpack_from("<Q", buf, pos)
        pos += 8
        return bytes(buf[pos: pos + n]), pos + n
    if t == b"L":
        (n,) = struct.unpack_from("<I", buf, pos)
        pos += 4
        out = []
        for _ in range(n):
            v, pos = _dec_obj(buf, pos)
            out.append(v)
        return out, pos
    if t == b"M":
        (n,) = struct.unpack_from("<I", buf, pos)
        pos += 4
        out = {}
        for _ in range(n):
            (kl,) = struct.unpack_from("<H", buf, pos)
            pos += 2
            k = bytes(buf[pos: pos + kl]).decode("utf-8")
            pos += kl
            out[k], pos = _dec_obj(buf, pos)
        return out, pos
    if t == b"A":
        (dl,) = struct.unpack_from("<B", buf, pos)
        pos += 1
        dt = np.dtype(bytes(buf[pos: pos + dl]).decode("ascii"))
        pos += dl
        (nd,) = struct.unpack_from("<B", buf, pos)
        pos += 1
        shape = struct.unpack_from(f"<{nd}Q", buf, pos) if nd else ()
        pos += 8 * nd
        (n,) = struct.unpack_from("<Q", buf, pos)
        pos += 8
        arr = np.frombuffer(buf[pos: pos + n], dt).reshape(shape).copy()
        return arr, pos + n
    raise WireError(f"unknown type marker {t!r}")


# ---------------------------------------------------------------------------
# frames
# ---------------------------------------------------------------------------


@dataclass
class Seg:
    """One protocol-metered message: raw payload addressed by ledger tag."""

    tag: str
    dir: int  # DIR_C2S | DIR_S2C — the *logical* direction the oracle meters
    data: bytes


@dataclass
class Msg:
    """A decoded frame."""

    kind: int
    phase: int = PHASE_NONE
    tag: str = ""
    payload: object = None
    segs: List[Seg] = field(default_factory=list)
    version: int = WIRE_VERSION


def _enc_tag(tag: str) -> bytes:
    raw = tag.encode("utf-8")
    return struct.pack("<H", len(raw)) + raw


def encode_msg(kind: int, tag: str = "", payload=None,
               phase: int = PHASE_NONE,
               version: int = WIRE_VERSION) -> bytes:
    """Encode a CONTROL or SIM frame."""
    if kind not in (KIND_CONTROL, KIND_SIM):
        raise WireError("encode_msg is for CONTROL/SIM frames")
    out = bytearray()
    out += MAGIC + struct.pack("<BBB", version, kind, phase)
    out += _enc_tag(tag)
    _enc_obj(out, payload)
    return bytes(out)


def encode_proto(segs: Sequence[Seg], phase: int,
                 version: int = WIRE_VERSION) -> bytes:
    """Encode a PROTO frame: a batch of raw tagged segments.

    nseg is u32: a preprocess response batches one segment per
    (op × bundle), which clears u16 at production batch sizes.
    """
    out = bytearray()
    out += MAGIC + struct.pack("<BBB", version, KIND_PROTO, phase)
    out += struct.pack("<I", len(segs))
    for s in segs:
        out += struct.pack("<B", s.dir) + _enc_tag(s.tag)
        out += struct.pack("<Q", len(s.data)) + s.data
    return bytes(out)


def decode_frame(data: bytes) -> Msg:
    buf = memoryview(data)
    if bytes(buf[:2]) != MAGIC:
        raise WireError("bad magic")
    ver, kind, phase = struct.unpack_from("<BBB", buf, 2)
    if ver not in SUPPORTED_VERSIONS:
        raise WireError(
            f"wire version {ver} not in {SUPPORTED_VERSIONS}")
    pos = 5
    if kind == KIND_PROTO:
        (nseg,) = struct.unpack_from("<I", buf, pos)
        pos += 4
        segs = []
        for _ in range(nseg):
            (d,) = struct.unpack_from("<B", buf, pos)
            pos += 1
            (tl,) = struct.unpack_from("<H", buf, pos)
            pos += 2
            tag = bytes(buf[pos: pos + tl]).decode("utf-8")
            pos += tl
            (n,) = struct.unpack_from("<Q", buf, pos)
            pos += 8
            segs.append(Seg(tag, d, bytes(buf[pos: pos + n])))
            pos += n
        return Msg(kind=kind, phase=phase, segs=segs, version=ver)
    (tl,) = struct.unpack_from("<H", buf, pos)
    pos += 2
    tag = bytes(buf[pos: pos + tl]).decode("utf-8")
    pos += tl
    payload, pos = _dec_obj(buf, pos)
    return Msg(kind=kind, phase=phase, tag=tag, payload=payload,
               version=ver)


# ---------------------------------------------------------------------------
# raw payload packers (shape-oracle encodings; sizes match the meter)
# ---------------------------------------------------------------------------


def pack_u64(arr: np.ndarray) -> bytes:
    """Share residues: 8 bytes/element (the meter's ``size * 8``)."""
    return np.ascontiguousarray(np.asarray(arr, np.uint64)).tobytes()


def unpack_u64(data: bytes, shape: Tuple[int, ...]) -> np.ndarray:
    return np.frombuffer(data, np.uint64).reshape(shape).copy()


def pack_labels(lab) -> bytes:
    """GC labels (..., 4) uint32: 16 bytes/label."""
    return np.ascontiguousarray(np.asarray(lab, np.uint32)).tobytes()


def unpack_labels(data: bytes, shape: Tuple[int, ...]) -> np.ndarray:
    return np.frombuffer(data, np.uint32).reshape(*shape, 4).copy()


def pack_tables(tables) -> bytes:
    """Garbled tables (I, nAND, 2, 4) uint32: the meter's ``size * 4``."""
    return np.ascontiguousarray(np.asarray(tables, np.uint32)).tobytes()


def unpack_tables(data: bytes, instances: int, n_and: int) -> np.ndarray:
    return np.frombuffer(data, np.uint32).reshape(
        instances, max(n_and, 1), 2, 4).copy()


def ct_pack(arr: np.ndarray, ct_bytes: int, poly_n: int) -> bytes:
    """Pack uint64 coefficients into BFV-ciphertext-sized blocks.

    The simulation's stand-in for encryption is the identity with
    padding: a block is exactly ``ct_bytes`` (2 polys × RNS limbs ×
    ``poly_n`` × 8B) and carries up to ``poly_n`` plaintext coefficients
    at its head — so wire sizes equal the metered ``ct_count *
    ct_bytes`` while the receiving party can still run the oracle math.
    """
    a = np.ascontiguousarray(np.asarray(arr, np.uint64))
    ct_count = max(1, -(-a.size // poly_n)) if a.size else 0
    out = bytearray(ct_count * ct_bytes)
    raw = a.tobytes()
    out[: len(raw)] = raw
    return bytes(out)


def ct_unpack(data: bytes, shape: Tuple[int, ...]) -> np.ndarray:
    n = int(np.prod(shape)) if shape else 1
    return np.frombuffer(data[: n * 8], np.uint64).reshape(shape).copy()


def ct_blocks(nelems: int, poly_n: int) -> int:
    return max(1, -(-nelems // poly_n)) if nelems else 0


def ct_pack_rows(arr: np.ndarray, ct_bytes: int) -> bytes:
    """One ciphertext block per leading-dim row (the meter's ``I *
    ct_bytes`` shape, e.g. the per-row LayerNorm inner-product cts)."""
    a = np.ascontiguousarray(np.asarray(arr, np.uint64))
    a = a.reshape(a.shape[0], -1)
    if a.shape[1] * 8 > ct_bytes:
        raise WireError("row does not fit one ciphertext block")
    out = np.zeros((a.shape[0], ct_bytes), np.uint8)
    out[:, : a.shape[1] * 8] = a.view(np.uint8).reshape(a.shape[0], -1)
    return out.tobytes()


def ct_unpack_rows(data: bytes, rows: int, ct_bytes: int,
                   row_elems: int = 1) -> np.ndarray:
    blocks = np.frombuffer(data, np.uint8).reshape(rows, ct_bytes)
    vals = np.ascontiguousarray(blocks[:, : row_elems * 8]).view(np.uint64)
    shape = (rows,) if row_elems == 1 else (rows, row_elems)
    return vals.reshape(shape).copy()


def pack_ot_request(bits: np.ndarray, msg_bytes: int = None) -> bytes:
    """Receiver's OT messages: one ``msg_bytes`` block per choice bit.

    The real IKNP column message is κ masked bits; the simulation embeds
    the choice bit in byte 0 of an otherwise-zero block so the garbler
    can run the OT functionality, at exactly the metered size (block
    sizes come from ``core/ot.py`` — the meter and the wire share one
    cost model by construction).
    """
    from repro.core.ot import OT_MSG_BYTES

    msg_bytes = OT_MSG_BYTES if msg_bytes is None else msg_bytes
    flat = np.asarray(bits, np.uint8).reshape(-1)
    out = np.zeros((flat.size, msg_bytes), np.uint8)
    out[:, 0] = flat
    return out.tobytes()


def unpack_ot_request(data: bytes, shape: Tuple[int, ...],
                      msg_bytes: int = None) -> np.ndarray:
    from repro.core.ot import OT_MSG_BYTES

    msg_bytes = OT_MSG_BYTES if msg_bytes is None else msg_bytes
    n = int(np.prod(shape))
    return (np.frombuffer(data, np.uint8).reshape(n, msg_bytes)[:, 0]
            .reshape(shape).copy())


def pack_ot_response(labels, per_transfer: int = None) -> bytes:
    """Sender's masked pairs: chosen label (16B) + IKNP padding."""
    from repro.core.ot import OT_BYTES_PER_TRANSFER

    per_transfer = OT_BYTES_PER_TRANSFER if per_transfer is None \
        else per_transfer
    lab = np.ascontiguousarray(np.asarray(labels, np.uint32))
    n = lab.size // 4
    out = np.zeros((n, per_transfer), np.uint8)
    out[:, :16] = lab.reshape(n, 4).view(np.uint8)
    return out.tobytes()


def unpack_ot_response(data: bytes, shape: Tuple[int, ...],
                       per_transfer: int = None) -> np.ndarray:
    from repro.core.ot import OT_BYTES_PER_TRANSFER

    per_transfer = OT_BYTES_PER_TRANSFER if per_transfer is None \
        else per_transfer
    n = int(np.prod(shape))
    blocks = np.frombuffer(data, np.uint8).reshape(n, per_transfer)
    lab = np.ascontiguousarray(blocks[:, :16]).view(np.uint32)
    return lab.reshape(*shape, 4).copy()


# ---------------------------------------------------------------------------
# v2 payload packers: seed streams + delta-encoded table batches
# ---------------------------------------------------------------------------
# The byte-size model is shared with the in-process oracle and lives in
# repro.core.wireformat (a pure struct/arith module — no cycle); the
# packers here are the codec side of the same format.

from repro.core.wireformat import (  # noqa: E402  (re-exported)
    SEED_STREAM_BYTES,
    TABLE_DELTA_HDR as _TABLE_DELTA_HDR,
    TABLE_DELTA_WORDS,
    tables_delta_anchor_bytes,
    tables_delta_wire_bytes,
    tables_resid_bytes,
)


def pack_seed_stream(seed: bytes, counter: int, count: int) -> bytes:
    """A PRG-seeded label stream: replaces ``count`` raw labels.

    ``seed`` is the 16-byte stream seed, ``counter`` the stream offset of
    the first label, ``count`` how many labels the receiver derives.
    """
    if len(seed) != 16:
        raise WireError("seed stream seed must be 16 bytes")
    return seed + struct.pack("<QQ", counter, count)


def unpack_seed_stream(data: bytes) -> Tuple[bytes, int, int]:
    if len(data) != SEED_STREAM_BYTES:
        raise WireError("bad seed stream segment length")
    counter, count = struct.unpack_from("<QQ", data, 16)
    return bytes(data[:16]), counter, count


def pack_tables_delta(tables) -> Tuple[bytes, bytes]:
    """Delta-encode a table slab → (PROTO wire bytes, SIM residual).

    Instance 0 ships verbatim as the anchor; instances ``i > 0`` ship
    their XOR against instance ``i-1``, split into an on-wire head
    (``TABLE_DELTA_WORDS`` uint32 per AND row pair — the modeled delta
    record) and a sideband tail. The split is lossless: the receiver
    reassembles head+tail and undoes the running XOR, so reconstruction
    is exact while the PROTO channel carries the modeled batch size.
    """
    t = np.ascontiguousarray(np.asarray(tables, np.uint32))
    inst, rows = int(t.shape[0]), int(t.shape[1])
    words = t.reshape(inst, rows, 8)
    d = words.copy()
    if inst > 1:
        d[1:] ^= words[:-1]
    wire = bytearray()
    wire += _TABLE_DELTA_HDR.pack(inst, rows, TABLE_DELTA_WORDS)
    wire += d[0].tobytes()
    resid = b""
    if inst > 1:
        wire += np.ascontiguousarray(d[1:, :, :TABLE_DELTA_WORDS]).tobytes()
        resid = np.ascontiguousarray(d[1:, :, TABLE_DELTA_WORDS:]).tobytes()
    return bytes(wire), resid


def unpack_tables_delta(wire: bytes, resid: bytes, instances: int,
                        n_and: int) -> np.ndarray:
    """Invert :func:`pack_tables_delta` → tables ``(I, rows, 2, 4)``."""
    inst, rows, dw = _TABLE_DELTA_HDR.unpack_from(wire, 0)
    if inst != instances or rows != max(n_and, 1) or dw != TABLE_DELTA_WORDS:
        raise WireError("table delta header does not match the plan")
    pos = _TABLE_DELTA_HDR.size
    d = np.empty((inst, rows, 8), np.uint32)
    d[0] = np.frombuffer(wire, np.uint32, rows * 8, pos).reshape(rows, 8)
    if inst > 1:
        pos += rows * 32
        head = np.frombuffer(wire, np.uint32, (inst - 1) * rows * dw, pos)
        d[1:, :, :dw] = head.reshape(inst - 1, rows, dw)
        tail = np.frombuffer(resid, np.uint32).reshape(inst - 1, rows, 8 - dw)
        d[1:, :, dw:] = tail
    tables = np.bitwise_xor.accumulate(d, axis=0)
    return tables.reshape(inst, rows, 2, 4)

"""Pluggable byte transports for the two-party runtime.

A :class:`Transport` moves whole frames (opaque byte strings) between two
endpoints, full-duplex. Two implementations:

* :class:`InProcPipe` — queue-backed, for same-process endpoints on two
  threads. Zero syscalls; the default for tests and for measuring pure
  protocol overhead.
* :class:`TcpTransport` — length-prefixed framing over a socket
  (loopback or real NICs), with :class:`TcpListener` for the serving
  side. ``TCP_NODELAY`` is set: the runtime already batches per-op
  messages, so Nagle only adds latency.

Both support *LAN-model shaping* (``bandwidth_bps`` / ``latency_s``):
each sent frame pays ``latency + bytes·8/bandwidth`` of sleep on the
sender, replaying the paper's 9.6 Gb/s / 0.165 ms setting so measured
wall-clock can be compared against the metered ``Channel.time_s``
prediction.

Every endpoint counts ``bytes_sent`` / ``bytes_recv`` (payload) and
``frames_sent`` / ``frames_recv``; the framing overhead (u64 length
prefixes) is ``8 * frames`` and reported by the benchmarks separately
from protocol payload.
"""

from __future__ import annotations

import dataclasses
import queue
import socket
import struct
import threading
import time
from typing import Callable, Optional, Tuple


class TransportClosed(ConnectionError):
    """The peer closed the connection (or the stream is unrecoverable)."""


class TransportTimeout(TransportClosed):
    """A recv/accept deadline expired with the connection still open.

    Subclasses :class:`TransportClosed` so every existing ``except
    TransportClosed`` teardown path still fires, but callers that care
    (the resilient client, the evaluator serve loop) can distinguish "the
    peer is slow" from "the peer is gone": a timeout at a frame boundary
    leaves the stream intact and the operation retryable, a close does
    not.
    """


@dataclasses.dataclass(frozen=True)
class Deadlines:
    """Per-phase recv deadlines for an endpoint (seconds, None = block).

    The two-party walk has phases with wildly different latency
    envelopes: a ``hello`` answers in one round trip, an ``offline`` prep
    streams garbled slabs for seconds, an ``online`` op is
    sub-second, and an ``idle`` serve loop may legitimately sit for a
    long time between client requests. One uniform timeout either kills
    idle sessions or lets a stalled prep hang for the idle budget —
    per-phase deadlines bound each wait by what that phase can honestly
    need. Unset phases fall back to ``default_s``.
    """

    hello_s: Optional[float] = None
    offline_s: Optional[float] = None
    online_s: Optional[float] = None
    idle_s: Optional[float] = None
    default_s: Optional[float] = None

    @classmethod
    def uniform(cls, timeout_s: Optional[float]) -> "Deadlines":
        return cls(default_s=timeout_s)

    def for_phase(self, phase: str) -> Optional[float]:
        t = getattr(self, f"{phase}_s", None)
        return self.default_s if t is None else t


class Transport:
    """Frame transport base: counts traffic and applies LAN shaping."""

    def __init__(self, *, bandwidth_bps: float = 0.0, latency_s: float = 0.0):
        self.bandwidth_bps = float(bandwidth_bps)
        self.latency_s = float(latency_s)
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.frames_sent = 0
        self.frames_recv = 0

    # -- shaping -------------------------------------------------------
    def _shape(self, nbytes: int) -> None:
        dt = self.latency_s
        if self.bandwidth_bps > 0:
            dt += nbytes * 8.0 / self.bandwidth_bps
        if dt > 0:
            time.sleep(dt)

    # -- interface -----------------------------------------------------
    def send(self, frame: bytes) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> bytes:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# in-process pipe
# ---------------------------------------------------------------------------


_CLOSE = object()


class InProcPipe(Transport):
    """One end of a threaded, queue-backed duplex pipe.

    ``recv_gate`` (optional :class:`threading.Event`) holds back frame
    *delivery* on this end until set — benchmarks/tests use it to pin a
    peer mid-exchange and prove that traffic on another transport keeps
    flowing (the pipelined refill-vs-serve overlap).
    """

    def __init__(self, send_q: "queue.Queue", recv_q: "queue.Queue",
                 **shaping):
        super().__init__(**shaping)
        self._send_q = send_q
        self._recv_q = recv_q
        self._closed = False
        self.recv_gate = None

    @classmethod
    def make_pair(cls, **shaping) -> Tuple["InProcPipe", "InProcPipe"]:
        """Two connected ends; shaping applies to both directions."""
        a2b: "queue.Queue" = queue.Queue()
        b2a: "queue.Queue" = queue.Queue()
        return cls(a2b, b2a, **shaping), cls(b2a, a2b, **shaping)

    def send(self, frame: bytes) -> None:
        if self._closed:
            raise TransportClosed("pipe closed")
        self._shape(len(frame))
        self.bytes_sent += len(frame)
        self.frames_sent += 1
        self._send_q.put(frame)

    def recv(self, timeout: Optional[float] = None) -> bytes:
        if self.recv_gate is not None:
            if not self.recv_gate.wait(timeout=timeout):
                raise TransportTimeout(
                    f"recv gate not released within {timeout}s")
        try:
            frame = self._recv_q.get(timeout=timeout)
        except queue.Empty:
            raise TransportTimeout(
                f"recv timed out after {timeout}s") from None
        if frame is _CLOSE:
            raise TransportClosed("peer closed the pipe")
        self.bytes_recv += len(frame)
        self.frames_recv += 1
        return frame

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._send_q.put(_CLOSE)


# ---------------------------------------------------------------------------
# TCP
# ---------------------------------------------------------------------------

# u64 length prefix: preprocess ships each netlist's garbled-table slab
# for a whole bundle batch as one frame, which crosses 4 GiB at
# production scale — a u32 prefix would fail only then, and only on TCP
_LEN = struct.Struct("<Q")


class TcpTransport(Transport):
    """Length-prefixed frames over a connected socket."""

    def __init__(self, sock: socket.socket, **shaping):
        super().__init__(**shaping)
        self._sock = sock
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass

    @classmethod
    def connect(cls, host: str, port: int, *, timeout: Optional[float] = 30.0,
                **shaping) -> "TcpTransport":
        sock = socket.create_connection((host, port), timeout=timeout)
        sock.settimeout(None)
        return cls(sock, **shaping)

    def send(self, frame: bytes) -> None:
        self._shape(len(frame))
        try:
            self._sock.sendall(_LEN.pack(len(frame)) + frame)
        except OSError as e:
            raise TransportClosed(f"send failed: {e}") from e
        self.bytes_sent += len(frame)
        self.frames_sent += 1

    def _recv_exact(self, n: int, *, mid_frame: bool = False) -> bytes:
        chunks = []
        while n:
            try:
                chunk = self._sock.recv(min(n, 1 << 20))
            except socket.timeout:
                if chunks or mid_frame:
                    # partial frame consumed: the byte stream has lost
                    # its framing — no retry can resynchronize it
                    raise TransportClosed(
                        "recv timed out mid-frame: framing lost") from None
                raise TransportTimeout("recv timed out") from None
            except OSError as e:
                raise TransportClosed(f"recv failed: {e}") from e
            if not chunk:
                raise TransportClosed("peer closed the socket")
            chunks.append(chunk)
            n -= len(chunk)
        return b"".join(chunks)

    def recv(self, timeout: Optional[float] = None) -> bytes:
        # settimeout on a socket closed by another thread (server
        # shutdown racing a blocked endpoint) raises EBADF — that is a
        # close, not an error worth a thread's life
        try:
            self._sock.settimeout(timeout)
        except OSError as e:
            raise TransportClosed(f"recv failed: {e}") from e
        try:
            (n,) = _LEN.unpack(self._recv_exact(_LEN.size))
            frame = self._recv_exact(n, mid_frame=True)
        finally:
            try:
                self._sock.settimeout(None)
            except OSError:
                pass
        self.bytes_recv += len(frame)
        self.frames_recv += 1
        return frame

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


class AcceptLoop:
    """Handle on a background accept loop (see ``TcpListener.accept_loop``).

    ``accepted`` counts handed-off connections; ``wait_accepted(n)``
    blocks until at least ``n`` arrived (how callers sequence "connect,
    then talk" without racing the acceptor); ``stop()`` asks the loop to
    exit at its next poll and ``join()`` waits for the thread. Closing
    the listener also stops the loop (the blocked ``accept`` fails).
    """

    def __init__(self, thread: threading.Thread, stop_event: threading.Event):
        self._thread = thread
        self._stop = stop_event
        self._cv = threading.Condition()
        self.accepted = 0
        self.error: Optional[BaseException] = None  # handler failure, if any

    def _note_accept(self) -> None:
        with self._cv:
            self.accepted += 1
            self._cv.notify_all()

    def wait_accepted(self, n: int, timeout: Optional[float] = None) -> bool:
        with self._cv:
            return self._cv.wait_for(lambda: self.accepted >= n,
                                     timeout=timeout)

    def stop(self) -> None:
        self._stop.set()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout=timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()


class TcpListener:
    """Serving-side acceptor: ``TcpListener() -> accept() -> TcpTransport``."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 backlog: int = 4):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(backlog)
        # serialize accepts: settimeout is socket-wide state, and callers
        # (PitNetServer.serve_tcp) accept from several threads at once
        self._accept_lock = threading.Lock()

    @property
    def address(self) -> Tuple[str, int]:
        return self._sock.getsockname()[:2]

    @property
    def port(self) -> int:
        return self.address[1]

    def accept(self, timeout: Optional[float] = None, **shaping
               ) -> TcpTransport:
        with self._accept_lock:
            self._sock.settimeout(timeout)
            try:
                sock, _ = self._sock.accept()
            except socket.timeout:
                raise TransportTimeout(
                    f"accept timed out after {timeout}s") from None
            finally:
                self._sock.settimeout(None)
        sock.settimeout(None)
        return TcpTransport(sock, **shaping)

    def accept_loop(self, handler: Callable[[TcpTransport], None], *,
                    accept_timeout: float = 1.0,
                    max_accepts: Optional[int] = None,
                    name: str = "accept-loop", **shaping) -> AcceptLoop:
        """Accept connections in the background until stopped.

        Every accepted transport is handed to ``handler`` from the loop
        thread (handlers that serve should spawn and return, like
        ``PitNetServer.serve_transport``). ``accept_timeout`` is the
        poll interval at which the loop re-checks its stop flag, so
        ``stop()`` takes effect within one interval; closing the
        listener stops it immediately. ``max_accepts`` bounds the number
        of connections (None = until stopped). A handler exception stops
        the loop and is kept on ``AcceptLoop.error`` — an acceptor that
        silently drops connections would look exactly like a network
        problem to clients.
        """
        stop = threading.Event()

        def work() -> None:
            while not stop.is_set():
                if max_accepts is not None and loop.accepted >= max_accepts:
                    return
                try:
                    transport = self.accept(timeout=accept_timeout, **shaping)
                except TransportClosed:
                    continue  # poll timeout: re-check the stop flag
                except OSError:
                    return  # listener closed under us: clean shutdown
                try:
                    handler(transport)
                except Exception as e:
                    loop.error = e
                    transport.close()
                    return
                loop._note_accept()

        th = threading.Thread(target=work, daemon=True, name=name)
        loop = AcceptLoop(th, stop)
        th.start()
        return loop

    def close(self) -> None:
        self._sock.close()

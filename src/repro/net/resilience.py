"""Client-side resilience: reconnect, resume, backoff, burn-on-interrupt.

:class:`ResilientClient` wraps the pipelined two-endpoint client
(offline + online :class:`GarblerEndpoint` pair over one
:class:`ClientShared`) and makes its operations survive transport
faults:

* **Reconnect + resume.** On any transport/protocol failure the client
  tears down *both* legs, then redials with exponential backoff +
  seeded jitter. The fresh hellos carry the client's existing uuid
  token, so a lease-holding server (``PitGateway(lease_s=...)``) rebinds
  the transports to the same :class:`SessionState` — the server-side
  bundle store, ledger, and epoch survive the reconnect. Both legs are
  always cycled together so the IKNP OT reset (``reset_ot`` in the
  hello) happens at a quiet point on both sides; half-pair reconnects
  would race the reset against an in-flight run's extension counters.
* **Typed give-up.** A reconnect that lands in a *different* session
  (the server reclaimed ours — lease expired or no lease) raises
  :class:`SessionLost`; exhausted retries re-raise the last typed error.
  Nothing in this module ever hangs: every wait is bounded by the
  endpoint deadlines plus the backoff budget.
* **Burn on interrupt.** A ``run`` that fails after its bundle was
  committed to the wire never reuses that bundle: partial label
  disclosure makes a second execution unsafe (two active labels per
  wire reconstructs the mask). The retry draws a *fresh* bundle —
  outputs stay bit-identical because reconstruction cancels whichever
  bundle's masks were used. Interrupted ``preprocess`` calls are
  idempotent by construction (neither side commits bundles before
  prep-done) and retry under fresh bundle ids.
* **Shed hints.** CONTROL ``shed`` frames (``BundlePoolEmpty``) are
  honored: the backoff sleeps at least the server's ``retry_after_s``.

Error text discipline matches the rest of the stack: retry/backoff/burn
paths log class names and counters, never exception payloads or label
bytes (``tests/fixtures/leaky_retry.py`` pins the lint rules for this).
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro import obs
from repro.net import wire as W
from repro.net.party import (
    ClientShared,
    GarblerEndpoint,
    NetProtocolError,
    SessionRebindError,
)
from repro.net.transport import (
    Deadlines,
    Transport,
    TransportClosed,
    TransportTimeout,
)
from repro.serve.errors import BundlePoolEmpty


class SessionLost(TransportClosed):
    """The server no longer holds our session: a resume hello was bound
    to a fresh session id. Pooled client bundles are unusable (their
    server halves are gone) — callers must start a new client."""


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with seeded jitter.

    ``attempts`` bounds each *operation* (one preprocess/run call), not
    the client's lifetime. Jitter is drawn from a ``random.Random(seed)``
    owned by the client, so a chaos run with a fixed seed replays the
    same backoff sequence.
    """

    attempts: int = 5
    base_s: float = 0.05
    factor: float = 2.0
    max_s: float = 2.0
    jitter: float = 0.25  # +/- fraction of the delay
    seed: int = 0

    def delay_s(self, attempt: int, rng: random.Random) -> float:
        d = min(self.max_s, self.base_s * (self.factor ** attempt))
        if self.jitter > 0:
            d *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return max(d, 0.0)


class ResilientClient:
    """A fault-tolerant pipelined client over one ``ClientShared``.

    ``connect`` is called once per fresh transport (twice per
    connection generation: offline leg first, then online) — wrap it
    with :class:`~repro.net.faults.FaultPlan` to chaos-test, or point it
    at ``TcpTransport.connect`` for production use.
    """

    def __init__(self, connect: Callable[[], Transport], *, seed: int = 0,
                 impl: str = "ref", policy: Optional[RetryPolicy] = None,
                 timeout: Optional[float] = None,
                 deadlines: Optional[Deadlines] = None,
                 wire_version: int = W.WIRE_V2, compression: bool = True):
        self._connect = connect
        self.policy = policy or RetryPolicy()
        self.timeout = timeout
        self.deadlines = deadlines
        self.shared = ClientShared(seed=seed, impl=impl,
                                   wire_version=wire_version,
                                   compression=compression)
        self.offline: Optional[GarblerEndpoint] = None
        self.online: Optional[GarblerEndpoint] = None
        self._rng = random.Random(self.policy.seed)
        self._lock = threading.RLock()  # one op at a time; resilience
        # wrapper serializes — pipelined throughput stays the concern of
        # NetPrivateServeEngine, this class's concern is surviving
        # faults without desyncing the pair
        # counters (read via stats())
        self.reconnects = 0
        self.resume_handshakes = 0
        self.bundles_burned = 0
        self.preps_retried = 0
        self.sheds_honored = 0
        self.backoffs = 0

    # -- connection management -----------------------------------------
    def _make_endpoint(self, *, reset_ot: bool) -> GarblerEndpoint:
        return GarblerEndpoint(self._connect(), shared=self.shared,
                               timeout=self.timeout,
                               deadlines=self.deadlines, reset_ot=reset_ot,
                               gen=self.reconnects)

    def _ensure_connected(self) -> None:
        if self.online is not None:
            return
        resuming = self.shared.plan is not None
        off = on = None
        try:
            # a resume must redo the base OT on both sides — the old
            # pair may have died mid-extension with desynced counters
            if resuming:
                with self.shared.lock:
                    self.shared.iknp = None
            off = self._make_endpoint(reset_ot=resuming)
            on = self._make_endpoint(reset_ot=resuming)
            try:
                off.handshake()
                on.handshake()
            except SessionRebindError as e:
                raise SessionLost(
                    "server reclaimed the session; pooled bundles are "
                    "void — start a new client") from e
        except BaseException:
            for ep in (off, on):
                if ep is not None:
                    self._close_quietly(ep)
            raise
        self.offline, self.online = off, on
        if resuming:
            self.resume_handshakes += 1
            obs.instant("resilience.resume", session=self.shared.session_id
                        if self.shared.session_id is not None else -1)

    @staticmethod
    def _close_quietly(ep: GarblerEndpoint) -> None:
        try:
            ep.transport.close()
        except OSError:
            pass

    def _teardown(self) -> None:
        """Drop both legs after a fault; the next op redials."""
        for ep in (self.offline, self.online):
            if ep is not None:
                self._close_quietly(ep)
        self.offline = self.online = None
        self.reconnects += 1

    def _backoff(self, attempt: int, hint_s: Optional[float] = None) -> None:
        d = self.policy.delay_s(attempt, self._rng)
        if hint_s is not None:
            d = max(d, float(hint_s))
            self.sheds_honored += 1
        self.backoffs += 1
        with obs.span("resilience.backoff", attempt=attempt,
                      delay_s=round(d, 4)):
            time.sleep(d)

    def _give_up(self, last: Optional[BaseException]) -> "BaseException":
        if isinstance(last, (TransportClosed, BundlePoolEmpty)):
            return last  # already typed
        name = type(last).__name__ if last is not None else "unknown"
        return TransportClosed(
            f"gave up after {self.policy.attempts} attempts "
            f"(last: {name})")

    # -- operations -----------------------------------------------------
    def handshake(self):
        with self._lock:
            last: Optional[BaseException] = None
            for attempt in range(self.policy.attempts):
                try:
                    self._ensure_connected()
                    return self.shared.plan
                except SessionLost:
                    raise
                except BundlePoolEmpty as e:
                    last = e
                    self._teardown()
                    self._backoff(attempt, e.retry_after_s)
                except (TransportClosed, NetProtocolError, W.WireError) as e:
                    last = e
                    self._teardown()
                    self._backoff(attempt)
            raise self._give_up(last)

    def preprocess(self, n: int = 1) -> List[int]:
        """Resilient offline prep: retried under *fresh* bundle ids on
        any failure — neither side commits a bundle before prep-done, so
        an interrupted prep leaves no partial state to collide with."""
        with self._lock:
            last: Optional[BaseException] = None
            for attempt in range(self.policy.attempts):
                try:
                    self._ensure_connected()
                    return self.offline.preprocess(n)
                except SessionLost:
                    raise
                except BundlePoolEmpty as e:
                    # typed shed: the server is healthy but full — keep
                    # the connection, honor the hint, ask again
                    last = e
                    self._backoff(attempt, e.retry_after_s)
                except (TransportClosed, NetProtocolError, W.WireError) as e:
                    last = e
                    self.preps_retried += 1
                    obs.instant("resilience.prep_retry", attempt=attempt,
                                error=type(e).__name__)
                    self._teardown()
                    self._backoff(attempt)
            raise self._give_up(last)

    def run(self, x: np.ndarray) -> np.ndarray:
        """Resilient online run. An interrupted attempt burns its bundle
        (client side mirrors the server's burn) and the retry consumes a
        fresh one — the output is bit-identical either way, because
        reconstruction cancels whichever bundle's masks were drawn."""
        with self._lock:
            last: Optional[BaseException] = None
            for attempt in range(self.policy.attempts):
                try:
                    self._ensure_connected()
                    bid = self.shared.take_bundle_id()
                    if bid is None:
                        self.offline.preprocess(1)
                        bid = self.shared.take_bundle_id()
                    if bid is None:
                        raise NetProtocolError(
                            "preprocess returned no bundle")
                except SessionLost:
                    raise
                except BundlePoolEmpty as e:
                    last = e
                    self._backoff(attempt, e.retry_after_s)
                    continue
                except (TransportClosed, NetProtocolError, W.WireError) as e:
                    last = e  # connect/refill failure: nothing burned
                    self._teardown()
                    self._backoff(attempt)
                    continue
                try:
                    return self.online.run(x, bundle_id=bid)
                except (TransportClosed, NetProtocolError, W.WireError) as e:
                    # the bundle is gone from the client pool and burned
                    # server-side — the retry MUST NOT re-run it: its
                    # labels are partially disclosed
                    last = e
                    self.bundles_burned += 1
                    obs.instant("resilience.burn", attempt=attempt,
                                error=type(e).__name__)
                    self._teardown()
                    self._backoff(attempt)
            raise self._give_up(last)

    def pool_size(self) -> int:
        return self.shared.pool_size()

    def stats(self) -> Dict[str, int]:
        return {
            "reconnects": self.reconnects,
            "resume_handshakes": self.resume_handshakes,
            "bundles_burned": self.bundles_burned,
            "preps_retried": self.preps_retried,
            "sheds_honored": self.sheds_honored,
            "backoffs": self.backoffs,
            "pool_size": self.pool_size(),
        }

    def close(self) -> None:
        with self._lock:
            for ep in (self.offline, self.online):
                if ep is not None:
                    try:
                        ep.close()  # sends bye: a clean goodbye releases
                        # the session immediately instead of parking it
                    except (TransportClosed, OSError):
                        pass
            self.offline = self.online = None

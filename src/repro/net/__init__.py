"""repro.net — a real two-party runtime for the PiT protocol.

Turns the in-process, byte-metered simulation (``core/protocol.py`` +
``core/ot.Channel``) into two endpoints talking over a pluggable
transport:

  ``net.wire``      versioned typed message codec (labels, garbled-table
                    streams, HE ciphertexts, Beaver shares, OT batches)
  ``net.transport`` Transport ABC + InProcPipe (threaded queues) +
                    TcpTransport (length-prefixed framing, loopback or
                    real sockets, optional LAN-model shaping) +
                    per-phase Deadlines and the
                    TransportTimeout/TransportClosed split
  ``net.party``     GarblerEndpoint / EvaluatorEndpoint: walk the compiled
                    ``core/plan.py`` op-graph and execute each op's
                    offline/online halves as actual message exchanges,
                    asserting byte totals against the metered Channel
                    (the in-process simulation is the oracle)
  ``net.faults``    FaultyTransport: seeded, deterministic fault
                    injection (reset/stall/torn/dup) over any transport
  ``net.resilience`` ResilientClient: reconnect with backoff + jitter,
                    session resume via the client token, burn-on-
                    interrupt bundle semantics
"""

from repro.net.transport import (
    AcceptLoop,
    Deadlines,
    InProcPipe,
    TcpListener,
    TcpTransport,
    Transport,
    TransportClosed,
    TransportTimeout,
)
from repro.net.wire import WIRE_VERSION, Msg, Seg, decode_frame, encode_msg
from repro.net.party import (
    EvaluatorEndpoint,
    GarblerEndpoint,
    NetProtocolError,
    PitNetServer,
    SessionRebindError,
    SessionState,
    WireLedger,
)
from repro.net.faults import Fault, FaultPlan, FaultSchedule, FaultyTransport
from repro.net.resilience import ResilientClient, RetryPolicy, SessionLost

__all__ = [
    "Transport", "InProcPipe", "TcpTransport", "TcpListener", "AcceptLoop",
    "TransportClosed", "TransportTimeout", "Deadlines",
    "WIRE_VERSION", "Msg", "Seg", "encode_msg", "decode_frame",
    "GarblerEndpoint", "EvaluatorEndpoint", "PitNetServer",
    "SessionState", "WireLedger", "NetProtocolError", "SessionRebindError",
    "Fault", "FaultSchedule", "FaultyTransport", "FaultPlan",
    "ResilientClient", "RetryPolicy", "SessionLost",
]

"""repro.net — a real two-party runtime for the PiT protocol.

Turns the in-process, byte-metered simulation (``core/protocol.py`` +
``core/ot.Channel``) into two endpoints talking over a pluggable
transport:

  ``net.wire``      versioned typed message codec (labels, garbled-table
                    streams, HE ciphertexts, Beaver shares, OT batches)
  ``net.transport`` Transport ABC + InProcPipe (threaded queues) +
                    TcpTransport (length-prefixed framing, loopback or
                    real sockets, optional LAN-model shaping)
  ``net.party``     GarblerEndpoint / EvaluatorEndpoint: walk the compiled
                    ``core/plan.py`` op-graph and execute each op's
                    offline/online halves as actual message exchanges,
                    asserting byte totals against the metered Channel
                    (the in-process simulation is the oracle)
"""

from repro.net.transport import (
    AcceptLoop,
    InProcPipe,
    TcpListener,
    TcpTransport,
    Transport,
)
from repro.net.wire import WIRE_VERSION, Msg, Seg, decode_frame, encode_msg
from repro.net.party import (
    EvaluatorEndpoint,
    GarblerEndpoint,
    NetProtocolError,
    PitNetServer,
    SessionState,
    WireLedger,
)

__all__ = [
    "Transport", "InProcPipe", "TcpTransport", "TcpListener", "AcceptLoop",
    "WIRE_VERSION", "Msg", "Seg", "encode_msg", "decode_frame",
    "GarblerEndpoint", "EvaluatorEndpoint", "PitNetServer",
    "SessionState", "WireLedger", "NetProtocolError",
]

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before any other import (jax locks the device count
on first init). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]

    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Each cell emits a JSON record with memory analysis, cost analysis
(FLOPs/bytes), the per-kind collective byte breakdown parsed from the
optimized HLO, and the three-term roofline (§Roofline in EXPERIMENTS.md).
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.config import (  # noqa: E402
    SHAPES,
    TrainConfig,
    assigned_shapes,
    get_config,
    list_configs,
)
from repro.config.base import SHAPES_BY_NAME  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    abstract_state,
    build_decode_step,
    build_prefill_step,
    build_train_step,
    state_shardings,
)
from repro.roofline.analysis import (  # noqa: E402
    HW,
    model_flops,
    parse_collectives,
    roofline_terms,
)
from repro.roofline.hlo_costs import analyze_hlo  # noqa: E402
from repro.roofline.analytic import traffic as analytic_traffic  # noqa: E402

ASSIGNED = [
    "olmoe-1b-7b",
    "llama4-scout-17b-a16e",
    "llama3.2-1b",
    "deepseek-67b",
    "qwen3-1.7b",
    "smollm-360m",
    "musicgen-medium",
    "xlstm-125m",
    "zamba2-2.7b",
    "internvl2-26b",
]

# gradient-accumulation factors for the train_4k cells sized so the
# per-device live set fits 16 GiB HBM on the 256-chip pod (see
# EXPERIMENTS.md §Dry-run memory notes)
TRAIN_MICROBATCHES = {
    "deepseek-67b": 8,
    "llama4-scout-17b-a16e": 4,
    "internvl2-26b": 4,
    "zamba2-2.7b": 4,
    "olmoe-1b-7b": 2,
    "xlstm-125m": 2,
    "musicgen-medium": 2,
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (lowered, compiled, meta) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tc = TrainConfig(microbatches=TRAIN_MICROBATCHES.get(arch, 1))

    if shape.kind == "train":
        fn, in_sh, out_sh, rules = build_train_step(cfg, tc, mesh, shape)
        state = abstract_state(cfg, tc)
        args = (state, SP.batch_struct(cfg, shape))
    elif shape.kind == "prefill":
        from repro.launch.steps import serve_param_struct

        fn, in_sh, out_sh, rules = build_prefill_step(cfg, mesh, shape)
        args = (serve_param_struct(cfg), SP.batch_struct(cfg, shape))
    else:  # decode
        from repro.launch.steps import serve_param_struct

        fn, in_sh, out_sh, rules = build_decode_step(cfg, mesh, shape)
        args = (serve_param_struct(cfg), SP.batch_struct(cfg, shape),
                SP.cache_struct(cfg, shape))

    # donation: the train state and decode caches are updated in place on a
    # real system — aliasing removes the full-buffer copy from DUS/opt update
    donate = ()
    if shape.kind == "train":
        donate = (0,)
    elif shape.kind == "decode":
        donate = (2,)
    with mesh:
        jitted = jax.jit(
            fn, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate
        )
        t0 = time.time()
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    meta = {
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "num_devices": mesh.devices.size,
        "mesh_shape": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
    }
    return lowered, compiled, meta


def analyze(compiled, num_devices: int, cfg, shape) -> dict:
    rec = {}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        rec["flops_per_device"] = float(cost.get("flops", 0.0))
        rec["bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        rec["cost_error"] = repr(e)
        rec["flops_per_device"] = 0.0
        rec["bytes_per_device"] = 0.0
    try:
        mem = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(mem, k):
                rec.setdefault("memory", {})[k] = int(getattr(mem, k))
    except Exception as e:  # pragma: no cover
        rec["memory_error"] = repr(e)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    rec["collectives_raw"] = coll  # body-once (cost_analysis convention)
    # trip-count-corrected accounting (scan bodies × known_trip_count)
    corr = analyze_hlo(hlo)
    rec["corrected"] = {
        "dot_flops_per_device": corr["dot_flops"],
        "traffic_bytes_per_device": corr["traffic_bytes"],
        "collectives": corr["collectives"],
        "num_whiles": corr["num_whiles"],
    }
    wire = corr["collectives"]["total"]["wire_bytes"]
    # memory term: analytic model with true dtypes (the CPU backend
    # emulates bf16 in f32, inflating HLO-derived bytes up to 2x — see
    # roofline/analytic.py); HLO traffic kept as the upper bound.
    ana = analytic_traffic(
        cfg, shape, multi_pod=num_devices > 256,
        microbatches=TRAIN_MICROBATCHES.get(cfg.name, 1),
    )
    rec["analytic_traffic"] = ana
    rec["roofline"] = roofline_terms(
        corr["dot_flops"], ana["total"], wire
    )
    rec["roofline_hlo_upper"] = roofline_terms(
        corr["dot_flops"], corr["traffic_bytes"], wire
    )
    mf = model_flops(cfg, shape)
    rec["model_flops_global"] = mf
    hlo_global = corr["dot_flops"] * num_devices
    rec["hlo_flops_global"] = hlo_global
    rec["model_to_hlo_flops"] = mf / hlo_global if hlo_global else 0.0
    rec["hlo_ops"] = {
        "while": hlo.count(" while("),
        "fusion": hlo.count(" fusion("),
        "dus": hlo.count("dynamic-update-slice"),
    }
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "multi_pod": multi_pod,
        "ok": False,
    }
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod)
        rec.update(meta)
        rec.update(analyze(compiled, meta["num_devices"], cfg, shape))
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        tag = "multipod" if multi_pod else "pod"
        path = os.path.join(
            outdir, f"{arch.replace('/', '_')}__{shape_name}__{tag}.json"
        )
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')})"
    print(
        f"[dryrun] {arch} x {shape_name} x "
        f"{'2x16x16' if multi_pod else '16x16'}: {status}",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        ok = True
        for arch in ASSIGNED:
            cfg = get_config(arch)
            for shape in assigned_shapes(cfg):
                rec = run_cell(arch, shape.name, args.multi_pod, args.out)
                ok &= rec["ok"]
        raise SystemExit(0 if ok else 1)

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    raise SystemExit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()

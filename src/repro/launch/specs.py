"""ShapeDtypeStruct stand-ins for every model input, per (arch × shape).

Weak-type-correct, shardable, zero allocation — consumed by
``jax.jit(...).lower(**input_specs(...))`` in the dry-run and by the
benchmarks for roofline accounting.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.models.sharding import Rules
from repro.models.transformer import init_caches


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """The batch dict for one step (no caches)."""
    B, S = shape.global_batch, shape.seq_len
    act = cfg.dtype
    if shape.kind == "decode":
        if cfg.input_mode == "embeddings":
            return {"embeddings": sds((B, 1, cfg.d_model), act)}
        return {"tokens": sds((B, 1), jnp.int32)}
    out: Dict = {}
    if cfg.input_mode == "embeddings":
        out["embeddings"] = sds((B, S, cfg.d_model), act)
    elif cfg.input_mode == "tokens+image":
        n_img = cfg.num_image_tokens
        out["tokens"] = sds((B, S - n_img), jnp.int32)
        out["image_embeds"] = sds((B, n_img, cfg.d_model), act)
    else:
        out["tokens"] = sds((B, S), jnp.int32)
    if shape.kind == "train":
        out["labels"] = sds((B, S), jnp.int32)
    return out


def cache_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Decode caches sized for a full context of shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: init_caches(cfg, B, S, dtype=jnp.dtype(cfg.dtype))
    )


# ---------------------------------------------------------------------------
# shardings for the structs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, rules: Rules, shape: ShapeConfig):
    ns = lambda spec: NamedSharding(rules.mesh, spec)
    b = rules.spec("batch")[0] if rules.table.get("batch") else None
    out = {}
    for name, st in batch_struct(cfg, shape).items():
        if st.ndim == 2:
            out[name] = ns(P(b, None))
        else:
            out[name] = ns(P(b, None, None))
    return out


def cache_specs(cfg: ModelConfig, rules: Rules, shape: ShapeConfig):
    """PartitionSpec tree mirroring init_caches structure."""
    mesh = rules.mesh
    b = rules.spec("batch")[0] if rules.table.get("batch") else None
    kv = rules.table.get("kv_seq")
    kv = kv[0] if kv and len(kv) == 1 else kv
    sh = rules.table.get("ssm_heads")
    sh = sh[0] if sh and len(sh) == 1 else (tuple(sh) if sh else None)

    def spec_for_path(path, st):
        nd = st.ndim
        leaf = path[-1]
        if leaf == "len":
            return NamedSharding(mesh, P())
        if leaf in ("k", "v"):  # (ns, B, T, KV, hd)
            return NamedSharding(mesh, P(None, b, kv, None, None))
        if leaf in ("ssm", "norm"):  # (ns[, inner], B, H, N, P)
            lead = (None,) * (nd - 4)
            return NamedSharding(mesh, P(*lead, b, sh, None, None))
        if leaf == "conv":  # (ns[, inner], B, W-1, C)
            lead = (None,) * (nd - 3)
            return NamedSharding(mesh, P(*lead, b, None, None))
        # slstm states (ns, B, d)
        return NamedSharding(mesh, P(None, b, None))

    def rec(tree, path):
        if isinstance(tree, dict):
            return {k: rec(v, path + (k,)) for k, v in tree.items()}
        return spec_for_path(path, tree)

    return rec(cache_struct(cfg, shape), ())

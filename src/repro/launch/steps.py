"""Step builders: train_step / prefill_step / decode_step.

Each builder returns ``(fn, in_shardings, out_shardings)`` ready for
``jax.jit``. Sharding rules (models/sharding.Rules) are activated during
tracing via the ``use_rules`` context inside the step functions, so the same
model code runs un-annotated on a single CPU device in tests.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.models import forward
from repro.models.sharding import Rules, make_rules, named_sharding_tree, use_rules
from repro.models.transformer import init_params
from repro.launch import specs as SP
from repro.train.losses import chunked_lm_loss
from repro.train.optimizer import adamw_update, init_opt_state


def serve_param_struct(cfg: ModelConfig):
    """Serving checkpoints store weights in the inference dtype (bf16):
    matrices take cfg.dtype, vectors stay f32."""
    dt = jnp.dtype(cfg.dtype)

    def build():
        return init_params(cfg, jax.random.PRNGKey(0))

    struct = jax.eval_shape(build)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt)
        if (s.dtype == jnp.float32 and len(s.shape) >= 2)
        else s,
        struct,
    )


def abstract_state(cfg: ModelConfig, tc: TrainConfig):
    """ShapeDtypeStruct tree of the train state (no allocation)."""

    def build():
        params = init_params(cfg, jax.random.PRNGKey(0))
        return {
            "params": params,
            "opt": init_opt_state(params),
            "step": jnp.int32(0),
        }

    return jax.eval_shape(build)


def state_shardings(cfg: ModelConfig, state_struct, mesh):
    pspecs = named_sharding_tree(cfg, state_struct["params"], mesh)
    return {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs},
        "step": NamedSharding(mesh, P()),
    }


# ---------------------------------------------------------------------------


def _cast_params_for_compute(cfg: ModelConfig, params, mesh,
                             fsdp_params: bool = True):
    """bf16 cast pinned to the master sharding so XLA casts *before* the
    FSDP all-gather (halves gather wire bytes). Vectors (norm scales,
    a_log, biases) stay f32 — model code handles their precision."""
    specs = named_sharding_tree(cfg, params, mesh, fsdp_params=fsdp_params)
    dt = jnp.dtype(cfg.dtype)

    def one(p, s):
        if p.ndim >= 2 and p.dtype == jnp.float32:
            return jax.lax.with_sharding_constraint(p.astype(dt), s)
        return p

    return jax.tree_util.tree_map(one, params, specs)


def build_train_step(
    cfg: ModelConfig,
    tc: TrainConfig,
    mesh=None,
    shape: Optional[ShapeConfig] = None,
):
    rules = None
    if mesh is not None:
        assert shape is not None
        rules = make_rules(
            cfg, mesh, kind="train", global_batch=shape.global_batch,
            seq_len=shape.seq_len,
        )
    cast_early = mesh is not None and tc.param_gather_dtype == "bfloat16"

    def loss_fn(params, batch):
        if cast_early:
            params = _cast_params_for_compute(cfg, params, mesh)
        hidden, aux = forward(cfg, params, batch, mode="train")
        loss, _ = chunked_lm_loss(
            cfg, params["out_head"], hidden, batch["labels"], z_coef=tc.z_loss
        )
        total = loss + cfg.router_aux_coef * aux
        return total, (loss, aux)

    def compute_grads(params, batch):
        if tc.microbatches > 1:
            mb = tc.microbatches
            B = batch[next(iter(batch))].shape[0]
            assert B % mb == 0, (B, mb)
            split = {
                k: v.reshape(mb, B // mb, *v.shape[1:]) for k, v in batch.items()
            }

            def body(carry, xs):
                gsum, lsum, asum = carry
                (l, (ce, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, xs
                )
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + ce, asum + aux), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (g, ce, aux), _ = jax.lax.scan(
                body, (g0, jnp.float32(0), jnp.float32(0)), split
            )
            g = jax.tree_util.tree_map(lambda x: x / mb, g)
            return g, ce / mb, aux / mb
        (l, (ce, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return g, ce, aux

    def train_step(state, batch):
        with use_rules(rules):
            grads, ce, aux = compute_grads(state["params"], batch)
            params, opt, met = adamw_update(
                tc, state["params"], grads, state["opt"], state["step"]
            )
        metrics = {"loss": ce, "aux": aux, **met}
        return {"params": params, "opt": opt, "step": state["step"] + 1}, metrics

    in_sh = out_sh = None
    if mesh is not None:
        st = abstract_state(cfg, tc)
        ssh = state_shardings(cfg, st, mesh)
        bsh = SP.batch_specs(cfg, rules, shape)
        in_sh = (ssh, bsh)
        out_sh = (ssh, None)
    return train_step, in_sh, out_sh, rules


def build_prefill_step(cfg: ModelConfig, mesh=None,
                       shape: Optional[ShapeConfig] = None,
                       fsdp_params: bool = False):
    """Serving default: TP-only weight sharding (fsdp_params=False) — FSDP
    weights would re-pay their all-gather every step (§Perf)."""
    rules = None
    if mesh is not None:
        rules = make_rules(
            cfg, mesh, kind="prefill", global_batch=shape.global_batch,
            seq_len=shape.seq_len,
        )

    def prefill_step(params, batch):
        with use_rules(rules):
            if mesh is not None:
                params = _cast_params_for_compute(cfg, params, mesh,
                                                  fsdp_params=fsdp_params)
            logits, caches = forward(cfg, params, batch, mode="prefill")
        return logits, caches

    in_sh = out_sh = None
    if mesh is not None:
        pstruct = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        psh = named_sharding_tree(cfg, pstruct, mesh, fsdp_params=fsdp_params)
        bsh = SP.batch_specs(cfg, rules, shape)
        in_sh = (psh, bsh)
        b = rules.spec("batch")[0] if rules.table.get("batch") else None
        logit_sh = NamedSharding(mesh, P(b, rules.spec("vocab")[0]))
        cash = SP.cache_specs(cfg, rules, shape)
        out_sh = (logit_sh, cash)
    return prefill_step, in_sh, out_sh, rules


def build_decode_step(cfg: ModelConfig, mesh=None,
                      shape: Optional[ShapeConfig] = None,
                      fsdp_params: bool = False):
    rules = None
    if mesh is not None:
        rules = make_rules(
            cfg, mesh, kind="decode", global_batch=shape.global_batch,
            seq_len=shape.seq_len,
        )

    def decode_step(params, batch, caches):
        with use_rules(rules):
            if mesh is not None:
                params = _cast_params_for_compute(cfg, params, mesh,
                                                  fsdp_params=fsdp_params)
            logits, caches = forward(cfg, params, batch, mode="decode",
                                     caches=caches)
        return logits, caches

    in_sh = out_sh = None
    if mesh is not None:
        pstruct = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        psh = named_sharding_tree(cfg, pstruct, mesh, fsdp_params=fsdp_params)
        bsh = SP.batch_specs(cfg, rules, shape)
        cash = SP.cache_specs(cfg, rules, shape)
        in_sh = (psh, bsh, cash)
        b = rules.spec("batch")[0] if rules.table.get("batch") else None
        logit_sh = NamedSharding(mesh, P(b, rules.spec("vocab")[0]))
        out_sh = (logit_sh, cash)
    return decode_step, in_sh, out_sh, rules

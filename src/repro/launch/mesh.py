"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
only data-parallel all-reduces (lowest inter-pod bandwidth demand).
"""

from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    if len(jax.devices()) == n:
        return jax.make_mesh(shape, axes)
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(jax.devices())} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)"
        )
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_host_mesh():
    """Whatever mesh the current process supports (elastic restart helper):
    prefers (data=N/16, model=16), falls back to (data=N, model=1)."""
    n = len(jax.devices())
    if n % 16 == 0 and n >= 16:
        shape = (n // 16, 16)
    else:
        shape = (n, 1)
    devs = np.array(jax.devices()[: shape[0] * shape[1]]).reshape(shape)
    return jax.sharding.Mesh(devs, ("data", "model"))

"""Single resolver for the ``impl`` flag used across all GC/HE kernels.

Historically each dispatch wrapper resolved ``"auto"`` on its own and they
disagreed: ``halfgate.ops`` mapped ``auto`` -> Pallas on TPU while
``core.garble`` treated ``auto`` as the host-side numpy loop. This module
is now the one place that decides, so ``auto`` means the same thing
everywhere: *the device-resident path* — the fused Pallas kernels on TPU,
the jitted jnp implementation elsewhere.

Resolved values:

  "ref"              host/numpy oracle where one exists (``core.garble``),
                     plain jnp in the kernel wrappers
  "jit"              device-resident jnp (identical math to "ref", but the
                     caller keeps the whole walk inside one ``jax.jit``)
  "pallas"           fused Pallas TPU kernels
  "pallas_interpret" Pallas kernels in interpreter mode (CPU testing)

Kernel wrappers treat "jit" and "ref" identically (their jnp reference *is*
the jit-able path); the distinction matters one level up, in
``core.garble``, where "ref" selects the per-level numpy oracle and
everything else the device-resident executor.
"""

from __future__ import annotations

import jax

DEVICE_IMPLS = ("jit", "pallas", "pallas_interpret")


def resolve_impl(impl: str = "auto") -> str:
    """Map ``auto`` to the device-resident impl for the current backend."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jit"
    return impl

"""jnp oracle: active-label encode  W = W0 ^ (bit ? R : 0).

This is the protocol's input-garbling hot path: every fixed-point tensor
entering GC is bit-decomposed (k bits/element × instances) and each bit
selects a label. Pure bandwidth — the kernel's job is to keep it at HBM
speed on (G, 4) uint32 tiles.
"""

from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32


def select_labels(zero_labels, r, bits):
    """zero_labels (..., 4); r broadcastable (..., 4); bits (...,) {0,1}."""
    mask = (-(bits.astype(U32)))[..., None]
    return zero_labels ^ (r & mask)


def bit_decompose(values, k: int):
    """(...,) uint -> (..., k) uint32 LSB-first bits."""
    shifts = jnp.arange(k, dtype=values.dtype)
    return ((values[..., None] >> shifts) & values.dtype.type(1)).astype(U32)
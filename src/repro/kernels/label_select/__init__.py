from repro.kernels.label_select.ops import select_labels

__all__ = ["select_labels"]

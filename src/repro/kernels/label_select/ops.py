"""Dispatch wrapper for the label_select kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dispatch import resolve_impl
from repro.kernels.label_select import ref as _ref
from repro.kernels.label_select.label_select import select_labels_pallas


def select_labels(zero_labels, r, bits, impl: str = "auto"):
    impl = resolve_impl(impl)
    if impl in ("ref", "jit"):
        return _ref.select_labels(zero_labels, r, bits)
    lead = zero_labels.shape[:-1]
    rb = jnp.broadcast_to(r, (*lead, 4)).reshape(-1, 4)
    out = select_labels_pallas(
        zero_labels.reshape(-1, 4), rb,
        bits.reshape(-1).astype(jnp.uint32),
        interpret=(impl == "pallas_interpret"),
    )
    return out.reshape(*lead, 4)

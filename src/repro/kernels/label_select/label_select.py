"""Pallas TPU kernel for active-label encoding (input garbling).

Tiles (BLOCK, 4) uint32 label rows through VMEM; bits ride as a (BLOCK, 1)
sidecar. Purely bandwidth-bound — the BlockSpec streaming (sequential grid,
double-buffered DMA) is the whole optimization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096
U32 = jnp.uint32


def _kernel(w0_ref, r_ref, bits_ref, out_ref):
    w0 = w0_ref[...]
    r = r_ref[...]
    bits = bits_ref[...][:, 0]
    mask = (-(bits.astype(U32)))[:, None]
    out_ref[...] = w0 ^ (r & mask)


def _pad(x, block):
    g = x.shape[0]
    p = (-g) % block
    if p:
        x = jnp.concatenate([x, jnp.zeros((p, *x.shape[1:]), x.dtype)])
    return x


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def select_labels_pallas(w0, r, bits, *, block=DEFAULT_BLOCK, interpret=False):
    """w0, r: (G, 4) uint32; bits: (G,) uint32 -> (G, 4)."""
    g = w0.shape[0]
    blk = min(block, max(8, 1 << (g - 1).bit_length()))
    w0p = _pad(w0, blk)
    rp = _pad(r, blk)
    bp = _pad(bits.reshape(-1, 1).astype(U32), blk)
    gp = w0p.shape[0]
    out = pl.pallas_call(
        _kernel,
        grid=(gp // blk,),
        in_specs=[
            pl.BlockSpec((blk, 4), lambda i: (i, 0)),
            pl.BlockSpec((blk, 4), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, 4), U32),
        interpret=interpret,
    )(w0p, rp, bp)
    return out[:g]

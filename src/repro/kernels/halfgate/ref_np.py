"""Numpy mirror of ref.py — the CPU fast path for the level-synchronous
garbling loops (no per-op dispatch overhead). Bit-identical to the jnp
oracle (tests assert it)."""

from __future__ import annotations

import numpy as np

from repro.kernels.halfgate.ref import _RC, NUM_ROUNDS

U32 = np.uint32
_RC_NP = np.asarray(_RC, dtype=np.uint32)
# note: ref._RC is a tuple of python ints; both backends share it


def _rotl(x, r):
    return ((x << U32(r)) | (x >> U32(32 - r))).astype(np.uint32)


def arx_perm(x):
    v0, v1, v2, v3 = (x[..., i].copy() for i in range(4))
    for r in range(NUM_ROUNDS):
        v0 += v1 + _RC_NP[r]
        v1 = _rotl(v1, 13) ^ v0
        v2 += v3
        v3 = _rotl(v3, 16) ^ v2
        v0 += v3
        v3 = _rotl(v3, 21) ^ v0
        v2 += v1
        v1 = _rotl(v1, 17) ^ v2
    return np.stack([v0, v1, v2, v3], axis=-1)


def expand_tweak(tweak):
    t = tweak.astype(np.uint32)
    return np.stack(
        [t, t ^ U32(0x9E3779B9), ~t, t + U32(0x85EBCA6B)], axis=-1
    )


def hash_labels(labels, tweaks):
    xin = labels ^ expand_tweak(tweaks)
    return arx_perm(xin) ^ xin


def _lsb_mask(label):
    return (-(label[..., 0:1] & U32(1))).astype(np.uint32)


def garble_and_gates(a0, b0, r, tweaks):
    t1 = tweaks.astype(np.uint32) * U32(2)
    t2 = t1 + U32(1)
    a1 = a0 ^ r
    b1 = b0 ^ r
    ha0 = hash_labels(a0, t1)
    ha1 = hash_labels(a1, t1)
    hb0 = hash_labels(b0, t2)
    hb1 = hash_labels(b1, t2)
    pa = _lsb_mask(a0)
    pb = _lsb_mask(b0)
    tg = ha0 ^ ha1 ^ (r & pb)
    wg = ha0 ^ (tg & pa)
    te = hb0 ^ hb1 ^ a0
    we = hb0 ^ ((te ^ a0) & pb)
    return wg ^ we, tg, te


def eval_and_gates(a, b, tg, te, tweaks):
    t1 = tweaks.astype(np.uint32) * U32(2)
    t2 = t1 + U32(1)
    ha = hash_labels(a, t1)
    hb = hash_labels(b, t2)
    sa = _lsb_mask(a)
    sb = _lsb_mask(b)
    wg = ha ^ (tg & sa)
    we = hb ^ ((te ^ a) & sb)
    return wg ^ we

"""Pallas TPU kernel: batched Half-Gate garbling / evaluation.

The GC hot loop is embarrassingly parallel over gates × instances: 128-bit
labels (uint32×4 lanes) through an ARX permutation — pure VPU work (adds,
xors, rotates). Tiling: gates stream through VMEM in (BLOCK, 4) tiles; the
FreeXOR offset R rides along as a (1, 4) broadcast block. One grid step
garbles/evaluates BLOCK gates; the DMA of tile i+1 overlaps the cipher of
tile i (Pallas double-buffers sequential grid dims) — the TPU analogue of
the paper's OoRW prefetch buffer (DESIGN.md §3).

The in-kernel math *is* the jnp oracle (`ref.py`) applied to VMEM tiles, so
kernel-vs-ref equality tests validate indexing/tiling, not a re-derivation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.halfgate import ref

DEFAULT_BLOCK = 2048
U32 = jnp.uint32


def _pad_gates(x, block):
    g = x.shape[0]
    pad = (-g) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
    return x


def _garble_kernel(a0_ref, b0_ref, r_ref, tw_ref, c0_ref, tg_ref, te_ref):
    a0 = a0_ref[...]
    b0 = b0_ref[...]
    r = r_ref[...]  # (BLOCK, 4): per-gate R (per-instance FreeXOR offsets)
    tw = tw_ref[...][:, 0]
    c0, tg, te = ref.garble_and_gates(a0, b0, r, tw)
    c0_ref[...] = c0
    tg_ref[...] = tg
    te_ref[...] = te


def _eval_kernel(a_ref, b_ref, tg_ref, te_ref, tw_ref, c_ref):
    a = a_ref[...]
    b = b_ref[...]
    tw = tw_ref[...][:, 0]
    c_ref[...] = ref.eval_and_gates(a, b, tg_ref[...], te_ref[...], tw)


def _label_spec(block):
    return pl.BlockSpec((block, 4), lambda i: (i, 0))


def _tweak_spec(block):
    return pl.BlockSpec((block, 1), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def garble_pallas(a0, b0, r, tweaks, *, block=DEFAULT_BLOCK, interpret=False):
    """a0,b0,r: (G,4) uint32 (r per-gate — batched instances carry their own
    FreeXOR offset); tweaks: (G,) uint32.

    Returns (c0, tg, te) each (G, 4) uint32.
    """
    g = a0.shape[0]
    blk = min(block, max(8, 1 << (g - 1).bit_length()))
    a0p = _pad_gates(a0, blk)
    b0p = _pad_gates(b0, blk)
    rp = _pad_gates(r, blk)
    twp = _pad_gates(tweaks.reshape(-1, 1), blk)
    gp = a0p.shape[0]
    out_sds = [jax.ShapeDtypeStruct((gp, 4), U32)] * 3
    c0, tg, te = pl.pallas_call(
        _garble_kernel,
        grid=(gp // blk,),
        in_specs=[
            _label_spec(blk),
            _label_spec(blk),
            _label_spec(blk),
            _tweak_spec(blk),
        ],
        out_specs=[_label_spec(blk)] * 3,
        out_shape=out_sds,
        interpret=interpret,
    )(a0p, b0p, rp, twp)
    return c0[:g], tg[:g], te[:g]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def eval_pallas(a, b, tg, te, tweaks, *, block=DEFAULT_BLOCK, interpret=False):
    """Active labels + table rows -> output labels, (G, 4) uint32."""
    g = a.shape[0]
    blk = min(block, max(8, 1 << (g - 1).bit_length()))
    ap = _pad_gates(a, blk)
    bp = _pad_gates(b, blk)
    tgp = _pad_gates(tg, blk)
    tep = _pad_gates(te, blk)
    twp = _pad_gates(tweaks.reshape(-1, 1), blk)
    gp = ap.shape[0]
    c = pl.pallas_call(
        _eval_kernel,
        grid=(gp // blk,),
        in_specs=[
            _label_spec(blk),
            _label_spec(blk),
            _label_spec(blk),
            _label_spec(blk),
            _tweak_spec(blk),
        ],
        out_specs=_label_spec(blk),
        out_shape=jax.ShapeDtypeStruct((gp, 4), U32),
        interpret=interpret,
    )(ap, bp, tgp, tep, twp)
    return c[:g]

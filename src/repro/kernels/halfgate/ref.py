"""Pure-jnp oracle for the Half-Gate cipher kernel.

Hash: Davies–Meyer over a 128-bit ARX permutation (SipRound-style on four
32-bit lanes, 8 rounds, round constants). TPU adaptation of the paper's
fixed-key AES (TPUs have no AES-NI; GC only needs a circular-correlation-
robust hash — see DESIGN.md §3). The permutation is pluggable; production
would swap in AES.

Half-Gate (Zahur–Rosulek–Evans, "two halves make a whole"):
  garbling an AND gate costs 4 hash calls and emits 2 table rows;
  evaluation costs 2 hash calls — matching the paper's cost model.
"""

from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32

# python-int round constants: they embed as immediates so the Pallas kernel
# body captures no arrays
_RC = (0x9E3779B9, 0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F,
       0x165667B1, 0xD3A2646C, 0xFD7046C5, 0xB55A4F09)

NUM_ROUNDS = 8


def _rotl(x, r):
    return (x << U32(r)) | (x >> U32(32 - r))


def arx_perm(x):
    """x: (..., 4) uint32 -> permuted (..., 4)."""
    v0, v1, v2, v3 = x[..., 0], x[..., 1], x[..., 2], x[..., 3]
    for r in range(NUM_ROUNDS):
        v0 = v0 + v1 + U32(_RC[r])
        v1 = _rotl(v1, 13) ^ v0
        v2 = v2 + v3
        v3 = _rotl(v3, 16) ^ v2
        v0 = v0 + v3
        v3 = _rotl(v3, 21) ^ v0
        v2 = v2 + v1
        v1 = _rotl(v1, 17) ^ v2
    return jnp.stack([v0, v1, v2, v3], axis=-1)


def expand_tweak(tweak):
    """tweak (...,) uint32 gate counter -> (..., 4) uint32 block."""
    t = tweak.astype(U32)
    return jnp.stack(
        [t, t ^ U32(0x9E3779B9), ~t, t + U32(0x85EBCA6B)], axis=-1
    )


def hash_labels(labels, tweaks):
    """H(x, t) = P(x ⊕ t̂) ⊕ (x ⊕ t̂). labels (..., 4); tweaks (...,)."""
    xin = labels ^ expand_tweak(tweaks)
    return arx_perm(xin) ^ xin


def _lsb_mask(label):
    """(..., 1) uint32 0x0/0xFFFFFFFF from the color bit."""
    return (-(label[..., 0:1] & U32(1))).astype(U32)


def garble_and_gates(a0, b0, r, tweaks):
    """Vectorized Half-Gate garbling.

    a0, b0: (..., 4) zero-labels of the two inputs; r broadcastable (..., 4);
    tweaks (...,) uint32 per-gate counter (two tweaks derived as 2t, 2t+1).
    Returns (c0, tg, te): output zero-label + the two garbled table rows.
    """
    t1 = tweaks * jnp.uint32(2)
    t2 = t1 + jnp.uint32(1)
    a1 = a0 ^ r
    b1 = b0 ^ r
    ha0 = hash_labels(a0, t1)
    ha1 = hash_labels(a1, t1)
    hb0 = hash_labels(b0, t2)
    hb1 = hash_labels(b1, t2)
    pa = _lsb_mask(a0)
    pb = _lsb_mask(b0)
    tg = ha0 ^ ha1 ^ (r & pb)
    wg = ha0 ^ (tg & pa)
    te = hb0 ^ hb1 ^ a0
    we = hb0 ^ ((te ^ a0) & pb)
    c0 = wg ^ we
    return c0, tg, te


def eval_and_gates(a, b, tg, te, tweaks):
    """Vectorized Half-Gate evaluation: 2 hash calls per gate.

    a, b: active labels (..., 4); tg/te: table rows; tweaks as in garbling.
    """
    t1 = tweaks * jnp.uint32(2)
    t2 = t1 + jnp.uint32(1)
    ha = hash_labels(a, t1)
    hb = hash_labels(b, t2)
    sa = _lsb_mask(a)
    sb = _lsb_mask(b)
    wg = ha ^ (tg & sa)
    we = hb ^ ((te ^ a) & sb)
    return wg ^ we


# ---------------------------------------------------------------------------
# planar variants: labels as four (N,) word planes instead of (N, 4)
#
# Bit-identical to the packed forms above, but every op runs on a
# contiguous vector, which is what XLA:CPU needs to vectorize the ARX
# rounds — inside the device executor's scan the packed (N, 4) form
# lowers to strided scalar code ~50x slower. The executor transposes its
# gathered label blocks once and feeds these.
# ---------------------------------------------------------------------------


def arx_perm_planar(v0, v1, v2, v3):
    for r in range(NUM_ROUNDS):
        v0 = v0 + v1 + U32(_RC[r])
        v1 = _rotl(v1, 13) ^ v0
        v2 = v2 + v3
        v3 = _rotl(v3, 16) ^ v2
        v0 = v0 + v3
        v3 = _rotl(v3, 21) ^ v0
        v2 = v2 + v1
        v1 = _rotl(v1, 17) ^ v2
    return v0, v1, v2, v3


def hash_labels_planar(x, tweaks):
    """x: 4-tuple of (N,) planes; tweaks (N,). Returns a 4-tuple."""
    t = tweaks.astype(U32)
    i = (x[0] ^ t, x[1] ^ (t ^ U32(0x9E3779B9)), x[2] ^ ~t,
         x[3] ^ (t + U32(0x85EBCA6B)))
    o = arx_perm_planar(*i)
    return tuple(o[k] ^ i[k] for k in range(4))


def eval_and_planar(a, b, tg, te, tweaks):
    """Half-Gate evaluation on planar labels (4-tuples of (N,) planes).

    The two hash calls are batched into one 2N-lane pass: fewer, longer
    vector loops is what the executor's per-level scan body wants.
    """
    n = a[0].shape[0]
    t1 = tweaks * U32(2)
    h = hash_labels_planar(
        tuple(jnp.concatenate([a[k], b[k]]) for k in range(4)),
        jnp.concatenate([t1, t1 + U32(1)]))
    ha = tuple(h[k][:n] for k in range(4))
    hb = tuple(h[k][n:] for k in range(4))
    sa = -(a[0] & U32(1))
    sb = -(b[0] & U32(1))
    return tuple(
        (ha[k] ^ (tg[k] & sa)) ^ (hb[k] ^ ((te[k] ^ a[k]) & sb))
        for k in range(4)
    )


def eval_and_split(a, b, tg, te, tweaks):
    """Half-Gate evaluation with one separate hash call per operand.

    Bit-identical to :func:`eval_and_planar`, but the two hashes are NOT
    concatenated into one 2N-lane pass: XLA's instruction fusion
    duplicates a multiply-consumed concat+slice hash chain into every
    consumer fusion (~3x the ARX work executed — measured, not
    hypothetical), while separate un-sliced hashes keep each ARX chain
    single-consumer and fuse cleanly. Planes may be ANY shape (the device
    executor passes (lanes, instances) planes straight from its planar
    wire store, with zero transposes).
    """
    t1 = tweaks * U32(2)
    ha = hash_labels_planar(a, t1)
    hb = hash_labels_planar(b, t1 + U32(1))
    sa = -(a[0] & U32(1))
    sb = -(b[0] & U32(1))
    return tuple(
        (ha[k] ^ (tg[k] & sa)) ^ (hb[k] ^ ((te[k] ^ a[k]) & sb))
        for k in range(4)
    )


def garble_and_split(a0, b0, r, tweaks):
    """Half-Gate garbling with one separate hash call per label group.

    Bit-identical to :func:`garble_and_planar`; same fusion rationale as
    :func:`eval_and_split` — the 4N-lane concatenated pass re-executes
    its ARX chain once per post-hash slice consumer under XLA:CPU.
    ``r``'s planes broadcast against the label planes.
    """
    t1 = tweaks * U32(2)
    t2 = t1 + U32(1)
    a1 = tuple(a0[k] ^ r[k] for k in range(4))
    b1 = tuple(b0[k] ^ r[k] for k in range(4))
    ha0 = hash_labels_planar(a0, t1)
    ha1 = hash_labels_planar(a1, t1)
    hb0 = hash_labels_planar(b0, t2)
    hb1 = hash_labels_planar(b1, t2)
    pa = -(a0[0] & U32(1))
    pb = -(b0[0] & U32(1))
    tg = tuple(ha0[k] ^ ha1[k] ^ (r[k] & pb) for k in range(4))
    te = tuple(hb0[k] ^ hb1[k] ^ a0[k] for k in range(4))
    wg = tuple(ha0[k] ^ (tg[k] & pa) for k in range(4))
    we = tuple(hb0[k] ^ ((te[k] ^ a0[k]) & pb) for k in range(4))
    return tuple(wg[k] ^ we[k] for k in range(4)), tg, te


def garble_and_planar(a0, b0, r, tweaks):
    """Half-Gate garbling on planar labels. Returns (c0, tg, te) tuples.

    All four hash calls are batched into one 4N-lane pass.
    """
    n = a0[0].shape[0]
    t1 = tweaks * U32(2)
    t2 = t1 + U32(1)
    a1 = tuple(a0[k] ^ r[k] for k in range(4))
    b1 = tuple(b0[k] ^ r[k] for k in range(4))
    h = hash_labels_planar(
        tuple(jnp.concatenate([a0[k], a1[k], b0[k], b1[k]])
              for k in range(4)),
        jnp.concatenate([t1, t1, t2, t2]))
    ha0 = tuple(h[k][:n] for k in range(4))
    ha1 = tuple(h[k][n:2 * n] for k in range(4))
    hb0 = tuple(h[k][2 * n:3 * n] for k in range(4))
    hb1 = tuple(h[k][3 * n:] for k in range(4))
    pa = -(a0[0] & U32(1))
    pb = -(b0[0] & U32(1))
    tg = tuple(ha0[k] ^ ha1[k] ^ (r[k] & pb) for k in range(4))
    te = tuple(hb0[k] ^ hb1[k] ^ a0[k] for k in range(4))
    wg = tuple(ha0[k] ^ (tg[k] & pa) for k in range(4))
    we = tuple(hb0[k] ^ ((te[k] ^ a0[k]) & pb) for k in range(4))
    c0 = tuple(wg[k] ^ we[k] for k in range(4))
    return c0, tg, te

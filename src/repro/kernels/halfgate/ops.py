"""Dispatch wrapper: Pallas on TPU, jnp reference elsewhere.

``impl``: "auto" | "ref" | "jit" | "pallas" | "pallas_interpret", resolved
through the shared :func:`repro.kernels.dispatch.resolve_impl` ("jit" and
"ref" both mean the jnp path here — it is the jit-able implementation).
The interpret path executes the kernel body in Python on CPU — used by the
test-suite shape/dtype sweeps to validate the kernel against the oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.dispatch import resolve_impl
from repro.kernels.halfgate import ref as _ref
from repro.kernels.halfgate import halfgate as _pk


def _resolve(impl: str) -> str:
    impl = resolve_impl(impl)
    return "ref" if impl == "jit" else impl


def hash_labels(labels, tweaks):
    return _ref.hash_labels(labels, tweaks)


def garble_and_gates(a0, b0, r, tweaks, impl: str = "auto"):
    """a0,b0 (..., 4); r broadcastable; tweaks (...,). Flattens to the
    kernel's (G, 4) layout and restores the caller's shape."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.garble_and_gates(a0, b0, r, tweaks)
    lead = a0.shape[:-1]
    a0f = a0.reshape(-1, 4)
    b0f = b0.reshape(-1, 4)
    rb = jnp.broadcast_to(r, (*lead, 4)).reshape(-1, 4)
    twf = jnp.broadcast_to(tweaks, lead).reshape(-1).astype(jnp.uint32)
    c0, tg, te = _pk.garble_pallas(
        a0f, b0f, rb, twf, interpret=(impl == "pallas_interpret")
    )
    return (
        c0.reshape(*lead, 4),
        tg.reshape(*lead, 4),
        te.reshape(*lead, 4),
    )


def eval_and_gates(a, b, tg, te, tweaks, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.eval_and_gates(a, b, tg, te, tweaks)
    lead = a.shape[:-1]
    twf = jnp.broadcast_to(tweaks, lead).reshape(-1).astype(jnp.uint32)
    c = _pk.eval_pallas(
        a.reshape(-1, 4),
        b.reshape(-1, 4),
        tg.reshape(-1, 4),
        te.reshape(-1, 4),
        twf,
        interpret=(impl == "pallas_interpret"),
    )
    return c.reshape(*lead, 4)

from repro.kernels.halfgate.ops import (
    hash_labels,
    garble_and_gates,
    eval_and_gates,
)

__all__ = ["hash_labels", "garble_and_gates", "eval_and_gates"]

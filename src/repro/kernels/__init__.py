"""Pallas TPU kernels for the privacy plane's compute hot spots.

Each kernel ships as a triplet:
  <name>/<name>.py — pl.pallas_call with explicit BlockSpec VMEM tiling
  <name>/ops.py    — jit'd dispatch wrapper (pallas on TPU / interpret on CPU,
                     jnp reference fallback)
  <name>/ref.py    — pure-jnp oracle used by tests and as the CPU path

Kernels:
  halfgate     — fixed-key ARX cipher Half-Gate garble/eval (GC hot loop)
  ntt          — negacyclic NTT for BFV-lite (small-prime RNS limbs)
  label_select — bit-plane -> active-label encode (protocol input garbling)
  level_eval   — fused XOR/INV/Half-Gate evaluation of a whole netlist level
"""

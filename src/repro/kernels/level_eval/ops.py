"""Dispatch wrapper for the fused level evaluator / garbler.

``impl`` resolution goes through :func:`repro.kernels.dispatch.resolve_impl`
so ``auto`` means the same thing here as in every other kernel wrapper and
in ``core.garble``. ``"ref"`` and ``"jit"`` both select the jnp oracle —
that *is* the jit-able path; the distinction only matters one level up.
"""

from __future__ import annotations

from repro.kernels.dispatch import resolve_impl
from repro.kernels.level_eval import ref as _ref
from repro.kernels.level_eval.level_eval import (
    eval_level_pallas,
    garble_level_pallas,
)


def eval_level(ops, a, b, tg, te, tweaks, impl: str = "auto"):
    impl = resolve_impl(impl)
    if impl in ("ref", "jit"):
        return _ref.eval_level(ops, a, b, tg, te, tweaks)
    return eval_level_pallas(ops, a, b, tg, te, tweaks,
                             interpret=(impl == "pallas_interpret"))


def garble_level(ops, a0, b0, r, tweaks, impl: str = "auto"):
    impl = resolve_impl(impl)
    if impl in ("ref", "jit"):
        return _ref.garble_level(ops, a0, b0, r, tweaks)
    return garble_level_pallas(ops, a0, b0, r, tweaks,
                               interpret=(impl == "pallas_interpret"))

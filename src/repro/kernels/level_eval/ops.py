"""Dispatch wrapper for the fused level evaluator."""

from __future__ import annotations

import jax

from repro.kernels.level_eval import ref as _ref
from repro.kernels.level_eval.level_eval import eval_level_pallas


def eval_level(ops, a, b, tg, te, tweaks, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref.eval_level(ops, a, b, tg, te, tweaks)
    return eval_level_pallas(ops, a, b, tg, te, tweaks,
                             interpret=(impl == "pallas_interpret"))

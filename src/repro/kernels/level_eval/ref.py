"""jnp oracle: garble/evaluate one topological netlist level in one pass.

Per gate (op ∈ {0:XOR, 1:AND, 2:INV, 3:PAD}):
    XOR -> a ^ b              (FreeXOR)
    AND -> HalfGate(a, b, tables, tweak)
    INV -> a                  (label passes through; semantics flip
                               garbler-side)
    PAD -> 0                  (padding lane of a compiled level plan;
                               reads/writes the plan's dummy wire)
Computing the Half-Gate for every lane and masking is branch-free — the
right shape for the VPU (the paper's PE co-issues Half-Gate and FreeXOR
units; a SIMD machine evaluates both and selects). The garble lane
mirrors this for the garbler side: FreeXOR / INV-offset / Half-Gate table
generation in one fused pass, with tg/te masked to zero off the AND lanes
so padded scatters stay deterministic. (Since the packed-table-emission
overhaul the device executor hands the garble lane AND/PAD lanes only —
free-lane table rows are zero by construction and are no longer shipped
through the kernel; eval still takes the full concatenated level.)
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.halfgate import ref as HG

U32 = jnp.uint32


def eval_level(ops, a, b, tg, te, tweaks):
    """ops (G,) uint32; labels/tables (G, 4); tweaks (G,). -> (G, 4)."""
    and_out = HG.eval_and_gates(a, b, tg, te, tweaks)
    xor_out = a ^ b
    is_and = (ops == U32(1))[:, None]
    is_inv = (ops == U32(2))[:, None]
    is_pad = (ops >= U32(3))[:, None]
    out = jnp.where(is_and, and_out, xor_out)
    out = jnp.where(is_inv, a, out)
    return jnp.where(is_pad, U32(0), out)


def garble_level(ops, a0, b0, r, tweaks):
    """Garbler-side fused level pass.

    ops (G,) uint32; a0/b0/r (G, 4) zero-labels and FreeXOR offset;
    tweaks (G,). Returns (c0, tg, te), each (G, 4): the output zero-label
    plus the two Half-Gate table rows (zero off the AND lanes).
    """
    c_and, tg, te = HG.garble_and_gates(a0, b0, r, tweaks)
    is_and = (ops == U32(1))[:, None]
    is_inv = (ops == U32(2))[:, None]
    is_pad = (ops >= U32(3))[:, None]
    c0 = jnp.where(is_and, c_and, a0 ^ b0)
    c0 = jnp.where(is_inv, a0 ^ r, c0)
    c0 = jnp.where(is_pad, U32(0), c0)
    zero = jnp.zeros_like(tg)
    return (
        c0,
        jnp.where(is_and, tg, zero),
        jnp.where(is_and, te, zero),
    )

"""jnp oracle: evaluate one topological netlist level in a single pass.

Per gate (op ∈ {0:XOR, 1:AND, 2:INV}):
    XOR -> a ^ b              (FreeXOR)
    AND -> HalfGate(a, b, tables, tweak)
    INV -> a                  (label passes through; semantics flip
                               garbler-side)
Computing the Half-Gate for every lane and masking is branch-free — the
right shape for the VPU (the paper's PE co-issues Half-Gate and FreeXOR
units; a SIMD machine evaluates both and selects).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.halfgate import ref as HG

U32 = jnp.uint32


def eval_level(ops, a, b, tg, te, tweaks):
    """ops (G,) uint32; labels/tables (G, 4); tweaks (G,). -> (G, 4)."""
    and_out = HG.eval_and_gates(a, b, tg, te, tweaks)
    xor_out = a ^ b
    is_and = (ops == U32(1))[:, None]
    is_inv = (ops == U32(2))[:, None]
    out = jnp.where(is_and, and_out, xor_out)
    return jnp.where(is_inv, a, out)

"""Pallas TPU kernel: fused level-synchronous GC evaluation.

One launch per netlist level: every gate of the level streams through VMEM
in (BLOCK, 4) label tiles together with its table rows and an op code; the
kernel computes FreeXOR and Half-Gate lanes branch-free and selects by op.
Compared with dispatching separate XOR / AND batches this halves the DMA
passes over the level and removes the gather/scatter between them — the
TPU counterpart of the paper's single pipelined PE that co-issues
Half-Gate (18 cy) and FreeXOR (1 cy) units.

Grid streams gate blocks (double-buffered); all operands are sequential so
the DMA engine prefetches block i+1 during the cipher of block i — the
OoRW-prefetch idea at the DMA level (DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.level_eval import ref

DEFAULT_BLOCK = 2048
U32 = jnp.uint32


def _kernel(ops_ref, a_ref, b_ref, tg_ref, te_ref, tw_ref, out_ref):
    ops = ops_ref[...][:, 0]
    tw = tw_ref[...][:, 0]
    out_ref[...] = ref.eval_level(
        ops, a_ref[...], b_ref[...], tg_ref[...], te_ref[...], tw
    )


def _garble_kernel(ops_ref, a_ref, b_ref, r_ref, tw_ref,
                   c_ref, tg_ref, te_ref):
    ops = ops_ref[...][:, 0]
    tw = tw_ref[...][:, 0]
    c0, tg, te = ref.garble_level(
        ops, a_ref[...], b_ref[...], r_ref[...], tw
    )
    c_ref[...] = c0
    tg_ref[...] = tg
    te_ref[...] = te


def _pad(x, block):
    g = x.shape[0]
    p = (-g) % block
    if p:
        x = jnp.concatenate([x, jnp.zeros((p, *x.shape[1:]), x.dtype)])
    return x


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def eval_level_pallas(ops, a, b, tg, te, tweaks, *, block=DEFAULT_BLOCK,
                      interpret=False):
    """ops (G,); a/b/tg/te (G,4); tweaks (G,). -> (G,4) uint32."""
    g = a.shape[0]
    blk = min(block, max(8, 1 << (g - 1).bit_length()))
    opsp = _pad(ops.reshape(-1, 1).astype(U32), blk)
    ap, bp = _pad(a, blk), _pad(b, blk)
    tgp, tep = _pad(tg, blk), _pad(te, blk)
    twp = _pad(tweaks.reshape(-1, 1).astype(U32), blk)
    gp = ap.shape[0]
    lab = lambda: pl.BlockSpec((blk, 4), lambda i: (i, 0))
    col = lambda: pl.BlockSpec((blk, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        _kernel,
        grid=(gp // blk,),
        in_specs=[col(), lab(), lab(), lab(), lab(), col()],
        out_specs=lab(),
        out_shape=jax.ShapeDtypeStruct((gp, 4), U32),
        interpret=interpret,
    )(opsp, ap, bp, tgp, tep, twp)
    return out[:g]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def garble_level_pallas(ops, a0, b0, r, tweaks, *, block=DEFAULT_BLOCK,
                        interpret=False):
    """Garbler lane: ops (G,); a0/b0/r (G,4); tweaks (G,).

    Returns (c0, tg, te), each (G,4) uint32 — the fused FreeXOR / INV /
    Half-Gate garbling pass over one padded level. The device executor
    feeds this lane the AND block ONLY (ops are AND/PAD): free lanes
    have all-zero table rows by construction, and shipping them through
    a 3-output kernel tripled the garble lane's write volume for
    nothing — the executor computes their XOR/INV-offset labels inline
    and keeps this kernel's DMA budget for rows that exist.
    """
    g = a0.shape[0]
    blk = min(block, max(8, 1 << (g - 1).bit_length()))
    opsp = _pad(ops.reshape(-1, 1).astype(U32), blk)
    ap, bp = _pad(a0, blk), _pad(b0, blk)
    rp = _pad(r, blk)
    twp = _pad(tweaks.reshape(-1, 1).astype(U32), blk)
    gp = ap.shape[0]
    lab = lambda: pl.BlockSpec((blk, 4), lambda i: (i, 0))
    col = lambda: pl.BlockSpec((blk, 1), lambda i: (i, 0))
    c0, tg, te = pl.pallas_call(
        _garble_kernel,
        grid=(gp // blk,),
        in_specs=[col(), lab(), lab(), lab(), col()],
        out_specs=(lab(), lab(), lab()),
        out_shape=(
            jax.ShapeDtypeStruct((gp, 4), U32),
            jax.ShapeDtypeStruct((gp, 4), U32),
            jax.ShapeDtypeStruct((gp, 4), U32),
        ),
        interpret=interpret,
    )(opsp, ap, bp, rp, twp)
    return c0[:g], tg[:g], te[:g]

from repro.kernels.level_eval.ops import eval_level, garble_level

__all__ = ["eval_level", "garble_level"]

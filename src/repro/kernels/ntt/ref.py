"""Negacyclic NTT over Z_q[X]/(X^N+1) — pure-jnp oracle (uint64 lanes).

Longa–Naehrig iterative butterflies with merged psi powers (bit-reversed
tables), so forward/inverse need no separate pre/post twisting. Requires
q ≡ 1 (mod 2N) and q < 2^31 so products fit in uint64 without reduction
tricks (the privacy plane enables x64).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# parameter search (host-side, python ints)
# ---------------------------------------------------------------------------


def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def find_ntt_primes(bits: int, count: int, n: int,
                    max_q: Optional[int] = None) -> list:
    """`count` primes q ≡ 1 (mod 2n) just below min(2^bits, max_q)."""
    out = []
    step = 2 * n
    hi = (1 << bits) - 1
    if max_q is not None:
        hi = min(hi, max_q)
    q = hi // step * step + 1
    if q > hi:
        q -= step
    while len(out) < count and q > 2 * n:
        if _is_prime(q):
            out.append(q)
        q -= step
    assert len(out) == count, f"not enough {bits}-bit NTT primes for N={n}"
    return out


INT32_PRODUCT_BOUND = 46340  # q^2 < 2^31: exact int32 butterfly products


def find_primitive_root(q: int, order: int) -> int:
    """An element of exact multiplicative order `order` mod prime q."""
    assert (q - 1) % order == 0
    for g in range(2, 10000):
        x = pow(g, (q - 1) // order, q)
        if pow(x, order // 2, q) != 1:  # order does not divide order/2
            return x
    raise RuntimeError("no root found")


def _bit_reverse(x: np.ndarray, bits: int) -> np.ndarray:
    out = np.zeros_like(x)
    for i in range(bits):
        out = (out << 1) | ((x >> i) & 1)
    return out


@functools.lru_cache(maxsize=64)
def ntt_tables(q: int, n: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """(psi_br, ipsi_br, n_inv): bit-reversed powers of psi (2n-th root)."""
    psi = find_primitive_root(q, 2 * n)
    assert pow(psi, n, q) == q - 1  # psi^n = -1 (negacyclic)
    bits = n.bit_length() - 1
    idx = np.arange(n, dtype=np.int64)
    br = _bit_reverse(idx, bits)
    powers = np.array([pow(psi, int(i), q) for i in range(n)], dtype=np.uint64)
    ipowers = np.array(
        [pow(psi, (-int(i)) % (2 * n), q) for i in range(n)], dtype=np.uint64
    )
    n_inv = pow(n, q - 2, q)
    return powers[br], ipowers[br], n_inv


# ---------------------------------------------------------------------------
# jnp butterflies
# ---------------------------------------------------------------------------


def _mulmod(a, b, q):
    return (a * b) % jnp.uint64(q)


def _addmod(a, b, q):
    s = a + b
    return jnp.where(s >= jnp.uint64(q), s - jnp.uint64(q), s)


def _submod(a, b, q):
    return jnp.where(a >= b, a - b, a + jnp.uint64(q) - b)


def ntt_forward(a: jnp.ndarray, q: int, n: int) -> jnp.ndarray:
    """a: (..., N) uint64 coefficients -> NTT domain (bit-reversed order)."""
    psi_br, _, _ = ntt_tables(q, n)
    psi_br = jnp.asarray(psi_br)
    batch = a.shape[:-1]
    t = n
    m = 1
    while m < n:
        t //= 2
        a = a.reshape(*batch, m, 2 * t)
        u = a[..., :t]
        v = a[..., t:]
        s = psi_br[m : 2 * m]  # (m,)
        v = _mulmod(v, s[:, None], q)
        a = jnp.concatenate([_addmod(u, v, q), _submod(u, v, q)], axis=-1)
        m *= 2
    return a.reshape(*batch, n)


def ntt_inverse(a: jnp.ndarray, q: int, n: int) -> jnp.ndarray:
    _, ipsi_br, n_inv = ntt_tables(q, n)
    ipsi_br = jnp.asarray(ipsi_br)
    batch = a.shape[:-1]
    t = 1
    m = n
    while m > 1:
        h = m // 2
        a = a.reshape(*batch, h, 2 * t)
        u = a[..., :t]
        v = a[..., t:]
        s = ipsi_br[h : 2 * h]  # (h,)
        nu = _addmod(u, v, q)
        nv = _mulmod(_submod(u, v, q), s[:, None], q)
        a = jnp.concatenate([nu, nv], axis=-1)
        t *= 2
        m = h
    a = a.reshape(*batch, n)
    return _mulmod(a, jnp.uint64(n_inv), q)


def negacyclic_mul(a: jnp.ndarray, b: jnp.ndarray, q: int, n: int) -> jnp.ndarray:
    """a * b mod (X^N + 1, q) via NTT -> pointwise -> INTT."""
    fa = ntt_forward(a, q, n)
    fb = ntt_forward(b, q, n)
    return ntt_inverse(_mulmod(fa, fb, q), q, n)


def negacyclic_mul_naive(a: np.ndarray, b: np.ndarray, q: int, n: int) -> np.ndarray:
    """O(N^2) oracle for tests (python ints, no overflow)."""
    a = np.asarray(a, dtype=object)
    b = np.asarray(b, dtype=object)
    out = np.zeros(n, dtype=object)
    for i in range(n):
        if int(a[i]) == 0:
            continue
        for j in range(n):
            k = i + j
            v = int(a[i]) * int(b[j])
            if k >= n:
                out[k - n] = (out[k - n] - v) % q
            else:
                out[k] = (out[k] + v) % q
    return out.astype(np.uint64)

from repro.kernels.ntt.ops import ntt_forward, ntt_inverse, negacyclic_mul

__all__ = ["ntt_forward", "ntt_inverse", "negacyclic_mul"]

"""Dispatch wrapper for the NTT kernel.

``impl``: "auto" | "ref" | "pallas" | "pallas_interpret".

The Pallas path targets RNS limb primes < 2^15 (products fit int32 exactly
on the TPU VPU — the standard HE-on-accelerator limb decomposition); the
jnp/uint64 reference handles the ~30-bit primes BFV-lite uses on CPU.
"""

from __future__ import annotations

from repro.kernels.dispatch import resolve_impl
from repro.kernels.ntt import ref as _ref


def _resolve(impl: str, q: int) -> str:
    auto = impl == "auto"
    impl = resolve_impl(impl)
    if impl == "jit" or (auto and q >= (1 << 15)):
        return "ref"  # large-prime products overflow int32 VPU lanes
    return impl


def ntt_forward(a, q: int, n: int, impl: str = "auto"):
    impl = _resolve(impl, q)
    if impl == "ref":
        return _ref.ntt_forward(a, q, n)
    from repro.kernels.ntt.ntt import ntt_pallas

    return ntt_pallas(a, q, n, inverse=False,
                      interpret=(impl == "pallas_interpret"))


def ntt_inverse(a, q: int, n: int, impl: str = "auto"):
    impl = _resolve(impl, q)
    if impl == "ref":
        return _ref.ntt_inverse(a, q, n)
    from repro.kernels.ntt.ntt import ntt_pallas

    return ntt_pallas(a, q, n, inverse=True,
                      interpret=(impl == "pallas_interpret"))


def negacyclic_mul(a, b, q: int, n: int, impl: str = "auto"):
    impl = _resolve(impl, q)
    if impl == "ref":
        return _ref.negacyclic_mul(a, b, q, n)
    fa = ntt_forward(a, q, n, impl)
    fb = ntt_forward(b, q, n, impl)
    prod = (fa.astype("int64") * fb.astype("int64")) % q  # host-side combine
    return ntt_inverse(prod.astype(a.dtype), q, n, impl)

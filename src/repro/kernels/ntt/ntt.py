"""Pallas TPU kernel: negacyclic NTT for RNS limb primes < 2^15.

TPU adaptation (DESIGN.md §3): the jnp reference uses ~30-bit primes with
uint64 products, which TPUs lack. Production HE-on-TPU decomposes the RNS
basis into limb primes below 2^15 so every butterfly product fits int32
exactly on the VPU; this kernel implements that limb path.

Tiling: one batch-block of polynomials is resident in VMEM ((BLOCK, N)
int32 — N=4096 is 16 KiB/row, far under VMEM); all log2(N) stages run
in-kernel (the Longa–Naehrig layout keeps every stage a contiguous
(m, 2t) reshape + concat, no gathers), so HBM sees exactly one read and
one write per polynomial per direction. Batch blocks stream through the
grid with Pallas double-buffering.

Validated in interpret mode against the jnp oracle for every (N, q) in the
test sweep.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ntt.ref import ntt_tables

DEFAULT_BLOCK = 8


def _mulmod(a, b, q):
    return (a * b) % jnp.int32(q)


def _addmod(a, b, q):
    s = a + b
    return jnp.where(s >= jnp.int32(q), s - jnp.int32(q), s)


def _submod(a, b, q):
    d = a - b
    return jnp.where(d < 0, d + jnp.int32(q), d)


def _fwd_kernel(n, q, a_ref, psi_ref, o_ref):
    a = a_ref[...]  # (blk, n) int32
    psi = psi_ref[...]  # (1, n)
    blk = a.shape[0]
    t = n
    m = 1
    while m < n:
        t //= 2
        a = a.reshape(blk, m, 2 * t)
        u = a[..., :t]
        v = a[..., t:]
        s = jax.lax.dynamic_slice(psi, (0, m), (1, m))  # (1, m)
        v = _mulmod(v, s[0][None, :, None], q)
        a = jnp.concatenate([_addmod(u, v, q), _submod(u, v, q)], axis=-1)
        m *= 2
    o_ref[...] = a.reshape(blk, n)


def _inv_kernel(n, q, n_inv, a_ref, ipsi_ref, o_ref):
    a = a_ref[...]
    ipsi = ipsi_ref[...]
    blk = a.shape[0]
    t = 1
    m = n
    while m > 1:
        h = m // 2
        a = a.reshape(blk, h, 2 * t)
        u = a[..., :t]
        v = a[..., t:]
        s = jax.lax.dynamic_slice(ipsi, (0, h), (1, h))
        nu = _addmod(u, v, q)
        nv = _mulmod(_submod(u, v, q), s[0][None, :, None], q)
        a = jnp.concatenate([nu, nv], axis=-1)
        t *= 2
        m = h
    o_ref[...] = _mulmod(a.reshape(blk, n), jnp.int32(n_inv), q)


@functools.partial(
    jax.jit, static_argnames=("q", "n", "inverse", "block", "interpret")
)
def ntt_pallas(a, q: int, n: int, *, inverse: bool = False,
               block: int = DEFAULT_BLOCK, interpret: bool = False):
    """a: (..., N) int32/uint32 residues of a prime q < 2^15."""
    assert q <= 46340, "limb kernel needs q^2 < 2^31 (exact int32 products)"
    psi_br, ipsi_br, n_inv = ntt_tables(q, n)
    lead = a.shape[:-1]
    af = a.reshape(-1, n).astype(jnp.int32)
    b = af.shape[0]
    pad = (-b) % block
    if pad:
        af = jnp.concatenate([af, jnp.zeros((pad, n), jnp.int32)])
    bp = af.shape[0]
    table = jnp.asarray(
        (ipsi_br if inverse else psi_br).astype(np.int64), jnp.int32
    ).reshape(1, n)
    kern = (
        functools.partial(_inv_kernel, n, q, int(n_inv))
        if inverse
        else functools.partial(_fwd_kernel, n, q)
    )
    out = pl.pallas_call(
        kern,
        grid=(bp // block,),
        in_specs=[
            pl.BlockSpec((block, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, n), jnp.int32),
        interpret=interpret,
    )(af, table)
    out = out[:b].reshape(*lead, n)
    return out.astype(a.dtype)

"""The APINT protocol (and the PRIMER-style baseline it improves on).

Two-party PiT: the *client* owns the input and acts as garbler; the
*server* owns the weights and acts as evaluator. Values are additive
shares mod prime t (= the BFV plaintext modulus, so HE slots and shares
are the same algebra). Both parties run in-process; every message is
metered through ``ot.Channel`` and every GC workload is counted, which is
what the paper's latency/communication tables are built from.

Layer menu:
  linear_*      — DELPHI split: offline HE Linear(R1), online standard matmul
  beaver_matmul — private×private products (attention scores, PV)
  gc_apply      — garbled nonlinear function with share reconstruct/remask
  layernorm     — full-GC baseline  OR  APINT offload (Fig. 4 ⑦–⑬):
                  mean/center on shares, variance via the HE inner-product
                  identity, β/γ affine via HE slots, only rsqrt·mul in GC.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.config import PrivacyConfig
from repro.core import garble as G
from repro.core import he as HE
from repro.core import secret_sharing as SS
from repro.core.circuits import arith, nonlinear as NL
from repro.core.circuits.builder import CircuitBuilder, Word
from repro.core.circuits.shares import (
    gc_word_bits,
    input_shared_word,
    output_shared,
)
from repro.core.netlist import Netlist
from repro.core.ot import Channel, ot_labels, OT_BYTES_PER_TRANSFER


@dataclass
class Stats:
    channel_offline: Channel = field(default_factory=Channel)
    channel_online: Channel = field(default_factory=Channel)
    gc_and_gates: int = 0
    gc_gates: int = 0
    gc_instances_gates: int = 0  # gates × instances actually executed
    gc_instances_ands: int = 0
    he_pt_muls: int = 0
    he_encrypts: int = 0
    he_decrypts: int = 0
    t_offline_s: float = 0.0
    t_online_s: float = 0.0
    per_fn: Dict[str, Dict[str, int]] = field(default_factory=dict)

    def fn(self, name: str) -> Dict[str, int]:
        return self.per_fn.setdefault(
            name, {"and": 0, "gates": 0, "instances": 0, "table_bytes": 0}
        )


def _bits_of(vals: np.ndarray, k: int, t: int) -> np.ndarray:
    """Share residues (I, n) mod t -> (I, n*k) LSB-first bits. k <= 62."""
    v = np.asarray(vals, np.uint64)
    shifts = np.arange(k, dtype=np.uint64)
    out = ((v[..., None] >> shifts) & np.uint64(1)).astype(np.uint8)
    return out.reshape(*v.shape[:-1], v.shape[-1] * k)


def _words_from_bits(bits: np.ndarray, k: int, t: int) -> np.ndarray:
    b = bits.reshape(*bits.shape[:-1], bits.shape[-1] // k, k).astype(np.uint64)
    shifts = np.arange(k, dtype=np.uint64)
    vals = np.sum(b << shifts, axis=-1, dtype=np.uint64)
    return np.mod(vals, np.uint64(t))


class PiTProtocol:
    def __init__(self, pcfg: PrivacyConfig, *, he_params: Optional[HE.BFVParams] = None,
                 seed: int = 0, impl: str = "ref"):
        HE.ensure_x64()
        self.pcfg = pcfg
        self.params = he_params or HE.make_params(
            n=pcfg.he_poly_n, num_primes=pcfg.he_num_primes,
            t_bits=pcfg.he_t_bits,
        )
        self.t = self.params.t
        self.k = gc_word_bits(self.t)
        self.frac = pcfg.frac_bits
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.impl = impl
        self.stats = Stats()
        self.sk, self.pk = HE.keygen(self.params, self._next_key())
        self._netlist_cache: Dict[str, Netlist] = {}

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    @property
    def style(self) -> str:
        return self.pcfg.mult_style

    # ------------------------------------------------------------------
    # shares
    # ------------------------------------------------------------------
    def share_input(self, x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Client-side fixed-point encode + share."""
        enc = SS.encode_fx(x, self.frac, self.t)
        c, s = SS.share(self.rng, enc, self.t)
        self.stats.channel_online.c2s(s.size * 8, "input-share")
        return c, s

    def reveal(self, c_share, s_share, scale_bits: Optional[int] = None) -> np.ndarray:
        v = SS.reconstruct(c_share, s_share, self.t)
        return SS.decode_fx(v, self.frac, self.t,
                            scale_bits if scale_bits is not None else self.frac)

    # ------------------------------------------------------------------
    # DELPHI linear layer (server weights)
    # ------------------------------------------------------------------
    def linear(self, W: np.ndarray, x_c, x_s, bias: Optional[np.ndarray] = None,
               use_he_offline: bool = False):
        """y = W x + b at scale 2·frac. Shares in (c, s); W float.

        Offline: client sends Enc(R1); server computes Enc(W·R1 − s_mask)
        (he_matvec for small dims or metered-equivalent modular math),
        client decrypts its share. Online: server computes W(x − R1) + s.
        """
        Wq = np.round(np.asarray(W, np.float64) * (1 << self.frac)).astype(np.int64)
        d_out, d_in = Wq.shape
        # offline ------------------------------------------------------
        t0 = time.time()
        r1 = self.rng.integers(0, self.t, x_c.shape, dtype=np.uint64)
        ct_count = math.ceil(x_c.size / self.params.n)
        ch = self.stats.channel_offline
        ch.c2s(ct_count * 2 * len(self.params.qs) * self.params.n * 8, "he-enc-r")
        Wmod = np.mod(Wq, self.t).astype(np.uint64)
        if use_he_offline and x_c.ndim == 1:
            ct_r = HE.encrypt(self.params, self.pk,
                              HE.encode_coeffs(self.params, r1), self._next_key())
            outs = HE.he_matvec(self.params, ct_r, Wq)
            self.stats.he_pt_muls += len(outs)
            self.stats.he_encrypts += 1
            polys = [HE.decrypt(self.params, self.sk, c) for c in outs]
            self.stats.he_decrypts += len(outs)
            wr = HE.he_matvec_extract(self.params, polys, d_in, d_out)
            per_ct, blocks = HE.matvec_plan(self.params, d_in, d_out)
            ch.s2c(blocks * 2 * len(self.params.qs) * self.params.n * 8, "he-wr")
        else:
            # metered-equivalent path (big matrices): same math mod t
            wr = (SS.matmul_mod(Wmod, r1.reshape(-1, 1), self.t).reshape(-1)
                  if r1.ndim == 1 else SS.matmul_mod(r1, Wmod.T, self.t))
            blocks = math.ceil(wr.size / self.params.n)
            self.stats.he_pt_muls += blocks
            ch.s2c(blocks * 2 * len(self.params.qs) * self.params.n * 8, "he-wr")
        s_mask = self.rng.integers(0, self.t, wr.shape, dtype=np.uint64)
        client_y = SS.sub_mod(wr, s_mask, self.t)  # client's offline share
        self.stats.t_offline_s += time.time() - t0
        # online -------------------------------------------------------
        t0 = time.time()
        x_open = SS.sub_mod(SS.add_mod(x_c, x_s, self.t), r1, self.t)
        # (client sends x_c − r1; server adds its share → x − r1 opened to server)
        self.stats.channel_online.c2s(x_open.size * 8, "x-minus-r")
        wx = (SS.matmul_mod(Wmod, x_open.reshape(-1, 1), self.t).reshape(-1)
              if x_open.ndim == 1 else SS.matmul_mod(x_open, Wmod.T, self.t))
        server_y = SS.add_mod(wx, s_mask, self.t)
        if bias is not None:
            bq = SS.encode_fx(bias, 2 * self.frac, self.t)
            server_y = SS.add_mod(server_y, np.broadcast_to(bq, server_y.shape), self.t)
        self.stats.t_online_s += time.time() - t0
        return client_y, server_y  # scale 2·frac

    # ------------------------------------------------------------------
    # Beaver matmul (private × private)
    # ------------------------------------------------------------------
    def matmul_private(self, xc, xs, yc, ys):
        m, k = xc.shape
        k2, n = yc.shape
        trip = SS.deal_matmul_triple(self.rng, m, k, n, self.t)
        # triple generation is offline traffic (HE-based in production)
        self.stats.channel_offline.s2c((m * k + k * n + m * n) * 8, "beaver")
        z1, z2, opened = SS.beaver_matmul(xc, xs, yc, ys, trip, self.t)
        self.stats.channel_online.c2s(opened // 2, "beaver-open")
        self.stats.channel_online.s2c(opened // 2, "beaver-open")
        return z1, z2  # scale doubles

    # ------------------------------------------------------------------
    # garbled nonlinear function
    # ------------------------------------------------------------------
    def build_fn_circuit(self, name: str, n_in: int, n_out: int,
                         body: Callable[[CircuitBuilder, List[Word]], List[Word]],
                         descale: int = 0, n_raw_e: int = 0) -> Netlist:
        """Share-reconstruct → body(ins, raws) → remask, cached by name.

        ``n_raw_e`` appends plain evaluator words (server-private values,
        e.g. γ/β in the full-GC LayerNorm), two's-complement encoded.
        """
        if name in self._netlist_cache:
            return self._netlist_cache[name]
        cb = CircuitBuilder(name)
        ins = [input_shared_word(cb, self.t, descale) for _ in range(n_in)]
        raws = [cb.e_input_word(self.k) for _ in range(n_raw_e)]
        outs = body(cb, ins, raws) if n_raw_e else body(cb, ins)
        assert len(outs) == n_out
        for y in outs:
            output_shared(cb, Word(y.bits[: self.k]), self.t)
        net = cb.build()
        self._netlist_cache[name] = net
        return net

    def gc_apply(self, net: Netlist, xc: np.ndarray, xs: np.ndarray,
                 n_out: int, raw_e: Optional[np.ndarray] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """xc/xs: (I, n_in) share residues mod t. Returns (I, n_out) shares.

        Client garbles (offline), server evaluates (online). Instances are
        batched — the paper's coarse-grained row mapping. ``raw_e``:
        (I, n_raw) signed int64 server-private values (two's complement).
        """
        I, n_in = xc.shape
        k = self.k
        st = self.stats
        # ---- offline: garble + send tables + client-input labels -------
        t0 = time.time()
        gcirc = G.garble(net, self._next_key(), I, impl=self.impl)
        masks = self.rng.integers(0, self.t, (I, n_out), dtype=np.uint64)
        mask_enc = SS.sub_mod(np.zeros_like(masks), masks, self.t)  # t − r
        g_bits = np.concatenate(
            [_bits_of(xc, k, self.t), _bits_of(mask_enc, k, self.t)], axis=1
        )
        st.channel_offline.c2s(int(gcirc.tables.size) * 4, f"tables:{net.name}")
        st.channel_offline.c2s(I * len(net.garbler_inputs) * 16, "g-labels")
        st.gc_and_gates += net.and_count
        st.gc_gates += net.num_gates
        st.gc_instances_ands += net.and_count * I
        st.gc_instances_gates += net.num_gates * I
        f = st.fn(net.name)
        f["and"] = net.and_count
        f["gates"] = net.num_gates
        f["instances"] += I
        f["table_bytes"] += int(gcirc.tables.size) * 4
        st.t_offline_s += time.time() - t0
        # ---- online: OT server labels, evaluate, decode ----------------
        t0 = time.time()
        assert g_bits.shape[1] == len(net.garbler_inputs)
        g_lab = G.encode_inputs(gcirc, net.garbler_inputs, g_bits)
        e_bits = _bits_of(xs, k, self.t)
        if raw_e is not None:
            rv = np.mod(np.asarray(raw_e, np.int64), 1 << k).astype(np.uint64)
            e_bits = np.concatenate(
                [e_bits, _bits_of(rv, k, 1 << k)], axis=1
            )
        e_zero = jnp.stack(
            [gcirc.input_zero[int(w)] for w in net.evaluator_inputs], axis=1
        )
        e_lab = ot_labels(st.channel_online, e_zero, gcirc.r[:, None, :],
                          e_bits, tag=f"ot:{net.name}")
        active = {int(w): g_lab[:, j] for j, w in enumerate(net.garbler_inputs)}
        active.update(
            {int(w): e_lab[:, j] for j, w in enumerate(net.evaluator_inputs)}
        )
        active.update(G.const_labels(gcirc))
        out_lab = G.evaluate(net, gcirc.tables, active, impl=self.impl)
        out_bits = G.decode_outputs(gcirc, out_lab)
        server_share = _words_from_bits(out_bits, k, self.t)
        st.t_online_s += time.time() - t0
        return masks, server_share  # client share = r (masks)

    # ------------------------------------------------------------------
    # composite layers
    # ------------------------------------------------------------------
    def softmax_rows(self, sc, ss, row_len: int, in_scale: int):
        """(I, n) shares at scale `in_scale` -> softmax shares at frac."""
        def body(cb, ins):
            return _softmax_body(cb, ins, self.frac, self.style)

        net = self.build_fn_circuit(
            f"softmax{row_len}", row_len, row_len, body,
            descale=in_scale - self.frac,
        )
        return self.gc_apply(net, sc, ss, row_len)

    def activation(self, kind: str, xc, xs, in_scale: int):
        """Elementwise GeLU/SiLU on shares of any shape (batched rows)."""
        def body(cb, ins):
            if kind == "gelu":
                return [_gelu_body(cb, ins[0], self.frac, self.style)]
            return [_silu_body(cb, ins[0], self.frac, self.style)]

        net = self.build_fn_circuit(
            f"{kind}", 1, 1, body, descale=in_scale - self.frac
        )
        flat_c = xc.reshape(-1, 1)
        flat_s = xs.reshape(-1, 1)
        oc, os_ = self.gc_apply(net, flat_c, flat_s, 1)
        return oc.reshape(xc.shape), os_.reshape(xs.shape)

    def layernorm(self, xc, xs, gamma, beta, in_scale: int):
        """(I, n) shares at scale `in_scale` -> LayerNorm shares at frac.

        APINT offload when pcfg.layernorm_offload, else full-GC baseline
        (γ/β enter the circuit as raw evaluator words — they are the
        server's weights, so they cost full word×word multiplies).
        """
        I, n = xc.shape
        f = self.frac
        if not self.pcfg.layernorm_offload:
            def body(cb, ins, raws):
                return _layernorm_body(cb, ins, f, self.style,
                                       raws[:n], raws[n:])

            net = self.build_fn_circuit(
                f"layernorm_full{n}", n, n, body,
                descale=in_scale - f, n_raw_e=2 * n,
            )
            gq = np.round(np.asarray(gamma, np.float64) * (1 << f)).astype(np.int64)
            bq = np.round(np.asarray(beta, np.float64) * (1 << f)).astype(np.int64)
            raw = np.concatenate([np.broadcast_to(gq, (I, n)),
                                  np.broadcast_to(bq, (I, n))], axis=1)
            return self.gc_apply(net, xc, xs, n, raw_e=raw)

        # ---- APINT Fig. 4 ⑦–⑬ -----------------------------------------
        t = self.t
        st = self.stats
        # ⑦ mean & center on shares (standard local ops): ×round(2^f/n)
        inv_n = int(round((1 << f) / n))
        mu_c = SS.scalar_mul_mod(inv_n, _row_sum(xc, t), t)
        mu_s = SS.scalar_mul_mod(inv_n, _row_sum(xs, t), t)
        # centered x' at scale Sc = in_scale + f
        cxc = SS.sub_mod(SS.scalar_mul_mod(1 << f, xc, t), mu_c[:, None], t)
        cxs = SS.sub_mod(SS.scalar_mul_mod(1 << f, xs, t), mu_s[:, None], t)
        sc_ = in_scale + f
        # ⑧⑨ variance via HE inner product: Σx'² = Σu² + 2⟨u, r'⟩ + Σr'²
        # (u = server's centered share, r' = client's centered share)
        t0 = time.time()
        cross_c, cross_s = self._he_inner(cxc, cxs)
        st.t_online_s += time.time() - t0
        var_c = SS.add_mod(_row_sum_sq(cxc, t),
                           SS.scalar_mul_mod(2, cross_c, t), t)
        var_s = SS.add_mod(_row_sum_sq(cxs, t),
                           SS.scalar_mul_mod(2, cross_s, t), t)
        var_c = SS.scalar_mul_mod(inv_n, var_c, t)  # scale 2·Sc + f
        var_s = SS.scalar_mul_mod(inv_n, var_s, t)
        var_descale = 2 * sc_  # (2·Sc + f) → f
        # ⑩⑪ γ·x' via HE slots: γ⊙r' offline (Enc(R2') sent offline), γ⊙u
        # server-local. Scale: Sc + f → descale Sc in GC.
        gq = SS.encode_fx(np.asarray(gamma), f, t)
        gxc = _rowwise_mul(gq, cxc, t)
        gxs = _rowwise_mul(gq, cxs, t)
        ct_blocks = math.ceil(cxc.size / self.params.n)
        st.channel_offline.c2s(
            ct_blocks * 2 * len(self.params.qs) * self.params.n * 8, "he-ln-r")
        st.he_pt_muls += ct_blocks
        # ⑫ reduced GC: rsqrt(var) × (γ·x')
        net = self.build_fn_circuit(
            f"layernorm_reduced{n}_s{in_scale}", n + 1, n,
            _make_ln_reduced(f, self.style, var_descale, sc_), descale=0,
        )
        in_c = np.concatenate([gxc, var_c[:, None]], axis=1)
        in_s = np.concatenate([gxs, var_s[:, None]], axis=1)
        oc, os_ = self.gc_apply(net, in_c, in_s, n)
        # ⑬ + β (server-held parameter added to its share)
        bq = SS.encode_fx(np.asarray(beta), f, t)
        os_ = SS.add_mod(os_, np.broadcast_to(bq, os_.shape), t)
        return oc, os_

    def _he_inner(self, cxc, cxs):
        """Shares of ⟨client_row, server_row⟩ per row (Fig. 4 ⑧).

        Offline: client sends Enc(r'_row) coefficient-packed; online the
        server mul_plains with its reversed share and masks.
        """
        I, n = cxc.shape
        st = self.stats
        ch_off, ch_on = st.channel_offline, st.channel_online
        ct_bytes = 2 * len(self.params.qs) * self.params.n * 8
        ch_off.c2s(I * ct_bytes, "he-enc-centered")
        st.he_encrypts += I
        # metered-equivalent modular math (exact same result as the HE path,
        # which tests exercise at small sizes through he.he_matvec):
        cross = np.array(
            [int(np.dot(cxc[i].astype(object), cxs[i].astype(object)) % self.t)
             for i in range(I)], dtype=np.uint64)
        st.he_pt_muls += I
        ch_on.s2c(I * ct_bytes, "he-cross")
        st.he_decrypts += I
        mask = self.rng.integers(0, self.t, I, dtype=np.uint64)
        return SS.sub_mod(cross, mask, self.t), mask


# ---------------------------------------------------------------------------
# circuit bodies (pure functions of reconstructed words)
# ---------------------------------------------------------------------------


def _softmax_body(cb, ins, frac, style):
    mx = ins[0]
    for w in ins[1:]:
        mx = arith.max_word(cb, mx, w)
    es = []
    for w in ins:
        d = arith.sub(cb, w, mx)
        es.append(NL.exp_circuit(cb, d, frac, style))
    s = es[0]
    for w in es[1:]:
        s = arith.add(cb, s, w)
    inv = NL.reciprocal_circuit(cb, s, frac, style)
    return [arith.fx_mul(cb, w, inv, frac, style=style) for w in es]


def _gelu_body(cb, x, frac, style):
    # inline of nonlinear.gelu on an existing word
    from repro.core.circuits.nonlinear import _fx, _gelu

    k = len(x)
    lo = cb.const_word(_fx(-4.0, frac, k), k)
    hi = cb.const_word(_fx(4.0, frac, k) - 1, k)
    xc = arith.mux(cb, arith.lt_signed(cb, x, lo), lo, x)
    xc = arith.mux(cb, arith.lt_signed(cb, hi, xc), hi, xc)
    xs = arith.add_const(cb, xc, _fx(4.0, frac, k))
    segs = 16
    seg_bits = 4
    lo_bit = frac + 3 - seg_bits
    idx = Word(tuple(xs[lo_bit + i] for i in range(seg_bits)))
    width = 8.0 / segs
    slopes, intercepts = [], []
    for s in range(segs):
        a = -4.0 + s * width
        ga, gb = _gelu(a), _gelu(a + width)
        m = (gb - ga) / width
        slopes.append(_fx(m, frac, k))
        intercepts.append(_fx(ga - m * a, frac, k))

    def lut(tbl):
        level = [cb.const_word(v, k) for v in tbl]
        for bit in idx:
            level = [arith.mux(cb, bit, level[i + 1], level[i])
                     for i in range(0, len(level), 2)]
        return level[0]

    y = arith.fx_mul(cb, xc, lut(slopes), frac, style=style)
    return arith.add(cb, y, lut(intercepts))


def _silu_body(cb, x, frac, style):
    from repro.core.circuits.nonlinear import _fx

    k = len(x)
    lo = cb.const_word(_fx(-6.0, frac, k), k)
    hi = cb.const_word(_fx(6.0, frac, k) - 1, k)
    xc = arith.mux(cb, arith.lt_signed(cb, x, lo), lo, x)
    xc = arith.mux(cb, arith.lt_signed(cb, hi, xc), hi, xc)
    xs = arith.add_const(cb, xc, _fx(6.0, frac, k))
    segs, seg_bits, int_bits = 32, 5, 4
    lo_bit = frac + int_bits - seg_bits  # 16-range
    idx = Word(tuple(xs[frac + int_bits - seg_bits + i] for i in range(seg_bits)))
    width = 16.0 / segs

    def f(v):
        vv = max(min(v, 6.0), -6.0)
        return vv / (1.0 + math.exp(-vv))

    slopes, intercepts = [], []
    for s in range(segs):
        a = -6.0 + s * width
        b = min(a + width, 6.0)
        fa, fb = f(a), f(b)
        m = (fb - fa) / (b - a) if b > a else 0.0
        slopes.append(_fx(m, frac, k))
        intercepts.append(_fx(fa - m * a, frac, k))

    def lut(tbl):
        level = [cb.const_word(v, k) for v in tbl]
        for bit in idx:
            level = [arith.mux(cb, bit, level[i + 1], level[i])
                     for i in range(0, len(level), 2)]
        return level[0]

    y = arith.fx_mul(cb, xc, lut(slopes), frac, style=style)
    return arith.add(cb, y, lut(intercepts))


def _layernorm_body(cb, ins, frac, style, gammas, betas):
    """Full-GC LayerNorm; γ/β are evaluator-supplied words."""
    n = len(ins)
    s = ins[0]
    for w in ins[1:]:
        s = arith.add(cb, s, w)
    sh = int(math.log2(n))
    mean = arith.shift_right_const(cb, s, sh, arithmetic=True)
    cs = [arith.sub(cb, w, mean) for w in ins]
    sq = [arith.fx_mul(cb, c, c, frac, style=style) for c in cs]
    v = sq[0]
    for w in sq[1:]:
        v = arith.add(cb, v, w)
    var = arith.shift_right_const(cb, v, sh, arithmetic=True)
    var = arith.add_const(cb, var, 1)
    rs = NL.rsqrt_circuit(cb, var, frac, style)
    outs = []
    for c, g, b in zip(cs, gammas, betas):
        y = arith.fx_mul(cb, c, rs, frac, style=style)
        y = arith.fx_mul(cb, y, g, frac, style=style)
        outs.append(arith.add(cb, y, b))
    return outs


def _make_ln_reduced(frac, style, var_descale, x_descale):
    def body(cb, ins):
        xs, var = ins[:-1], ins[-1]
        var = arith.shift_right_const(cb, var, var_descale, arithmetic=True)
        var = arith.add_const(cb, var, 1)
        rs = NL.rsqrt_circuit(cb, var, frac, style)
        outs = []
        for x in xs:
            xd = arith.shift_right_const(cb, x, x_descale, arithmetic=True)
            outs.append(arith.fx_mul(cb, xd, rs, frac, style=style))
        return outs

    return body


def _ln_reduced_body(cb, ins, frac, style):  # kept for direct benching
    return _make_ln_reduced(frac, style, 0, 0)(cb, ins)


# ---------------------------------------------------------------------------
# share helpers
# ---------------------------------------------------------------------------


def _row_sum(x, t):
    return np.array(
        [int(np.sum(x[i].astype(object)) % t) for i in range(x.shape[0])],
        dtype=np.uint64,
    )


def _row_sum_sq(x, t):
    return np.array(
        [int(np.dot(x[i].astype(object), x[i].astype(object)) % t)
         for i in range(x.shape[0])],
        dtype=np.uint64,
    )


def _rowwise_mul(const_row, x, t):
    return ((const_row.astype(object)[None, :] * x.astype(object)) % t).astype(
        np.uint64
    )

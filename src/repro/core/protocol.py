"""The APINT protocol (and the PRIMER-style baseline it improves on).

Two-party PiT: the *client* owns the input and acts as garbler; the
*server* owns the weights and acts as evaluator. Values are additive
shares mod prime t (= the BFV plaintext modulus, so HE slots and shares
are the same algebra). Both parties run in-process; every message is
metered through ``ot.Channel`` and every GC workload is counted, which is
what the paper's latency/communication tables are built from.

Layer menu:
  linear_*      — DELPHI split: offline HE Linear(R1), online standard matmul
  beaver_matmul — private×private products (attention scores, PV)
  gc_apply      — garbled nonlinear function with share reconstruct/remask
  trunc         — exact deferred rescale through a tiny identity circuit
  layernorm     — full-GC baseline  OR  APINT offload (Fig. 4 ⑦–⑬):
                  mean/center on shares, variance via the HE inner-product
                  identity, β/γ affine via HE slots, only rsqrt·mul in GC.

Every layer is split into an explicit ``*_offline(...) -> correlation`` /
``*_online(x, correlation)`` pair. Offline methods depend only on shapes
and server weights (they garble circuits, precompute the HE masked
products and deal Beaver triples); online methods consume one correlation
and the live input shares. The single-call composites (``linear``,
``matmul_private``, ``gc_apply``, ``softmax_rows``, ``activation``,
``trunc``, ``layernorm``) are thin compatibility wrappers over the pairs.
``core/session.py`` builds the request-pooled preprocessing API on top.
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.config import PrivacyConfig
from repro import obs
from repro.core import garble as G
from repro.core import he as HE
from repro.core import secret_sharing as SS
from repro.core.circuits import arith, nonlinear as NL
from repro.core.circuits.builder import CircuitBuilder, Word
from repro.core.circuits.shares import (
    gc_word_bits,
    input_shared_word,
    output_shared,
)
from repro.core.netlist import Netlist
from repro.core.ot import (
    Channel, choose_labels, ot_labels, OT_BYTES_PER_TRANSFER,
    BASE_OT_A_BYTES, BASE_OT_B_BYTES, ot_v2_request_bytes,
    ot_v2_response_bytes,
)
from repro.core.wireformat import (
    SEED_STREAM_BYTES, TABLE_DELTA_WORDS, tables_delta_anchor_bytes,
)


@dataclass
class PhaseStats:
    """One protocol phase: its channel ledger and wall time."""

    channel: Channel = field(default_factory=Channel)
    t_s: float = 0.0

    def comm_snapshot(self) -> Dict[str, object]:
        return {
            "total": self.channel.total,
            "c2s": self.channel.client_to_server,
            "s2c": self.channel.server_to_client,
            "by_tag": dict(self.channel.by_tag),
        }


class Stats:
    """Phase-scoped protocol accounting.

    All traffic and wall time is attributed to an explicit phase
    (``offline`` or ``online``) through the :meth:`phase` context manager
    rather than ad-hoc field mutation; ``channel_offline`` /
    ``t_offline_s`` etc. remain as read-only compatibility views. Timing
    is span-backed (``obs.timer``, monotonic) and re-entrant: nested
    ``phase`` blocks of the same name accumulate wall time exactly once.
    """

    def __init__(self):
        self.offline = PhaseStats()
        self.online = PhaseStats()
        self.gc_and_gates = 0
        self.gc_gates = 0
        self.gc_instances_gates = 0  # gates × instances actually executed
        self.gc_instances_ands = 0
        self.he_pt_muls = 0
        self.he_encrypts = 0
        self.he_decrypts = 0
        self.per_fn: Dict[str, Dict[str, int]] = {}
        self._depth: Dict[str, int] = {"offline": 0, "online": 0}
        # v2 wire: the IKNP base-OT exchange happens once per session,
        # lazily at the first online OT batch — mirrored here so the
        # oracle meters it exactly once too
        self.ot_base_metered = False

    # -- compatibility views -------------------------------------------
    @property
    def channel_offline(self) -> Channel:
        return self.offline.channel

    @property
    def channel_online(self) -> Channel:
        return self.online.channel

    @property
    def t_offline_s(self) -> float:
        return self.offline.t_s

    @property
    def t_online_s(self) -> float:
        return self.online.t_s

    def fn(self, name: str) -> Dict[str, int]:
        return self.per_fn.setdefault(
            name, {"and": 0, "gates": 0, "instances": 0, "table_bytes": 0}
        )

    def _phase(self, name: str) -> PhaseStats:
        if name == "offline":
            return self.offline
        if name == "online":
            return self.online
        raise ValueError(f"unknown phase {name!r}")

    @contextmanager
    def phase(self, name: str):
        """Time a block into the named phase (outermost block wins).

        Span-backed: the outermost block opens an ``obs.timer`` span, so
        with tracing on every phase block shows up in the trace (and op
        spans opened inside nest under it); with tracing off the timer
        is an unrecorded monotonic measurement — either way ``t_s``
        accumulates exactly once per outermost block.
        """
        ph = self._phase(name)
        self._depth[name] += 1
        sp = obs.timer(name) if self._depth[name] == 1 else None
        try:
            yield ph
        finally:
            self._depth[name] -= 1
            if sp is not None:
                sp.close()
                ph.t_s += sp.elapsed_s

    def comm_snapshot(self) -> Dict[str, Dict[str, object]]:
        """Copy of both phase ledgers (for before/after diffing in tests)."""
        return {
            "offline": self.offline.comm_snapshot(),
            "online": self.online.comm_snapshot(),
        }


def bits_of(vals: np.ndarray, k: int, t: int) -> np.ndarray:
    """Share residues (I, n) mod t -> (I, n*k) LSB-first bits. k <= 62.

    Public: the two-party runtime (:mod:`repro.net`) packs its GC input
    words with the exact same bit layout on both endpoints.
    """
    v = np.asarray(vals, np.uint64)
    shifts = np.arange(k, dtype=np.uint64)
    out = ((v[..., None] >> shifts) & np.uint64(1)).astype(np.uint8)
    return out.reshape(*v.shape[:-1], v.shape[-1] * k)


def words_from_bits(bits: np.ndarray, k: int, t: int) -> np.ndarray:
    b = bits.reshape(*bits.shape[:-1], bits.shape[-1] // k, k).astype(np.uint64)
    shifts = np.arange(k, dtype=np.uint64)
    vals = np.sum(b << shifts, axis=-1, dtype=np.uint64)
    return np.mod(vals, np.uint64(t))


# back-compat aliases (pre-net internal names)
_bits_of = bits_of
_words_from_bits = words_from_bits


# ---------------------------------------------------------------------------
# offline correlations (the bundle parts consumed by the online phase)
# ---------------------------------------------------------------------------


@dataclass
class LinearCorrelation:
    """DELPHI linear-layer preprocessing: Enc(R1) round already metered."""

    Wmod: np.ndarray
    r1: np.ndarray
    s_mask: np.ndarray
    client_y: np.ndarray  # W·R1 − s_mask (client's offline output share)
    bias_q: Optional[np.ndarray] = None


@dataclass
class BeaverCorrelation:
    trip: SS.BeaverTriple


@dataclass
class GCCorrelation:
    """A garbled netlist batch plus fresh output masks for one use."""

    net: Netlist
    gcirc: G.GarbledCircuit
    masks: np.ndarray  # (I, n_out) — the client's output shares r
    mask_enc: np.ndarray  # t − r, wired as garbler inputs
    n_out: int

    @property
    def instances(self) -> int:
        return self.masks.shape[0]


@dataclass
class LayerNormCorrelation:
    offload: bool
    gc: GCCorrelation
    bq: np.ndarray
    gq: Optional[np.ndarray] = None  # offload: γ at scale f
    raw_e: Optional[np.ndarray] = None  # full-GC: (I, 2n) γ/β words
    he_mask: Optional[np.ndarray] = None  # offload: inner-product mask
    inv_n: int = 0
    in_scale: int = 0


class PiTProtocol:
    def __init__(self, pcfg: PrivacyConfig, *, he_params: Optional[HE.BFVParams] = None,
                 seed: int = 0, impl: str = "ref", wire_version: int = 1,
                 compression: bool = True):
        HE.ensure_x64()
        self.pcfg = pcfg
        self.params = he_params or HE.make_params(
            n=pcfg.he_poly_n, num_primes=pcfg.he_num_primes,
            t_bits=pcfg.he_t_bits,
        )
        self.t = self.params.t
        self.k = gc_word_bits(self.t)
        self.frac = pcfg.frac_bits
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.impl = impl
        #: wire-format revision this protocol *meters* (the net layer
        #: negotiates the same number at hello): 1 = raw label/table
        #: streams + sim-OT blocks; 2 = seed streams, delta-encoded table
        #: batches and IKNP OT (see repro.net.wire). The ledger test
        #: asserts the wire equals this meter, so both must move together.
        self.wire_version = wire_version
        #: v2 sub-knob: seed-stream/delta-table compression of the
        #: offline garbling stream (IKNP + coalescing stay on when off)
        self.compression = compression
        self.stats = Stats()
        self.sk, self.pk = HE.keygen(self.params, self._next_key())
        self._netlist_cache: Dict[str, Netlist] = {}

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    @property
    def style(self) -> str:
        return self.pcfg.mult_style

    @property
    def _ct_bytes(self) -> int:
        """Wire size of one BFV ciphertext (2 polys, RNS limbs, 8B words)."""
        return 2 * len(self.params.qs) * self.params.n * 8

    # ------------------------------------------------------------------
    # shares
    # ------------------------------------------------------------------
    def share_input(self, x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Client-side fixed-point encode + share."""
        enc = SS.encode_fx(x, self.frac, self.t)
        c, s = SS.share(self.rng, enc, self.t)
        self.stats.channel_online.c2s(s.size * 8, "input-share")
        return c, s

    def reveal(self, c_share, s_share, scale_bits: Optional[int] = None) -> np.ndarray:
        v = SS.reconstruct(c_share, s_share, self.t)
        return SS.decode_fx(v, self.frac, self.t,
                            scale_bits if scale_bits is not None else self.frac)

    # ------------------------------------------------------------------
    # DELPHI linear layer (server weights)
    # ------------------------------------------------------------------
    def quantize_weight(self, W: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(Wq signed fixed-point, Wmod residues) — bundle-invariant, so
        sessions compute it once and share it across correlations."""
        Wq = np.round(np.asarray(W, np.float64) * (1 << self.frac)).astype(np.int64)
        return Wq, np.mod(Wq, self.t).astype(np.uint64)

    def linear_offline(self, W: Optional[np.ndarray], x_shape: Tuple[int, ...],
                       bias: Optional[np.ndarray] = None,
                       use_he_offline: bool = False,
                       quantized: Optional[Tuple[np.ndarray, np.ndarray]] = None
                       ) -> LinearCorrelation:
        """Offline half of ``y = W x + b``: client sends Enc(R1); server
        computes Enc(W·R1 − s_mask) (he_matvec for small dims or
        metered-equivalent modular math); client decrypts its share.
        Depends only on the input *shape*, never the input. ``quantized``
        is a cached :meth:`quantize_weight` result; when given, ``W`` may
        be None."""
        Wq, Wmod = quantized if quantized is not None else self.quantize_weight(W)
        d_out, d_in = Wq.shape
        with self.stats.phase("offline"), \
                obs.span("linear_offline", d_out=int(d_out), d_in=int(d_in)):
            r1 = self.rng.integers(0, self.t, x_shape, dtype=np.uint64)
            ct_count = math.ceil(r1.size / self.params.n)
            ch = self.stats.channel_offline
            ch.c2s(ct_count * self._ct_bytes, "he-enc-r")
            if use_he_offline and r1.ndim == 1:
                ct_r = HE.encrypt(self.params, self.pk,
                                  HE.encode_coeffs(self.params, r1), self._next_key())
                outs = HE.he_matvec(self.params, ct_r, Wq)
                self.stats.he_pt_muls += len(outs)
                self.stats.he_encrypts += 1
                polys = [HE.decrypt(self.params, self.sk, c) for c in outs]
                self.stats.he_decrypts += len(outs)
                wr = HE.he_matvec_extract(self.params, polys, d_in, d_out)
                per_ct, blocks = HE.matvec_plan(self.params, d_in, d_out)
                ch.s2c(blocks * self._ct_bytes, "he-wr")
            else:
                # metered-equivalent path (big matrices): same math mod t
                wr = (SS.matmul_mod(Wmod, r1.reshape(-1, 1), self.t).reshape(-1)
                      if r1.ndim == 1 else SS.matmul_mod(r1, Wmod.T, self.t))
                blocks = math.ceil(wr.size / self.params.n)
                self.stats.he_pt_muls += blocks
                ch.s2c(blocks * self._ct_bytes, "he-wr")
            s_mask = self.rng.integers(0, self.t, wr.shape, dtype=np.uint64)
            client_y = SS.sub_mod(wr, s_mask, self.t)  # client's offline share
            bias_q = None
            if bias is not None:
                bias_q = SS.encode_fx(bias, 2 * self.frac, self.t)
        return LinearCorrelation(Wmod=Wmod, r1=r1, s_mask=s_mask,
                                 client_y=client_y, bias_q=bias_q)

    def linear_online(self, corr: LinearCorrelation, x_c, x_s
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Online half: server computes W(x − R1) + s_mask (+ b)."""
        with self.stats.phase("online"), \
                obs.span("linear_online", n=int(np.asarray(x_c).size)):
            x_open = SS.sub_mod(SS.add_mod(x_c, x_s, self.t), corr.r1, self.t)
            # (client sends x_c − r1; server adds its share → x − r1 opened)
            self.stats.channel_online.c2s(x_open.size * 8, "x-minus-r")
            wx = (SS.matmul_mod(corr.Wmod, x_open.reshape(-1, 1), self.t).reshape(-1)
                  if x_open.ndim == 1
                  else SS.matmul_mod(x_open, corr.Wmod.T, self.t))
            server_y = SS.add_mod(wx, corr.s_mask, self.t)
            if corr.bias_q is not None:
                server_y = SS.add_mod(
                    server_y, np.broadcast_to(corr.bias_q, server_y.shape), self.t
                )
        return corr.client_y, server_y  # scale 2·frac

    def linear(self, W: np.ndarray, x_c, x_s, bias: Optional[np.ndarray] = None,
               use_he_offline: bool = False):
        """y = W x + b at scale 2·frac (compat wrapper: offline + online)."""
        corr = self.linear_offline(W, x_c.shape, bias=bias,
                                   use_he_offline=use_he_offline)
        return self.linear_online(corr, x_c, x_s)

    # ------------------------------------------------------------------
    # Beaver matmul (private × private)
    # ------------------------------------------------------------------
    def beaver_offline(self, m: int, k: int, n: int) -> BeaverCorrelation:
        """Deal one (m,k)×(k,n) matmul triple (HE-based in production)."""
        with self.stats.phase("offline"), \
                obs.span("beaver_offline", m=m, k=k, n=n):
            trip = SS.deal_matmul_triple(self.rng, m, k, n, self.t)
            self.stats.channel_offline.s2c((m * k + k * n + m * n) * 8, "beaver")
        return BeaverCorrelation(trip)

    def beaver_online(self, corr: BeaverCorrelation, xc, xs, yc, ys
                      ) -> Tuple[np.ndarray, np.ndarray]:
        with self.stats.phase("online"), \
                obs.span("beaver_online", m=int(np.asarray(xc).shape[0])):
            z1, z2, opened = SS.beaver_matmul(xc, xs, yc, ys, corr.trip, self.t)
            self.stats.channel_online.c2s(opened // 2, "beaver-open")
            self.stats.channel_online.s2c(opened // 2, "beaver-open")
        return z1, z2  # scale doubles

    def matmul_private(self, xc, xs, yc, ys):
        m, k = xc.shape
        k2, n = yc.shape
        corr = self.beaver_offline(m, k, n)
        return self.beaver_online(corr, xc, xs, yc, ys)

    # ------------------------------------------------------------------
    # garbled nonlinear function
    # ------------------------------------------------------------------
    def build_fn_circuit(self, name: str, n_in: int, n_out: int,
                         body: Callable[[CircuitBuilder, List[Word]], List[Word]],
                         descale: int = 0, n_raw_e: int = 0) -> Netlist:
        """Share-reconstruct → body(ins, raws) → remask, cached by name.

        ``n_raw_e`` appends plain evaluator words (server-private values,
        e.g. γ/β in the full-GC LayerNorm), two's-complement encoded.
        """
        if name in self._netlist_cache:
            return self._netlist_cache[name]
        cb = CircuitBuilder(name)
        ins = [input_shared_word(cb, self.t, descale) for _ in range(n_in)]
        raws = [cb.e_input_word(self.k) for _ in range(n_raw_e)]
        outs = body(cb, ins, raws) if n_raw_e else body(cb, ins)
        assert len(outs) == n_out
        for y in outs:
            output_shared(cb, Word(y.bits[: self.k]), self.t)
        net = cb.build()
        self._netlist_cache[name] = net
        return net

    def gc_offline(self, net: Netlist, instances: int, n_out: int,
                   gcirc: Optional[G.GarbledCircuit] = None) -> GCCorrelation:
        """Garble + draw output masks + meter tables/label transfer.

        ``gcirc`` lets a session pass a slice of a batch-garbled circuit
        (one garbling call per cached netlist across the whole bundle
        batch); when omitted the netlist is garbled here.
        """
        I = instances
        st = self.stats
        standalone = gcirc is None
        with st.phase("offline"), \
                obs.span("gc_offline", netlist=net.name, instances=I,
                         and_gates=net.and_count,
                         garbles_here=standalone):
            if gcirc is None:
                gcirc = G.garble(net, self._next_key(), I, impl=self.impl)
            assert gcirc.num_instances == I
            masks = self.rng.integers(0, self.t, (I, n_out), dtype=np.uint64)
            mask_enc = SS.sub_mod(np.zeros_like(masks), masks, self.t)  # t − r
            if self.wire_version >= 2 and self.compression:
                # delta-encoded table batch: each op meters its linear
                # per-instance share; the slab's fixed anchor + the seed
                # record are metered at the slab site (gc_slab_offline),
                # or here when this call IS the slab (no outer batch)
                rows = max(net.and_count, 1)
                st.channel_offline.c2s(I * rows * 4 * TABLE_DELTA_WORDS,
                                       f"tables:{net.name}")
                if standalone:
                    st.channel_offline.c2s(
                        tables_delta_anchor_bytes(net.and_count),
                        f"tables:{net.name}")
                    st.channel_offline.c2s(SEED_STREAM_BYTES, "g-labels")
            else:
                st.channel_offline.c2s(int(gcirc.tables.size) * 4,
                                       f"tables:{net.name}")
                # only the output-mask labels are offline-known garbler
                # input; labels for the live share xc can only flow online
                # (gc_online)
                st.channel_offline.c2s(I * n_out * self.k * 16, "g-labels")
            st.gc_and_gates += net.and_count
            st.gc_gates += net.num_gates
            st.gc_instances_ands += net.and_count * I
            st.gc_instances_gates += net.num_gates * I
            f = st.fn(net.name)
            f["and"] = net.and_count
            f["gates"] = net.num_gates
            f["instances"] += I
            f["table_bytes"] += int(gcirc.tables.size) * 4
        return GCCorrelation(net=net, gcirc=gcirc, masks=masks,
                             mask_enc=mask_enc, n_out=n_out)

    def gc_slab_offline(self, net: Netlist) -> None:
        """Meter the per-slab fixed v2 offline costs (anchor + seed).

        A session garbles ONE slab per distinct netlist and slices it
        per op (``core/session.py``), while the wire runtime frames one
        delta-table segment and one seed-stream record per slab. The
        per-op :meth:`gc_offline` legs meter only their linear
        per-instance delta share, so the batch-fixed anchor bytes and
        the 32-byte seed record are metered here, once per slab — the
        same granularity the garbler frames them at.
        """
        if self.wire_version < 2 or not self.compression:
            return
        with self.stats.phase("offline"), \
                obs.span("gc_slab_offline", netlist=net.name):
            ch = self.stats.channel_offline
            ch.c2s(tables_delta_anchor_bytes(net.and_count),
                   f"tables:{net.name}")
            ch.c2s(SEED_STREAM_BYTES, "g-labels")

    def gc_online(self, corr: GCCorrelation, xc: np.ndarray, xs: np.ndarray,
                  raw_e: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """OT server labels, evaluate, decode. Returns (client, server) shares."""
        net, gcirc = corr.net, corr.gcirc
        k = self.k
        st = self.stats
        with st.phase("online"), \
                obs.span("gc_online", netlist=net.name,
                         instances=int(np.asarray(xc).shape[0])):
            g_bits = np.concatenate(
                [_bits_of(xc, k, self.t), _bits_of(corr.mask_enc, k, self.t)],
                axis=1,
            )
            assert g_bits.shape[1] == len(net.garbler_inputs)
            g_lab = G.encode_inputs(gcirc, net.garbler_inputs, g_bits)
            # labels for the client's live input share (the mask-label half
            # was already transferred with the tables during preprocessing)
            xc_bits = len(net.garbler_inputs) - corr.n_out * k
            st.channel_online.c2s(xc.shape[0] * xc_bits * 16, "g-labels")
            e_bits = _bits_of(xs, k, self.t)
            if raw_e is not None:
                rv = np.mod(np.asarray(raw_e, np.int64), 1 << k).astype(np.uint64)
                e_bits = np.concatenate(
                    [e_bits, _bits_of(rv, k, 1 << k)], axis=1
                )
            e_zero = G.input_zeros(gcirc, net.evaluator_inputs)
            if self.wire_version >= 2:
                # real IKNP extension: lazy one-time base OT (the
                # evaluator is the base-OT *sender*: A is s2c, the κ
                # B-elements come back c2s), then per-batch column
                # matrix u (16 B/OT, c2s like the old sim request) and
                # masked label pairs (32 B/OT s2c, down from 48)
                ch = st.channel_online
                if not st.ot_base_metered:
                    st.ot_base_metered = True
                    ch.s2c(BASE_OT_A_BYTES, "ot-base")
                    ch.c2s(BASE_OT_B_BYTES, "ot-base")
                n_ot = int(np.prod(e_bits.shape))
                ch.c2s(ot_v2_request_bytes(n_ot), f"ot:{net.name}")
                ch.s2c(ot_v2_response_bytes(n_ot), f"ot:{net.name}")
                e_lab = choose_labels(e_zero, gcirc.r[:, None, :], e_bits)
            else:
                e_lab = ot_labels(st.channel_online, e_zero,
                                  gcirc.r[:, None, :], e_bits,
                                  tag=f"ot:{net.name}")
            # packed active labels: one (wire_ids, (I, n, 4)) pair straight
            # into the device executor — no per-wire host-side dict work
            cw, c_lab = G.const_wires_labels(gcirc)
            wire_ids = np.concatenate([
                np.asarray(net.garbler_inputs, np.int64),
                np.asarray(net.evaluator_inputs, np.int64), cw])
            labels = jnp.concatenate(
                [g_lab, e_lab, c_lab], axis=1)
            out_lab = G.evaluate(net, gcirc.tables, (wire_ids, labels),
                                 impl=self.impl)
            out_bits = G.decode_outputs(gcirc, out_lab)
            server_share = _words_from_bits(out_bits, k, self.t)
        return corr.masks, server_share  # client share = r (masks)

    def gc_apply(self, net: Netlist, xc: np.ndarray, xs: np.ndarray,
                 n_out: int, raw_e: Optional[np.ndarray] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """xc/xs: (I, n_in) share residues mod t. Returns (I, n_out) shares.

        Client garbles (offline), server evaluates (online). Instances are
        batched — the paper's coarse-grained row mapping. ``raw_e``:
        (I, n_raw) signed int64 server-private values (two's complement).
        """
        corr = self.gc_offline(net, xc.shape[0], n_out)
        return self.gc_online(corr, xc, xs, raw_e=raw_e)

    # ------------------------------------------------------------------
    # cached netlist builders (shared by wrappers and sessions)
    # ------------------------------------------------------------------
    def softmax_net(self, row_len: int, in_scale: int) -> Netlist:
        def body(cb, ins):
            return _softmax_body(cb, ins, self.frac, self.style)

        return self.build_fn_circuit(
            f"softmax{row_len}", row_len, row_len, body,
            descale=in_scale - self.frac,
        )

    def activation_net(self, kind: str, in_scale: int) -> Netlist:
        def body(cb, ins):
            if kind == "gelu":
                return [_gelu_body(cb, ins[0], self.frac, self.style)]
            return [_silu_body(cb, ins[0], self.frac, self.style)]

        return self.build_fn_circuit(
            f"{kind}", 1, 1, body, descale=in_scale - self.frac
        )

    def trunc_net(self, in_scale: int) -> Netlist:
        def body(cb, ins):
            return [ins[0]]

        return self.build_fn_circuit(
            f"trunc_s{in_scale}", 1, 1, body, descale=in_scale - self.frac
        )

    def layernorm_full_net(self, n: int, in_scale: int) -> Netlist:
        def body(cb, ins, raws):
            return _layernorm_body(cb, ins, self.frac, self.style,
                                   raws[:n], raws[n:])

        return self.build_fn_circuit(
            f"layernorm_full{n}", n, n, body,
            descale=in_scale - self.frac, n_raw_e=2 * n,
        )

    def layernorm_reduced_net(self, n: int, in_scale: int) -> Netlist:
        f = self.frac
        sc_ = in_scale + f
        return self.build_fn_circuit(
            f"layernorm_reduced{n}_s{in_scale}", n + 1, n,
            _make_ln_reduced(f, self.style, 2 * sc_, sc_), descale=0,
        )

    # ------------------------------------------------------------------
    # composite layers: offline/online pairs + compat wrappers
    # ------------------------------------------------------------------
    def softmax_offline(self, row_len: int, in_scale: int, instances: int,
                        gcirc: Optional[G.GarbledCircuit] = None
                        ) -> GCCorrelation:
        return self.gc_offline(self.softmax_net(row_len, in_scale),
                               instances, row_len, gcirc)

    def softmax_rows(self, sc, ss, row_len: int, in_scale: int):
        """(I, n) shares at scale `in_scale` -> softmax shares at frac."""
        corr = self.softmax_offline(row_len, in_scale, sc.shape[0])
        return self.gc_online(corr, sc, ss)

    def activation_offline(self, kind: str, in_scale: int, n_elems: int,
                           gcirc: Optional[G.GarbledCircuit] = None
                           ) -> GCCorrelation:
        return self.gc_offline(self.activation_net(kind, in_scale),
                               n_elems, 1, gcirc)

    def activation_online(self, corr: GCCorrelation, xc, xs):
        oc, os_ = self.gc_online(corr, xc.reshape(-1, 1), xs.reshape(-1, 1))
        return oc.reshape(xc.shape), os_.reshape(xs.shape)

    def activation(self, kind: str, xc, xs, in_scale: int):
        """Elementwise GeLU/SiLU on shares of any shape (batched rows)."""
        corr = self.activation_offline(kind, in_scale, xc.size)
        return self.activation_online(corr, xc, xs)

    def trunc_offline(self, in_scale: int, n_elems: int,
                      gcirc: Optional[G.GarbledCircuit] = None
                      ) -> GCCorrelation:
        return self.gc_offline(self.trunc_net(in_scale), n_elems, 1, gcirc)

    def trunc_online(self, corr: GCCorrelation, xc, xs):
        oc, os_ = self.gc_online(corr, xc.reshape(-1, 1), xs.reshape(-1, 1))
        return oc.reshape(xc.shape), os_.reshape(xs.shape)

    def trunc(self, xc, xs, in_scale: int):
        """Exact GC truncation back to scale frac (elementwise)."""
        corr = self.trunc_offline(in_scale, xc.size)
        return self.trunc_online(corr, xc, xs)

    def layernorm_offline(self, n: int, instances: int, in_scale: int,
                          gamma, beta,
                          gcirc: Optional[G.GarbledCircuit] = None
                          ) -> LayerNormCorrelation:
        """All input-independent LayerNorm work for a (instances, n) input."""
        I = instances
        f = self.frac
        t = self.t
        st = self.stats
        if not self.pcfg.layernorm_offload:
            net = self.layernorm_full_net(n, in_scale)
            gcc = self.gc_offline(net, I, n, gcirc)
            with st.phase("offline"):
                gq = np.round(np.asarray(gamma, np.float64) * (1 << f)).astype(np.int64)
                bq = np.round(np.asarray(beta, np.float64) * (1 << f)).astype(np.int64)
                raw = np.concatenate([np.broadcast_to(gq, (I, n)),
                                      np.broadcast_to(bq, (I, n))], axis=1)
            return LayerNormCorrelation(offload=False, gc=gcc, bq=bq,
                                        raw_e=raw, in_scale=in_scale)

        # ---- APINT Fig. 4, offline legs -------------------------------
        with st.phase("offline"), \
                obs.span("layernorm_offline", n=n, instances=I):
            inv_n = int(round((1 << f) / n))
            gq = SS.encode_fx(np.asarray(gamma), f, t)
            bq = SS.encode_fx(np.asarray(beta), f, t)
            # ⑩ Enc(R2') for the γ⊙r' slot products is sent ahead of time
            ct_blocks = math.ceil(I * n / self.params.n)
            st.channel_offline.c2s(ct_blocks * self._ct_bytes, "he-ln-r")
            st.he_pt_muls += ct_blocks
            # ⑧ Enc of the client's centered share for the inner product
            st.channel_offline.c2s(I * self._ct_bytes, "he-enc-centered")
            st.he_encrypts += I
            he_mask = self.rng.integers(0, t, I, dtype=np.uint64)
        gcc = self.gc_offline(self.layernorm_reduced_net(n, in_scale), I, n, gcirc)
        return LayerNormCorrelation(offload=True, gc=gcc, gq=gq, bq=bq,
                                    he_mask=he_mask, inv_n=inv_n,
                                    in_scale=in_scale)

    def layernorm_online(self, corr: LayerNormCorrelation, xc, xs):
        t = self.t
        st = self.stats
        if not corr.offload:
            oc, os_ = self.gc_online(corr.gc, xc, xs, raw_e=corr.raw_e)
            return oc, os_

        # ---- APINT Fig. 4 ⑦–⑬, online legs ----------------------------
        with st.phase("online"), \
                obs.span("layernorm_online", n=int(xc.shape[1]),
                         instances=int(xc.shape[0])):
            I, n = xc.shape
            f = self.frac
            in_scale = corr.in_scale
            # ⑦ mean & center on shares (standard local ops): ×round(2^f/n)
            mu_c = SS.scalar_mul_mod(corr.inv_n, _row_sum(xc, t), t)
            mu_s = SS.scalar_mul_mod(corr.inv_n, _row_sum(xs, t), t)
            # centered x' at scale Sc = in_scale + f
            cxc = SS.sub_mod(SS.scalar_mul_mod(1 << f, xc, t), mu_c[:, None], t)
            cxs = SS.sub_mod(SS.scalar_mul_mod(1 << f, xs, t), mu_s[:, None], t)
            # ⑧⑨ variance via HE inner product: Σx'² = Σu² + 2⟨u, r'⟩ + Σr'²
            cross_c, cross_s = self._he_inner_online(cxc, cxs, corr.he_mask)
            var_c = SS.add_mod(_row_sum_sq(cxc, t),
                               SS.scalar_mul_mod(2, cross_c, t), t)
            var_s = SS.add_mod(_row_sum_sq(cxs, t),
                               SS.scalar_mul_mod(2, cross_s, t), t)
            var_c = SS.scalar_mul_mod(corr.inv_n, var_c, t)  # scale 2·Sc + f
            var_s = SS.scalar_mul_mod(corr.inv_n, var_s, t)
            # ⑩⑪ γ·x' via HE slots: γ⊙r' offline, γ⊙u server-local
            gxc = _rowwise_mul(corr.gq, cxc, t)
            gxs = _rowwise_mul(corr.gq, cxs, t)
            in_c = np.concatenate([gxc, var_c[:, None]], axis=1)
            in_s = np.concatenate([gxs, var_s[:, None]], axis=1)
        # ⑫ reduced GC: rsqrt(var) × (γ·x')
        oc, os_ = self.gc_online(corr.gc, in_c, in_s)
        with st.phase("online"):
            # ⑬ + β (server-held parameter added to its share)
            os_ = SS.add_mod(os_, np.broadcast_to(corr.bq, os_.shape), t)
        return oc, os_

    def layernorm(self, xc, xs, gamma, beta, in_scale: int):
        """(I, n) shares at scale `in_scale` -> LayerNorm shares at frac.

        APINT offload when pcfg.layernorm_offload, else full-GC baseline
        (γ/β enter the circuit as raw evaluator words — they are the
        server's weights, so they cost full word×word multiplies).
        """
        corr = self.layernorm_offline(xc.shape[1], xc.shape[0], in_scale,
                                      gamma, beta)
        return self.layernorm_online(corr, xc, xs)

    def _he_inner_online(self, cxc, cxs, mask: np.ndarray):
        """Shares of ⟨client_row, server_row⟩ per row (Fig. 4 ⑧).

        The client's Enc(r'_row) was sent during preprocessing; online the
        server mul_plains with its reversed share and returns the masked
        cross term. ``mask`` is the offline-drawn server share.
        """
        I, n = cxc.shape
        st = self.stats
        # metered-equivalent modular math (exact same result as the HE path,
        # which tests exercise at small sizes through he.he_matvec):
        cross = np.array(
            [int(np.dot(cxc[i].astype(object), cxs[i].astype(object)) % self.t)
             for i in range(I)], dtype=np.uint64)
        st.he_pt_muls += I
        st.channel_online.s2c(I * self._ct_bytes, "he-cross")
        st.he_decrypts += I
        return SS.sub_mod(cross, mask, self.t), mask


# ---------------------------------------------------------------------------
# circuit bodies (pure functions of reconstructed words)
# ---------------------------------------------------------------------------


def _softmax_body(cb, ins, frac, style):
    mx = ins[0]
    for w in ins[1:]:
        mx = arith.max_word(cb, mx, w)
    es = []
    for w in ins:
        d = arith.sub(cb, w, mx)
        es.append(NL.exp_circuit(cb, d, frac, style))
    s = es[0]
    for w in es[1:]:
        s = arith.add(cb, s, w)
    inv = NL.reciprocal_circuit(cb, s, frac, style)
    return [arith.fx_mul(cb, w, inv, frac, style=style) for w in es]


def _gelu_body(cb, x, frac, style):
    # inline of nonlinear.gelu on an existing word
    from repro.core.circuits.nonlinear import _fx, _gelu

    k = len(x)
    lo = cb.const_word(_fx(-4.0, frac, k), k)
    hi = cb.const_word(_fx(4.0, frac, k) - 1, k)
    xc = arith.mux(cb, arith.lt_signed(cb, x, lo), lo, x)
    xc = arith.mux(cb, arith.lt_signed(cb, hi, xc), hi, xc)
    xs = arith.add_const(cb, xc, _fx(4.0, frac, k))
    segs = 16
    seg_bits = 4
    lo_bit = frac + 3 - seg_bits
    idx = Word(tuple(xs[lo_bit + i] for i in range(seg_bits)))
    width = 8.0 / segs
    slopes, intercepts = [], []
    for s in range(segs):
        a = -4.0 + s * width
        ga, gb = _gelu(a), _gelu(a + width)
        m = (gb - ga) / width
        slopes.append(_fx(m, frac, k))
        intercepts.append(_fx(ga - m * a, frac, k))

    def lut(tbl):
        level = [cb.const_word(v, k) for v in tbl]
        for bit in idx:
            level = [arith.mux(cb, bit, level[i + 1], level[i])
                     for i in range(0, len(level), 2)]
        return level[0]

    y = arith.fx_mul(cb, xc, lut(slopes), frac, style=style)
    return arith.add(cb, y, lut(intercepts))


def _silu_body(cb, x, frac, style):
    from repro.core.circuits.nonlinear import _fx

    k = len(x)
    lo = cb.const_word(_fx(-6.0, frac, k), k)
    hi = cb.const_word(_fx(6.0, frac, k) - 1, k)
    xc = arith.mux(cb, arith.lt_signed(cb, x, lo), lo, x)
    xc = arith.mux(cb, arith.lt_signed(cb, hi, xc), hi, xc)
    xs = arith.add_const(cb, xc, _fx(6.0, frac, k))
    segs, seg_bits, int_bits = 32, 5, 4
    lo_bit = frac + int_bits - seg_bits  # 16-range
    idx = Word(tuple(xs[frac + int_bits - seg_bits + i] for i in range(seg_bits)))
    width = 16.0 / segs

    def f(v):
        vv = max(min(v, 6.0), -6.0)
        return vv / (1.0 + math.exp(-vv))

    slopes, intercepts = [], []
    for s in range(segs):
        a = -6.0 + s * width
        b = min(a + width, 6.0)
        fa, fb = f(a), f(b)
        m = (fb - fa) / (b - a) if b > a else 0.0
        slopes.append(_fx(m, frac, k))
        intercepts.append(_fx(fa - m * a, frac, k))

    def lut(tbl):
        level = [cb.const_word(v, k) for v in tbl]
        for bit in idx:
            level = [arith.mux(cb, bit, level[i + 1], level[i])
                     for i in range(0, len(level), 2)]
        return level[0]

    y = arith.fx_mul(cb, xc, lut(slopes), frac, style=style)
    return arith.add(cb, y, lut(intercepts))


def _layernorm_body(cb, ins, frac, style, gammas, betas):
    """Full-GC LayerNorm; γ/β are evaluator-supplied words."""
    n = len(ins)
    s = ins[0]
    for w in ins[1:]:
        s = arith.add(cb, s, w)
    sh = int(math.log2(n))
    mean = arith.shift_right_const(cb, s, sh, arithmetic=True)
    cs = [arith.sub(cb, w, mean) for w in ins]
    sq = [arith.fx_mul(cb, c, c, frac, style=style) for c in cs]
    v = sq[0]
    for w in sq[1:]:
        v = arith.add(cb, v, w)
    var = arith.shift_right_const(cb, v, sh, arithmetic=True)
    var = arith.add_const(cb, var, 1)
    rs = NL.rsqrt_circuit(cb, var, frac, style)
    outs = []
    for c, g, b in zip(cs, gammas, betas):
        y = arith.fx_mul(cb, c, rs, frac, style=style)
        y = arith.fx_mul(cb, y, g, frac, style=style)
        outs.append(arith.add(cb, y, b))
    return outs


def _make_ln_reduced(frac, style, var_descale, x_descale):
    def body(cb, ins):
        xs, var = ins[:-1], ins[-1]
        var = arith.shift_right_const(cb, var, var_descale, arithmetic=True)
        var = arith.add_const(cb, var, 1)
        rs = NL.rsqrt_circuit(cb, var, frac, style)
        outs = []
        for x in xs:
            xd = arith.shift_right_const(cb, x, x_descale, arithmetic=True)
            outs.append(arith.fx_mul(cb, xd, rs, frac, style=style))
        return outs

    return body


def _ln_reduced_body(cb, ins, frac, style):  # kept for direct benching
    return _make_ln_reduced(frac, style, 0, 0)(cb, ins)


# ---------------------------------------------------------------------------
# share helpers
# ---------------------------------------------------------------------------


def _row_sum(x, t):
    return np.array(
        [int(np.sum(x[i].astype(object)) % t) for i in range(x.shape[0])],
        dtype=np.uint64,
    )


def _row_sum_sq(x, t):
    return np.array(
        [int(np.dot(x[i].astype(object), x[i].astype(object)) % t)
         for i in range(x.shape[0])],
        dtype=np.uint64,
    )


def _rowwise_mul(const_row, x, t):
    return ((const_row.astype(object)[None, :] * x.astype(object)) % t).astype(
        np.uint64
    )

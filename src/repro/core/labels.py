"""Wire-label algebra for garbled circuits.

A label is 128 bits stored as ``uint32[..., 4]``. The global FreeXOR offset R
has its point-and-permute (color) bit — bit 0 of word 0 — forced to 1, so
``lsb(W ^ R) != lsb(W)`` and the color bit of an active label selects garbled
table rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LABEL_WORDS = 4
U32 = jnp.uint32


def random_labels(key, shape) -> jnp.ndarray:
    """Uniform labels, shape (*shape, 4) uint32."""
    return jax.random.bits(key, (*shape, LABEL_WORDS), dtype=U32)


def random_delta(key, batch_shape=()) -> jnp.ndarray:
    """FreeXOR offset R with color bit set."""
    r = random_labels(key, batch_shape)
    return r.at[..., 0].set(r[..., 0] | U32(1))


def lsb(label: jnp.ndarray) -> jnp.ndarray:
    """Color bit, uint32 {0,1}; label (..., 4) -> (...)."""
    return label[..., 0] & U32(1)


def xor(a, b):
    return jnp.bitwise_xor(a, b)


def select(cond, a, b):
    """cond (...,) in {0,1} -> a if cond else b, label-shaped (..., 4)."""
    return jnp.where(cond[..., None].astype(bool), a, b)


def maybe_xor(label, cond, offset):
    """label ^ (cond ? offset : 0)."""
    mask = (-(cond.astype(U32)))[..., None]  # 0x0 or 0xFFFFFFFF
    return label ^ (offset & mask)

"""Wire-label algebra for garbled circuits.

A label is 128 bits stored as ``uint32[..., 4]``. The global FreeXOR offset R
has its point-and-permute (color) bit — bit 0 of word 0 — forced to 1, so
``lsb(W ^ R) != lsb(W)`` and the color bit of an active label selects garbled
table rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

LABEL_WORDS = 4
U32 = jnp.uint32


def random_labels(key, shape) -> jnp.ndarray:
    """Uniform labels, shape (*shape, 4) uint32."""
    return jax.random.bits(key, (*shape, LABEL_WORDS), dtype=U32)


def random_delta(key, batch_shape=()) -> jnp.ndarray:
    """FreeXOR offset R with color bit set."""
    r = random_labels(key, batch_shape)
    return r.at[..., 0].set(r[..., 0] | U32(1))


def lsb(label: jnp.ndarray) -> jnp.ndarray:
    """Color bit, uint32 {0,1}; label (..., 4) -> (...)."""
    return label[..., 0] & U32(1)


def xor(a, b):
    return jnp.bitwise_xor(a, b)


def select(cond, a, b):
    """cond (...,) in {0,1} -> a if cond else b, label-shaped (..., 4)."""
    return jnp.where(cond[..., None].astype(bool), a, b)


def maybe_xor(label, cond, offset):
    """label ^ (cond ? offset : 0)."""
    mask = (-(cond.astype(U32)))[..., None]  # 0x0 or 0xFFFFFFFF
    return label ^ (offset & mask)


# ---------------------------------------------------------------------------
# PRG label streams (v2 wire format)
# ---------------------------------------------------------------------------


def stream_seed(rng: np.random.Generator) -> bytes:
    """Mint a 16-byte seed for an *active*-label stream.

    The approved way to create a transmittable label seed: the stream it
    expands to (:func:`stream_labels`) is one label per wire — active
    labels the receiver is entitled to anyway — never a (zero, one) pair,
    so shipping the seed reveals nothing the raw stream would not. Do NOT
    ship garbling keys (``jax.random.PRNGKey`` / ``_next_key()``): those
    expand to R and both labels of every wire.
    """
    return rng.bytes(16)


def stream_labels(seed: bytes, counter: int, count: int) -> np.ndarray:
    """Deterministic label stream: (count, 4) uint32 from (seed, counter).

    Counter-mode Philox keyed by the 128-bit seed; ``counter`` is the
    stream offset in labels, so both endpoints can derive any window of
    the stream independently. This is the replay side of a v2 seed-stream
    segment (:func:`repro.net.wire.pack_seed_stream`).
    """
    bg = np.random.Philox(key=int.from_bytes(seed, "little"))
    # one Philox counter block is 4×64 bits = two labels; an odd label
    # offset additionally skips one drawn label
    if counter:
        bg.advance(counter // 2)
    skip = counter % 2
    raw = np.random.Generator(bg).integers(
        0, 1 << 64, size=(max(count, 0) + skip) * 2, dtype=np.uint64,
        endpoint=False)
    return raw[2 * skip:].view(np.uint32).reshape(count, LABEL_WORDS)

"""Device-resident execution of a compiled netlist level plan.

The host-side numpy walk in :mod:`repro.core.garble` pays a Python round
trip per level: gather labels, dispatch XOR/INV/AND batches separately,
scatter, repeat. This module compiles the whole walk — wire store,
gathers, FreeXOR/INV/Half-Gate, scatters — into ONE ``jax.jit`` call per
``(netlist, instances, impl)``: a ``lax.scan`` over the plan's fixed-shape
*chunks* (see :class:`~repro.core.netlist.LevelPlan`) whose body evaluates
one padded level. Because every chunk has the same (and_width, free_width)
shape, the executable contains a single level body regardless of netlist
depth, so XLA compile time stays flat in circuit size.

The body is built around what profiling the scan showed matters on a CPU
host (and costs nothing on TPU):

* the wire store is **row-major** ``(n_rows, I, 4)`` and compactly
  numbered, so each chunk commits with ONE contiguous
  ``dynamic_update_slice`` of its ``perm``-ordered lane block — a
  scattered store, an instance-major store, or a second dynamic write on
  the same carry all force XLA to copy the whole store every step;
* AND labels are hashed in **planar** form (four ``(lanes,)`` word
  planes) via :func:`repro.kernels.halfgate.ref.eval_and_planar` — the
  packed ``(lanes, 4)`` form lowers to strided scalar code inside the
  scan, ~50x slower;
* the ``"jit"`` impl hashes only the AND block (XOR/INV lanes are one
  vector XOR: INV second inputs read the zero dummy row, so there is no
  per-lane select anywhere); the ``"pallas"``/``"pallas_interpret"``
  impls hand the concatenated block to the fused ``kernels/level_eval``
  pass — one kernel launch per level instead of separate XOR/INV/AND
  dispatches.

The wire store lives entirely inside the executable (scan carry — XLA
updates it in place), so a cached evaluate performs zero per-level
host<->device transfers: one launch in, output labels out. Chunk widths
come in two regimes (see ``netlist._chunk_widths``): tiny batches get a
wide/low-chunk-count latency plan, big batches a tight throughput plan.

Executors are cached on the plan, keyed by ``(instances, impl)``;
``n_traces`` counts actual retraces (it only advances while jax traces the
body) and ``n_eval_calls`` / ``n_garble_calls`` count invocations, which
is what the cache-hit and single-dispatch tests assert on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.netlist import (
    LevelPlan,
    Netlist,
    OP_AND,
    OP_PAD,
    compile_level_plan,
)
from repro.kernels.halfgate import ref as HG
from repro.kernels.level_eval.level_eval import (
    eval_level_pallas,
    garble_level_pallas,
)

U32 = jnp.uint32
I32 = jnp.int32


def _planar(x):
    """(lanes, I, 4) labels -> 4-tuple of flat (lanes*I,) word planes."""
    p = x.transpose(2, 0, 1).reshape(4, -1)
    return (p[0], p[1], p[2], p[3])


def _packed(planes, lanes, instances):
    return jnp.stack(planes, 0).reshape(4, lanes, instances).transpose(1, 2, 0)


class LevelExecutor:
    """One compiled evaluate/garble walk for a fixed (plan, I, impl)."""

    def __init__(self, plan: LevelPlan, instances: int, impl: str):
        if impl not in ("jit", "pallas", "pallas_interpret"):
            raise ValueError(f"device executor impl {impl!r}")
        self.plan = plan
        self.instances = int(instances)
        self.impl = impl
        self.n_traces = 0
        self.n_eval_calls = 0
        self.n_garble_calls = 0
        K, ca = plan.n_chunks, plan.and_width
        self.n_src = len(plan.source_ids)
        # per-chunk scan operands: device-resident once, reused every
        # call; the four wire-read index blocks are fused into ONE array
        # so the body issues a single gather per chunk (per-step thunk
        # count dominates small-batch walks). Arrays a body doesn't touch
        # (op codes in the jit path) are dead-code-eliminated.
        self._xs = (
            jnp.asarray(plan.base, I32),
            jnp.asarray(np.concatenate(
                [plan.and_in0, plan.and_in1, plan.free_in0, plan.free_in1],
                axis=1), I32),
            jnp.asarray(plan.and_slot, I32),
            jnp.asarray(plan.perm, I32),
            jnp.asarray(
                np.where(plan.and_slot < plan.n_and, OP_AND, OP_PAD), U32),
            jnp.asarray(plan.free_inv, U32),
            jnp.asarray(plan.free_ops, U32),
        )
        self._outs = jnp.asarray(plan.out_rows, I32)
        self._wire_rows = jnp.asarray(plan.wire_rows, I32)
        self._and_rows = jnp.asarray(plan.and_rows, I32)
        self._eval = jax.jit(self._eval_fn)
        self._garble = jax.jit(self._garble_fn,
                               static_argnames=("keep_wires",))

    # ------------------------------------------------------------------
    # fused-kernel bodies (pallas / pallas_interpret)
    # ------------------------------------------------------------------
    def _lanes(self, per_lane):
        """(lanes,) per-lane scalar -> flat (lanes*I,) lane-major vector."""
        I = self.instances
        return jnp.broadcast_to(per_lane[:, None],
                                (per_lane.shape[0], I)).reshape(-1)

    def _fused_eval(self, and_ops, a, b, tg, te, slot, fops, fa, fb):
        """Concatenated AND+free block through the fused level kernel."""
        I = self.instances
        ca, cf = self.plan.and_width, self.plan.free_width
        ops = self._lanes(jnp.concatenate([and_ops, fops]))
        tw = self._lanes(jnp.concatenate(
            [slot.astype(U32), jnp.zeros((cf,), U32)]))
        z = jnp.zeros((cf, I, 4), U32)
        o = eval_level_pallas(
            ops,
            jnp.concatenate([a, fa], 0).reshape(-1, 4),
            jnp.concatenate([b, fb], 0).reshape(-1, 4),
            jnp.concatenate([tg, z], 0).reshape(-1, 4),
            jnp.concatenate([te, z], 0).reshape(-1, 4),
            tw,
            interpret=(self.impl == "pallas_interpret"),
        )
        o = o.reshape(ca + cf, I, 4)
        return o[:ca], o[ca:]

    def _fused_garble(self, and_ops, a0, b0, slot, r, fops, fa, fb):
        I = self.instances
        ca, cf = self.plan.and_width, self.plan.free_width
        ops = self._lanes(jnp.concatenate([and_ops, fops]))
        tw = self._lanes(jnp.concatenate(
            [slot.astype(U32), jnp.zeros((cf,), U32)]))
        rf = jnp.broadcast_to(r[None], (ca + cf, I, 4)).reshape(-1, 4)
        c0, tg, te = garble_level_pallas(
            ops,
            jnp.concatenate([a0, fa], 0).reshape(-1, 4),
            jnp.concatenate([b0, fb], 0).reshape(-1, 4),
            rf, tw,
            interpret=(self.impl == "pallas_interpret"),
        )
        c0 = c0.reshape(ca + cf, I, 4)
        tg = tg.reshape(ca + cf, I, 4)[:ca]
        te = te.reshape(ca + cf, I, 4)[:ca]
        return c0[:ca], c0[ca:], tg, te

    # ------------------------------------------------------------------
    # evaluate
    # ------------------------------------------------------------------
    def _eval_fn(self, active: jnp.ndarray, tables: jnp.ndarray):
        """active (I, n_src, 4); tables (I, >=nAND, 2, 4) -> (I, n_out, 4)."""
        self.n_traces += 1  # python side effect: advances only on retrace
        I, ca = self.instances, self.plan.and_width
        tabT = jnp.transpose(tables.astype(U32), (1, 2, 0, 3))
        wires = jnp.zeros((self.plan.n_rows, I, 4), U32)
        wires = lax.dynamic_update_slice(
            wires, active.astype(U32).transpose(1, 0, 2),
            (I32(0), I32(0), I32(0)))

        cf = self.plan.free_width

        def body(w, xs):
            off, widx, slot, pm, and_ops, _, fops = xs
            g = w[widx]  # one gather: [a | b | fa | fb] blocks
            a, b = g[:ca], g[ca:2 * ca]  # (Ca, I, 4)
            fa, fb = g[2 * ca:2 * ca + cf], g[2 * ca + cf:]  # (Cf, I, 4)
            # pad slots gather a clamped table row; the pad tail absorbs
            # it (INV/pad free lanes read the zero dummy row)
            tgte = tabT[slot]  # (Ca, 2, I, 4)
            if self.impl == "jit":
                # hash only the AND block, in planar form; free lanes are
                # one vector XOR (INV passes through via b == 0)
                tw = self._lanes(slot.astype(U32))
                and_out = _packed(
                    HG.eval_and_planar(_planar(a), _planar(b),
                                       _planar(tgte[:, 0]),
                                       _planar(tgte[:, 1]), tw), ca, I)
                free_out = fa ^ fb
            else:
                and_out, free_out = self._fused_eval(
                    and_ops, a, b, tgte[:, 0], tgte[:, 1], slot, fops,
                    fa, fb)
            out = jnp.concatenate([and_out, free_out], 0)[pm]
            return lax.dynamic_update_slice(w, out, (off, I32(0), I32(0))), \
                None

        wires, _ = lax.scan(body, wires, self._xs)
        return wires[self._outs].transpose(1, 0, 2)

    def evaluate(self, active, tables) -> jnp.ndarray:
        self.n_eval_calls += 1
        return self._eval(jnp.asarray(active), jnp.asarray(tables))

    # ------------------------------------------------------------------
    # garble
    # ------------------------------------------------------------------
    def _garble_fn(self, src_labels: jnp.ndarray, r: jnp.ndarray,
                   *, keep_wires: bool = False):
        """src_labels (I, n_src, 4) fresh zero-labels; r (I, 4) offset.

        Returns (input_zero at source order, tables (I, max(nAND,1), 2, 4),
        output color bits (I, n_out)[, full wire-zero store]).
        """
        self.n_traces += 1
        I, nA = self.instances, self.plan.n_and
        ca = self.plan.and_width
        r = r.astype(U32)
        rp = tuple(jnp.broadcast_to(r[None, :, k], (ca, I)).reshape(-1)
                   for k in range(4))  # planar R, AND-block shaped
        wires = jnp.zeros((self.plan.n_rows, I, 4), U32)
        wires = lax.dynamic_update_slice(
            wires, src_labels.astype(U32).transpose(1, 0, 2),
            (I32(0), I32(0), I32(0)))

        cf = self.plan.free_width

        def body(w, xs):
            off, widx, slot, pm, and_ops, finv, fops = xs
            g = w[widx]
            a, b = g[:ca], g[ca:2 * ca]
            fa, fb = g[2 * ca:2 * ca + cf], g[2 * ca + cf:]
            if self.impl == "jit":
                tw = self._lanes(slot.astype(U32))
                c0, tg, te = HG.garble_and_planar(_planar(a), _planar(b),
                                                  rp, tw)
                and_out = _packed(c0, ca, I)
                tg = _packed(tg, ca, I)
                te = _packed(te, ca, I)
                # free: XOR lanes a0^b0; INV lanes a0^R (b reads zero)
                free_out = fa ^ fb
                free_out = jnp.where(finv[:, None, None] != 0,
                                     free_out ^ r[None], free_out)
            else:
                and_out, free_out, tg, te = self._fused_garble(
                    and_ops, a, b, slot, r, fops, fa, fb)
            out = jnp.concatenate([and_out, free_out], 0)[pm]
            w = lax.dynamic_update_slice(w, out, (off, I32(0), I32(0)))
            # tables leave through the scan's stacked ys (always written
            # in place) rather than a second carry, which would break the
            # wire store's buffer aliasing
            return w, jnp.stack([tg, te], 1)

        wires, tab = lax.scan(body, wires, self._xs)
        in_zero = wires[: self.n_src].transpose(1, 0, 2)
        out_perm = (wires[self._outs, :, 0] & U32(1)).T
        # chunk-major (K, Ca) table stack -> dense AND-slot order
        tables = (jnp.transpose(
            tab.reshape(-1, 2, I, 4)[self._and_rows], (2, 0, 1, 3)) if nA
            else jnp.zeros((I, 1, 2, 4), U32))
        if keep_wires:
            return (in_zero, tables, out_perm,
                    wires[self._wire_rows].transpose(1, 0, 2))
        return in_zero, tables, out_perm

    def garble(self, src_labels, r, *, keep_wires: bool = False):
        self.n_garble_calls += 1
        return self._garble(jnp.asarray(src_labels), jnp.asarray(r),
                            keep_wires=keep_wires)


def get_executor(net: Netlist, instances: int, impl: str) -> LevelExecutor:
    """Compiled-walk cache: one executor per (netlist, instances, impl).

    The plan (and thus the cache) hangs off the netlist object, so its
    lifetime matches the protocol's netlist cache and the jit executables
    are reused across every preprocess/run that touches the same shape.
    Small batches get the latency-regime plan (wider chunks, fewer scan
    steps); large batches the tight throughput plan.
    """
    plan = compile_level_plan(net, instances=instances)
    key = (int(instances), impl)
    exe = plan._executors.get(key)
    if exe is None:
        exe = LevelExecutor(plan, instances, impl)
        plan._executors[key] = exe
    return exe

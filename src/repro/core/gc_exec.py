"""Device-resident execution of a compiled netlist level plan.

The host-side numpy walk in :mod:`repro.core.garble` pays a Python round
trip per level: gather labels, dispatch XOR/INV/AND batches separately,
scatter, repeat. This module compiles the whole walk — wire store,
gathers, FreeXOR/INV/Half-Gate, scatters — into ONE ``jax.jit`` call per
``(netlist, instances, impl)``: a ``lax.scan`` over the plan's fixed-shape
*chunks* (see :class:`~repro.core.netlist.LevelPlan`) whose body evaluates
one padded level. Because every chunk has the same (and_width, free_width)
shape, the executable contains a single level body regardless of netlist
depth, so XLA compile time stays flat in circuit size.

The body is built around what profiling the scan showed matters on a CPU
host (and costs nothing on TPU):

* the wire store is **liveness-compacted** (default): the plan recycles a
  gate's row once its fanout is consumed, so the carry tracks the peak
  live label set instead of the gate count and gathers stay
  cache-resident (a production softmax row shrinks ~10x);
* each chunk commits with ONE contiguous ``dynamic_update_slice`` of its
  ``perm``-ordered lane block per carry — a scattered store or a second
  dynamic write on the same carry forces XLA to copy that carry every
  step;
* garble tables are emitted **packed**: dense table-store carries written
  with one contiguous slice per chunk at the plan's ``table_base``
  offsets — not through the scan's stacked ys, which padded every chunk
  to ``and_width`` rows and materialized ``K×Ca`` garbage rows at
  preprocessing-scale instance counts;
* two **instance regimes** (same threshold as the plan's width regimes):

  - *throughput* (I > 8): the store is **planar** ``(4, n_rows, I)`` —
    one plane per label word — so the Half-Gate cipher consumes gathered
    ``(lanes, I)`` planes with ZERO per-chunk transposes, and hashes go
    through :func:`repro.kernels.halfgate.ref.eval_and_split` /
    ``garble_and_split``: one un-concatenated hash call per label group.
    The previous 2N/4N-lane batched pass looked cheaper but XLA
    duplicates a multiply-consumed concat+slice chain into every
    consumer fusion — the compiled body executed the ARX permutation ~3x
    over, which is why garbling used to LOSE to the numpy oracle at
    I=256;
  - *latency* (I <= 8, e.g. one online request): the store is row-major
    ``(n_rows, I, 4)`` and the cipher runs on flat concatenated planes
    (:func:`~repro.kernels.halfgate.ref.eval_and_planar`). At tiny
    batches per-op dispatch dominates and the fused 2N/4N pass wins;
    planar gathers of 1-word rows lose the old layout's contiguous
    16-byte label reads (measured ~2x at I=1);

* per-chunk gathers can be **double-buffered** (``prefetch``): the scan
  carry holds the current chunk's pre-gathered block and the body issues
  the NEXT chunk's gather speculatively against the pre-write store —
  pinned alongside the cipher with ``lax.optimization_barrier``, then
  patched from the freshly computed write block for the lanes the
  current chunk just produced (the paper's speculation-against-memory-
  stall). On XLA:CPU the pre-write gather defeats the carry's in-place
  aliasing (measured ~8x regression: the store is copied every step), so
  prefetch defaults ON only for the real-TPU ``"pallas"`` impl; both
  settings are bit-exact;
* the ``"jit"`` impl hashes only the AND block (XOR/INV lanes are one
  vector XOR: INV second inputs read the zero dummy row, so there is no
  per-lane select anywhere); the ``"pallas"``/``"pallas_interpret"``
  impls hand the concatenated block to the fused ``kernels/level_eval``
  pass on evaluate, and the AND block alone on garble (free-lane table
  rows are zero by construction — shipping them through the kernel
  tripled the garble lane's output volume for nothing).

The wire store lives entirely inside the executable (scan carry — XLA
updates it in place), so a cached evaluate performs zero per-level
host<->device transfers: one launch in, output labels out.

Executors are cached on the plan, keyed by ``(instances, impl)``;
``n_traces`` counts actual retraces (it only advances while jax traces the
body) and ``n_eval_calls`` / ``n_garble_calls`` count invocations, which
is what the cache-hit and single-dispatch tests assert on.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import obs
from repro.core.netlist import (
    LATENCY_MAX_INSTANCES,
    LevelPlan,
    Netlist,
    OP_AND,
    OP_PAD,
    compile_level_plan,
)
from repro.kernels.halfgate import ref as HG
from repro.kernels.level_eval.level_eval import (
    eval_level_pallas,
    garble_level_pallas,
)

U32 = jnp.uint32
I32 = jnp.int32


def _planes(x):
    """(4, lanes, I) planar block -> 4-tuple of (lanes, I) planes."""
    return (x[0], x[1], x[2], x[3])


def _flat_planar(x):
    """(lanes, I, 4) packed block -> 4-tuple of flat (lanes*I,) planes."""
    p = x.transpose(2, 0, 1).reshape(4, -1)
    return (p[0], p[1], p[2], p[3])


def _flat_packed(planes, lanes, instances):
    return jnp.stack(planes, 0).reshape(4, lanes, instances).transpose(1, 2, 0)


class LevelExecutor:
    """One compiled evaluate/garble walk for a fixed (plan, I, impl).

    ``prefetch=None`` resolves to True only on the real-TPU ``"pallas"``
    impl (see module docstring); any explicit value wins. Both settings
    are bit-exact — prefetch is purely a scheduling change. The store
    layout (planar vs row-major, see module docstring) follows the
    instance regime and is likewise invisible in the results.
    """

    def __init__(self, plan: LevelPlan, instances: int, impl: str,
                 prefetch: Optional[bool] = None):
        if impl not in ("jit", "pallas", "pallas_interpret"):
            raise ValueError(f"device executor impl {impl!r}")
        self.plan = plan
        self.instances = int(instances)
        self.impl = impl
        self.prefetch = (impl == "pallas") if prefetch is None \
            else bool(prefetch)
        self.planar = self.instances > LATENCY_MAX_INSTANCES
        self.n_traces = 0
        self.n_eval_calls = 0
        self.n_garble_calls = 0
        self.n_src = len(plan.source_ids)
        # per-chunk scan operands: device-resident once, reused every
        # call; the four wire-read index blocks are fused into ONE array
        # so the body issues a single gather per chunk (per-step thunk
        # count dominates small-batch walks). With prefetch the xs carry
        # the NEXT chunk's indices (rolled by one): the body consumes the
        # pre-gathered block from the carry and issues chunk k+1's load.
        # Arrays a body doesn't touch are dead-code-eliminated.
        widx = np.concatenate(
            [plan.and_in0, plan.and_in1, plan.free_in0, plan.free_in1],
            axis=1)
        self._widx0 = jnp.asarray(widx[0], I32)
        self._xs = (
            jnp.asarray(plan.base, I32),
            jnp.asarray(plan.table_base, I32),
            jnp.asarray(np.roll(widx, -1, axis=0) if self.prefetch
                        else widx, I32),
            jnp.asarray(plan.and_slot, I32),
            jnp.asarray(plan.perm, I32),
            jnp.asarray(
                np.where(plan.and_slot < plan.n_and, OP_AND, OP_PAD), U32),
            jnp.asarray(plan.free_inv, U32),
            jnp.asarray(plan.free_ops, U32),
        )
        self._outs = jnp.asarray(plan.out_rows, I32)
        self._wire_rows = jnp.asarray(plan.wire_rows, I32)
        self._and_rows = jnp.asarray(plan.and_rows, I32)
        self._eval = jax.jit(self._eval_fn)
        self._garble = jax.jit(self._garble_fn,
                               static_argnames=("keep_wires",))

    # ------------------------------------------------------------------
    # layout adapters: 'block' shapes are (4, lanes, I) planar or
    # (lanes, I, 4) row-major depending on the regime
    # ------------------------------------------------------------------
    def _store_init(self, labels):
        """labels (I, n, 4) -> zero store with rows [0, n) filled."""
        I = self.instances
        if self.planar:
            w = jnp.zeros((4, self.plan.n_rows, I), U32)
            blk = labels.astype(U32).transpose(2, 1, 0)
        else:
            w = jnp.zeros((self.plan.n_rows, I, 4), U32)
            blk = labels.astype(U32).transpose(1, 0, 2)
        return lax.dynamic_update_slice(w, blk, (I32(0), I32(0), I32(0)))

    def _gather(self, w, rows):
        return w[:, rows] if self.planar else w[rows]

    def _commit(self, w, out, off):
        at = (I32(0), off, I32(0)) if self.planar else \
            (off, I32(0), I32(0))
        return lax.dynamic_update_slice(w, out, at)

    def _rows_out(self, w, rows):
        """Store rows -> (I, n, 4) result layout."""
        return w[:, rows].transpose(2, 1, 0) if self.planar else \
            w[rows].transpose(1, 0, 2)

    def _split_block(self, g):
        """Gathered block -> (a, b, fa, fb) sub-blocks along lanes."""
        ca, cf = self.plan.and_width, self.plan.free_width
        if self.planar:
            return (g[:, :ca], g[:, ca:2 * ca],
                    g[:, 2 * ca:2 * ca + cf], g[:, 2 * ca + cf:])
        return (g[:ca], g[ca:2 * ca],
                g[2 * ca:2 * ca + cf], g[2 * ca + cf:])

    def _cat_perm(self, and_out, free_out, pm):
        if self.planar:
            return jnp.concatenate([and_out, free_out], 1)[:, pm]
        return jnp.concatenate([and_out, free_out], 0)[pm]

    def _free_xor(self, fa, fb):
        return fa ^ fb

    def _free_inv_r(self, free_out, finv, rb):
        """Garbler: XOR R onto the INV lanes. rb broadcasts per layout."""
        mask = finv[None, :, None] if self.planar else finv[:, None, None]
        return jnp.where(mask != 0, free_out ^ rb, free_out)

    def _to_kernel(self, x):
        """block -> (lanes*I, 4) packed kernel layout."""
        if self.planar:
            return x.transpose(1, 2, 0).reshape(-1, 4)
        return x.reshape(-1, 4)

    def _from_kernel(self, x, lanes):
        I = self.instances
        if self.planar:
            return x.reshape(lanes, I, 4).transpose(2, 0, 1)
        return x.reshape(lanes, I, 4)

    # ------------------------------------------------------------------
    # fused-kernel bodies (pallas / pallas_interpret)
    # ------------------------------------------------------------------
    def _lanes(self, per_lane):
        """(lanes,) per-lane scalar -> flat (lanes*I,) lane-major vector."""
        I = self.instances
        return jnp.broadcast_to(per_lane[:, None],
                                (per_lane.shape[0], I)).reshape(-1)

    def _fused_eval(self, and_ops, a, b, tg, te, slot, fops, fa, fb):
        """Concatenated AND+free block through the fused level kernel.

        Blocks are packed to the kernel's (G, 4) layout and the output
        unpacked — on TPU these transposes are register shuffles; the
        CPU ``"jit"`` impl never takes this path.
        """
        I = self.instances
        ca, cf = self.plan.and_width, self.plan.free_width
        ops = self._lanes(jnp.concatenate([and_ops, fops]))
        tw = self._lanes(jnp.concatenate(
            [slot.astype(U32), jnp.zeros((cf,), U32)]))
        z = jnp.zeros((cf * I, 4), U32)
        o = eval_level_pallas(
            ops,
            jnp.concatenate([self._to_kernel(a), self._to_kernel(fa)], 0),
            jnp.concatenate([self._to_kernel(b), self._to_kernel(fb)], 0),
            jnp.concatenate([self._to_kernel(tg), z], 0),
            jnp.concatenate([self._to_kernel(te), z], 0),
            tw,
            interpret=(self.impl == "pallas_interpret"),
        )
        o = self._from_kernel(o, ca + cf)
        if self.planar:
            return o[:, :ca], o[:, ca:]
        return o[:ca], o[ca:]

    def _fused_garble(self, and_ops, a0, b0, slot, rb, finv, fa, fb):
        """Garble lane: ONLY the AND block goes through the fused kernel.

        Free lanes are one vector XOR (INV lanes XOR R on top) — their
        table rows are zero by construction, so shipping them through the
        kernel's 3-output garble lane was pure wasted volume.
        """
        I = self.instances
        ca = self.plan.and_width
        ops = self._lanes(and_ops)
        tw = self._lanes(slot.astype(U32))
        if self.planar:
            rf = jnp.broadcast_to(rb, (4, ca, I))
        else:
            rf = jnp.broadcast_to(rb, (ca, I, 4))
        c0, tg, te = garble_level_pallas(
            ops, self._to_kernel(a0), self._to_kernel(b0),
            self._to_kernel(rf), tw,
            interpret=(self.impl == "pallas_interpret"),
        )
        free_out = self._free_inv_r(fa ^ fb, finv, rb)
        return (self._from_kernel(c0, ca), free_out,
                self._from_kernel(tg, ca), self._from_kernel(te, ca))

    # ------------------------------------------------------------------
    # the double-buffered gather
    # ------------------------------------------------------------------
    def _spec_gather_commit(self, w, out, off, widx_nxt):
        """Commit chunk k's block; return (new store, chunk k+1's block).

        The next chunk's gather is issued against the PRE-write store —
        ``optimization_barrier`` pins it next to the cipher output so the
        load overlaps the hash instead of queueing behind the store
        commit — then the lanes chunk k itself just produced (rows inside
        the freshly written window) are forwarded from the write block.
        Rows outside the window are final by the plan's liveness
        invariant, so the speculative value is the true value.
        """
        stride = self.plan.and_width + self.plan.free_width
        spec = self._gather(w, widx_nxt)
        out, spec = lax.optimization_barrier((out, spec))
        w = self._commit(w, out, off)
        rel = jnp.clip(widx_nxt - off, 0, stride - 1)
        hit = (widx_nxt >= off) & (widx_nxt < off + stride)
        if self.planar:
            g_nxt = jnp.where(hit[None, :, None], out[:, rel], spec)
        else:
            g_nxt = jnp.where(hit[:, None, None], out[rel], spec)
        return w, g_nxt

    # ------------------------------------------------------------------
    # evaluate
    # ------------------------------------------------------------------
    def _eval_fn(self, active: jnp.ndarray, tables: jnp.ndarray):
        """active (I, n_src, 4); tables (I, >=nAND, 2, 4) -> (I, n_out, 4)."""
        self.n_traces += 1  # python side effect: advances only on retrace
        I, ca = self.instances, self.plan.and_width
        cf = self.plan.free_width
        # (4, 2, nA, I) planar / (nA, 2, I, 4) row-major table views
        tabT = (jnp.transpose(tables.astype(U32), (3, 2, 1, 0))
                if self.planar
                else jnp.transpose(tables.astype(U32), (1, 2, 0, 3)))
        wires = self._store_init(active)

        def body(carry, xs):
            w, g = carry if self.prefetch else (carry, None)
            off, _tboff, widx, slot, pm, and_ops, _, fops = xs
            if not self.prefetch:
                g = self._gather(w, widx)  # one gather: [a|b|fa|fb]
            a, b, fa, fb = self._split_block(g)
            # pad slots gather a clamped table row; the pad tail absorbs
            # it (INV/pad free lanes read the zero dummy row)
            if self.planar:
                tt = tabT[:, :, slot]  # (4, 2, Ca, I)
                tg, te = tt[:, 0], tt[:, 1]
            else:
                tt = tabT[slot]  # (Ca, 2, I, 4)
                tg, te = tt[:, 0], tt[:, 1]
            if self.impl == "jit":
                # hash only the AND block; free lanes are one vector XOR
                # (INV passes through via b == 0)
                if self.planar:
                    tw = jnp.broadcast_to(slot.astype(U32)[:, None],
                                          (ca, I))
                    and_out = jnp.stack(HG.eval_and_split(
                        _planes(a), _planes(b),
                        _planes(tg), _planes(te), tw), 0)
                else:
                    tw = self._lanes(slot.astype(U32))
                    and_out = _flat_packed(
                        HG.eval_and_planar(
                            _flat_planar(a), _flat_planar(b),
                            _flat_planar(tg), _flat_planar(te), tw),
                        ca, I)
                free_out = self._free_xor(fa, fb)
            else:
                and_out, free_out = self._fused_eval(
                    and_ops, a, b, tg, te, slot, fops, fa, fb)
            out = self._cat_perm(and_out, free_out, pm)
            if self.prefetch:
                w, g_nxt = self._spec_gather_commit(w, out, off, widx)
                return (w, g_nxt), None
            return self._commit(w, out, off), None

        if self.prefetch:
            g0 = self._gather(wires, self._widx0)
            (wires, _), _ = lax.scan(body, (wires, g0), self._xs)
        else:
            wires, _ = lax.scan(body, wires, self._xs)
        return self._rows_out(wires, self._outs)

    def evaluate(self, active, tables) -> jnp.ndarray:
        self.n_eval_calls += 1
        # host-side dispatch boundary: the span must never cross into the
        # jitted body (jit_hygiene), so it wraps the executable call only
        with obs.span("gc_exec.evaluate",
                      netlist=getattr(self.plan._net, "name", "") or "",
                      instances=self.instances, impl=self.impl):
            return self._eval(jnp.asarray(active), jnp.asarray(tables))

    # ------------------------------------------------------------------
    # garble
    # ------------------------------------------------------------------
    def _garble_fn(self, src_labels: jnp.ndarray, r: jnp.ndarray,
                   *, keep_wires: bool = False):
        """src_labels (I, n_src, 4) fresh zero-labels; r (I, 4) offset.

        Returns (input_zero at source order, tables (I, max(nAND,1), 2, 4),
        output color bits (I, n_out)[, full wire-zero store]).
        """
        self.n_traces += 1
        I, nA = self.instances, self.plan.n_and
        ca = self.plan.and_width
        r = r.astype(U32)
        # R broadcast shaped for the regime's block layout
        rb = r.T[:, None, :] if self.planar else r[None]
        rp_flat = tuple(jnp.broadcast_to(r[None, :, k], (ca, I)).reshape(-1)
                        for k in range(4))  # latency path: planar R
        wires = self._store_init(src_labels)
        # packed table stores: one contiguous slice per chunk at
        # table_base — each its own scan carry with its own single
        # dynamic write, so XLA aliases every store in place (the old
        # ys-stack materialized K×Ca padded rows and re-gathered them on
        # exit). Planar regime: two (4, nT, I) carries; latency regime:
        # one (nT, 2, I, 4) carry.
        if self.planar:
            tabs0 = (jnp.zeros((4, self.plan.n_table_rows, I), U32),
                     jnp.zeros((4, self.plan.n_table_rows, I), U32))
        else:
            tabs0 = (jnp.zeros((self.plan.n_table_rows, 2, I, 4), U32),)

        def tab_commit(tabs, tg, te, tboff):
            if self.planar:
                return (lax.dynamic_update_slice(
                            tabs[0], tg, (I32(0), tboff, I32(0))),
                        lax.dynamic_update_slice(
                            tabs[1], te, (I32(0), tboff, I32(0))))
            blk = jnp.stack([tg, te], 1)  # (Ca, 2, I, 4)
            return (lax.dynamic_update_slice(
                tabs[0], blk, (tboff, I32(0), I32(0), I32(0))),)

        def body(carry, xs):
            if self.prefetch:
                w, tabs, g = carry[0], carry[1], carry[2]
            else:
                (w, tabs), g = carry, None
            off, tboff, widx, slot, pm, and_ops, finv, fops = xs
            if not self.prefetch:
                g = self._gather(w, widx)
            a, b, fa, fb = self._split_block(g)
            if self.impl == "jit":
                if self.planar:
                    tw = jnp.broadcast_to(slot.astype(U32)[:, None],
                                          (ca, I))
                    c0, tg, te = HG.garble_and_split(
                        _planes(a), _planes(b), _planes(rb), tw)
                    and_out = jnp.stack(c0, 0)
                    tg = jnp.stack(tg, 0)
                    te = jnp.stack(te, 0)
                else:
                    tw = self._lanes(slot.astype(U32))
                    c0, tg, te = HG.garble_and_planar(
                        _flat_planar(a), _flat_planar(b), rp_flat, tw)
                    and_out = _flat_packed(c0, ca, I)
                    tg = _flat_packed(tg, ca, I)
                    te = _flat_packed(te, ca, I)
                # free: XOR lanes a0^b0; INV lanes a0^R (b reads zero)
                free_out = self._free_inv_r(fa ^ fb, finv, rb)
            else:
                and_out, free_out, tg, te = self._fused_garble(
                    and_ops, a, b, slot, rb, finv, fa, fb)
            out = self._cat_perm(and_out, free_out, pm)
            tabs = tab_commit(tabs, tg, te, tboff)
            if self.prefetch:
                w, g_nxt = self._spec_gather_commit(w, out, off, widx)
                return (w, tabs, g_nxt), None
            return (self._commit(w, out, off), tabs), None

        if self.prefetch:
            g0 = self._gather(wires, self._widx0)
            (wires, tabs, _), _ = lax.scan(
                body, (wires, tabs0, g0), self._xs)
        else:
            (wires, tabs), _ = lax.scan(body, (wires, tabs0), self._xs)
        in_zero = (wires[:, : self.n_src].transpose(2, 1, 0)
                   if self.planar
                   else wires[: self.n_src].transpose(1, 0, 2))
        out_perm = ((wires[0, self._outs] if self.planar
                     else wires[self._outs, :, 0]) & U32(1)).T
        # packed table stores -> dense AND-slot order (I, nA, 2, 4)
        if not nA:
            tables = jnp.zeros((I, 1, 2, 4), U32)
        elif self.planar:
            tables = jnp.stack([tabs[0][:, self._and_rows],
                                tabs[1][:, self._and_rows]],
                               0).transpose(3, 2, 0, 1)
        else:
            tables = jnp.transpose(tabs[0][self._and_rows], (2, 0, 1, 3))
        if keep_wires:
            return (in_zero, tables, out_perm,
                    self._rows_out(wires, self._wire_rows))
        return in_zero, tables, out_perm

    def garble(self, src_labels, r, *, keep_wires: bool = False):
        if keep_wires and self.plan.compact:
            raise ValueError(
                "keep_wires needs the full wire store: use a "
                "compact=False plan (rows are recycled here)")
        self.n_garble_calls += 1
        # host-side dispatch boundary (see evaluate): span stays outside
        # the jitted walk
        with obs.span("gc_exec.garble",
                      netlist=getattr(self.plan._net, "name", "") or "",
                      instances=self.instances, impl=self.impl):
            return self._garble(jnp.asarray(src_labels), jnp.asarray(r),
                                keep_wires=keep_wires)


def get_executor(net: Netlist, instances: int, impl: str,
                 compact: bool = True,
                 garbling: bool = False) -> LevelExecutor:
    """Compiled-walk cache: one executor per (netlist, instances, impl).

    The plan (and thus the cache) hangs off the netlist object, so its
    lifetime matches the protocol's netlist cache and the jit executables
    are reused across every preprocess/run that touches the same shape.
    Small batches get the latency-regime plan (wider chunks, fewer scan
    steps) and store layout; large batches the tight throughput plan with
    the planar store. ``compact`` selects the liveness-compacted store
    (default; ``keep_wires`` garbling needs ``compact=False``).
    ``garbling`` picks the garble-tightened widths on AND-rich netlists
    (see ``netlist._chunk_widths``) — a separate plan whose executors are
    cached independently; plans of any width/compact combination produce
    bit-identical labels/tables, so garbling on one plan and evaluating
    on another is safe by construction.
    """
    plan = compile_level_plan(net, instances=instances, compact=compact,
                              garbling=garbling)
    key = (int(instances), impl)
    exe = plan._executors.get(key)
    if exe is None:
        exe = LevelExecutor(plan, instances, impl)
        plan._executors[key] = exe
    return exe

"""Netlist: the gate-level DAG consumed by garbling, scheduling and the
accelerator simulator.

Gate ops: 0 = XOR, 1 = AND, 2 = INV. Wires are dense ints. Constants are
garbler-supplied input wires with recorded bits (free under garbling).
Gates are stored in topological order (the builder emits them that way).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

OP_XOR, OP_AND, OP_INV = 0, 1, 2
OP_PAD = 3  # padding lane in a compiled level plan (reads/writes dummies)
OP_NAMES = {OP_XOR: "XOR", OP_AND: "AND", OP_INV: "INV", OP_PAD: "PAD"}


@dataclass
class Netlist:
    num_wires: int
    op: np.ndarray  # (G,) uint8
    in0: np.ndarray  # (G,) int32
    in1: np.ndarray  # (G,) int32 (INV: == in0)
    out: np.ndarray  # (G,) int32
    garbler_inputs: np.ndarray  # wire ids
    evaluator_inputs: np.ndarray
    outputs: np.ndarray
    const_bits: Dict[int, int] = field(default_factory=dict)  # wire -> 0/1
    name: str = ""

    # ---- stats -----------------------------------------------------------
    @property
    def num_gates(self) -> int:
        return len(self.op)

    @property
    def and_count(self) -> int:
        return int(np.sum(self.op == OP_AND))

    @property
    def xor_count(self) -> int:
        return int(np.sum(self.op == OP_XOR))

    @property
    def inv_count(self) -> int:
        return int(np.sum(self.op == OP_INV))

    def stats(self) -> Dict:
        lv = self.levels()
        return {
            "name": self.name,
            "wires": self.num_wires,
            "gates": self.num_gates,
            "and": self.and_count,
            "xor": self.xor_count,
            "inv": self.inv_count,
            "depth": len(lv),
            "max_level_width": max((len(l) for l in lv), default=0),
            "garbled_table_bytes": self.and_count * 32,  # 2 rows x 16B
        }

    # ---- levelization (TPU-plane schedule) --------------------------------
    def levels(self) -> List[np.ndarray]:
        """Topological layers of gate indices: every gate's inputs are
        produced strictly earlier. This is the level-synchronous schedule the
        TPU plane evaluates (gather -> cipher -> scatter per level)."""
        wire_level = np.zeros(self.num_wires, np.int32)
        gate_level = np.zeros(self.num_gates, np.int32)
        for g in range(self.num_gates):
            a, b = self.in0[g], self.in1[g]
            l = wire_level[a]
            if self.op[g] != OP_INV:
                l = max(l, wire_level[b])
            gate_level[g] = l + 1
            wire_level[self.out[g]] = l + 1
        out = []
        if self.num_gates:
            for lvl in range(1, int(gate_level.max()) + 1):
                idx = np.nonzero(gate_level == lvl)[0]
                if len(idx):
                    out.append(idx.astype(np.int32))
        return out

    def and_gate_index(self) -> np.ndarray:
        """Per-gate index among AND gates (for garbled-table addressing)."""
        idx = np.cumsum(self.op == OP_AND) - 1
        return idx.astype(np.int32)

    # ---- plaintext oracle --------------------------------------------------
    def eval_plain(self, garbler_bits: np.ndarray, evaluator_bits: np.ndarray):
        """Vectorized plaintext evaluation.

        garbler_bits: (I, len(garbler_inputs)); evaluator_bits likewise.
        Returns (I, len(outputs)) uint8.
        """
        garbler_bits = np.atleast_2d(np.asarray(garbler_bits, np.uint8))
        evaluator_bits = np.atleast_2d(np.asarray(evaluator_bits, np.uint8))
        I = garbler_bits.shape[0]
        w = np.zeros((I, self.num_wires), np.uint8)
        if len(self.garbler_inputs):
            w[:, self.garbler_inputs] = garbler_bits
        if len(self.evaluator_inputs):
            w[:, self.evaluator_inputs] = evaluator_bits
        for wire, bit in self.const_bits.items():
            w[:, wire] = bit
        op, in0, in1, out = self.op, self.in0, self.in1, self.out
        for g in range(self.num_gates):
            a = w[:, in0[g]]
            if op[g] == OP_XOR:
                w[:, out[g]] = a ^ w[:, in1[g]]
            elif op[g] == OP_AND:
                w[:, out[g]] = a & w[:, in1[g]]
            else:
                w[:, out[g]] = a ^ 1
        return w[:, self.outputs]


# ---------------------------------------------------------------------------
# compiled level plan (device-resident execution)
# ---------------------------------------------------------------------------


@dataclass
class LevelPlan:
    """Device-ready execution plan for a netlist.

    Gates are list-scheduled (respecting wire dependencies) into
    ``n_chunks`` fixed-shape *chunks*, each holding up to ``and_width``
    AND lanes and ``free_width`` XOR/INV lanes — the level schedule
    bucketed to two padded widths, so ONE scan body covers the whole
    netlist and the executable contains a single level shape regardless
    of depth. Spare lanes read the zero *dummy* row (``n_rows - 1``).

    Wires are renumbered into executor *rows*: sources (inputs +
    constants) occupy rows ``[0, n_src)`` in ascending-wire order, and
    gate outputs are packed **compactly** — chunk ``k``'s valid outputs
    start at ``base[k]`` (AND lanes first, then free lanes) and
    ``base[k+1] = base[k] + valid_k``, so the wire store holds exactly
    ``n_src + n_gates`` live rows however much lane padding the chunk
    shape carries. The executor still commits one full fixed-width block
    per chunk — a SINGLE ``dynamic_update_slice`` of the computed lanes
    permuted by ``perm`` so valid lanes come first (one dynamic write per
    scan step is what lets XLA alias the carry in place; a second one
    forces a full-store copy every chunk). The pad-lane tail clobbers
    rows of *later* chunks, which is safe because chunk ``m`` only ever
    reads rows below ``base[m]`` — every clobbered row is rewritten
    before use. A ``stride``-row scratch tail plus the dummy row absorb
    the last chunk's spill.

    INV lanes are encoded as XOR-with-dummy: their second input reads the
    zero row, so the evaluator needs no per-lane select at all (INV labels
    pass through; the garbler XORs R on lanes flagged in ``free_inv``).

    ``and_slot`` holds the dense garbled-table index per AND lane (also
    the Half-Gate tweak, matching the host oracle bit-for-bit);
    ``and_rows`` maps dense slot -> chunk-major table-store row
    (``chunk * and_width + lane``) for the garbler.
    """

    num_wires: int
    n_and: int
    n_gates: int
    n_levels: int  # natural (unconstrained) levelization depth
    n_chunks: int
    and_width: int
    free_width: int
    n_rows: int  # wire-store rows: n_src + n_gates + stride scratch + dummy
    base: np.ndarray  # (K,) first output row of each chunk
    and_valid: np.ndarray  # (K,) live AND lanes per chunk
    free_valid: np.ndarray  # (K,) live free lanes per chunk
    and_in0: np.ndarray  # (K, Ca) row ids (pad -> dummy)
    and_in1: np.ndarray
    and_slot: np.ndarray  # (K, Ca) dense table slot (pad -> n_and)
    free_in0: np.ndarray  # (K, Cf) row ids (pad -> dummy)
    free_in1: np.ndarray  # (K, Cf) row ids (INV and pad -> dummy)
    free_inv: np.ndarray  # (K, Cf) uint32 1 on INV lanes (garbler XORs R)
    free_ops: np.ndarray  # (K, Cf) uint32 XOR/INV/PAD (fused-kernel path)
    perm: np.ndarray  # (K, Ca+Cf) write order: valid AND, valid free, pads
    source_ids: np.ndarray  # (n_src,) original wire ids, ascending
    out_rows: np.ndarray  # (n_out,) rows of the netlist outputs
    wire_rows: np.ndarray  # (W,) original wire -> row
    and_rows: np.ndarray  # (nA,) dense slot -> garble table-store row
    _executors: Dict = field(default_factory=dict)  # (I, impl) -> executor

    @property
    def widths(self) -> Tuple[int, int]:
        return (self.and_width, self.free_width)

    @property
    def padded_gate_lanes(self) -> int:
        """Total kernel lanes including padding (wasted-work metric)."""
        return self.n_chunks * (self.and_width + self.free_width)

    @property
    def padded_and_lanes(self) -> int:
        return self.n_chunks * self.and_width

    def source_positions(self, wire_ids) -> np.ndarray:
        """Positions of ``wire_ids`` inside the ``source_ids`` ordering."""
        pos = np.searchsorted(self.source_ids, wire_ids)
        if len(wire_ids) and (
            pos.max(initial=0) >= len(self.source_ids)
            or not np.array_equal(self.source_ids[pos], np.asarray(wire_ids))
        ):
            raise KeyError("wire ids are not source wires of this netlist")
        return pos.astype(np.int64)


def _ceil8(n: int) -> int:
    return max(8, -(-n // 8) * 8)


def _chunk_widths(net: Netlist, depth: int,
                  instances: Optional[int] = None) -> Tuple[int, int]:
    """Bucket the level profile to one AND width and one free width.

    Two regimes, selected by the executor batch size:

    * **throughput** (default / large batches): widths sized just above
      the average per-level population. The compact row numbering makes
      lane padding cheap (pad lanes read the cache-hot dummy row and
      clobber rows that are rewritten anyway), so the only real cost of
      slack is gather/store volume — keep the widths tight and let wide
      levels spill into extra chunks.
    * **latency** (``instances`` <= 8, e.g. a single online request):
      per-chunk volume is negligible, the scan's fixed per-chunk cost
      dominates — widen ~4x so the chunk count approaches the natural
      levelization depth.
    """
    depth = max(depth, 1)
    n_and = net.and_count
    n_free = net.num_gates - n_and
    # AND lanes floor to /8 (hash + table traffic: keep tight, spill
    # instead); free lanes ceil to /8 of the per-level average
    ca = min(max((n_and // depth) // 8 * 8, 8), 1024)
    cf = min(_ceil8(-(-n_free // depth)), 4096)
    if instances is not None and instances <= 8:
        ca = min(4 * ca, 1024)
        cf = min(4 * cf, 4096)
    return ca, cf


def compile_level_plan(net: Netlist,
                       and_width: Optional[int] = None,
                       free_width: Optional[int] = None,
                       instances: Optional[int] = None) -> LevelPlan:
    """Compile (and cache on the netlist, per width config) a level plan.

    ``instances`` only steers the default width choice (latency vs
    throughput regime); explicit ``and_width``/``free_width`` win. Plans
    are cached per (and_width, free_width) — source ordering, dense table
    slots and output order are width-independent, so any plan of the same
    netlist is interchangeable for packing/encoding purposes.
    """
    W, nA, G = net.num_wires, net.and_count, net.num_gates
    depth = getattr(net, "_plan_depth", None)
    if depth is None:
        depth = len(net.levels())
        net._plan_depth = depth  # type: ignore[attr-defined]
    ca, cf = _chunk_widths(net, depth, instances)
    ca = and_width or ca
    cf = free_width or cf
    plans = getattr(net, "_level_plans", None)
    if plans is None:
        plans = net._level_plans = {}  # type: ignore[attr-defined]
    cached = plans.get((ca, cf))
    if cached is not None:
        return cached

    op, in0, in1, out = net.op, net.in0, net.in1, net.out
    # greedy list scheduling under per-class lane capacity: every gate
    # lands in the earliest chunk after all its inputs with a spare lane
    wire_chunk = np.full(W, -1, np.int64)
    fill_and: List[int] = []
    fill_free: List[int] = []
    chunk_of = np.empty(G, np.int64)
    lane_of = np.empty(G, np.int64)
    for g in range(G):
        e = wire_chunk[in0[g]] + 1
        if op[g] != OP_INV:
            e = max(e, wire_chunk[in1[g]] + 1)
        is_and = op[g] == OP_AND
        fill, cap = (fill_and, ca) if is_and else (fill_free, cf)
        c = e
        while c < len(fill) and fill[c] >= cap:
            c += 1
        while c >= len(fill):
            fill_and.append(0)
            fill_free.append(0)
        lane_of[g] = fill[c]
        fill[c] += 1
        chunk_of[g] = c
        wire_chunk[out[g]] = c

    K = max(len(fill_and), 1)
    stride = ca + cf
    and_valid = np.zeros(K, np.int64)
    and_valid[: len(fill_and)] = fill_and
    free_valid = np.zeros(K, np.int64)
    free_valid[: len(fill_free)] = fill_free

    src = np.ones(W, bool)
    src[out] = False
    source_ids = np.nonzero(src)[0].astype(np.int64)
    n_src = len(source_ids)
    # compact numbering: exactly one live row per gate + scratch tail
    base = n_src + np.concatenate(
        [[0], np.cumsum(and_valid + free_valid)[:-1]])
    n_rows = n_src + G + stride + 1
    dummy = n_rows - 1

    wire_rows = np.full(W, dummy, np.int64)
    wire_rows[source_ids] = np.arange(n_src)
    is_and_g = op == OP_AND
    wire_rows[out] = np.where(
        is_and_g,
        base[chunk_of] + lane_of,
        base[chunk_of] + and_valid[chunk_of] + lane_of,
    )

    and_in0 = np.full((K, ca), dummy, np.int64)
    and_in1 = np.full((K, ca), dummy, np.int64)
    and_slot = np.full((K, ca), nA, np.int64)
    free_in0 = np.full((K, cf), dummy, np.int64)
    free_in1 = np.full((K, cf), dummy, np.int64)
    free_inv = np.zeros((K, cf), np.uint32)
    free_ops = np.full((K, cf), OP_PAD, np.uint32)

    and_idx = net.and_gate_index()
    r0 = wire_rows[in0]
    r1 = np.where(op == OP_INV, dummy, wire_rows[in1])  # INV: b reads zero
    ag = np.nonzero(is_and_g)[0]
    and_in0[chunk_of[ag], lane_of[ag]] = r0[ag]
    and_in1[chunk_of[ag], lane_of[ag]] = wire_rows[in1[ag]]
    and_slot[chunk_of[ag], lane_of[ag]] = and_idx[ag]
    fg = np.nonzero(~is_and_g)[0]
    free_in0[chunk_of[fg], lane_of[fg]] = r0[fg]
    free_in1[chunk_of[fg], lane_of[fg]] = r1[fg]
    free_inv[chunk_of[fg], lane_of[fg]] = (op[fg] == OP_INV).astype(np.uint32)
    free_ops[chunk_of[fg], lane_of[fg]] = op[fg]

    # dense table slot -> garbler table-store row (chunk-major AND lanes)
    and_rows = np.empty(max(nA, 0), np.int64)
    if nA:
        and_rows[and_idx[ag]] = chunk_of[ag] * ca + lane_of[ag]

    # write permutation over concat([AND lanes, free lanes]): valid lanes
    # first (so the block lands compactly at base[k]), pads trailing
    perm = np.empty((K, stride), np.int64)
    for k in range(K):
        va_k, vf_k = and_valid[k], free_valid[k]
        pads = np.concatenate(
            [np.arange(va_k, ca), ca + np.arange(vf_k, cf)])
        perm[k] = np.concatenate(
            [np.arange(va_k), ca + np.arange(vf_k), pads])

    plan = LevelPlan(
        num_wires=W,
        n_and=nA,
        n_gates=G,
        n_levels=depth,
        n_chunks=K,
        and_width=ca,
        free_width=cf,
        n_rows=n_rows,
        base=base,
        and_valid=and_valid,
        free_valid=free_valid,
        and_in0=and_in0,
        and_in1=and_in1,
        and_slot=and_slot,
        free_in0=free_in0,
        free_in1=free_in1,
        free_inv=free_inv,
        free_ops=free_ops,
        perm=perm,
        source_ids=source_ids,
        out_rows=wire_rows[np.asarray(net.outputs, np.int64)]
        if len(net.outputs) else np.array([], np.int64),
        wire_rows=wire_rows,
        and_rows=and_rows,
    )
    plans[(ca, cf)] = plan
    return plan


def wire_fanout(net: Netlist) -> np.ndarray:
    """Number of reads per wire (used by scheduling / LBUW policy)."""
    fan = np.zeros(net.num_wires, np.int64)
    np.add.at(fan, net.in0, 1)
    not_inv = net.op != OP_INV
    np.add.at(fan, net.in1[not_inv], 1)
    return fan

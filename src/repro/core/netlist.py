"""Netlist: the gate-level DAG consumed by garbling, scheduling and the
accelerator simulator.

Gate ops: 0 = XOR, 1 = AND, 2 = INV. Wires are dense ints. Constants are
garbler-supplied input wires with recorded bits (free under garbling).
Gates are stored in topological order (the builder emits them that way).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

OP_XOR, OP_AND, OP_INV = 0, 1, 2
OP_NAMES = {OP_XOR: "XOR", OP_AND: "AND", OP_INV: "INV"}


@dataclass
class Netlist:
    num_wires: int
    op: np.ndarray  # (G,) uint8
    in0: np.ndarray  # (G,) int32
    in1: np.ndarray  # (G,) int32 (INV: == in0)
    out: np.ndarray  # (G,) int32
    garbler_inputs: np.ndarray  # wire ids
    evaluator_inputs: np.ndarray
    outputs: np.ndarray
    const_bits: Dict[int, int] = field(default_factory=dict)  # wire -> 0/1
    name: str = ""

    # ---- stats -----------------------------------------------------------
    @property
    def num_gates(self) -> int:
        return len(self.op)

    @property
    def and_count(self) -> int:
        return int(np.sum(self.op == OP_AND))

    @property
    def xor_count(self) -> int:
        return int(np.sum(self.op == OP_XOR))

    @property
    def inv_count(self) -> int:
        return int(np.sum(self.op == OP_INV))

    def stats(self) -> Dict:
        lv = self.levels()
        return {
            "name": self.name,
            "wires": self.num_wires,
            "gates": self.num_gates,
            "and": self.and_count,
            "xor": self.xor_count,
            "inv": self.inv_count,
            "depth": len(lv),
            "max_level_width": max((len(l) for l in lv), default=0),
            "garbled_table_bytes": self.and_count * 32,  # 2 rows x 16B
        }

    # ---- levelization (TPU-plane schedule) --------------------------------
    def levels(self) -> List[np.ndarray]:
        """Topological layers of gate indices: every gate's inputs are
        produced strictly earlier. This is the level-synchronous schedule the
        TPU plane evaluates (gather -> cipher -> scatter per level)."""
        wire_level = np.zeros(self.num_wires, np.int32)
        gate_level = np.zeros(self.num_gates, np.int32)
        for g in range(self.num_gates):
            a, b = self.in0[g], self.in1[g]
            l = wire_level[a]
            if self.op[g] != OP_INV:
                l = max(l, wire_level[b])
            gate_level[g] = l + 1
            wire_level[self.out[g]] = l + 1
        out = []
        if self.num_gates:
            for lvl in range(1, int(gate_level.max()) + 1):
                idx = np.nonzero(gate_level == lvl)[0]
                if len(idx):
                    out.append(idx.astype(np.int32))
        return out

    def and_gate_index(self) -> np.ndarray:
        """Per-gate index among AND gates (for garbled-table addressing)."""
        idx = np.cumsum(self.op == OP_AND) - 1
        return idx.astype(np.int32)

    # ---- plaintext oracle --------------------------------------------------
    def eval_plain(self, garbler_bits: np.ndarray, evaluator_bits: np.ndarray):
        """Vectorized plaintext evaluation.

        garbler_bits: (I, len(garbler_inputs)); evaluator_bits likewise.
        Returns (I, len(outputs)) uint8.
        """
        garbler_bits = np.atleast_2d(np.asarray(garbler_bits, np.uint8))
        evaluator_bits = np.atleast_2d(np.asarray(evaluator_bits, np.uint8))
        I = garbler_bits.shape[0]
        w = np.zeros((I, self.num_wires), np.uint8)
        if len(self.garbler_inputs):
            w[:, self.garbler_inputs] = garbler_bits
        if len(self.evaluator_inputs):
            w[:, self.evaluator_inputs] = evaluator_bits
        for wire, bit in self.const_bits.items():
            w[:, wire] = bit
        op, in0, in1, out = self.op, self.in0, self.in1, self.out
        for g in range(self.num_gates):
            a = w[:, in0[g]]
            if op[g] == OP_XOR:
                w[:, out[g]] = a ^ w[:, in1[g]]
            elif op[g] == OP_AND:
                w[:, out[g]] = a & w[:, in1[g]]
            else:
                w[:, out[g]] = a ^ 1
        return w[:, self.outputs]


def wire_fanout(net: Netlist) -> np.ndarray:
    """Number of reads per wire (used by scheduling / LBUW policy)."""
    fan = np.zeros(net.num_wires, np.int64)
    np.add.at(fan, net.in0, 1)
    not_inv = net.op != OP_INV
    np.add.at(fan, net.in1[not_inv], 1)
    return fan

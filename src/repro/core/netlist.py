"""Netlist: the gate-level DAG consumed by garbling, scheduling and the
accelerator simulator.

Gate ops: 0 = XOR, 1 = AND, 2 = INV. Wires are dense ints. Constants are
garbler-supplied input wires with recorded bits (free under garbling).
Gates are stored in topological order (the builder emits them that way).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

OP_XOR, OP_AND, OP_INV = 0, 1, 2
OP_PAD = 3  # padding lane in a compiled level plan (reads/writes dummies)
OP_NAMES = {OP_XOR: "XOR", OP_AND: "AND", OP_INV: "INV", OP_PAD: "PAD"}


@dataclass
class Netlist:
    num_wires: int
    op: np.ndarray  # (G,) uint8
    in0: np.ndarray  # (G,) int32
    in1: np.ndarray  # (G,) int32 (INV: == in0)
    out: np.ndarray  # (G,) int32
    garbler_inputs: np.ndarray  # wire ids
    evaluator_inputs: np.ndarray
    outputs: np.ndarray
    const_bits: Dict[int, int] = field(default_factory=dict)  # wire -> 0/1
    name: str = ""

    # ---- stats -----------------------------------------------------------
    @property
    def num_gates(self) -> int:
        return len(self.op)

    @property
    def and_count(self) -> int:
        return int(np.sum(self.op == OP_AND))

    @property
    def xor_count(self) -> int:
        return int(np.sum(self.op == OP_XOR))

    @property
    def inv_count(self) -> int:
        return int(np.sum(self.op == OP_INV))

    def stats(self) -> Dict:
        lv = self.levels()
        st = {
            "name": self.name,
            "wires": self.num_wires,
            "gates": self.num_gates,
            "and": self.and_count,
            "xor": self.xor_count,
            "inv": self.inv_count,
            "depth": len(lv),
            "max_level_width": max((len(l) for l in lv), default=0),
            "garbled_table_bytes": self.and_count * 32,  # 2 rows x 16B
        }
        # dataflow counters (dead/foldable/duplicate gates, removable
        # ANDs) from the static analyzer — the measurement front-end of
        # the AND-minimization work; cached on the netlist
        from repro.analysis.netcheck import dataflow_summary
        st.update(dataflow_summary(self))
        return st

    # ---- levelization (TPU-plane schedule) --------------------------------
    def levels(self) -> List[np.ndarray]:
        """Topological layers of gate indices: every gate's inputs are
        produced strictly earlier. This is the level-synchronous schedule the
        TPU plane evaluates (gather -> cipher -> scatter per level)."""
        wire_level = np.zeros(self.num_wires, np.int32)
        gate_level = np.zeros(self.num_gates, np.int32)
        for g in range(self.num_gates):
            a, b = self.in0[g], self.in1[g]
            l = wire_level[a]
            if self.op[g] != OP_INV:
                l = max(l, wire_level[b])
            gate_level[g] = l + 1
            wire_level[self.out[g]] = l + 1
        out = []
        if self.num_gates:
            for lvl in range(1, int(gate_level.max()) + 1):
                idx = np.nonzero(gate_level == lvl)[0]
                if len(idx):
                    out.append(idx.astype(np.int32))
        return out

    def and_gate_index(self) -> np.ndarray:
        """Per-gate index among AND gates (for garbled-table addressing)."""
        idx = np.cumsum(self.op == OP_AND) - 1
        return idx.astype(np.int32)

    # ---- plaintext oracle --------------------------------------------------
    def eval_plain(self, garbler_bits: np.ndarray, evaluator_bits: np.ndarray):
        """Vectorized plaintext evaluation.

        garbler_bits: (I, len(garbler_inputs)); evaluator_bits likewise.
        Returns (I, len(outputs)) uint8.
        """
        garbler_bits = np.atleast_2d(np.asarray(garbler_bits, np.uint8))
        evaluator_bits = np.atleast_2d(np.asarray(evaluator_bits, np.uint8))
        I = garbler_bits.shape[0]
        w = np.zeros((I, self.num_wires), np.uint8)
        if len(self.garbler_inputs):
            w[:, self.garbler_inputs] = garbler_bits
        if len(self.evaluator_inputs):
            w[:, self.evaluator_inputs] = evaluator_bits
        for wire, bit in self.const_bits.items():
            w[:, wire] = bit
        op, in0, in1, out = self.op, self.in0, self.in1, self.out
        for g in range(self.num_gates):
            a = w[:, in0[g]]
            if op[g] == OP_XOR:
                w[:, out[g]] = a ^ w[:, in1[g]]
            elif op[g] == OP_AND:
                w[:, out[g]] = a & w[:, in1[g]]
            else:
                w[:, out[g]] = a ^ 1
        return w[:, self.outputs]


# ---------------------------------------------------------------------------
# compiled level plan (device-resident execution)
# ---------------------------------------------------------------------------


@dataclass
class LevelPlan:
    """Device-ready execution plan for a netlist.

    Gates are list-scheduled (respecting wire dependencies) into
    ``n_chunks`` fixed-shape *chunks*, each holding up to ``and_width``
    AND lanes and ``free_width`` XOR/INV lanes — the level schedule
    bucketed to two padded widths, so ONE scan body covers the whole
    netlist and the executable contains a single level shape regardless
    of depth. Spare lanes read the zero *dummy* row (``n_rows - 1``).

    Wires are renumbered into executor *rows*: sources (inputs +
    constants) occupy rows ``[0, n_src)`` in ascending-wire order; gate
    outputs land at ``base[k] + lane`` (AND lanes first, then free
    lanes). The executor commits one full fixed-width block per chunk — a
    SINGLE ``dynamic_update_slice`` of the computed lanes permuted by
    ``perm`` so valid lanes come first (one dynamic write per scan carry
    is what lets XLA alias the carry in place; a second write on the same
    carry forces a full-store copy every chunk). The pad-lane tail
    clobbers only rows whose current value is dead, which is safe because
    every clobbered row is rewritten before its next read.

    Two row-numbering modes:

    * ``compact=True`` (default) — the **liveness pass**: each gate row's
      last-use chunk is computed from the fanout, and chunk ``k``'s
      ``stride``-row block is placed at the lowest window containing no
      *live* row, so rows are recycled as soon as their fanout is
      consumed. The store size tracks the peak live label set (typically
      a small multiple of the chunk width) instead of the gate count —
      the paper's wire-memory reuse, applied to the scan carry. Sources,
      netlist outputs and the dummy row are pinned (never recycled).
      ``wire_rows`` gives each wire's row *during its live range only*;
      a full ``keep_wires`` snapshot needs ``compact=False``.
    * ``compact=False`` — append-only: ``base[k+1] = base[k] + valid_k``,
      exactly one row per gate for the store's whole life (escape hatch,
      and what ``keep_wires`` garbling uses).

    INV lanes are encoded as XOR-with-dummy: their second input reads the
    zero row, so the evaluator needs no per-lane select at all (INV labels
    pass through; the garbler XORs R on lanes flagged in ``free_inv``).

    ``and_slot`` holds the dense garbled-table index per AND lane (also
    the Half-Gate tweak, matching the host oracle bit-for-bit). Garbled
    tables are emitted **packed**: chunk ``k``'s valid AND lanes write
    table rows ``[table_base[k], table_base[k] + and_valid[k])`` — one
    contiguous slice per chunk into a dense ``n_table_rows``-row store
    (``n_and`` real rows + an ``and_width`` spill tail), no ys-stack
    padding. Pad lanes spill into rows owned by later chunks, which
    rewrite them before the store is read. ``and_rows`` maps dense slot
    -> packed table-store row (``table_base[chunk] + lane``).
    """

    num_wires: int
    n_and: int
    n_gates: int
    n_levels: int  # natural (unconstrained) levelization depth
    n_chunks: int
    and_width: int
    free_width: int
    n_rows: int  # wire-store rows (incl. spill scratch + dummy)
    base: np.ndarray  # (K,) first output row of each chunk
    and_valid: np.ndarray  # (K,) live AND lanes per chunk
    free_valid: np.ndarray  # (K,) live free lanes per chunk
    and_in0: np.ndarray  # (K, Ca) row ids (pad -> dummy)
    and_in1: np.ndarray
    and_slot: np.ndarray  # (K, Ca) dense table slot (pad -> n_and)
    free_in0: np.ndarray  # (K, Cf) row ids (pad -> dummy)
    free_in1: np.ndarray  # (K, Cf) row ids (INV and pad -> dummy)
    free_inv: np.ndarray  # (K, Cf) uint32 1 on INV lanes (garbler XORs R)
    free_ops: np.ndarray  # (K, Cf) uint32 XOR/INV/PAD (fused-kernel path)
    perm: np.ndarray  # (K, Ca+Cf) write order: valid AND, valid free, pads
    source_ids: np.ndarray  # (n_src,) original wire ids, ascending
    out_rows: np.ndarray  # (n_out,) rows of the netlist outputs
    wire_rows: np.ndarray  # (W,) original wire -> row (at write time)
    and_rows: np.ndarray  # (nA,) dense slot -> packed table-store row
    table_base: np.ndarray = None  # (K,) first packed table row per chunk
    n_table_rows: int = 0  # packed table store: n_and + and_width spill
    compact: bool = False  # liveness-compacted rows?
    store_rows_naive: int = 0  # store size the append-only numbering needs
    _executors: Dict = field(default_factory=dict)  # (I, impl) -> executor
    _net: Optional["Netlist"] = None  # source netlist (stats counters)

    @property
    def widths(self) -> Tuple[int, int]:
        return (self.and_width, self.free_width)

    @property
    def padded_gate_lanes(self) -> int:
        """Total kernel lanes including padding (wasted-work metric)."""
        return self.n_chunks * (self.and_width + self.free_width)

    @property
    def padded_and_lanes(self) -> int:
        return self.n_chunks * self.and_width

    def stats(self) -> Dict:
        """Plan-shape metrics: wire-store rows before/after the liveness
        pass and real-vs-padded garble table rows (what the ys-stack
        emission used to materialize). Surfaced by ``bench_gc_eval`` so
        reuse wins are visible per netlist."""
        padded_tables = self.n_chunks * self.and_width
        st = {
            "chunks": self.n_chunks,
            "and_width": self.and_width,
            "free_width": self.free_width,
            "compact": self.compact,
            "store_rows": self.n_rows,
            "store_rows_naive": self.store_rows_naive,
            "store_row_reduction": round(
                self.store_rows_naive / max(self.n_rows, 1), 2),
            "table_rows_real": self.n_and,
            "table_rows_padded": padded_tables,
            "table_pad_ratio": round(
                padded_tables / max(self.n_and, 1), 2),
        }
        net = self._net
        if net is not None:
            # removable-AND / dead-gate counters of the *source netlist*
            # (compile_level_plan pins it): how much of the plan's lane
            # and table volume the dataflow analyzer can still prove away
            from repro.analysis.netcheck import dataflow_summary
            st.update(dataflow_summary(net))
        return st

    def source_positions(self, wire_ids) -> np.ndarray:
        """Positions of ``wire_ids`` inside the ``source_ids`` ordering."""
        pos = np.searchsorted(self.source_ids, wire_ids)
        if len(wire_ids) and (
            pos.max(initial=0) >= len(self.source_ids)
            or not np.array_equal(self.source_ids[pos], np.asarray(wire_ids))
        ):
            raise KeyError("wire ids are not source wires of this netlist")
        return pos.astype(np.int64)


#: instance count at or below which the latency regime applies — wider
#: chunks (here) and the row-major store layout (``gc_exec``)
LATENCY_MAX_INSTANCES = 8


def _ceil8(n: int) -> int:
    return max(8, -(-n // 8) * 8)


def _chunk_widths(net: Netlist, depth: int,
                  instances: Optional[int] = None,
                  garbling: bool = False) -> Tuple[int, int]:
    """Bucket the level profile to one AND width and one free width.

    Two regimes, selected by the executor batch size:

    * **throughput** (default / large batches): widths sized just above
      the average per-level population. The compact row numbering makes
      lane padding cheap (pad lanes read the cache-hot dummy row and
      clobber rows that are rewritten anyway), so the only real cost of
      slack is gather/store volume — keep the widths tight and let wide
      levels spill into extra chunks.
    * **latency** (``instances`` <= 8, e.g. a single online request):
      per-chunk volume is negligible, the scan's fixed per-chunk cost
      dominates — widen ~4x so the chunk count approaches the natural
      levelization depth.

    ``garbling`` requests the garble walk's variant: every padded AND
    lane costs the garbler 4 hash lanes (vs the evaluator's 2), so
    AND-rich netlists whose default width sits above the /8 floor get
    their AND width halved and the free width trimmed to 2/3 — more,
    narrower chunks with much less dead hashing. Netlists already at
    the floor keep the shared shape (tightening the free width alone
    just adds scan steps). Garbled tables are dense-slot ordered, so a
    garble plan and an eval plan of different widths interoperate
    bit-exactly.
    """
    depth = max(depth, 1)
    n_and = net.and_count
    n_free = net.num_gates - n_and
    # AND lanes floor to /8 (hash + table traffic: keep tight, spill
    # instead); free lanes ceil to /8 of the per-level average
    ca = min(max((n_and // depth) // 8 * 8, 8), 1024)
    cf = min(_ceil8(-(-n_free // depth)), 4096)
    if instances is not None and instances <= LATENCY_MAX_INSTANCES:
        ca = min(4 * ca, 1024)
        cf = min(4 * cf, 4096)
    elif garbling and ca > 8:
        ca = max(ca // 2, 8)
        cf = _ceil8(2 * cf // 3)
    return ca, cf


def _allocate_rows_liveness(net: Netlist, K: int, stride: int, n_src: int,
                            chunk_of: np.ndarray, row_off: np.ndarray,
                            ) -> Tuple[np.ndarray, int]:
    """The liveness pass: reuse-aware placement of the per-chunk blocks.

    Each gate row's last-use chunk comes from the fanout (readers of its
    output wire); netlist outputs and sources are pinned forever. Chunk
    ``k`` still commits ONE contiguous ``stride``-row block, placed
    first-fit at the lowest window of rows whose occupants are all dead
    by chunk ``k`` (last read at chunk <= k — the scan body gathers
    before it writes, so a row read by chunk ``k`` itself may sit in its
    window). Rows are recycled as soon as their fanout is consumed, so
    the store tracks the peak live label set instead of the gate count.

    Returns ``(base (K,), n_rows)`` with the dummy row appended past the
    highest window (never inside one, so pad/INV reads always see zero).
    """
    INF = K + 2
    last_read = np.full(net.num_wires, -1, np.int64)
    if net.num_gates:
        np.maximum.at(last_read, net.in0, chunk_of)
        ni = net.op != OP_INV
        np.maximum.at(last_read, net.in1[ni], chunk_of[ni])
    if len(net.outputs):
        last_read[np.asarray(net.outputs, np.int64)] = INF
    # per-gate release chunk, grouped by chunk in lane order
    live_until = (last_read[net.out] if net.num_gates
                  else np.zeros(0, np.int64))
    order = np.argsort(chunk_of, kind="stable") if net.num_gates else \
        np.zeros(0, np.int64)
    counts = np.bincount(chunk_of, minlength=K) if net.num_gates else \
        np.zeros(K, np.int64)
    bounds = np.concatenate([[0], np.cumsum(counts)])

    release = np.zeros(n_src + 4 * stride, np.int64)
    release[:n_src] = INF  # sources pinned: garble reads them at the end
    base = np.empty(K, np.int64)
    for k in range(K):
        while True:
            blocked = release[n_src:] > k
            if len(blocked) >= stride:
                csum = np.cumsum(blocked)
                wsum = csum[stride - 1:].copy()
                wsum[1:] -= csum[:-stride]
                free_at = np.flatnonzero(wsum == 0)
                if len(free_at):
                    break
            release = np.concatenate(
                [release,
                 np.zeros(max(stride, len(release) // 4), np.int64)])
        b = int(n_src + free_at[0])
        base[k] = b
        g_k = order[bounds[k]: bounds[k + 1]]
        release[b + row_off[g_k]] = live_until[g_k]
    n_rows = int(base.max(initial=n_src) + stride) + 1 if K else \
        n_src + stride + 1
    return base, n_rows


def _validate_plan(net: Netlist, plan: LevelPlan,
                   chunk_of: np.ndarray, lane_of: np.ndarray) -> None:
    """Host-side simulation of the store discipline (plan invariants).

    Walks the chunks tracking which wire each row currently holds and
    checks every read — including the dummy reads of pad/INV lanes —
    sees exactly the wire the schedule expects ("no row rewritten while
    live"), that no write block touches the dummy row, and that sources
    and netlist outputs survive to the end. A renumbering that recycles
    a row before its last reader fails here at compile time. Raises
    explicitly (never bare ``assert``): this guard must survive
    ``python -O`` — a bad plan is a silent wrong-label disaster.
    """
    def _check(ok: bool, msg: str) -> None:
        if not ok:
            raise AssertionError(f"level plan invariant violated: {msg}")

    K, ca, cf = plan.n_chunks, plan.and_width, plan.free_width
    stride = ca + cf
    dummy = plan.n_rows - 1
    GARBAGE, DUMMY = -3, -1
    _check(bool((plan.base + stride <= dummy).all()),
           "write block hits dummy row")

    is_and_g = net.op == OP_AND
    ag = np.nonzero(is_and_g)[0]
    fg = np.nonzero(~is_and_g)[0]
    exp_a0 = np.full((K, ca), DUMMY, np.int64)
    exp_a1 = np.full((K, ca), DUMMY, np.int64)
    exp_f0 = np.full((K, cf), DUMMY, np.int64)
    exp_f1 = np.full((K, cf), DUMMY, np.int64)
    exp_a0[chunk_of[ag], lane_of[ag]] = net.in0[ag]
    exp_a1[chunk_of[ag], lane_of[ag]] = net.in1[ag]
    exp_f0[chunk_of[fg], lane_of[fg]] = net.in0[fg]
    exp_f1[chunk_of[fg], lane_of[fg]] = np.where(
        net.op[fg] == OP_INV, DUMMY, net.in1[fg])
    outw = np.full((K, stride), GARBAGE, np.int64)
    if net.num_gates:
        row_off = np.where(is_and_g, lane_of,
                           plan.and_valid[chunk_of] + lane_of)
        outw[chunk_of, row_off] = net.out

    owner = np.full(plan.n_rows, GARBAGE, np.int64)
    owner[dummy] = DUMMY
    owner[np.arange(len(plan.source_ids))] = plan.source_ids
    for k in range(K):
        for rows, exp in ((plan.and_in0[k], exp_a0[k]),
                          (plan.and_in1[k], exp_a1[k]),
                          (plan.free_in0[k], exp_f0[k]),
                          (plan.free_in1[k], exp_f1[k])):
            _check(np.array_equal(owner[rows], exp),
                   f"chunk {k}: read of a recycled/garbage row")
        # the executor writes concat([AND, free])[perm] at base[k]; the
        # owner bookkeeping above places gates by row_off, so pin the
        # two to each other: perm must put the valid lanes, in lane
        # order, exactly at positions [0, valid_k)
        va, vf = plan.and_valid[k], plan.free_valid[k]
        _check(np.array_equal(
            plan.perm[k][: va + vf],
            np.concatenate([np.arange(va), ca + np.arange(vf)])),
            f"chunk {k}: perm does not land valid lanes at the "
            "row_off placement")
        b = plan.base[k]
        owner[b: b + stride] = outw[k]
    n_src = len(plan.source_ids)
    _check(np.array_equal(owner[:n_src], plan.source_ids),
           "a source row was clobbered")
    if len(net.outputs):
        _check(np.array_equal(owner[plan.out_rows],
                              np.asarray(net.outputs, np.int64)),
               "a netlist output row was clobbered before the end")


def compile_level_plan(net: Netlist,
                       and_width: Optional[int] = None,
                       free_width: Optional[int] = None,
                       instances: Optional[int] = None,
                       compact: bool = True,
                       garbling: bool = False) -> LevelPlan:
    """Compile (and cache on the netlist, per width config) a level plan.

    ``instances`` and ``garbling`` only steer the default width choice
    (latency vs throughput regime; garble-tightened AND width — see
    :func:`_chunk_widths`); explicit ``and_width``/``free_width`` win.
    ``compact`` selects the liveness-compacted wire store (default; see
    :class:`LevelPlan`) — ``compact=False`` keeps the append-only
    one-row-per-gate numbering, which ``keep_wires`` garbling needs.
    Plans are cached per (and_width, free_width, compact) — source
    ordering, dense table slots and output order are width-independent,
    so any plan of the same netlist is interchangeable for
    packing/encoding purposes (a garble-width plan's tables feed an
    eval-width plan's evaluate bit-exactly).
    """
    W, nA, G = net.num_wires, net.and_count, net.num_gates
    depth = getattr(net, "_plan_depth", None)
    if depth is None:
        depth = len(net.levels())
        net._plan_depth = depth  # type: ignore[attr-defined]
    ca, cf = _chunk_widths(net, depth, instances, garbling)
    ca = and_width or ca
    cf = free_width or cf
    plans = getattr(net, "_level_plans", None)
    if plans is None:
        plans = net._level_plans = {}  # type: ignore[attr-defined]
    cached = plans.get((ca, cf, bool(compact)))
    if cached is not None:
        return cached

    op, in0, in1, out = net.op, net.in0, net.in1, net.out
    # greedy list scheduling under per-class lane capacity: every gate
    # lands in the earliest chunk after all its inputs with a spare lane
    wire_chunk = np.full(W, -1, np.int64)
    fill_and: List[int] = []
    fill_free: List[int] = []
    chunk_of = np.empty(G, np.int64)
    lane_of = np.empty(G, np.int64)
    for g in range(G):
        e = wire_chunk[in0[g]] + 1
        if op[g] != OP_INV:
            e = max(e, wire_chunk[in1[g]] + 1)
        is_and = op[g] == OP_AND
        fill, cap = (fill_and, ca) if is_and else (fill_free, cf)
        c = e
        while c < len(fill) and fill[c] >= cap:
            c += 1
        while c >= len(fill):
            fill_and.append(0)
            fill_free.append(0)
        lane_of[g] = fill[c]
        fill[c] += 1
        chunk_of[g] = c
        wire_chunk[out[g]] = c

    K = max(len(fill_and), 1)
    stride = ca + cf
    and_valid = np.zeros(K, np.int64)
    and_valid[: len(fill_and)] = fill_and
    free_valid = np.zeros(K, np.int64)
    free_valid[: len(fill_free)] = fill_free

    src = np.ones(W, bool)
    src[out] = False
    source_ids = np.nonzero(src)[0].astype(np.int64)
    n_src = len(source_ids)
    is_and_g = op == OP_AND
    # per-gate row offset inside its chunk's write block (AND lanes first)
    row_off = (np.where(is_and_g, lane_of,
                        and_valid[chunk_of] + lane_of).astype(np.int64)
               if G else np.zeros(0, np.int64))
    naive_rows = n_src + G + stride + 1
    if compact:
        base, n_rows = _allocate_rows_liveness(
            net, K, stride, n_src, chunk_of, row_off)
    else:
        # append-only: exactly one live row per gate + scratch tail
        base = n_src + np.concatenate(
            [[0], np.cumsum(and_valid + free_valid)[:-1]])
        n_rows = naive_rows
    dummy = n_rows - 1

    wire_rows = np.full(W, dummy, np.int64)
    wire_rows[source_ids] = np.arange(n_src)
    if G:
        wire_rows[out] = base[chunk_of] + row_off

    and_in0 = np.full((K, ca), dummy, np.int64)
    and_in1 = np.full((K, ca), dummy, np.int64)
    and_slot = np.full((K, ca), nA, np.int64)
    free_in0 = np.full((K, cf), dummy, np.int64)
    free_in1 = np.full((K, cf), dummy, np.int64)
    free_inv = np.zeros((K, cf), np.uint32)
    free_ops = np.full((K, cf), OP_PAD, np.uint32)

    and_idx = net.and_gate_index()
    r0 = wire_rows[in0]
    r1 = np.where(op == OP_INV, dummy, wire_rows[in1])  # INV: b reads zero
    ag = np.nonzero(is_and_g)[0]
    and_in0[chunk_of[ag], lane_of[ag]] = r0[ag]
    and_in1[chunk_of[ag], lane_of[ag]] = wire_rows[in1[ag]]
    and_slot[chunk_of[ag], lane_of[ag]] = and_idx[ag]
    fg = np.nonzero(~is_and_g)[0]
    free_in0[chunk_of[fg], lane_of[fg]] = r0[fg]
    free_in1[chunk_of[fg], lane_of[fg]] = r1[fg]
    free_inv[chunk_of[fg], lane_of[fg]] = (op[fg] == OP_INV).astype(np.uint32)
    free_ops[chunk_of[fg], lane_of[fg]] = op[fg]

    # packed garble-table layout: chunk k's valid AND lanes write table
    # rows [table_base[k], table_base[k] + and_valid[k]); pad-lane spill
    # lands in rows owned by LATER chunks (table_base is the cumsum of
    # and_valid, so row t of owner chunk j satisfies t < table_base[m]
    # for every m > j — later chunks never clobber an owned row) plus an
    # and_width scratch tail for the last chunk
    table_base = np.concatenate(
        [[0], np.cumsum(and_valid)[:-1]]).astype(np.int64)
    n_table_rows = int(nA + ca)
    and_rows = np.empty(max(nA, 0), np.int64)
    if nA:
        and_rows[and_idx[ag]] = table_base[chunk_of[ag]] + lane_of[ag]

    # write permutation over concat([AND lanes, free lanes]): valid lanes
    # first (so the block lands compactly at base[k]), pads trailing
    perm = np.empty((K, stride), np.int64)
    for k in range(K):
        va_k, vf_k = and_valid[k], free_valid[k]
        pads = np.concatenate(
            [np.arange(va_k, ca), ca + np.arange(vf_k, cf)])
        perm[k] = np.concatenate(
            [np.arange(va_k), ca + np.arange(vf_k), pads])

    plan = LevelPlan(
        num_wires=W,
        n_and=nA,
        n_gates=G,
        n_levels=depth,
        n_chunks=K,
        and_width=ca,
        free_width=cf,
        n_rows=n_rows,
        base=base,
        and_valid=and_valid,
        free_valid=free_valid,
        and_in0=and_in0,
        and_in1=and_in1,
        and_slot=and_slot,
        free_in0=free_in0,
        free_in1=free_in1,
        free_inv=free_inv,
        free_ops=free_ops,
        perm=perm,
        source_ids=source_ids,
        out_rows=wire_rows[np.asarray(net.outputs, np.int64)]
        if len(net.outputs) else np.array([], np.int64),
        wire_rows=wire_rows,
        and_rows=and_rows,
        table_base=table_base,
        n_table_rows=n_table_rows,
        compact=bool(compact),
        store_rows_naive=naive_rows,
        _net=net,
    )
    # always-on invariant check: a bad renumber is a silent wrong-label
    # disaster, so every freshly compiled plan is simulated once
    _validate_plan(net, plan, chunk_of, lane_of)
    plans[(ca, cf, bool(compact))] = plan
    return plan


def wire_fanout(net: Netlist) -> np.ndarray:
    """Number of reads per wire (used by scheduling / LBUW policy)."""
    fan = np.zeros(net.num_wires, np.int64)
    np.add.at(fan, net.in0, 1)
    not_inv = net.op != OP_INV
    np.add.at(fan, net.in1[not_inv], 1)
    return fan

"""Level-synchronous garbling and evaluation of netlists.

TPU adaptation of the paper's execution model (DESIGN.md §3): instead of 16
MIMD cores walking a serial netlist, gates are processed one topological
*level* at a time, vectorized across (instances × gates-in-level):

    gather input labels  ->  FreeXOR / INV (xors)  ->  Half-Gate cipher
    (kernels/halfgate)   ->  scatter output labels

The paper's coarse-grained scheduling (independent softmax rows -> cores)
becomes the leading `instances` dim, which also shards over the `data` mesh
axis at scale. Garbled tables are produced per (instance, AND-gate).

Two execution paths share one interface, selected by ``impl`` (resolved by
:func:`repro.kernels.dispatch.resolve_impl`):

  "ref"                      the per-level numpy walk below — the oracle
  "jit"/"pallas"/"pallas_*"  the device-resident executor
                             (:mod:`repro.core.gc_exec`): the whole walk
                             compiled into one jitted call through the
                             fused ``kernels/level_eval`` pass, cached per
                             ``(netlist, instances)``

``auto`` therefore never drops to the host loop: it resolves to the
device-resident path everywhere ("pallas" on TPU, "jit" elsewhere).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import labels as LB
from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR
from repro.core.gc_exec import get_executor
from repro.kernels.dispatch import resolve_impl
from repro.kernels.halfgate import ref_np as HGNP

#: ``active`` argument of :func:`evaluate`: either the legacy per-wire dict
#: or a packed ``(wire_ids (n,), labels (I, n, 4))`` pair — the packed form
#: is what the online protocol path uses (no per-wire host work).
ActiveLabels = Union[Dict[int, jnp.ndarray], Tuple[np.ndarray, jnp.ndarray]]


@dataclass
class GarbledCircuit:
    """Garbler-side artifact for a batch of instances.

    Input zero-labels are position-indexed: ``input_zero[:, j]`` is the
    zero-label of wire ``input_wires[j]`` (garbler inputs, then evaluator
    inputs, then constant wires). ``input_positions`` maps wire ids to
    positions through a dense lookup so encode never does per-wire dict
    stacking.
    """

    net: Netlist
    r: jnp.ndarray  # (I, 4)
    input_wires: np.ndarray  # (n_in,) wire ids in position order
    input_zero: jnp.ndarray  # (I, n_in, 4) zero-labels, position-indexed
    tables: jnp.ndarray  # (I, nAND, 2, 4)
    output_perm: jnp.ndarray  # (I, n_out) color bit of the FALSE label
    wire_zero: Optional[jnp.ndarray] = None  # (I, W, 4) if kept
    _pos: Optional[np.ndarray] = field(default=None, repr=False)

    @property
    def num_instances(self) -> int:
        return self.r.shape[0]

    def input_positions(self, wire_ids) -> np.ndarray:
        """Positions of ``wire_ids`` in the packed ``input_zero`` array."""
        if self._pos is None:
            pos = np.full(self.net.num_wires, -1, np.int64)
            pos[self.input_wires] = np.arange(len(self.input_wires))
            self._pos = pos
        p = self._pos[np.asarray(wire_ids, np.int64)]
        if len(p) and p.min(initial=0) < 0:
            raise KeyError("wire ids are not input/const wires")
        return p


def _input_ids(net: Netlist) -> np.ndarray:
    """Position order of the packed input labels (stable across paths)."""
    if not net.num_wires:
        return np.array([], np.int64)
    return np.concatenate([
        net.garbler_inputs, net.evaluator_inputs,
        np.array(sorted(net.const_bits), dtype=np.int64),
    ]).astype(np.int64)


def _plan(net: Netlist):
    """Static per-level gather/scatter plans (cached on the netlist)."""
    if getattr(net, "_gc_plan", None) is not None:
        return net._gc_plan
    levels = net.levels()
    and_idx = net.and_gate_index()
    plan = []
    for lvl in levels:
        ops = net.op[lvl]
        plan.append(
            dict(
                gates=lvl,
                in0=net.in0[lvl],
                in1=net.in1[lvl],
                out=net.out[lvl],
                xor_idx=np.nonzero(ops == OP_XOR)[0],
                inv_idx=np.nonzero(ops == OP_INV)[0],
                and_idx=np.nonzero(ops == OP_AND)[0],
                and_slot=and_idx[lvl],
            )
        )
    net._gc_plan = plan  # type: ignore[attr-defined]
    return plan


def _seeded_zero_labels(seeded_inputs, instances: int, r):
    """Zero-labels for wires whose *active* labels come from a PRG stream.

    ``seeded_inputs`` is ``(wire_ids, bits, seed, counter)``: the garbler
    commits that the active label of wire ``wire_ids[j]`` in instance
    ``i`` is stream label ``counter + i*n + j``, so the zero-label must
    be ``active ^ bits * R``. ``encode_inputs`` on those wires then
    reproduces the stream exactly — which is what lets the v2 wire ship
    a 32-byte seed record instead of the label bytes.
    """
    wire_ids, bits, seed, counter = seeded_inputs
    wire_ids = np.asarray(wire_ids, np.int64)
    n = len(wire_ids)
    active = LB.stream_labels(seed, counter, instances * n)
    active = jnp.asarray(active.reshape(instances, n, 4))
    bits = jnp.asarray(bits, jnp.uint32)
    return wire_ids, LB.maybe_xor(active, bits, jnp.asarray(r)[:, None, :])


def garble(
    net: Netlist,
    key,
    instances: int = 1,
    *,
    impl: str = "auto",
    keep_wires: bool = False,
    seeded_inputs=None,
) -> GarbledCircuit:
    """Garble ``instances`` independent copies of ``net``.

    ``impl="ref"``: host-side numpy walk (levels mutate O(level) rows in
    place; only the Half-Gate cipher batches go through jnp). Any other
    impl: the whole walk runs inside one jitted device executor. Both
    paths draw labels from the same key stream, so they are bit-exact.

    ``seeded_inputs=(wire_ids, bits, seed, counter)`` presets the listed
    input wires so their active labels replay a PRG stream (see
    :func:`_seeded_zero_labels`); all other wires draw fresh labels.
    """
    impl = resolve_impl(impl)
    I, W = instances, net.num_wires
    k_r, k_w = jax.random.split(key)
    in_ids = _input_ids(net)

    if impl != "ref":
        # keep_wires needs every gate's row alive at the end, so it pins
        # the append-only (compact=False) plan; the default path garbles
        # through the liveness-compacted store + packed table emission,
        # on the garble-width plan (tighter AND lanes: a padded AND lane
        # costs the garbler 4 hash lanes vs the evaluator's 2)
        exe = get_executor(net, I, impl, compact=not keep_wires,
                           garbling=True)
        plan = exe.plan
        r = LB.random_delta(k_r, (I,))
        src_labels = LB.random_labels(k_w, (I, len(plan.source_ids)))
        if seeded_inputs is not None:
            wids, zeros = _seeded_zero_labels(seeded_inputs, I, r)
            src_labels = src_labels.at[
                :, plan.source_positions(wids)].set(zeros)
        res = exe.garble(src_labels, r, keep_wires=keep_wires)
        src_zero, tables, out_perm = res[:3]
        in_zero = src_zero[:, plan.source_positions(in_ids)]
        return GarbledCircuit(
            net=net, r=r, input_wires=in_ids, input_zero=in_zero,
            tables=tables, output_perm=out_perm,
            wire_zero=res[3] if keep_wires else None,
        )

    r = np.asarray(LB.random_delta(k_r, (I,)))  # (I, 4)
    wire0 = np.zeros((I, W, 4), np.uint32)
    # fresh zero-labels for all non-gate-output wires (inputs + constants)
    src = np.ones(W, bool)
    src[net.out] = False
    src_ids = np.nonzero(src)[0]
    wire0[:, src_ids] = np.asarray(LB.random_labels(k_w, (I, len(src_ids))))
    if seeded_inputs is not None:
        wids, zeros = _seeded_zero_labels(seeded_inputs, I, r)
        wire0[:, wids] = np.asarray(zeros)

    n_and = net.and_count
    tables = np.zeros((I, max(n_and, 1), 2, 4), np.uint32)

    for step in _plan(net):
        a0 = wire0[:, step["in0"]]  # (I, L, 4)
        b0 = wire0[:, step["in1"]]
        out0 = np.empty_like(a0)
        xi = step["xor_idx"]
        vi = step["inv_idx"]
        ai = step["and_idx"]
        if len(xi):
            out0[:, xi] = a0[:, xi] ^ b0[:, xi]
        if len(vi):
            out0[:, vi] = a0[:, vi] ^ r[:, None, :]
        if len(ai):
            tw = step["and_slot"][ai].astype(np.uint32)
            c0, tg, te = HGNP.garble_and_gates(
                a0[:, ai], b0[:, ai], r[:, None, :],
                np.broadcast_to(tw[None, :], (I, len(ai))),
            )
            out0[:, ai] = np.asarray(c0)
            tables[:, step["and_slot"][ai], 0] = np.asarray(tg)
            tables[:, step["and_slot"][ai], 1] = np.asarray(te)
        wire0[:, step["out"]] = out0

    out_perm = (
        (wire0[:, net.outputs, 0] & 1).astype(np.uint32)
        if len(net.outputs)
        else np.zeros((I, 0), np.uint32)
    )
    return GarbledCircuit(
        net=net,
        r=jnp.asarray(r),
        input_wires=in_ids,
        input_zero=jnp.asarray(wire0[:, in_ids]),
        tables=jnp.asarray(tables),
        output_perm=jnp.asarray(out_perm),
        wire_zero=wire0 if keep_wires else None,
    )


def slice_instances(gc: GarbledCircuit, lo: int, hi: int) -> GarbledCircuit:
    """A view of instances [lo, hi) of a batch-garbled circuit.

    Sessions garble once per cached netlist for a whole preprocessing
    batch, then hand each op/request its instance band.
    """
    return GarbledCircuit(
        net=gc.net,
        r=gc.r[lo:hi],
        input_wires=gc.input_wires,
        input_zero=gc.input_zero[lo:hi],
        tables=gc.tables[lo:hi],
        output_perm=gc.output_perm[lo:hi],
        wire_zero=None if gc.wire_zero is None else gc.wire_zero[lo:hi],
        _pos=gc._pos,
    )


def input_zeros(gc: GarbledCircuit, wire_ids: Sequence[int]) -> jnp.ndarray:
    """Zero-labels for the given input/const wires: one gather, (I, n, 4)."""
    return gc.input_zero[:, gc.input_positions(wire_ids)]


def encode_inputs(gc: GarbledCircuit, wire_ids: Sequence[int], bits) -> jnp.ndarray:
    """Active labels for given wires/bits. bits: (I, n) in {0,1}.

    This is the garbler-side encode (and what OT delivers for evaluator
    inputs). Returns (I, n, 4).
    """
    bits = jnp.asarray(bits, jnp.uint32)
    return LB.maybe_xor(input_zeros(gc, wire_ids), bits, gc.r[:, None, :])


def const_wires_labels(gc: GarbledCircuit) -> Tuple[np.ndarray, jnp.ndarray]:
    """Active labels of constant wires, packed: (wire_ids, (I, n_c, 4))."""
    if not gc.net.const_bits:
        return (np.array([], np.int64),
                jnp.zeros((gc.num_instances, 0, 4), jnp.uint32))
    wires = np.array(sorted(gc.net.const_bits), np.int64)
    bits = np.array([gc.net.const_bits[int(w)] for w in wires], np.uint32)
    lab = encode_inputs(gc, wires, np.broadcast_to(bits, (gc.num_instances,
                                                          len(wires))))
    return wires, lab


def const_labels(gc: GarbledCircuit) -> Dict[int, jnp.ndarray]:
    """Active labels of constant wires (garbler supplies with the tables)."""
    wires, lab = const_wires_labels(gc)
    return {int(w): lab[:, j] for j, w in enumerate(wires)}


def _pack_active(active: ActiveLabels) -> Tuple[np.ndarray, jnp.ndarray]:
    """Normalize ``active`` to (host wire_ids, labels (I, n, 4)).

    Labels stay device-resident (jnp) — only the wire ids are needed on
    the host, to resolve static packing positions.
    """
    if isinstance(active, dict):
        wire_ids = np.fromiter(active.keys(), np.int64, len(active))
        labels = jnp.stack([jnp.asarray(v) for v in active.values()],
                           axis=1)
        return wire_ids, labels
    wire_ids, labels = active
    return np.asarray(wire_ids, np.int64), jnp.asarray(labels)


def evaluate(
    net: Netlist,
    tables: jnp.ndarray,
    active: ActiveLabels,
    *,
    impl: str = "auto",
) -> jnp.ndarray:
    """Evaluator: active labels for all input+const wires -> output labels.

    ``active``: wire -> (I, 4) dict, or packed (wire_ids, (I, n, 4)).
    Returns (I, n_out, 4). ``impl="ref"`` is the host-loop oracle; anything
    else runs the cached device-resident executor — a single jitted call,
    no per-level host<->device transfers.
    """
    impl = resolve_impl(impl)
    wire_ids, labels = _pack_active(active)
    I = labels.shape[0]

    if impl != "ref":
        exe = get_executor(net, I, impl)
        plan = exe.plan
        # positions are static per netlist; the scatter runs on device so
        # online labels never round-trip through the host
        pos = plan.source_positions(wire_ids)
        packed = jnp.zeros((I, len(plan.source_ids), 4), jnp.uint32)
        packed = packed.at[:, pos].set(labels.astype(jnp.uint32))
        return exe.evaluate(packed, tables)

    W = net.num_wires
    wires = np.zeros((I, W, 4), np.uint32)
    wires[:, wire_ids] = np.asarray(labels)
    tables_np = np.asarray(tables)

    for step in _plan(net):
        a = wires[:, step["in0"]]
        b = wires[:, step["in1"]]
        out = np.empty_like(a)
        xi, vi, ai = step["xor_idx"], step["inv_idx"], step["and_idx"]
        if len(xi):
            out[:, xi] = a[:, xi] ^ b[:, xi]
        if len(vi):
            # free: the label passes through (semantics flip garbler-side)
            out[:, vi] = a[:, vi]
        if len(ai):
            slots = step["and_slot"][ai]
            tw = slots.astype(np.uint32)
            c = HGNP.eval_and_gates(
                a[:, ai], b[:, ai],
                tables_np[:, slots, 0], tables_np[:, slots, 1],
                np.broadcast_to(tw[None, :], (I, len(ai))),
            )
            out[:, ai] = np.asarray(c)
        wires[:, step["out"]] = out
    return jnp.asarray(wires[:, net.outputs])


def decode_outputs(gc: GarbledCircuit, out_labels: jnp.ndarray) -> np.ndarray:
    """(I, n_out, 4) active labels -> (I, n_out) bits via output permute bits."""
    return np.asarray(LB.lsb(out_labels) ^ gc.output_perm, np.uint8)


# ---------------------------------------------------------------------------
# convenience: end-to-end two-party run (tests / engine)
# ---------------------------------------------------------------------------


def run_garbled(
    net: Netlist,
    key,
    garbler_bits,
    evaluator_bits,
    *,
    impl: str = "auto",
):
    """Full garble -> encode -> evaluate -> decode round trip.

    garbler_bits: (I, len(garbler_inputs)); evaluator_bits: (I, len(eval)).
    Returns (I, n_out) uint8 — must equal net.eval_plain(...).
    """
    garbler_bits = jnp.atleast_2d(jnp.asarray(garbler_bits, jnp.uint32))
    evaluator_bits = jnp.atleast_2d(jnp.asarray(evaluator_bits, jnp.uint32))
    I = garbler_bits.shape[0]
    gc = garble(net, key, I, impl=impl)
    parts = []
    if len(net.garbler_inputs):
        parts.append((np.asarray(net.garbler_inputs, np.int64),
                      encode_inputs(gc, net.garbler_inputs, garbler_bits)))
    if len(net.evaluator_inputs):
        parts.append((np.asarray(net.evaluator_inputs, np.int64),
                      encode_inputs(gc, net.evaluator_inputs,
                                    evaluator_bits)))  # via OT
    cw, cl = const_wires_labels(gc)
    if len(cw):
        parts.append((cw, cl))
    wire_ids = np.concatenate([p[0] for p in parts]) if parts else \
        np.array([], np.int64)
    labels = jnp.concatenate([p[1] for p in parts], axis=1) if \
        parts else jnp.zeros((I, 0, 4), jnp.uint32)
    out = evaluate(net, gc.tables, (wire_ids, labels), impl=impl)
    return decode_outputs(gc, out)

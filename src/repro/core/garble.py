"""Level-synchronous garbling and evaluation of netlists.

TPU adaptation of the paper's execution model (DESIGN.md §3): instead of 16
MIMD cores walking a serial netlist, gates are processed one topological
*level* at a time, vectorized across (instances × gates-in-level):

    gather input labels  ->  FreeXOR / INV (xors)  ->  Half-Gate cipher
    (kernels/halfgate)   ->  scatter output labels

The paper's coarse-grained scheduling (independent softmax rows -> cores)
becomes the leading `instances` dim, which also shards over the `data` mesh
axis at scale. Garbled tables are produced per (instance, AND-gate).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import labels as LB
from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR
from repro.kernels.halfgate import ops as HG
from repro.kernels.halfgate import ref_np as HGNP


@dataclass
class GarbledCircuit:
    """Garbler-side artifact for a batch of instances."""

    net: Netlist
    r: jnp.ndarray  # (I, 4)
    input_zero: Dict[int, jnp.ndarray]  # wire -> (I, 4) zero-label
    tables: jnp.ndarray  # (I, nAND, 2, 4)
    output_perm: jnp.ndarray  # (I, n_out) color bit of the FALSE label
    wire_zero: Optional[jnp.ndarray] = None  # (I, W, 4) if kept

    @property
    def num_instances(self) -> int:
        return self.r.shape[0]


def _plan(net: Netlist):
    """Static per-level gather/scatter plans (cached on the netlist)."""
    if getattr(net, "_gc_plan", None) is not None:
        return net._gc_plan
    levels = net.levels()
    and_idx = net.and_gate_index()
    plan = []
    for lvl in levels:
        ops = net.op[lvl]
        plan.append(
            dict(
                gates=lvl,
                in0=net.in0[lvl],
                in1=net.in1[lvl],
                out=net.out[lvl],
                xor_idx=np.nonzero(ops == OP_XOR)[0],
                inv_idx=np.nonzero(ops == OP_INV)[0],
                and_idx=np.nonzero(ops == OP_AND)[0],
                and_slot=and_idx[lvl],
            )
        )
    net._gc_plan = plan  # type: ignore[attr-defined]
    return plan


def garble(
    net: Netlist,
    key,
    instances: int = 1,
    *,
    impl: str = "auto",
    keep_wires: bool = False,
) -> GarbledCircuit:
    """Wire store is an in-place numpy array (levels mutate O(level) rows);
    only the Half-Gate cipher batches go through jnp/Pallas."""
    I, W = instances, net.num_wires
    k_r, k_w = jax.random.split(key)
    r = np.asarray(LB.random_delta(k_r, (I,)))  # (I, 4)

    wire0 = np.zeros((I, W, 4), np.uint32)
    # fresh zero-labels for all non-gate-output wires (inputs + constants)
    src = np.ones(W, bool)
    src[net.out] = False
    src_ids = np.nonzero(src)[0]
    wire0[:, src_ids] = np.asarray(LB.random_labels(k_w, (I, len(src_ids))))

    n_and = net.and_count
    tables = np.zeros((I, max(n_and, 1), 2, 4), np.uint32)

    for step in _plan(net):
        a0 = wire0[:, step["in0"]]  # (I, L, 4)
        b0 = wire0[:, step["in1"]]
        out0 = np.empty_like(a0)
        xi = step["xor_idx"]
        vi = step["inv_idx"]
        ai = step["and_idx"]
        if len(xi):
            out0[:, xi] = a0[:, xi] ^ b0[:, xi]
        if len(vi):
            out0[:, vi] = a0[:, vi] ^ r[:, None, :]
        if len(ai):
            tw = step["and_slot"][ai].astype(np.uint32)
            if impl in ("auto", "ref"):
                c0, tg, te = HGNP.garble_and_gates(
                    a0[:, ai], b0[:, ai], r[:, None, :],
                    np.broadcast_to(tw[None, :], (I, len(ai))),
                )
            else:
                c0, tg, te = HG.garble_and_gates(
                    jnp.asarray(a0[:, ai]),
                    jnp.asarray(b0[:, ai]),
                    jnp.asarray(r[:, None, :]),
                    jnp.broadcast_to(jnp.asarray(tw)[None, :], (I, len(ai))),
                    impl=impl,
                )
            out0[:, ai] = np.asarray(c0)
            tables[:, step["and_slot"][ai], 0] = np.asarray(tg)
            tables[:, step["and_slot"][ai], 1] = np.asarray(te)
        wire0[:, step["out"]] = out0

    out_perm = (
        (wire0[:, net.outputs, 0] & 1).astype(np.uint32)
        if len(net.outputs)
        else np.zeros((I, 0), np.uint32)
    )
    in_ids = np.concatenate([
        net.garbler_inputs, net.evaluator_inputs,
        np.array(sorted(net.const_bits), dtype=np.int64),
    ]).astype(np.int64) if W else np.array([], np.int64)
    in_zero = {int(w): jnp.asarray(wire0[:, w]) for w in in_ids}
    return GarbledCircuit(
        net=net,
        r=jnp.asarray(r),
        input_zero=in_zero,
        tables=jnp.asarray(tables),
        output_perm=jnp.asarray(out_perm),
        wire_zero=wire0 if keep_wires else None,
    )


def slice_instances(gc: GarbledCircuit, lo: int, hi: int) -> GarbledCircuit:
    """A view of instances [lo, hi) of a batch-garbled circuit.

    Sessions garble once per cached netlist for a whole preprocessing
    batch, then hand each op/request its instance band.
    """
    return GarbledCircuit(
        net=gc.net,
        r=gc.r[lo:hi],
        input_zero={w: z[lo:hi] for w, z in gc.input_zero.items()},
        tables=gc.tables[lo:hi],
        output_perm=gc.output_perm[lo:hi],
        wire_zero=None if gc.wire_zero is None else gc.wire_zero[lo:hi],
    )


def encode_inputs(gc: GarbledCircuit, wire_ids: Sequence[int], bits) -> jnp.ndarray:
    """Active labels for given wires/bits. bits: (I, n) in {0,1}.

    This is the garbler-side encode (and what OT delivers for evaluator
    inputs). Returns (I, n, 4).
    """
    bits = jnp.asarray(bits, jnp.uint32)
    zero = jnp.stack([gc.input_zero[int(w)] for w in wire_ids], axis=1)  # (I,n,4)
    return LB.maybe_xor(zero, bits, gc.r[:, None, :])


def const_labels(gc: GarbledCircuit) -> Dict[int, jnp.ndarray]:
    """Active labels of constant wires (garbler supplies with the tables)."""
    out = {}
    for w, bit in gc.net.const_bits.items():
        zero = gc.input_zero[int(w)]
        if bit:
            out[int(w)] = zero ^ gc.r
        else:
            out[int(w)] = zero
    return out


def evaluate(
    net: Netlist,
    tables: jnp.ndarray,
    active: Dict[int, jnp.ndarray],
    *,
    impl: str = "auto",
) -> jnp.ndarray:
    """Evaluator: active labels for all input+const wires -> output labels.

    active: wire -> (I, 4). Returns (I, n_out, 4).
    """
    some = next(iter(active.values()))
    I = some.shape[0]
    W = net.num_wires
    wires = np.zeros((I, W, 4), np.uint32)
    for w, lab in active.items():
        wires[:, int(w)] = np.asarray(lab)
    tables_np = np.asarray(tables)

    for step in _plan(net):
        a = wires[:, step["in0"]]
        b = wires[:, step["in1"]]
        out = np.empty_like(a)
        xi, vi, ai = step["xor_idx"], step["inv_idx"], step["and_idx"]
        if len(xi):
            out[:, xi] = a[:, xi] ^ b[:, xi]
        if len(vi):
            # free: the label passes through (semantics flip garbler-side)
            out[:, vi] = a[:, vi]
        if len(ai):
            slots = step["and_slot"][ai]
            tw = slots.astype(np.uint32)
            if impl in ("auto", "ref"):
                c = HGNP.eval_and_gates(
                    a[:, ai], b[:, ai],
                    tables_np[:, slots, 0], tables_np[:, slots, 1],
                    np.broadcast_to(tw[None, :], (I, len(ai))),
                )
            else:
                c = HG.eval_and_gates(
                    jnp.asarray(a[:, ai]),
                    jnp.asarray(b[:, ai]),
                    jnp.asarray(tables_np[:, slots, 0]),
                    jnp.asarray(tables_np[:, slots, 1]),
                    jnp.broadcast_to(jnp.asarray(tw)[None, :], (I, len(ai))),
                    impl=impl,
                )
            out[:, ai] = np.asarray(c)
        wires[:, step["out"]] = out
    return jnp.asarray(wires[:, net.outputs])


def decode_outputs(gc: GarbledCircuit, out_labels: jnp.ndarray) -> np.ndarray:
    """(I, n_out, 4) active labels -> (I, n_out) bits via output permute bits."""
    return np.asarray(LB.lsb(out_labels) ^ gc.output_perm, np.uint8)


# ---------------------------------------------------------------------------
# convenience: end-to-end two-party run (tests / engine)
# ---------------------------------------------------------------------------


def run_garbled(
    net: Netlist,
    key,
    garbler_bits,
    evaluator_bits,
    *,
    impl: str = "auto",
):
    """Full garble -> encode -> evaluate -> decode round trip.

    garbler_bits: (I, len(garbler_inputs)); evaluator_bits: (I, len(eval)).
    Returns (I, n_out) uint8 — must equal net.eval_plain(...).
    """
    garbler_bits = jnp.atleast_2d(jnp.asarray(garbler_bits, jnp.uint32))
    evaluator_bits = jnp.atleast_2d(jnp.asarray(evaluator_bits, jnp.uint32))
    I = garbler_bits.shape[0]
    gc = garble(net, key, I, impl=impl)
    active: Dict[int, jnp.ndarray] = {}
    if len(net.garbler_inputs):
        lab = encode_inputs(gc, net.garbler_inputs, garbler_bits)
        for j, w in enumerate(net.garbler_inputs):
            active[int(w)] = lab[:, j]
    if len(net.evaluator_inputs):
        lab = encode_inputs(gc, net.evaluator_inputs, evaluator_bits)  # via OT
        for j, w in enumerate(net.evaluator_inputs):
            active[int(w)] = lab[:, j]
    active.update(const_labels(gc))
    out = evaluate(net, gc.tables, active, impl=impl)
    return decode_outputs(gc, out)

"""BFV-lite: exactly the homomorphic surface the APINT protocol needs.

  * RNS ciphertext modulus Q = Π q_i (NTT primes ~30 bits, jnp uint64)
  * plaintext modulus t: prime ≡ 1 (mod 2N) -> slot batching (the plaintext
    NTT over Z_t reuses the same butterfly code), so the protocol's
    elementwise products (LayerNorm steps ⑧–⑪) are slot-wise
  * enc / dec / ct+ct / ct+pt / ct×pt — no ct×ct, no relinearization
    (the protocol never multiplies two ciphertexts)
  * coefficient-packed matvec (Cheetah-style inner-product packing) for the
    offline Linear(R) evaluation

Security knobs are research-grade (ternary secrets, CBD errors, σ≈3.2-ish);
parameters chosen so one plaintext multiply of full-range values keeps
decryption exact (tests assert it).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.ntt import ref as NTT


def ensure_x64():
    if not jax.config.read("jax_enable_x64"):
        jax.config.update("jax_enable_x64", True)


@dataclass(frozen=True)
class BFVParams:
    n: int
    qs: Tuple[int, ...]
    t: int

    @property
    def Q(self) -> int:
        out = 1
        for q in self.qs:
            out *= q
        return out

    @functools.cached_property
    def delta_rns(self) -> np.ndarray:
        d = self.Q // self.t
        return np.array([d % q for q in self.qs], dtype=np.uint64)

    @functools.cached_property
    def crt_weights(self):
        """(Q_i_hat, inv) pairs for CRT reconstruction (python ints)."""
        out = []
        for q in self.qs:
            qh = self.Q // q
            out.append((qh, pow(qh % q, q - 2, q)))
        return out


def make_params(n: int = 2048, log_q: int = 30, num_primes: int = 4,
                t_bits: int = 30) -> BFVParams:
    ensure_x64()
    qs = tuple(NTT.find_ntt_primes(log_q, num_primes, n))
    # slot batching needs t ≡ 1 (mod 2n); pick a prime disjoint from qs
    cands = NTT.find_ntt_primes(t_bits, num_primes + 2, n)
    t = next(c for c in cands if c not in qs)
    return BFVParams(n=n, qs=qs, t=t)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


def _ternary(key, n):
    return jax.random.randint(key, (n,), -1, 2, dtype=jnp.int64)


def _cbd(key, n, eta: int = 3):
    """Centered binomial error, var = eta/2."""
    bits = jax.random.bits(key, (2 * eta, n), dtype=jnp.uint32) & 1
    return (
        jnp.sum(bits[:eta].astype(jnp.int64), 0)
        - jnp.sum(bits[eta:].astype(jnp.int64), 0)
    )


def _to_rns(poly_signed: jnp.ndarray, qs) -> jnp.ndarray:
    """(n,) signed int64 -> (k, n) uint64 residues."""
    out = []
    for q in qs:
        out.append(jnp.mod(poly_signed, q).astype(jnp.uint64))
    return jnp.stack(out)


# ---------------------------------------------------------------------------
# keys / encrypt / decrypt
# ---------------------------------------------------------------------------


def keygen(params: BFVParams, key):
    ensure_x64()
    k_s, k_a, k_e = jax.random.split(key, 3)
    s = _ternary(k_s, params.n)
    a = jnp.stack(
        [
            jax.random.randint(jax.random.fold_in(k_a, i), (params.n,), 0, q)
            .astype(jnp.uint64)
            for i, q in enumerate(params.qs)
        ]
    )
    e = _cbd(k_e, params.n)
    s_rns = _to_rns(s, params.qs)
    e_rns = _to_rns(e, params.qs)
    b = []
    for i, q in enumerate(params.qs):
        as_ = NTT.negacyclic_mul(a[i], s_rns[i], q, params.n)
        b.append((q - as_ + (q - e_rns[i])) % jnp.uint64(q))
    pk = (jnp.stack(b), a)
    return s, pk


def encrypt(params: BFVParams, pk, pt_poly: jnp.ndarray, key):
    """pt_poly: (n,) uint64 in [0, t). Returns ct = (c0, c1), each (k, n)."""
    b, a = pk
    k_u, k_e1, k_e2 = jax.random.split(key, 3)
    u = _to_rns(_ternary(k_u, params.n), params.qs)
    e1 = _to_rns(_cbd(k_e1, params.n), params.qs)
    e2 = _to_rns(_cbd(k_e2, params.n), params.qs)
    d = params.delta_rns
    c0, c1 = [], []
    for i, q in enumerate(params.qs):
        qq = jnp.uint64(q)
        dm = (jnp.uint64(d[i]) * (pt_poly % jnp.uint64(q))) % qq
        bu = NTT.negacyclic_mul(b[i], u[i], q, params.n)
        au = NTT.negacyclic_mul(a[i], u[i], q, params.n)
        c0.append((bu + e1[i] + dm) % qq)
        c1.append((au + e2[i]) % qq)
    return jnp.stack(c0), jnp.stack(c1)


def decrypt(params: BFVParams, s, ct) -> np.ndarray:
    """Returns pt poly (n,) uint64 in [0, t). Exact CRT scaling."""
    c0, c1 = ct
    s_rns = _to_rns(s, params.qs)
    phase = []
    for i, q in enumerate(params.qs):
        cs = NTT.negacyclic_mul(c1[i], s_rns[i], q, params.n)
        phase.append((c0[i] + cs) % jnp.uint64(q))
    phase = np.asarray(jnp.stack(phase))  # (k, n)
    # CRT reconstruct to python ints, then m = round(t * c / Q) mod t
    Q, t = params.Q, params.t
    out = np.zeros(params.n, dtype=np.uint64)
    weights = params.crt_weights
    for j in range(params.n):
        c = 0
        for i, q in enumerate(params.qs):
            qh, inv = weights[i]
            c += int(phase[i, j]) * inv % q * qh
        c %= Q
        m = (int(c) * t + Q // 2) // Q
        out[j] = m % t
    return out


# ---------------------------------------------------------------------------
# homomorphic ops
# ---------------------------------------------------------------------------


def add_ct(params: BFVParams, ct_a, ct_b):
    qs = jnp.asarray(np.array(params.qs, dtype=np.uint64))[:, None]
    return tuple((x + y) % qs for x, y in zip(ct_a, ct_b))


def add_plain(params: BFVParams, ct, pt_poly):
    c0, c1 = ct
    d = params.delta_rns
    rows = []
    for i, q in enumerate(params.qs):
        qq = jnp.uint64(q)
        rows.append((c0[i] + (jnp.uint64(d[i]) * (pt_poly % qq)) % qq) % qq)
    return jnp.stack(rows), c1


def mul_plain(params: BFVParams, ct, pt_poly, center: bool = True):
    """ct × pt (negacyclic poly product per RNS prime, NTT-based).

    ``center`` lifts plaintext residues to [−t/2, t/2) before reducing mod
    each q_i: same result mod t, but noise grows with the *signed* magnitude
    (negative fixed-point coefficients would otherwise look like ~t).
    """
    c0, c1 = ct
    if center:
        v = np.asarray(pt_poly, np.uint64).astype(np.int64)
        v = np.where(v > params.t // 2, v - params.t, v)
    else:
        v = np.asarray(pt_poly, np.uint64).astype(np.int64)
    o0, o1 = [], []
    for i, q in enumerate(params.qs):
        p = jnp.asarray(np.mod(v, q).astype(np.uint64))
        o0.append(NTT.negacyclic_mul(c0[i], p, q, params.n))
        o1.append(NTT.negacyclic_mul(c1[i], p, q, params.n))
    return jnp.stack(o0), jnp.stack(o1)


# ---------------------------------------------------------------------------
# plaintext encodings
# ---------------------------------------------------------------------------


def encode_slots(params: BFVParams, values: np.ndarray) -> jnp.ndarray:
    """values (n,) mod t -> poly whose slot products are elementwise."""
    v = jnp.asarray(np.asarray(values, dtype=np.uint64) % params.t)
    return NTT.ntt_inverse(v, params.t, params.n)


def decode_slots(params: BFVParams, poly: np.ndarray) -> np.ndarray:
    return np.asarray(
        NTT.ntt_forward(jnp.asarray(poly, jnp.uint64), params.t, params.n)
    )


def encode_coeffs(params: BFVParams, values: np.ndarray) -> jnp.ndarray:
    v = np.zeros(params.n, dtype=np.uint64)
    vv = np.asarray(values, dtype=np.int64) % params.t
    v[: len(vv)] = vv.astype(np.uint64)
    return jnp.asarray(v)


# ---------------------------------------------------------------------------
# coefficient-packed matvec (Cheetah-style): offline Linear(R)
# ---------------------------------------------------------------------------


def matvec_plan(params: BFVParams, d_in: int, d_out: int):
    per_ct = max(1, params.n // d_in)
    blocks = math.ceil(d_out / per_ct)
    return per_ct, blocks


def he_matvec(params: BFVParams, ct_r, W: np.ndarray) -> List:
    """Enc(r) (coeff-packed, len d_in) × W (d_out, d_in) ->
    list of cts whose coefficient (i·d_in + d_in −1) holds ⟨W_row, r⟩."""
    d_out, d_in = W.shape
    per_ct, blocks = matvec_plan(params, d_in, d_out)
    outs = []
    for bidx in range(blocks):
        pt = np.zeros(params.n, dtype=np.int64)
        for slot in range(per_ct):
            row = bidx * per_ct + slot
            if row >= d_out:
                break
            # reversed row at offset slot*d_in: product coeff at
            # slot*d_in + (d_in-1) = <W_row, r>
            for j in range(d_in):
                pt[slot * d_in + (d_in - 1 - j)] += int(W[row, j])
        pt_poly = jnp.asarray(pt % params.t, jnp.uint64)
        outs.append(mul_plain(params, ct_r, pt_poly))
    return outs


def he_matvec_extract(params: BFVParams, pt_polys: Sequence[np.ndarray],
                      d_in: int, d_out: int) -> np.ndarray:
    per_ct, _ = matvec_plan(params, d_in, d_out)
    vals = []
    for poly in pt_polys:
        for slot in range(per_ct):
            if len(vals) >= d_out:
                break
            vals.append(int(poly[slot * d_in + d_in - 1]))
    return np.array(vals[:d_out], dtype=np.uint64)

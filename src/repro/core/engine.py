"""Private transformer inference on top of the PiT protocol.

BERT-style post-norm encoder stack (the paper's evaluation model), with
every layer routed per the APINT recipe:

  linear layers     -> DELPHI split (HE offline, standard matmul online)
  QKᵀ / PV          -> Beaver matmul (private × private)
  softmax / GeLU    -> GC (share-reconstruct → i-BERT/LUT circuit → remask)
  truncation        -> tiny GC (exact deferred rescale — keeps all scales
                       at `frac` across residuals)
  LayerNorm         -> full-GC baseline or the APINT Fig. 4 offload

The engine also produces a float reference (`forward_float`) for the
accuracy-parity analog of Fig. 8(a).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.config import ModelConfig, PrivacyConfig
from repro.core import secret_sharing as SS
from repro.core.circuits import arith
from repro.core.protocol import PiTProtocol


@dataclass
class BertWeights:
    """Per-layer float weights (numpy)."""

    wq: np.ndarray
    wk: np.ndarray
    wv: np.ndarray
    wo: np.ndarray
    w1: np.ndarray
    w2: np.ndarray
    ln1_g: np.ndarray
    ln1_b: np.ndarray
    ln2_g: np.ndarray
    ln2_b: np.ndarray


def random_weights(rng, d: int, d_ff: int, layers: int) -> List[BertWeights]:
    def w(shape, scale):
        return rng.normal(0, scale, shape)

    out = []
    s = 1.0 / math.sqrt(d)
    for _ in range(layers):
        out.append(
            BertWeights(
                wq=w((d, d), s), wk=w((d, d), s), wv=w((d, d), s),
                wo=w((d, d), s),
                w1=w((d_ff, d), s), w2=w((d, d_ff), 1.0 / math.sqrt(d_ff)),
                ln1_g=rng.normal(1, 0.05, d), ln1_b=rng.normal(0, 0.05, d),
                ln2_g=rng.normal(1, 0.05, d), ln2_b=rng.normal(0, 0.05, d),
            )
        )
    return out


class PrivateTransformer:
    def __init__(self, pcfg: PrivacyConfig, d: int, heads: int, d_ff: int,
                 weights: List[BertWeights], *, seed: int = 0,
                 activation: str = "gelu", impl: str = "ref"):
        assert d % heads == 0
        self.p = PiTProtocol(pcfg, seed=seed, impl=impl)
        self.d, self.h, self.hd, self.d_ff = d, heads, d // heads, d_ff
        self.weights = weights
        self.activation = activation
        self.scale_q = 1.0 / math.sqrt(self.hd)

    # ------------------------------------------------------------------
    def compile_session(self, seq_len: int, *, seed: int = 0,
                        impl: Optional[str] = None, wire_version: int = 1,
                        compression: bool = True):
        """Offline/online serving API: trace this model into a
        ``PiTSession`` (see ``repro.core.session``) for one request bucket.

        ``session.preprocess(n)`` then runs all garbling/HE/triple work up
        front; each ``session.run(x, bundle)`` is online-phase only.
        ``wire_version`` selects which wire revision the session's
        channel meter models (the net layer's byte oracle).
        """
        from repro.core import session as PS

        return PS.compile(self, shape=(seq_len, self.d), seed=seed,
                          impl=impl, wire_version=wire_version,
                          compression=compression)

    def _linear_t(self, W, xc, xs):
        """(S, d_in) shares × W (d_out, d_in) -> shares at frac (trunc'd)."""
        yc, ys = self.p.linear(W, xc, xs)
        return self.p.trunc(yc, ys, 2 * self.p.frac)

    # ------------------------------------------------------------------
    def forward_private(self, x: np.ndarray) -> np.ndarray:
        """x: (S, d) client input (float). Returns (S, d) revealed output.

        Eager compatibility path: offline and online legs interleave per
        layer. Production serving should go through ``compile_session`` →
        ``preprocess`` → ``run`` so offline work pools across requests.
        """
        p = self.p
        f = p.frac
        S = x.shape[0]
        xc, xs = p.share_input(x)
        for W in self.weights:
            # ---- attention ------------------------------------------------
            qc, qs = self._linear_t(W.wq * self.scale_q, xc, xs)
            kc, ks = self._linear_t(W.wk, xc, xs)
            vc, vs = self._linear_t(W.wv, xc, xs)
            ctx_c = np.zeros((S, self.d), np.uint64)
            ctx_s = np.zeros((S, self.d), np.uint64)
            for h in range(self.h):
                sl = slice(h * self.hd, (h + 1) * self.hd)
                sc_, ss_ = p.matmul_private(
                    qc[:, sl], qs[:, sl],
                    kc[:, sl].T.copy(), ks[:, sl].T.copy(),
                )  # (S, S) at 2f
                pc_, ps_ = p.softmax_rows(sc_, ss_, S, in_scale=2 * f)
                oc_, os_ = p.matmul_private(pc_, ps_, vc[:, sl], vs[:, sl])
                oc_, os_ = p.trunc(oc_, os_, 2 * f)
                ctx_c[:, sl] = oc_
                ctx_s[:, sl] = os_
            ac, as_ = self._linear_t(W.wo, ctx_c, ctx_s)
            # residual + LN1 (post-norm)
            hc = SS.add_mod(xc, ac, p.t)
            hs = SS.add_mod(xs, as_, p.t)
            xc, xs = p.layernorm(hc, hs, W.ln1_g, W.ln1_b, in_scale=f)
            # ---- MLP -------------------------------------------------------
            h1c, h1s = p.linear(W.w1, xc, xs)  # (S, d_ff) at 2f
            gc_, gs_ = p.activation(self.activation, h1c, h1s, in_scale=2 * f)
            h2c, h2s = self._linear_t(W.w2, gc_, gs_)
            hc = SS.add_mod(xc, h2c, p.t)
            hs = SS.add_mod(xs, h2s, p.t)
            xc, xs = p.layernorm(hc, hs, W.ln2_g, W.ln2_b, in_scale=f)
        return p.reveal(xc, xs)

    # ------------------------------------------------------------------
    def forward_float(self, x: np.ndarray) -> np.ndarray:
        from repro.core.circuits.nonlinear import _gelu

        def act(v):
            if self.activation == "gelu":
                return np.vectorize(lambda z: _gelu(max(min(z, 4), -4)))(v)
            vv = np.clip(v, -6, 6)
            return vv / (1 + np.exp(-vv))

        def ln(v, g, b):
            mu = v.mean(-1, keepdims=True)
            sd = np.sqrt(((v - mu) ** 2).mean(-1, keepdims=True) + 1e-9)
            return (v - mu) / sd * g + b

        for W in self.weights:
            q = x @ (W.wq * self.scale_q).T
            k = x @ W.wk.T
            v = x @ W.wv.T
            ctx = np.zeros_like(x)
            for h in range(self.h):
                sl = slice(h * self.hd, (h + 1) * self.hd)
                s = q[:, sl] @ k[:, sl].T
                e = np.exp(s - s.max(-1, keepdims=True))
                pmat = e / e.sum(-1, keepdims=True)
                ctx[:, sl] = pmat @ v[:, sl]
            x = ln(x + ctx @ W.wo.T, W.ln1_g, W.ln1_b)
            hdn = act(x @ W.w1.T)
            x = ln(x + hdn @ W.w2.T, W.ln2_g, W.ln2_b)
        return x

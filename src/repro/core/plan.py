"""Declarative op-graph plans for private transformer inference.

``compile_plan(model, seq_len)`` traces the exact operation sequence of
``PrivateTransformer.forward_private`` into a flat, declarative program
over a small register file of share pairs. Op kinds mirror the protocol
surface one-to-one:

  linear         — DELPHI split matmul against a server weight
  beaver_matmul  — private×private matmul (QKᵀ, PV)
  gc_apply       — garbled nonlinear circuit (softmax rows, GeLU/SiLU)
  layernorm      — residual add + LayerNorm (full-GC or APINT offload)
  trunc          — exact GC rescale back to `frac`

Shapes and scales are resolved at compile time for one request bucket
(a fixed sequence length), so the offline phase can execute every op's
preprocessing — garbling, HE mask products, Beaver triples — from the
plan alone, with no input in sight. ``core/session.py`` interprets plans.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class RegRef:
    """A read/write site in the register file of (client, server) shares.

    ``cols`` selects a column band [lo, hi) — the per-head slices;
    ``transpose`` reads the transposed matrix (K in QKᵀ).
    """

    reg: str
    cols: Optional[Tuple[int, int]] = None
    transpose: bool = False


@dataclass(frozen=True)
class OpSpec:
    """One protocol-level operation with fully resolved shapes/scales."""

    kind: str  # linear | beaver_matmul | gc_apply | layernorm | trunc
    name: str  # unique within a plan, e.g. "L0.h1.softmax"
    reads: Tuple[RegRef, ...]
    write: RegRef
    shape: Tuple[int, int]  # output shape
    in_scale: int
    out_scale: int
    attrs: Dict[str, object] = field(default_factory=dict)


GC_KINDS = ("gc_apply", "trunc", "layernorm")


@dataclass
class Plan:
    """A compiled program for one (seq_len, model) request bucket."""

    seq_len: int
    d: int
    heads: int
    head_dim: int
    d_ff: int
    n_layers: int
    activation: str
    frac: int
    layernorm_offload: bool
    ops: Tuple[OpSpec, ...] = ()
    reg_shapes: Dict[str, Tuple[int, int]] = field(default_factory=dict)
    output_reg: str = "x"

    @property
    def plan_id(self) -> str:
        return (f"bert(S={self.seq_len},d={self.d},h={self.heads},"
                f"dff={self.d_ff},L={self.n_layers},act={self.activation},"
                f"f={self.frac},ln_off={self.layernorm_offload})")

    def read_shape(self, ref: RegRef) -> Tuple[int, int]:
        r, c = self.reg_shapes[ref.reg]
        if ref.cols is not None:
            c = ref.cols[1] - ref.cols[0]
        return (c, r) if ref.transpose else (r, c)

    def gc_instances(self, op: OpSpec) -> int:
        """Rows fed to the garbled circuit backing ``op`` (0 if none)."""
        rows, cols = op.shape
        if op.kind == "trunc":
            return rows * cols  # elementwise, flattened
        if op.kind == "gc_apply":
            circ = op.attrs.get("circuit")
            return rows if circ == "softmax" else rows * cols
        if op.kind == "layernorm":
            return rows
        return 0

    def gc_ops(self) -> List[OpSpec]:
        return [op for op in self.ops if op.kind in GC_KINDS]

    def coarse_schedule(self, num_cores: int) -> List[List[str]]:
        """APINT coarse-grained scheduling hook: map the plan's independent
        GC unit operations onto accelerator cores round-robin (§3.3.1)."""
        from repro.sched.schedulers import coarse_grained_partition

        names = [op.name for op in self.gc_ops()]
        assign = coarse_grained_partition(names, num_cores)
        return [[names[i] for i in core] for core in assign]


def plan_to_spec(plan: Plan) -> Dict[str, object]:
    """Serialize a :class:`Plan` to plain JSON-safe data.

    Plans are pure shape/scale programs — no weights, no protocol state —
    so the two-party runtime (:mod:`repro.net`) ships them in the session
    handshake and the evaluator reconstructs an identical walk with
    :func:`plan_from_spec`.
    """

    def ref(r: RegRef) -> Dict[str, object]:
        return {"reg": r.reg,
                "cols": list(r.cols) if r.cols is not None else None,
                "transpose": r.transpose}

    return {
        "seq_len": plan.seq_len, "d": plan.d, "heads": plan.heads,
        "head_dim": plan.head_dim, "d_ff": plan.d_ff,
        "n_layers": plan.n_layers, "activation": plan.activation,
        "frac": plan.frac, "layernorm_offload": plan.layernorm_offload,
        "output_reg": plan.output_reg,
        "reg_shapes": {k: list(v) for k, v in plan.reg_shapes.items()},
        "ops": [
            {"kind": op.kind, "name": op.name,
             "reads": [ref(r) for r in op.reads], "write": ref(op.write),
             "shape": list(op.shape), "in_scale": op.in_scale,
             "out_scale": op.out_scale, "attrs": dict(op.attrs)}
            for op in plan.ops
        ],
    }


def plan_from_spec(spec: Dict[str, object]) -> Plan:
    """Inverse of :func:`plan_to_spec` (round-trips to an equal walk)."""

    def ref(d) -> RegRef:
        return RegRef(d["reg"],
                      tuple(d["cols"]) if d["cols"] is not None else None,
                      bool(d["transpose"]))

    plan = Plan(
        seq_len=int(spec["seq_len"]), d=int(spec["d"]),
        heads=int(spec["heads"]), head_dim=int(spec["head_dim"]),
        d_ff=int(spec["d_ff"]), n_layers=int(spec["n_layers"]),
        activation=str(spec["activation"]), frac=int(spec["frac"]),
        layernorm_offload=bool(spec["layernorm_offload"]),
        output_reg=str(spec["output_reg"]),
        reg_shapes={k: tuple(v) for k, v in spec["reg_shapes"].items()},
    )
    plan.ops = tuple(
        OpSpec(o["kind"], o["name"], tuple(ref(r) for r in o["reads"]),
               ref(o["write"]), tuple(o["shape"]), int(o["in_scale"]),
               int(o["out_scale"]), dict(o["attrs"]))
        for o in spec["ops"]
    )
    return plan


def compile_plan(model, seq_len: int) -> Plan:
    """Trace ``model.forward_private`` (a ``PrivateTransformer``) at a fixed
    sequence length into a :class:`Plan`.

    The emitted op order is exactly the order the legacy eager path
    executes, so a session run replays the same protocol transcript.
    """
    S = int(seq_len)
    d, h, hd, dff = model.d, model.h, model.hd, model.d_ff
    f = model.p.frac
    plan = Plan(
        seq_len=S, d=d, heads=h, head_dim=hd, d_ff=dff,
        n_layers=len(model.weights), activation=model.activation,
        frac=f, layernorm_offload=model.p.pcfg.layernorm_offload,
        reg_shapes={
            "x": (S, d), "q": (S, d), "k": (S, d), "v": (S, d),
            "att": (S, S), "o": (S, hd), "ctx": (S, d), "a": (S, d),
            "h1": (S, dff), "g": (S, dff), "h2": (S, d),
        },
    )
    ops: List[OpSpec] = []

    def lin(name, layer, wkey, src, dst, shape, wscale=1.0):
        ops.append(OpSpec(
            "linear", name, (RegRef(src),), RegRef(dst), shape, f, 2 * f,
            {"layer": layer, "weight": wkey, "wscale": wscale},
        ))

    def trunc(name, src, dst, shape, cols=None):
        ops.append(OpSpec(
            "trunc", name, (RegRef(src),), RegRef(dst, cols=cols),
            shape, 2 * f, f,
        ))

    for l in range(len(model.weights)):
        # ---- attention ------------------------------------------------
        lin(f"L{l}.q", l, "wq", "x", "q", (S, d), wscale=model.scale_q)
        trunc(f"L{l}.q.t", "q", "q", (S, d))
        lin(f"L{l}.k", l, "wk", "x", "k", (S, d))
        trunc(f"L{l}.k.t", "k", "k", (S, d))
        lin(f"L{l}.v", l, "wv", "x", "v", (S, d))
        trunc(f"L{l}.v.t", "v", "v", (S, d))
        for hh in range(h):
            sl = (hh * hd, (hh + 1) * hd)
            ops.append(OpSpec(
                "beaver_matmul", f"L{l}.h{hh}.qk",
                (RegRef("q", cols=sl), RegRef("k", cols=sl, transpose=True)),
                RegRef("att"), (S, S), f, 2 * f,
            ))
            ops.append(OpSpec(
                "gc_apply", f"L{l}.h{hh}.softmax",
                (RegRef("att"),), RegRef("att"), (S, S), 2 * f, f,
                {"circuit": "softmax", "row_len": S},
            ))
            ops.append(OpSpec(
                "beaver_matmul", f"L{l}.h{hh}.pv",
                (RegRef("att"), RegRef("v", cols=sl)),
                RegRef("o"), (S, hd), f, 2 * f,
            ))
            trunc(f"L{l}.h{hh}.o.t", "o", "ctx", (S, hd), cols=sl)
        lin(f"L{l}.wo", l, "wo", "ctx", "a", (S, d))
        trunc(f"L{l}.wo.t", "a", "a", (S, d))
        # residual + LN1 (post-norm); reads are summed before the LN
        ops.append(OpSpec(
            "layernorm", f"L{l}.ln1", (RegRef("x"), RegRef("a")),
            RegRef("x"), (S, d), f, f, {"layer": l, "which": "ln1"},
        ))
        # ---- MLP ------------------------------------------------------
        lin(f"L{l}.w1", l, "w1", "x", "h1", (S, dff))
        ops.append(OpSpec(
            "gc_apply", f"L{l}.act", (RegRef("h1"),), RegRef("g"),
            (S, dff), 2 * f, f, {"circuit": model.activation},
        ))
        lin(f"L{l}.w2", l, "w2", "g", "h2", (S, d))
        trunc(f"L{l}.w2.t", "h2", "h2", (S, d))
        ops.append(OpSpec(
            "layernorm", f"L{l}.ln2", (RegRef("x"), RegRef("h2")),
            RegRef("x"), (S, d), f, f, {"layer": l, "which": "ln2"},
        ))

    plan.ops = tuple(ops)
    return plan

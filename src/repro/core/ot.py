"""Simulated oblivious transfer + channel accounting.

Honest-but-curious simulation: both endpoints live in-process, but every
protocol message is metered so the benchmarks reproduce the paper's
communication columns. Cost model follows IKNP OT extension [11]: κ=128
bits per extended OT plus the chosen 128-bit label.

The byte constants and :func:`choose_labels` are shared with the real
two-party runtime (:mod:`repro.net`), which frames OT batches on the wire
at exactly the metered sizes — the in-process meter is the oracle the net
layer's ledger is asserted against.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np


@dataclass
class Channel:
    client_to_server: int = 0
    server_to_client: int = 0
    rounds: int = 0
    by_tag: Dict[str, int] = field(default_factory=dict)

    def c2s(self, nbytes: int, tag: str = ""):
        self.client_to_server += int(nbytes)
        self.rounds += 1
        if tag:
            self.by_tag[tag] = self.by_tag.get(tag, 0) + int(nbytes)

    def s2c(self, nbytes: int, tag: str = ""):
        self.server_to_client += int(nbytes)
        self.rounds += 1
        if tag:
            self.by_tag[tag] = self.by_tag.get(tag, 0) + int(nbytes)

    @property
    def total(self) -> int:
        return self.client_to_server + self.server_to_client

    def time_s(self, bandwidth_bps: float = 9.6e9, latency_s: float = 0.165e-3,
               max_rounds: int = 0) -> float:
        """LAN model from the paper's setup (9.6 Gb/s, 0.165 ms)."""
        rounds = max_rounds if max_rounds else self.rounds
        return self.total * 8 / bandwidth_bps + rounds * latency_s


OT_MSG_BYTES = 16  # receiver's per-transfer IKNP column message
OT_BYTES_PER_TRANSFER = 2 * 16 + 16  # IKNP: 2 masked labels + correction


def ot_request_bytes(n: int) -> int:
    """Bytes of the receiver's choice-derived messages for ``n`` OTs."""
    return n * OT_MSG_BYTES


def ot_response_bytes(n: int) -> int:
    """Bytes of the sender's masked label pairs for ``n`` OTs."""
    return n * OT_BYTES_PER_TRANSFER


def choose_labels(zero_labels, r, choice_bits):
    """The OT functionality itself: labels for the receiver's choice bits.

    zero_labels: (..., 4) uint32; r: broadcastable; choice_bits (...,).
    Pure label algebra (no metering) — shared by the in-process simulation
    and the garbler side of the wire runtime.
    """
    import jax.numpy as jnp

    from repro.core import labels as LB

    bits = jnp.asarray(choice_bits, jnp.uint32)
    return LB.maybe_xor(zero_labels, bits, r)


def ot_labels(channel: Channel, zero_labels, r, choice_bits, tag="ot"):
    """Evaluator obtains labels for its choice bits; garbler learns nothing."""
    n = int(np.prod(choice_bits.shape))
    channel.c2s(ot_request_bytes(n), tag)  # receiver's OT messages
    channel.s2c(ot_response_bytes(n), tag)
    return choose_labels(zero_labels, r, choice_bits)

"""Simulated oblivious transfer + channel accounting.

Honest-but-curious simulation: both endpoints live in-process, but every
protocol message is metered so the benchmarks reproduce the paper's
communication columns. Cost model follows IKNP OT extension [11]: κ=128
bits per extended OT plus the chosen 128-bit label.

The byte constants and :func:`choose_labels` are shared with the real
two-party runtime (:mod:`repro.net`), which frames OT batches on the wire
at exactly the metered sizes — the in-process meter is the oracle the net
layer's ledger is asserted against.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np


@dataclass
class Channel:
    client_to_server: int = 0
    server_to_client: int = 0
    rounds: int = 0
    by_tag: Dict[str, int] = field(default_factory=dict)

    def c2s(self, nbytes: int, tag: str = ""):
        self.client_to_server += int(nbytes)
        self.rounds += 1
        if tag:
            self.by_tag[tag] = self.by_tag.get(tag, 0) + int(nbytes)

    def s2c(self, nbytes: int, tag: str = ""):
        self.server_to_client += int(nbytes)
        self.rounds += 1
        if tag:
            self.by_tag[tag] = self.by_tag.get(tag, 0) + int(nbytes)

    @property
    def total(self) -> int:
        return self.client_to_server + self.server_to_client

    def time_s(self, bandwidth_bps: float = 9.6e9, latency_s: float = 0.165e-3,
               max_rounds: int = 0) -> float:
        """LAN model from the paper's setup (9.6 Gb/s, 0.165 ms)."""
        rounds = max_rounds if max_rounds else self.rounds
        return self.total * 8 / bandwidth_bps + rounds * latency_s


OT_MSG_BYTES = 16  # receiver's per-transfer IKNP column message
OT_BYTES_PER_TRANSFER = 2 * 16 + 16  # IKNP: 2 masked labels + correction


def ot_request_bytes(n: int) -> int:
    """Bytes of the receiver's choice-derived messages for ``n`` OTs."""
    return n * OT_MSG_BYTES


def ot_response_bytes(n: int) -> int:
    """Bytes of the sender's masked label pairs for ``n`` OTs."""
    return n * OT_BYTES_PER_TRANSFER


def choose_labels(zero_labels, r, choice_bits):
    """The OT functionality itself: labels for the receiver's choice bits.

    zero_labels: (..., 4) uint32; r: broadcastable; choice_bits (...,).
    Pure label algebra (no metering) — shared by the in-process simulation
    and the garbler side of the wire runtime.
    """
    import jax.numpy as jnp

    from repro.core import labels as LB

    bits = jnp.asarray(choice_bits, jnp.uint32)
    return LB.maybe_xor(zero_labels, bits, r)


def ot_labels(channel: Channel, zero_labels, r, choice_bits, tag="ot"):
    """Evaluator obtains labels for its choice bits; garbler learns nothing."""
    n = int(np.prod(choice_bits.shape))
    channel.c2s(ot_request_bytes(n), tag)  # receiver's OT messages
    channel.s2c(ot_response_bytes(n), tag)
    return choose_labels(zero_labels, r, choice_bits)


# ---------------------------------------------------------------------------
# IKNP OT extension (v2 wire format): real base OT + extension matrix
# ---------------------------------------------------------------------------
#
# Roles follow the GC protocol: the evaluator endpoint is the OT
# *receiver* (choice bits = its masked-input bits), the garbler the OT
# *sender* — so in IKNP's base phase the roles reverse: the receiver acts
# as base-OT sender of κ=128 seed pairs, the garbler as base-OT receiver
# with a secret selection string s.
#
# Base OTs are Chou–Orlandi over the RFC 3526 2048-bit MODP group
# (g = 2); H is SHA-256 truncated to a 16-byte PRG seed. The extension
# PRG is counter-mode Philox, the correlation-robust hash is the repo's
# ARX label hash — the same primitive stack as garbling itself.
#
# Wire cost per batch of n OTs: the column matrix u is exactly
# κ bits = 16 B per OT (receiver→sender, same as the old sim-OT request)
# and the masked pair (y0, y1) is 32 B per OT (sender→receiver, down
# from the sim's 48 B block) — plus the one-time base exchange below.

KAPPA = 128  # IKNP security parameter / number of base OTs
BASE_OT_MSG_BYTES = 256  # one 2048-bit group element
BASE_OT_A_BYTES = BASE_OT_MSG_BYTES
BASE_OT_B_BYTES = KAPPA * BASE_OT_MSG_BYTES
OT_V2_PAIR_BYTES = 2 * 16  # two masked 128-bit labels

# RFC 3526, group 14 (2048-bit MODP), generator 2.
_MODP_P = int(
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD129024E088A67CC74"
    "020BBEA63B139B22514A08798E3404DDEF9519B3CD3A431B302B0A6DF25F1437"
    "4FE1356D6D51C245E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED"
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3DC2007CB8A163BF05"
    "98DA48361C55D39A69163FA8FD24CF5F83655D23DCA3AD961C62F356208552BB"
    "9ED529077096966D670C354E4ABC9804F1746C08CA18217C32905E462E36CE3B"
    "E39E772C180E86039B2783A2EC07A28FB5C55DF06F4C52C9DE2BCBF695581718"
    "3995497CEA956AE515D2261898FA051015728E5A8AACAA68FFFFFFFFFFFFFFFF",
    16,
)
_MODP_G = 2


def _h_group(x: int) -> bytes:
    import hashlib

    return hashlib.sha256(x.to_bytes(BASE_OT_MSG_BYTES, "big")).digest()[:16]


# Short-exponent DH: 2·κ = 256-bit exponents in the 2048-bit group give
# the κ=128 target (Pollard-λ on an ℓ-bit exponent costs 2^{ℓ/2}), and
# cut the pure-python modexp cost ~8x versus full-size exponents.
_EXP_BYTES = 32


class _FixedBase:
    """Fixed-base modexp: precompute base^(2^i) once, then each random
    exponent costs ~ℓ/2 modmuls instead of a full square-and-multiply
    (the sender exponentiates the same two bases κ times)."""

    def __init__(self, base: int, p: int, bits: int = 8 * _EXP_BYTES):
        self.p = p
        pows = [base % p]
        for _ in range(bits - 1):
            pows.append(pows[-1] * pows[-1] % p)
        self.pows = pows

    def pow(self, e: int) -> int:
        acc, p, i = 1, self.p, 0
        while e:
            if e & 1:
                acc = acc * self.pows[i] % p
            e >>= 1
            i += 1
        return acc


def _prg_bits(seed: bytes, word_offset: int, n: int) -> np.ndarray:
    """n pseudorandom bits (uint8) from a 16-byte seed at a 64-bit-word
    offset (one Philox counter block = four 64-bit words)."""
    nw = -(-n // 64)
    bg = np.random.Philox(key=int.from_bytes(seed, "little"))
    if word_offset:
        bg.advance(word_offset // 4)
    skip = word_offset % 4
    words = np.random.Generator(bg).integers(
        0, 1 << 64, size=nw + skip, dtype=np.uint64, endpoint=False)
    return np.unpackbits(words[skip:].view(np.uint8),
                         bitorder="little")[:n]


def _pack_cols(bits: np.ndarray) -> np.ndarray:
    """Bit matrix (KAPPA, n) -> per-OT 128-bit columns (n, 4) uint32."""
    cols = np.packbits(np.ascontiguousarray(bits.T), axis=1,
                       bitorder="little")
    return np.ascontiguousarray(cols).view(np.uint32)


def _unpack_cols(cols: np.ndarray) -> np.ndarray:
    """(n, 4) uint32 columns -> bit matrix (KAPPA, n) uint8."""
    rows = np.unpackbits(np.ascontiguousarray(cols).view(np.uint8),
                         axis=1, bitorder="little")
    return np.ascontiguousarray(rows.T)


def _crh(blocks: np.ndarray, tweak0: int) -> np.ndarray:
    """Correlation-robust hash of (n, 4) uint32 blocks (ARX label hash)."""
    from repro.kernels.halfgate import ref_np as HGNP

    n = blocks.shape[0]
    tweaks = (np.arange(tweak0, tweak0 + n) & 0xFFFFFFFF).astype(np.uint32)
    return np.asarray(HGNP.hash_labels(blocks, tweaks), np.uint32)


class IknpReceiver:
    """Evaluator side: base-OT sender, extension-matrix producer."""

    def __init__(self, rng: np.random.Generator):
        self._a = int.from_bytes(rng.bytes(_EXP_BYTES), "little") | 1
        self._k0 = self._k1 = None
        self._word_off = 0
        self._tweak = 0

    def base_msg_a(self) -> bytes:
        return pow(_MODP_G, self._a, _MODP_P).to_bytes(
            BASE_OT_MSG_BYTES, "big")

    def absorb_base_b(self, data: bytes) -> None:
        a, p = self._a, _MODP_P
        A = pow(_MODP_G, a, p)
        # k1 = (B/A)^a = B^a · A^{-a}: one modmul per OT on top of the
        # shared B^a, instead of a second full modexp
        A_neg_a = pow(pow(A, p - 2, p), a, p)
        k0, k1 = [], []
        for i in range(KAPPA):
            B = int.from_bytes(
                data[i * BASE_OT_MSG_BYTES: (i + 1) * BASE_OT_MSG_BYTES],
                "big")
            Ba = pow(B, a, p)
            k0.append(_h_group(Ba))
            k1.append(_h_group(Ba * A_neg_a % p))
        self._k0, self._k1 = k0, k1

    def extend(self, choice_bits: np.ndarray):
        """Choice bits -> (u column matrix bytes, private t columns)."""
        x = np.asarray(choice_bits, np.uint8).reshape(-1)
        n = x.size
        t_rows = np.stack([_prg_bits(k, self._word_off, n)
                           for k in self._k0])
        v_rows = np.stack([_prg_bits(k, self._word_off, n)
                           for k in self._k1])
        self._word_off += -(-n // 64)
        u_rows = t_rows ^ v_rows ^ x[None, :]
        return _pack_cols(u_rows).tobytes(), _pack_cols(t_rows)

    def receive(self, y_data: bytes, choice_bits: np.ndarray,
                t_cols: np.ndarray) -> np.ndarray:
        """Unmask the chosen labels: flat (n, 4) uint32."""
        x = np.asarray(choice_bits, np.uint8).reshape(-1)
        n = x.size
        pairs = np.frombuffer(y_data, np.uint32).reshape(n, 2, 4)
        mask = _crh(t_cols, self._tweak)
        self._tweak += n
        return pairs[np.arange(n), x.astype(np.int64)] ^ mask


class IknpSender:
    """Garbler side: base-OT receiver (secret s), masked-pair producer."""

    def __init__(self, rng: np.random.Generator):
        self._s_bits = np.unpackbits(
            np.frombuffer(rng.bytes(KAPPA // 8), np.uint8),
            bitorder="little")
        self._b = [int.from_bytes(rng.bytes(_EXP_BYTES), "little") | 1
                   for _ in range(KAPPA)]
        self._A = None
        self._ks = None
        self._s_block = None
        self._word_off = 0
        self._tweak = 0

    def base_msg_b(self, a_data: bytes) -> bytes:
        p = _MODP_P
        self._A = int.from_bytes(a_data, "big")
        # both bases are fixed across the κ exponentiations — amortize
        # the squaring chains once
        fb_g = _FixedBase(_MODP_G, p)
        fb_a = _FixedBase(self._A, p)
        out = bytearray()
        ks = []
        for i in range(KAPPA):
            B = fb_g.pow(self._b[i])
            if self._s_bits[i]:
                B = B * self._A % p
            out += B.to_bytes(BASE_OT_MSG_BYTES, "big")
            ks.append(_h_group(fb_a.pow(self._b[i])))
        self._ks = ks
        self._s_block = _pack_cols(
            self._s_bits[:, None].astype(np.uint8)).reshape(4)
        return bytes(out)

    def respond(self, u_data: bytes, n: int, zero_labels,
                r) -> bytes:
        """u matrix + the (zero, one) label pairs -> masked pairs bytes.

        ``zero_labels``: (..., 4) with n leading elements; ``r``
        broadcastable FreeXOR offset. Output: n × (y0, y1) 32-byte pairs.
        """
        u_rows = _unpack_cols(np.frombuffer(u_data, np.uint32).reshape(n, 4))
        g_rows = np.stack([
            _prg_bits(self._ks[i], self._word_off, n) for i in range(KAPPA)])
        self._word_off += -(-n // 64)
        q_rows = g_rows ^ (self._s_bits[:, None] & u_rows)
        q_cols = _pack_cols(q_rows)
        z = np.asarray(zero_labels, np.uint32)
        one = z ^ np.broadcast_to(np.asarray(r, np.uint32), z.shape)
        lab0 = z.reshape(n, 4)
        lab1 = one.reshape(n, 4)
        y0 = lab0 ^ _crh(q_cols, self._tweak)
        y1 = lab1 ^ _crh(q_cols ^ self._s_block[None, :], self._tweak)
        self._tweak += n
        out = np.empty((n, 2, 4), np.uint32)
        out[:, 0] = y0
        out[:, 1] = y1
        return out.tobytes()


def ot_v2_request_bytes(n: int) -> int:
    """Receiver→sender extension-matrix bytes (κ bits per OT)."""
    return n * OT_MSG_BYTES


def ot_v2_response_bytes(n: int) -> int:
    """Sender→receiver masked-pair bytes (two 128-bit labels per OT)."""
    return n * OT_V2_PAIR_BYTES

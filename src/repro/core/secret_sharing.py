"""Additive secret sharing over Z_t (prime t, matching BFV slot batching)
with fixed-point encoding and Beaver-triple private×private matmul.

Fixed point: value v -> round(v·2^frac) mod t (negatives wrap). Products
carry scale 2^frac·2^frac; truncation is deferred into the GC input stage
(exact, free rewiring) — see circuits/shares.py.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


def encode_fx(x: np.ndarray, frac: int, t: int) -> np.ndarray:
    v = np.round(np.asarray(x, np.float64) * (1 << frac)).astype(np.int64)
    return np.mod(v, t).astype(np.uint64)


def decode_fx(v: np.ndarray, frac: int, t: int, scale_bits: Optional[int] = None) -> np.ndarray:
    v = np.asarray(v, np.uint64).astype(np.int64)
    centered = np.where(v > t // 2, v - t, v)
    return centered.astype(np.float64) / (1 << (scale_bits if scale_bits is not None else frac))


def share(rng: np.random.Generator, x: np.ndarray, t: int) -> Tuple[np.ndarray, np.ndarray]:
    x = np.mod(np.asarray(x, dtype=np.int64), t).astype(np.uint64)
    s1 = rng.integers(0, t, x.shape, dtype=np.uint64)
    s2 = (x.astype(object) - s1.astype(object)) % t
    return s1, s2.astype(np.uint64)


def reconstruct(s1: np.ndarray, s2: np.ndarray, t: int) -> np.ndarray:
    return ((s1.astype(object) + s2.astype(object)) % t).astype(np.uint64)


def add_mod(a, b, t):
    return ((a.astype(object) + b.astype(object)) % t).astype(np.uint64)


def sub_mod(a, b, t):
    return ((a.astype(object) - b.astype(object)) % t).astype(np.uint64)


def matmul_mod(A, B, t):
    """Exact modular matmul via object dtype (sizes are protocol-small)."""
    return np.asarray(
        (np.asarray(A, dtype=object) @ np.asarray(B, dtype=object)) % t
    ).astype(np.uint64)


def scalar_mul_mod(c, A, t):
    return ((int(c) * A.astype(object)) % t).astype(np.uint64)


# ---------------------------------------------------------------------------
# Beaver triples (matmul form): private × private products
# ---------------------------------------------------------------------------


@dataclass
class BeaverTriple:
    """Shares of (A, B, C=A@B) with A:(m,k), B:(k,n)."""

    a1: np.ndarray
    a2: np.ndarray
    b1: np.ndarray
    b2: np.ndarray
    c1: np.ndarray
    c2: np.ndarray


def deal_matmul_triple(rng, m: int, k: int, n: int, t: int) -> BeaverTriple:
    """Offline dealer. In production the triple is generated with the same
    BFV machinery (client encrypts A-share, server mul_plains its B-share);
    bytes for that path are accounted analytically in the benchmarks."""
    A = rng.integers(0, t, (m, k), dtype=np.uint64)
    B = rng.integers(0, t, (k, n), dtype=np.uint64)
    C = matmul_mod(A, B, t)
    a1, a2 = share(rng, A, t)
    b1, b2 = share(rng, B, t)
    c1, c2 = share(rng, C, t)
    return BeaverTriple(a1, a2, b1, b2, c1, c2)


def beaver_matmul(
    x1, x2, y1, y2, trip: BeaverTriple, t: int
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Shares of X@Y from shares of X, Y. Returns (z1, z2, opened_bytes).

    Each party opens (X−A) and (Y−B); z = C + (X−A)B + A(Y−B) + (X−A)(Y−B),
    the last term computed by party 1 (standard convention).
    """
    e1 = sub_mod(x1, trip.a1, t)
    e2 = sub_mod(x2, trip.a2, t)
    f1 = sub_mod(y1, trip.b1, t)
    f2 = sub_mod(y2, trip.b2, t)
    E = add_mod(e1, e2, t)  # opened
    F = add_mod(f1, f2, t)
    z1 = add_mod(
        add_mod(trip.c1, matmul_mod(E, trip.b1, t), t),
        add_mod(matmul_mod(trip.a1, F, t), matmul_mod(E, F, t), t),
        t,
    )
    z2 = add_mod(
        add_mod(trip.c2, matmul_mod(E, trip.b2, t), t),
        matmul_mod(trip.a2, F, t),
        t,
    )
    opened_bytes = (E.size + F.size) * 8 * 2  # both directions
    return z1, z2, opened_bytes

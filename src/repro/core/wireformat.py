"""Byte-size model for the v2 wire format's compressed streams.

The v2 frame codec itself lives in :mod:`repro.net.wire`; this module
holds only the *size arithmetic* shared between the codec and the
in-process oracle (:mod:`repro.core.protocol`), mirroring how
:mod:`repro.core.ot` owns the OT byte model. Keeping it in ``core`` with
zero intra-repo imports avoids a ``core -> net -> core`` cycle: the
oracle meters exactly these sizes and the ledger test asserts the wire
matches them byte-for-byte.

Two compressed stream kinds exist in v2:

* **seed streams** — a raw per-instance label batch is replaced by a
  fixed 32-byte (seed, counter, count) record; the receiver replays the
  labels with :func:`repro.core.labels.stream_labels`.
* **delta table batches** — a garbled-table slab ships one full anchor
  instance plus ``TABLE_DELTA_WORDS`` words per AND gate for every
  further instance; the remaining XOR-residual words travel on the SIM
  sideband (ledgered as overhead, like identity-HE blocks).
"""

from __future__ import annotations

import struct

#: bytes of a packed seed-stream record: 16-byte seed + u64 counter + u64 count
SEED_STREAM_BYTES = 32

#: uint32 words per AND gate kept on the wire for non-anchor instances
TABLE_DELTA_WORDS = 2

#: delta table batch header: instances u32 | rows u32 | delta words u8
TABLE_DELTA_HDR = struct.Struct("<IIB")


def tables_delta_wire_bytes(instances: int, n_and: int) -> int:
    """PROTO bytes of a v2 delta-encoded table batch.

    One full anchor instance (32 B/AND) plus ``TABLE_DELTA_WORDS`` words
    per AND for each remaining instance.
    """
    rows = max(n_and, 1)
    return (TABLE_DELTA_HDR.size + rows * 32
            + max(instances - 1, 0) * rows * 4 * TABLE_DELTA_WORDS)


def tables_resid_bytes(instances: int, n_and: int) -> int:
    """SIM-sideband residual bytes of a v2 table batch."""
    rows = max(n_and, 1)
    return max(instances - 1, 0) * rows * 4 * (8 - TABLE_DELTA_WORDS)


def tables_delta_anchor_bytes(n_and: int) -> int:
    """Per-batch fixed cost of a v2 table batch (header + anchor excess).

    ``tables_delta_wire_bytes(I, a) == tables_delta_anchor_bytes(a)
    + I * max(a, 1) * 4 * TABLE_DELTA_WORDS`` — the affine split that
    lets the oracle meter per-op instance slices while the party frames
    one segment per garbled slab: each op contributes its linear share,
    the slab's fixed anchor cost is metered once at the slab site.
    """
    rows = max(n_and, 1)
    return TABLE_DELTA_HDR.size + rows * 4 * (8 - TABLE_DELTA_WORDS)

"""PiTSession: an explicit compile → preprocess → run lifecycle for
private transformer serving.

APINT's headline result is the offline/online split: everything that does
not depend on the client's input — garbling, the DELPHI HE mask products,
Beaver triple dealing — can be generated ahead of time and pooled across
inferences. This module makes that split a first-class API:

    session = compile(model, pcfg, shape=(S, d))   # trace → op-graph Plan
    bundles = session.preprocess(n)                # ALL offline work, n×
    y = session.run(x, bundles[0])                 # online phase only

``compile`` traces ``PrivateTransformer.forward_private`` into a
declarative :class:`~repro.core.plan.Plan`; ``preprocess`` executes every
op's ``*_offline`` protocol leg (with one *batched* garbling call per
cached netlist across the whole bundle batch) and returns poolable
:class:`PreprocessedBundle`\\ s; ``run`` replays the plan against one
bundle, touching only ``channel_online``. A bundle is single-use — holding
fresh garbled tables and masks is exactly what makes the online phase
secure — so ``run`` raises :class:`BundleExhausted` on reuse.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.config import PrivacyConfig
from repro import obs
from repro.core import garble as G
from repro.core import secret_sharing as SS
from repro.core.netlist import Netlist
from repro.core.plan import GC_KINDS, OpSpec, Plan, RegRef, compile_plan
from repro.core.protocol import PiTProtocol, Stats

_bundle_counter = itertools.count()


class BundleExhausted(RuntimeError):
    """Raised when ``run`` is asked to reuse a consumed (or foreign) bundle."""


def gc_net_for(protocol: PiTProtocol, op: OpSpec) -> Netlist:
    """The cached netlist backing a GC-kind op.

    Module-level because both :class:`PiTSession` and the two-party
    endpoints (:mod:`repro.net.party`) must resolve *identical* netlists
    from a plan — the circuit structure depends only on the privacy config
    and the op's shapes/scales, never on weights, so two protocol
    instances on two machines build the same gate DAG.
    """
    p = protocol
    if op.kind == "trunc":
        return p.trunc_net(op.in_scale)
    if op.kind == "gc_apply":
        circ = op.attrs["circuit"]
        if circ == "softmax":
            return p.softmax_net(op.attrs["row_len"], op.in_scale)
        return p.activation_net(circ, op.in_scale)
    if op.kind == "layernorm":
        n = op.shape[1]
        if p.pcfg.layernorm_offload:
            return p.layernorm_reduced_net(n, op.in_scale)
        return p.layernorm_full_net(n, op.in_scale)
    raise ValueError(op.kind)


class GarblingCache:
    """Observable shared-garbling-cache keying: ``(netlist, instances,
    impl)`` → one slab structure, however many sessions use it.

    The expensive artifacts behind a GC op are the generated
    :class:`Netlist` (circuit generation is seconds-scale for production
    rows) and the compiled executor walk :mod:`repro.core.gc_exec` keys
    on ``(netlist, instances, impl)``. Both hang off ONE protocol
    instance's netlist cache — so a multi-client gateway that shares one
    protocol across all sessions garbles/compiles each distinct slab
    once and serves it to every client. This wrapper makes that sharing
    *observable and thread-safe*: every resolution goes through one lock
    (two sessions racing a first build would otherwise construct the
    netlist twice via the protocol's bare check-then-set cache), counts
    a miss the first time a key is seen and a hit on every reuse.
    """

    def __init__(self, protocol: PiTProtocol):
        self.protocol = protocol
        self._lock = threading.Lock()
        self._slabs: Dict[Tuple[str, int, str], int] = {}  # key -> uses
        self.hits = 0
        self.misses = 0

    def distinct_nets(self, plan: Plan, n: int = 1
                      ) -> Tuple[Dict[str, Netlist], Dict[str, int]]:
        """Resolve every GC op's netlist for an ``n``-bundle slab batch.

        Returns netlists in first-appearance order plus per-request
        instance totals (the garbler's slab widths are ``per_req[name] *
        n``). The whole walk holds the cache lock so concurrent first
        resolutions from two sessions cannot double-build a netlist, and
        each distinct slab key counts one hit/miss per call.
        """
        with self._lock:
            nets: Dict[str, Netlist] = {}
            per_req: Dict[str, int] = {}
            for op in plan.ops:
                if op.kind in GC_KINDS:
                    net = gc_net_for(self.protocol, op)
                    per_req[net.name] = (per_req.get(net.name, 0)
                                         + plan.gc_instances(op))
                    nets.setdefault(net.name, net)
            for name in nets:
                key = (name, per_req[name] * n, self.protocol.impl)
                if key in self._slabs:
                    self.hits += 1
                else:
                    self.misses += 1
                    self._slabs[key] = 0
                self._slabs[key] += 1
            return nets, per_req

    @property
    def distinct_netlists(self) -> int:
        with self._lock:
            return len({name for name, _, _ in self._slabs})

    def summary(self) -> Dict[str, object]:
        with self._lock:
            return {
                "slabs": len(self._slabs),
                "distinct_netlists": len({n for n, _, _ in self._slabs}),
                "hits": self.hits,
                "misses": self.misses,
                "by_key": {f"{n}/I{i}/{im}": uses
                           for (n, i, im), uses in sorted(self._slabs.items())},
            }


@dataclass
class PreprocessedBundle:
    """Offline material for exactly one online inference.

    ``session_id`` pins the bundle to the session whose garbled circuits
    and HE masks it holds — structural plan equality is not enough, since
    two same-shape models would otherwise silently swap weights.
    """

    plan_id: str
    session_id: int
    parts: Dict[str, object]
    bundle_id: int = field(default_factory=lambda: next(_bundle_counter))
    consumed: bool = False


class PiTSession:
    """Executes a compiled :class:`Plan` in two explicit phases."""

    def __init__(self, plan: Plan, weights: Sequence, pcfg: PrivacyConfig,
                 *, seed: int = 0, impl: str = "ref",
                 protocol: Optional[PiTProtocol] = None,
                 wire_version: int = 1, compression: bool = True):
        assert plan.n_layers == len(weights)
        self.plan = plan
        self.weights = list(weights)
        self.protocol = protocol or PiTProtocol(
            pcfg, seed=seed, impl=impl, wire_version=wire_version,
            compression=compression)
        if self.protocol.frac != plan.frac or \
                self.protocol.pcfg.layernorm_offload != plan.layernorm_offload:
            raise ValueError(
                f"privacy config (frac_bits={self.protocol.frac}, "
                f"layernorm_offload={self.protocol.pcfg.layernorm_offload}) "
                f"disagrees with the traced plan ({plan.plan_id}); recompile "
                f"from a model built with this config")
        # quantized weights are bundle-invariant: cache once per linear op
        self._quantized: Dict[str, tuple] = {}
        self._session_id = next(_bundle_counter)

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Stats:
        return self.protocol.stats

    def _weight(self, op: OpSpec) -> np.ndarray:
        W = self.weights[op.attrs["layer"]]
        w = getattr(W, op.attrs["weight"])
        scale = op.attrs.get("wscale", 1.0)
        return w * scale if scale != 1.0 else w

    def _ln_params(self, op: OpSpec) -> Tuple[np.ndarray, np.ndarray]:
        W = self.weights[op.attrs["layer"]]
        which = op.attrs["which"]
        return getattr(W, f"{which}_g"), getattr(W, f"{which}_b")

    def _gc_net(self, op: OpSpec) -> Netlist:
        """The cached netlist backing a GC-kind op."""
        return gc_net_for(self.protocol, op)

    # ------------------------------------------------------------------
    # offline phase
    # ------------------------------------------------------------------
    def preprocess(self, n: int = 1) -> List[PreprocessedBundle]:
        """Execute all offline work for ``n`` future requests up front.

        Garbling is batched per cached netlist: every netlist appearing in
        the plan is garbled in ONE call covering all its instances across
        all ops and all ``n`` bundles, then sliced per use. HE mask
        products, output masks and Beaver triples are drawn per bundle.
        At these instance counts the executor runs its throughput regime
        — liveness-compacted planar wire store and packed garble-table
        emission (:mod:`repro.core.gc_exec`) — so the offline producer
        stays ahead of online bundle consumption.
        """
        if n < 1:
            raise ValueError("preprocess needs n >= 1")
        p = self.protocol
        plan = self.plan
        with obs.span("preprocess", plan=plan.plan_id, bundles=n), \
                p.stats.phase("offline"):
            # ---- one garbling call per distinct netlist ----------------
            gc_ops = [(op, self._gc_net(op), plan.gc_instances(op))
                      for op in plan.ops if op.kind in GC_KINDS]
            per_req: Dict[str, int] = {}
            nets: Dict[str, Netlist] = {}
            for _, net, I in gc_ops:
                per_req[net.name] = per_req.get(net.name, 0) + I
                nets[net.name] = net
            slabs = {}
            for name in nets:
                with obs.span("garble", netlist=name,
                              instances=per_req[name] * n):
                    slabs[name] = G.garble(
                        nets[name], p._next_key(), per_req[name] * n,
                        impl=p.impl)
            for name in nets:
                # v2 wire: the batch-fixed costs (delta-table anchor +
                # seed-stream record) are per garbled slab, not per op —
                # meter them here, where the slab exists (no-op on v1)
                p.gc_slab_offline(nets[name])
            offsets = {name: 0 for name in nets}

            def take(net: Netlist, I: int) -> G.GarbledCircuit:
                lo = offsets[net.name]
                offsets[net.name] = lo + I
                return G.slice_instances(slabs[net.name], lo, lo + I)

            # ---- per-bundle correlations -------------------------------
            bundles: List[PreprocessedBundle] = []
            for _ in range(n):
                parts: Dict[str, object] = {}
                for op in plan.ops:
                    if op.kind == "linear":
                        if op.name not in self._quantized:
                            self._quantized[op.name] = p.quantize_weight(
                                self._weight(op))
                        parts[op.name] = p.linear_offline(
                            None, plan.read_shape(op.reads[0]),
                            quantized=self._quantized[op.name])
                    elif op.kind == "beaver_matmul":
                        m, k = plan.read_shape(op.reads[0])
                        _, nn = plan.read_shape(op.reads[1])
                        parts[op.name] = p.beaver_offline(m, k, nn)
                    elif op.kind == "trunc":
                        I = plan.gc_instances(op)
                        parts[op.name] = p.trunc_offline(
                            op.in_scale, I, gcirc=take(self._gc_net(op), I))
                    elif op.kind == "gc_apply":
                        I = plan.gc_instances(op)
                        circ = op.attrs["circuit"]
                        if circ == "softmax":
                            parts[op.name] = p.softmax_offline(
                                op.attrs["row_len"], op.in_scale, I,
                                gcirc=take(self._gc_net(op), I))
                        else:
                            parts[op.name] = p.activation_offline(
                                circ, op.in_scale, I,
                                gcirc=take(self._gc_net(op), I))
                    elif op.kind == "layernorm":
                        I = plan.gc_instances(op)
                        gamma, beta = self._ln_params(op)
                        parts[op.name] = p.layernorm_offline(
                            op.shape[1], I, op.in_scale, gamma, beta,
                            gcirc=take(self._gc_net(op), I))
                    else:
                        raise ValueError(op.kind)
                bundles.append(PreprocessedBundle(
                    plan.plan_id, self._session_id, parts))
        return bundles

    # ------------------------------------------------------------------
    # online phase
    # ------------------------------------------------------------------
    def run(self, x: np.ndarray, bundle: PreprocessedBundle) -> np.ndarray:
        """Online phase only: serve one request against one bundle."""
        if bundle.consumed:
            raise BundleExhausted(
                f"bundle {bundle.bundle_id} already consumed — preprocess "
                f"more bundles or refill the pool")
        if (bundle.plan_id != self.plan.plan_id
                or bundle.session_id != self._session_id):
            raise BundleExhausted(
                f"bundle {bundle.bundle_id} was preprocessed by another "
                f"session (for {bundle.plan_id}), not this one "
                f"({self.plan.plan_id})")
        x = np.asarray(x, np.float64)
        if x.shape != (self.plan.seq_len, self.plan.d):
            raise ValueError(
                f"input shape {x.shape} != bucket shape "
                f"{(self.plan.seq_len, self.plan.d)}")
        bundle.consumed = True
        p = self.protocol
        plan = self.plan
        with obs.span("run", plan=plan.plan_id,
                      bundle_id=bundle.bundle_id), \
                p.stats.phase("online"):
            regs: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
            regs["x"] = p.share_input(x)
            for op in plan.ops:
                part = bundle.parts[op.name]
                rd = [self._read(regs, ref) for ref in op.reads]
                if op.kind == "linear":
                    out = p.linear_online(part, *rd[0])
                elif op.kind == "beaver_matmul":
                    out = p.beaver_online(part, *rd[0], *rd[1])
                elif op.kind == "trunc":
                    out = p.trunc_online(part, *rd[0])
                elif op.kind == "gc_apply":
                    if op.attrs["circuit"] == "softmax":
                        out = p.gc_online(part, *rd[0])
                    else:
                        out = p.activation_online(part, *rd[0])
                elif op.kind == "layernorm":
                    hc, hs = rd[0]
                    for (ac, as_) in rd[1:]:  # residual adds
                        hc = SS.add_mod(hc, ac, p.t)
                        hs = SS.add_mod(hs, as_, p.t)
                    out = p.layernorm_online(part, hc, hs)
                else:
                    raise ValueError(op.kind)
                self._write(regs, op.write, out)
            return p.reveal(*regs[plan.output_reg])

    def _read(self, regs, ref: RegRef) -> Tuple[np.ndarray, np.ndarray]:
        c, s = regs[ref.reg]
        if ref.cols is not None:
            lo, hi = ref.cols
            c, s = c[:, lo:hi], s[:, lo:hi]
        if ref.transpose:
            c, s = c.T.copy(), s.T.copy()
        return c, s

    def _write(self, regs, ref: RegRef, out) -> None:
        oc, os_ = out
        if ref.cols is None:
            regs[ref.reg] = (oc, os_)
            return
        if ref.reg not in regs:
            shape = self.plan.reg_shapes[ref.reg]
            regs[ref.reg] = (np.zeros(shape, np.uint64),
                             np.zeros(shape, np.uint64))
        lo, hi = ref.cols
        regs[ref.reg][0][:, lo:hi] = oc
        regs[ref.reg][1][:, lo:hi] = os_


def compile(model, pcfg: Optional[PrivacyConfig] = None,
            shape: Union[int, Tuple[int, ...], None] = None,
            *, seed: Optional[int] = None,
            impl: Optional[str] = None, wire_version: int = 1,
            compression: bool = True) -> PiTSession:
    """Trace ``model.forward_private`` into a Plan and wrap it in a session.

    ``model``: a ``PrivateTransformer`` (or any object with ``d``, ``h``,
    ``hd``, ``d_ff``, ``weights``, ``activation``, ``scale_q`` and a
    protocol ``p``). ``shape`` is the request bucket: ``(seq_len, d)`` or
    just ``seq_len``. ``pcfg`` defaults to the model's privacy config; the
    session gets its own protocol instance so its phase ledgers start
    clean and bundles never alias the model's eager state.

    ``impl`` defaults to ``"auto"`` — the device-resident GC executor
    (:mod:`repro.core.gc_exec`), NOT the model's eager impl: serving is
    the production path and must never drop to the per-level numpy walk.
    Pass ``impl="ref"`` explicitly to pin a session to the host oracle.
    """
    if shape is None:
        raise ValueError("compile needs the request bucket shape (S, d)")
    if isinstance(shape, (tuple, list)):
        seq_len = int(shape[0])
        if len(shape) > 1 and int(shape[1]) != model.d:
            raise ValueError(f"shape {shape} does not match model d={model.d}")
    else:
        seq_len = int(shape)
    with obs.span("compile", seq_len=seq_len, d=int(model.d)):
        plan = compile_plan(model, seq_len)
    pcfg = pcfg or model.p.pcfg
    return PiTSession(
        plan, model.weights, pcfg,
        seed=seed if seed is not None else 0,
        impl=impl or "auto",
        wire_version=wire_version, compression=compression,
    )

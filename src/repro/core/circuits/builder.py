"""Circuit construction DSL with aggressive constant folding.

The paper's "GC-friendly circuit generation" premise is that the *structure*
of the circuit — not just post-hoc XAG rewriting — determines AND count.
The builder therefore folds at build time:

    XOR(x,0)=x  XOR(x,1)=INV(x)  XOR(x,x)=0  XOR(c1,c2)=const
    AND(x,1)=x  AND(x,0)=0       AND(x,x)=x  AND(c1,c2)=const
    INV(INV(x))=x                INV(const)=const

so e.g. multiplications by constants, mux trees over constant tables, and
the XFBQ correction terms are automatically reduced — reproducing the
"modify the fundamental implementation" effect (§3.2).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR


@dataclass(frozen=True)
class Word:
    """Little-endian fixed-width bundle of wire ids (two's complement)."""

    bits: Tuple[int, ...]

    def __len__(self):
        return len(self.bits)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return Word(self.bits[i])
        return self.bits[i]

    def __iter__(self):
        return iter(self.bits)


class CircuitBuilder:
    def __init__(self, name: str = ""):
        self.name = name
        self._ops: List[int] = []
        self._in0: List[int] = []
        self._in1: List[int] = []
        self._out: List[int] = []
        self._n = 0
        self._g_inputs: List[int] = []
        self._e_inputs: List[int] = []
        self._outputs: List[int] = []
        self._const_of: Dict[int, int] = {}  # wire -> 0/1 (known constant)
        self._const_wire: Dict[int, int] = {}  # bit -> materialized wire
        self._inv_of: Dict[int, int] = {}  # wire -> its INV wire (dedup)
        self._cse: Dict[Tuple[int, int, int], int] = {}  # structural dedup

    # ---- wires -------------------------------------------------------------
    def _new(self) -> int:
        w = self._n
        self._n += 1
        return w

    def g_input(self) -> int:
        w = self._new()
        self._g_inputs.append(w)
        return w

    def e_input(self) -> int:
        w = self._new()
        self._e_inputs.append(w)
        return w

    def g_input_word(self, width: int) -> Word:
        return Word(tuple(self.g_input() for _ in range(width)))

    def e_input_word(self, width: int) -> Word:
        return Word(tuple(self.e_input() for _ in range(width)))

    def constant(self, bit: int) -> int:
        bit = int(bit) & 1
        if bit not in self._const_wire:
            w = self._new()
            # const wires are neither gate outputs nor party inputs; the
            # garbler knows their bits and supplies active labels directly.
            self._const_of[w] = bit
            self._const_wire[bit] = w
        return self._const_wire[bit]

    def const_word(self, value: int, width: int) -> Word:
        return Word(tuple(self.constant((value >> i) & 1) for i in range(width)))

    def is_const(self, w: int) -> Optional[int]:
        return self._const_of.get(w)

    # ---- gates (with folding) -----------------------------------------------
    def _emit(self, op: int, a: int, b: int) -> int:
        w = self._new()
        self._ops.append(op)
        self._in0.append(a)
        self._in1.append(b)
        self._out.append(w)
        return w

    def XOR(self, a: int, b: int) -> int:
        ca, cb = self.is_const(a), self.is_const(b)
        if ca is not None and cb is not None:
            return self.constant(ca ^ cb)
        if ca is not None:
            a, b, ca, cb = b, a, cb, ca
        if cb == 0:
            return a
        if cb == 1:
            return self.INV(a)
        if a == b:
            return self.constant(0)
        if self._inv_of.get(a) == b:
            return self.constant(1)
        key = (OP_XOR, a, b) if a < b else (OP_XOR, b, a)
        w = self._cse.get(key)
        if w is None:
            w = self._cse[key] = self._emit(OP_XOR, a, b)
        return w

    def AND(self, a: int, b: int) -> int:
        ca, cb = self.is_const(a), self.is_const(b)
        if ca is not None and cb is not None:
            return self.constant(ca & cb)
        if ca is not None:
            a, b, ca, cb = b, a, cb, ca
        if cb == 0:
            return self.constant(0)
        if cb == 1:
            return a
        if a == b:
            return a
        if self._inv_of.get(a) == b:
            return self.constant(0)
        key = (OP_AND, a, b) if a < b else (OP_AND, b, a)
        w = self._cse.get(key)
        if w is None:
            w = self._cse[key] = self._emit(OP_AND, a, b)
        return w

    def INV(self, a: int) -> int:
        ca = self.is_const(a)
        if ca is not None:
            return self.constant(1 - ca)
        if a in self._inv_of:
            return self._inv_of[a]
        w = self._emit(OP_INV, a, a)
        self._inv_of[a] = w
        self._inv_of[w] = a
        return w

    def OR(self, a: int, b: int) -> int:
        return self.INV(self.AND(self.INV(a), self.INV(b)))

    def MUX(self, sel: int, a: int, b: int) -> int:
        """sel ? a : b — one AND."""
        return self.XOR(b, self.AND(sel, self.XOR(a, b)))

    # ---- finalize -----------------------------------------------------------
    def output(self, wires) -> None:
        if isinstance(wires, Word):
            wires = wires.bits
        if isinstance(wires, int):
            wires = [wires]
        self._outputs.extend(wires)

    def build(self, prune: bool = True) -> Netlist:
        """Finalize into a Netlist.

        ``prune=True`` (default) drops gates whose output never reaches a
        netlist output — composed generators routinely compute wide
        intermediate words and then slice (e.g. ``exp``'s widened q
        product), leaving whole dead cones that would still cost garbled
        tables and hash lanes. Party input wires are always kept (the
        protocol's I/O contract); unused constant wires are dropped.
        Wires are renumbered compactly, preserving creation order (and
        therefore topological gate order).
        """
        ops, in0, in1, out = self._ops, self._in0, self._in1, self._out
        G, W = len(ops), self._n
        if prune and G:
            needed = bytearray(W)
            for w in self._outputs:
                needed[w] = 1
            live = bytearray(G)
            for g in range(G - 1, -1, -1):
                if needed[out[g]]:
                    live[g] = 1
                    needed[in0[g]] = 1
                    if ops[g] != OP_INV:
                        needed[in1[g]] = 1
            if not all(live):
                keep_wire = bytearray(W)
                for w in self._g_inputs:
                    keep_wire[w] = 1
                for w in self._e_inputs:
                    keep_wire[w] = 1
                for w in self._outputs:
                    keep_wire[w] = 1
                for w in self._const_of:
                    if needed[w]:
                        keep_wire[w] = 1
                for g in range(G):
                    if live[g]:
                        keep_wire[in0[g]] = 1
                        keep_wire[in1[g]] = 1
                        keep_wire[out[g]] = 1
                remap = np.cumsum(
                    np.frombuffer(keep_wire, np.uint8)).astype(np.int32) - 1
                lv = np.frombuffer(live, np.uint8).astype(bool)
                return Netlist(
                    num_wires=int(remap[-1]) + 1,
                    op=np.asarray(ops, np.uint8)[lv],
                    in0=remap[np.asarray(in0, np.int32)[lv]],
                    in1=remap[np.asarray(in1, np.int32)[lv]],
                    out=remap[np.asarray(out, np.int32)[lv]],
                    garbler_inputs=remap[np.asarray(
                        self._g_inputs, np.int32)],
                    evaluator_inputs=remap[np.asarray(
                        self._e_inputs, np.int32)],
                    outputs=remap[np.asarray(self._outputs, np.int32)],
                    const_bits={int(remap[w]): b
                                for w, b in self._const_of.items()
                                if needed[w]},
                    name=self.name,
                )
        return Netlist(
            num_wires=W,
            op=np.asarray(ops, np.uint8),
            in0=np.asarray(in0, np.int32),
            in1=np.asarray(in1, np.int32),
            out=np.asarray(out, np.int32),
            garbler_inputs=np.asarray(self._g_inputs, np.int32),
            evaluator_inputs=np.asarray(self._e_inputs, np.int32),
            outputs=np.asarray(self._outputs, np.int32),
            const_bits=dict(self._const_of),
            name=self.name,
        )

"""Share-boundary circuits: the glue the protocol wraps around every
nonlinear function circuit (the paper's C̃: "integrates adding the secret
shares from both parties, processing the nonlinear function, and
subtracting a random matrix").

Values are additive shares mod prime t. GC words are k = bits(t)+2 wide
two's complement:

  reconstruct: v = a + b; if v ≥ t: v −= t; center to signed (v > t/2 ⇒ v−t)
  descale:     exact arithmetic shift by extra_frac (deferred truncation)
  remask:      y (signed) → y mod t → y + (t − r) mod t  (evaluator's share)
"""

from __future__ import annotations

from typing import List, Tuple

from repro.core.circuits import arith
from repro.core.circuits.builder import CircuitBuilder, Word


def gc_word_bits(t: int) -> int:
    return t.bit_length() + 2


def reconstruct_shared(cb: CircuitBuilder, g_share: Word, e_share: Word,
                       t: int, descale: int = 0) -> Word:
    """g_share (garbler) + e_share (evaluator) mod t, centered signed,
    then >> descale (exact truncation inside GC)."""
    k = len(g_share)
    v = arith.add(cb, g_share, e_share)  # < 2t < 2^k
    tw = cb.const_word(t, k)
    ge = cb.INV(arith.lt_unsigned(cb, v, tw))  # v >= t
    v = arith.mux(cb, ge, arith.sub(cb, v, tw), v)
    half = cb.const_word(t // 2, k)
    over = cb.INV(arith.lt_unsigned(cb, v, half))  # v > t/2 ⇒ negative value
    v = arith.mux(cb, over, arith.sub(cb, v, tw), v)
    if descale:
        v = arith.shift_right_const(cb, v, descale, arithmetic=True)
    return v


def input_shared_word(cb: CircuitBuilder, t: int, descale: int = 0) -> Word:
    k = gc_word_bits(t)
    g = cb.g_input_word(k)
    e = cb.e_input_word(k)
    return reconstruct_shared(cb, g, e, t, descale)


def remask_output(cb: CircuitBuilder, y: Word, t: int,
                  mask: Word = None) -> Word:
    """y signed → (y mod t) + (t − r) mod t; r is a fresh garbler word.

    The evaluator learns only its share; the garbler's share is r.
    """
    k = len(y)
    tw = cb.const_word(t, k)
    neg = y[-1]
    v = arith.mux(cb, neg, arith.add(cb, y, tw), y)  # y mod t (|y| < t/2)
    m = mask if mask is not None else cb.g_input_word(k)
    s = arith.add(cb, v, m)  # m encodes (t − r)
    ge = cb.INV(arith.lt_unsigned(cb, s, tw))
    return arith.mux(cb, ge, arith.sub(cb, s, tw), s)


def output_shared(cb: CircuitBuilder, y: Word, t: int) -> Word:
    out = remask_output(cb, y, t)
    cb.output(out)
    return out

from repro.core.circuits.builder import CircuitBuilder, Word

__all__ = ["CircuitBuilder", "Word"]

"""GC-friendly circuits for the transformer's nonlinear functions (§3.2).

Fixed-point format: k-bit two's complement, `frac` fractional bits
(paper §4.1: k=37 for Softmax/LayerNorm, k=21 for GeLU; frac configurable).

  * exp: i-BERT range reduction — x ≤ 0, q = ⌊x / ln2⌋ via constant
    multiply, r = x − q·ln2 ∈ (−ln2, 0], 2nd-order i-BERT polynomial
    0.3585(r + 1.353)² + 0.344, then a barrel right-shift by q.
  * softmax row: max-tree → subtract → exp → sum-tree → Newton–Raphson
    reciprocal → per-element multiply.
  * GeLU: clip to (−4, 4) then 16-segment piecewise-linear LUT
    (mux tree over constant tables folds to XOR-only leaf levels).
  * LayerNorm FULL (baseline protocol): mean, variance, rsqrt (NR in
    fixed point), normalize, γ/β affine.
  * LayerNorm REDUCED Ĉ₂ (APINT protocol): mean/variance/γ/β are computed
    outside GC (shares + HE); the circuit only does rsqrt(var) and the
    per-element multiply — the paper's Fig. 4 workload reallocation.

Every multiplication routes through ``arith.mul`` so the XFBQ/conventional
choice (PrivacyConfig.mult_style) applies globally.
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

from repro.core.circuits import arith
from repro.core.circuits.builder import CircuitBuilder, Word

LN2 = math.log(2.0)


def _fx(value: float, frac: int, k: int) -> int:
    return int(round(value * (1 << frac))) % (1 << k)


# ---------------------------------------------------------------------------
# exp (i-BERT) — input x <= 0
# ---------------------------------------------------------------------------


def exp_circuit(cb: CircuitBuilder, x: Word, frac: int, style: str,
                max_shift_bits: int = 5) -> Word:
    """exp(x) for x ∈ (−2^(k-frac-1), 0]; result in (0, 1]."""
    k = len(x)
    # i-BERT convention: q = ⌊−x/ln2⌋ ≥ 0, r = x + q·ln2 ∈ (−ln2, 0],
    # exp(x) = exp(r) · 2^(−q). The q product is formed in a widened word so
    # it cannot wrap; arithmetic shift by 2·frac is exact floor division.
    kw = k + frac + 2
    nxw = arith.sign_extend(cb, arith.neg(cb, x), kw)
    z = arith.mul_const(cb, nxw, _fx(1.0 / LN2, frac, kw), width=kw)
    qw = arith.shift_right_const(cb, z, 2 * frac, arithmetic=True)  # int ≥ 0
    q = Word(qw.bits[:k])
    qln2 = arith.mul_const(cb, q, _fx(LN2, frac, k))  # scale frac
    r = arith.add(cb, x, qln2)  # ∈ (−ln2, 0]
    # i-BERT: exp(r) ≈ 0.3585 (r + 1.353)^2 + 0.344 on (−ln2, 0]
    t = arith.add_const(cb, r, _fx(1.353, frac, k))
    t2 = arith.fx_mul(cb, t, t, frac, style=style)
    p = arith.mul_const(cb, t2, _fx(0.3585, frac, k))
    p = arith.shift_right_const(cb, p, frac, arithmetic=True)
    p = arith.add_const(cb, p, _fx(0.344, frac, k))
    # shift right by q: amount = min(q, 2^max_shift_bits − 1)
    amount = Word(q.bits[:max_shift_bits])
    # saturate: if q >= 2^max_shift_bits, result ~ 0 — detect high bits set
    high = cb.constant(0)
    for b in q.bits[max_shift_bits:k - 1]:
        high = cb.OR(high, b)
    shifted = arith.shift_right_var(cb, p, amount, arithmetic=False)
    zero = cb.const_word(0, k)
    return arith.mux(cb, high, zero, shifted)


# ---------------------------------------------------------------------------
# reciprocal / rsqrt via Newton–Raphson with LZC normalization
# ---------------------------------------------------------------------------


def _leading_one_onehot(cb: CircuitBuilder, x: Word) -> List[int]:
    """One-hot of the most significant set bit (MSB-first scan)."""
    k = len(x)
    none_yet = cb.constant(1)
    onehot = [cb.constant(0)] * k
    for i in reversed(range(k)):
        hit = cb.AND(none_yet, x[i])
        onehot[i] = hit
        none_yet = cb.AND(none_yet, cb.INV(x[i]))
    return onehot


def reciprocal_circuit(cb: CircuitBuilder, x: Word, frac: int, style: str,
                       iters: int = 3) -> Word:
    """1/x for x > 0, fixed point. Normalize x ∈ [0.5, 1) by LZC shift,
    NR iterate y ← y(2 − xy), denormalize."""
    k = len(x)
    onehot = _leading_one_onehot(cb, x)
    # normalized m = x·2^sh with leading one at frac−1 → m ∈ [0.5, 1):
    # build by mux-summing shifted copies against the one-hot (XOR-combine,
    # rows are disjoint).
    m_bits = [cb.constant(0)] * k
    e_onehot: List[Tuple[int, int]] = []  # (shift_amount_signed, sel)
    for pos in range(k):
        sel = onehot[pos]
        sh = (frac - 1) - pos  # leading one lands at frac−1 → m ∈ [0.5, 1)
        if sh >= 0:
            row = arith.shift_left_const(cb, x, sh)
        else:
            row = arith.shift_right_const(cb, x, -sh)
        for i in range(k):
            m_bits[i] = cb.XOR(m_bits[i], cb.AND(sel, row[i]))
        e_onehot.append((sh, sel))
    m = Word(tuple(m_bits))
    # initial guess y0 = 48/17 − 32/17·m  (standard NR seed for [0.5, 1))
    y = arith.sub(
        cb,
        cb.const_word(_fx(48.0 / 17.0, frac, k), k),
        arith.shift_right_const(
            cb, arith.mul_const(cb, m, _fx(32.0 / 17.0, frac, k)), frac,
            arithmetic=True,
        ),
    )
    two = cb.const_word(_fx(2.0, frac, k), k)
    for _ in range(iters):
        xy = arith.fx_mul(cb, m, y, frac, style=style)
        y = arith.fx_mul(cb, y, arith.sub(cb, two, xy), frac, style=style)
    # denormalize: 1/x = y * 2^(sh) where m = x·2^sh / 2^frac
    out_bits = [cb.constant(0)] * k
    for sh, sel in e_onehot:
        if sh >= 0:
            row = arith.shift_left_const(cb, y, sh)
        else:
            row = arith.shift_right_const(cb, y, -sh)
        for i in range(k):
            out_bits[i] = cb.XOR(out_bits[i], cb.AND(sel, row[i]))
    return Word(tuple(out_bits))


def rsqrt_circuit(cb: CircuitBuilder, x: Word, frac: int, style: str,
                  iters: int = 3) -> Word:
    """1/sqrt(x) for x > 0: normalize to [1,4), NR y ← y(3 − x y²)/2."""
    k = len(x)
    onehot = _leading_one_onehot(cb, x)
    # pair positions so the exponent shift is even: leading bit at frac or
    # frac+1 -> m ∈ [1, 4)
    m_bits = [cb.constant(0)] * k
    rows: List[Tuple[int, int]] = []
    for pos in range(k):
        sel = onehot[pos]
        sh = frac - pos
        sh_even = sh if sh % 2 == 0 else sh + 1  # keep parity even
        if sh_even >= 0:
            row = arith.shift_left_const(cb, x, sh_even)
        else:
            row = arith.shift_right_const(cb, x, -sh_even)
        for i in range(k):
            m_bits[i] = cb.XOR(m_bits[i], cb.AND(sel, row[i]))
        rows.append((sh_even, sel))
    m = Word(tuple(m_bits))
    # seed y0 ≈ 1.12 − 0.17·m (stays positive on all of [1,4); NR basin)
    y = arith.sub(
        cb,
        cb.const_word(_fx(1.12, frac, k), k),
        arith.shift_right_const(
            cb, arith.mul_const(cb, m, _fx(0.17, frac, k)), frac,
            arithmetic=True,
        ),
    )
    three = cb.const_word(_fx(3.0, frac, k), k)
    for _ in range(iters):
        y2 = arith.fx_mul(cb, y, y, frac, style=style)
        xy2 = arith.fx_mul(cb, m, y2, frac, style=style)
        y = arith.fx_mul(cb, y, arith.sub(cb, three, xy2), frac, style=style)
        y = arith.shift_right_const(cb, y, 1, arithmetic=True)
    # denormalize: 1/sqrt(x) = y · 2^(sh/2)
    out_bits = [cb.constant(0)] * k
    for sh_even, sel in rows:
        h = sh_even // 2
        if h >= 0:
            row = arith.shift_left_const(cb, y, h)
        else:
            row = arith.shift_right_const(cb, y, -h)
        for i in range(k):
            out_bits[i] = cb.XOR(out_bits[i], cb.AND(sel, row[i]))
    return Word(tuple(out_bits))


# ---------------------------------------------------------------------------
# softmax row
# ---------------------------------------------------------------------------


def softmax_circuit(n: int, k: int = 37, frac: int = 12, style: str = "xfbq",
                    inputs: str = "e") -> CircuitBuilder:
    """Softmax over an n-element row; all inputs are evaluator words
    (the shares sum x = <x> is reconstructed by a free XOR-add outside;
    here the row arrives as cleartext-in-labels, as in the protocol)."""
    cb = CircuitBuilder(f"softmax{n}_{k}b")
    xs = [
        (cb.e_input_word(k) if inputs == "e" else cb.g_input_word(k))
        for _ in range(n)
    ]
    # max tree
    mx = xs[0]
    for w in xs[1:]:
        mx = arith.max_word(cb, mx, w)
    es = []
    for w in xs:
        d = arith.sub(cb, w, mx)  # <= 0
        es.append(exp_circuit(cb, d, frac, style))
    s = es[0]
    for w in es[1:]:
        s = arith.add(cb, s, w)
    inv = reciprocal_circuit(cb, s, frac, style)
    for w in es:
        cb.output(arith.fx_mul(cb, w, inv, frac, style=style))
    return cb


# ---------------------------------------------------------------------------
# GeLU via clipping + LUT interpolation
# ---------------------------------------------------------------------------


def _gelu(v: float) -> float:
    return 0.5 * v * (1.0 + math.erf(v / math.sqrt(2.0)))


def gelu_circuit(k: int = 21, frac: int = 10, style: str = "xfbq",
                 segments: int = 16) -> CircuitBuilder:
    """GeLU(x): clip x to (−4, 4) [7], piecewise-linear over `segments`."""
    cb = CircuitBuilder(f"gelu_{k}b")
    x = cb.e_input_word(k)
    lo = cb.const_word(_fx(-4.0, frac, k), k)
    hi = cb.const_word(_fx(4.0, frac, k) - 1, k)  # 4 − ulp keeps idx in range
    x_lt_lo = arith.lt_signed(cb, x, lo)
    hi_lt_x = arith.lt_signed(cb, hi, x)
    xc = arith.mux(cb, x_lt_lo, lo, x)
    xc = arith.mux(cb, hi_lt_x, hi, xc)
    # segment index from the top bits of (xc + 4) ∈ [0, 8)
    xs = arith.add_const(cb, xc, _fx(4.0, frac, k))
    seg_bits = int(math.log2(segments))
    # xs in [0, 8): integer part is 3 bits above frac; take seg_bits msbs of
    # the [0,8) range: bits [frac+3-seg_bits, frac+3)
    lo_bit = frac + 3 - seg_bits
    idx = Word(tuple(xs[lo_bit + i] for i in range(seg_bits)))
    # constant tables
    width = 8.0 / segments
    slopes, intercepts = [], []
    for s in range(segments):
        a = -4.0 + s * width
        b = a + width
        ga, gb = _gelu(a), _gelu(b)
        m = (gb - ga) / width
        c = ga - m * a
        slopes.append(_fx(m, frac, k))
        intercepts.append(_fx(c, frac, k))
    # mux trees over constants (leaf levels fold to XORs)
    def lut(table: List[int]) -> Word:
        words = [cb.const_word(v, k) for v in table]
        level = words
        for bit in idx:
            nxt = []
            for i in range(0, len(level), 2):
                nxt.append(arith.mux(cb, bit, level[i + 1], level[i]))
            level = nxt
        return level[0]

    m_w = lut(slopes)
    c_w = lut(intercepts)
    y = arith.fx_mul(cb, xc, m_w, frac, style=style)
    y = arith.add(cb, y, c_w)
    cb.output(y)
    return cb


def silu_circuit(k: int = 21, frac: int = 10, style: str = "xfbq",
                 segments: int = 16) -> CircuitBuilder:
    """SiLU(x) = x·σ(x), same clip+LUT recipe (llama-family activation)."""
    cb = CircuitBuilder(f"silu_{k}b")
    x = cb.e_input_word(k)
    lo = cb.const_word(_fx(-6.0, frac, k), k)
    hi = cb.const_word(_fx(6.0, frac, k) - 1, k)
    x_lt_lo = arith.lt_signed(cb, x, lo)
    hi_lt_x = arith.lt_signed(cb, hi, x)
    xc = arith.mux(cb, x_lt_lo, lo, x)
    xc = arith.mux(cb, hi_lt_x, hi, xc)
    xs = arith.add_const(cb, xc, _fx(6.0, frac, k))
    seg_bits = int(math.log2(segments))
    rng = 12.0
    int_bits = 4  # [0, 16) covers [0,12]
    lo_bit = frac + int_bits - seg_bits
    idx = Word(tuple(xs[lo_bit + i] for i in range(seg_bits)))
    width = 16.0 / segments

    def f(v: float) -> float:
        return v / (1.0 + math.exp(-v))

    slopes, intercepts = [], []
    for s in range(segments):
        a = -6.0 + s * width
        b = min(a + width, 6.0)
        fa, fb = f(a), f(b)
        m = (fb - fa) / (b - a) if b > a else 0.0
        c = fa - m * a
        slopes.append(_fx(m, frac, k))
        intercepts.append(_fx(c, frac, k))

    def lut(table):
        level = [cb.const_word(v, k) for v in table]
        for bit in idx:
            nxt = []
            for i in range(0, len(level), 2):
                nxt.append(arith.mux(cb, bit, level[i + 1], level[i]))
            level = nxt
        return level[0]

    y = arith.fx_mul(cb, xc, lut(slopes), frac, style=style)
    y = arith.add(cb, y, lut(intercepts))
    cb.output(y)
    return cb


# ---------------------------------------------------------------------------
# LayerNorm: full C2 (baseline) vs reduced Ĉ2 (APINT)
# ---------------------------------------------------------------------------


def layernorm_full_circuit(n: int, k: int = 37, frac: int = 12,
                           style: str = "xfbq") -> CircuitBuilder:
    """Conventional LayerNorm entirely in GC (the PRIMER-baseline workload):
    mean, variance, rsqrt, normalize, γ/β affine. n must be a power of 2."""
    assert n & (n - 1) == 0
    cb = CircuitBuilder(f"layernorm_full{n}_{k}b")
    xs = [cb.e_input_word(k) for _ in range(n)]
    gammas = [cb.g_input_word(k) for _ in range(n)]
    betas = [cb.g_input_word(k) for _ in range(n)]
    s = xs[0]
    for w in xs[1:]:
        s = arith.add(cb, s, w)
    mean = arith.shift_right_const(cb, s, int(math.log2(n)), arithmetic=True)
    cs = [arith.sub(cb, w, mean) for w in xs]
    sq = [arith.fx_mul(cb, c, c, frac, style=style) for c in cs]
    v = sq[0]
    for w in sq[1:]:
        v = arith.add(cb, v, w)
    var = arith.shift_right_const(cb, v, int(math.log2(n)), arithmetic=True)
    var = arith.add_const(cb, var, 1)  # + eps (1 ulp)
    rs = rsqrt_circuit(cb, var, frac, style)
    for c, g, b in zip(cs, gammas, betas):
        yn = arith.fx_mul(cb, c, rs, frac, style=style)
        yg = arith.fx_mul(cb, yn, g, frac, style=style)
        cb.output(arith.add(cb, yg, b))
    return cb


def layernorm_reduced_circuit(n: int, k: int = 37, frac: int = 12,
                              style: str = "xfbq") -> CircuitBuilder:
    """APINT Ĉ₂ (Fig. 4 ⑦–⑫): mean/variance/γ·x/β live *outside* GC.

    Inputs: centered elements x'_i (evaluator, from standard ops on shares)
    and the variance (computed via the HE-assisted identity ⑧–⑨). The
    circuit does rsqrt + per-element multiply only.
    """
    cb = CircuitBuilder(f"layernorm_reduced{n}_{k}b")
    cs = [cb.e_input_word(k) for _ in range(n)]
    var = cb.e_input_word(k)
    var = arith.add_const(cb, var, 1)
    rs = rsqrt_circuit(cb, var, frac, style)
    for c in cs:
        cb.output(arith.fx_mul(cb, c, rs, frac, style=style))
    return cb

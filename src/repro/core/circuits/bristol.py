"""Bristol Fashion netlist emit/parse [Tillich & Smart].

Format:
    <num_gates> <num_wires>
    <n_input_values> <wires_per_value...>
    <n_output_values> <wires_per_value...>
    (blank)
    2 1 <a> <b> <out> AND|XOR
    1 1 <a> <out> INV

Input value 0 = garbler inputs, value 1 = evaluator inputs, value 2
(when present) = constant wires (the format has no constants; we emit them
as a third input bundle and record their bits in a `# const:` header
comment, which our parser understands and foreign parsers skip).
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR


def emit(net: Netlist) -> str:
    """Emit with Bristol wire numbering: inputs first, outputs last.

    Gate order already carries the topology, so renumbering is a pure
    permutation of ids.
    """
    outputs = [int(w) for w in net.outputs]
    assert len(set(outputs)) == len(outputs), "duplicate output wires"
    out_set = set(outputs)
    in_groups = [list(map(int, net.garbler_inputs)),
                 list(map(int, net.evaluator_inputs))]
    const_order = sorted(net.const_bits)
    if const_order:
        in_groups.append(const_order)

    remap = {}
    nxt = 0
    for g in in_groups:
        for w in g:
            remap[w] = nxt
            nxt += 1
    n_out = len(outputs)
    tail_start = net.num_wires - n_out
    for g in range(net.num_gates):
        w = int(net.out[g])
        if w not in remap and w not in out_set:
            remap[w] = nxt
            nxt += 1
    for i, w in enumerate(outputs):
        remap[w] = tail_start + i
    # any untouched wires (dangling inputs of nothing) — fill remaining slots
    for w in range(net.num_wires):
        if w not in remap:
            remap[w] = nxt
            nxt += 1

    lines: List[str] = []
    if const_order:
        bits = "".join(str(net.const_bits[w]) for w in const_order)
        mapped = " ".join(str(remap[w]) for w in const_order)
        lines.append(f"# const: {mapped} = {bits}")
    lines.append(f"{net.num_gates} {net.num_wires}")
    lines.append(
        " ".join([str(len(in_groups))] + [str(len(g)) for g in in_groups])
    )
    lines.append(f"1 {n_out}")
    lines.append("")
    names = {OP_AND: "AND", OP_XOR: "XOR", OP_INV: "INV"}
    for g in range(net.num_gates):
        op = int(net.op[g])
        if op == OP_INV:
            lines.append(
                f"1 1 {remap[int(net.in0[g])]} {remap[int(net.out[g])]} INV"
            )
        else:
            lines.append(
                f"2 1 {remap[int(net.in0[g])]} {remap[int(net.in1[g])]} "
                f"{remap[int(net.out[g])]} {names[op]}"
            )
    return "\n".join(lines) + "\n"


def parse(text: str, name: str = "", verify: bool = True) -> Netlist:
    """Parse a Bristol Fashion netlist.

    Malformed files — bad headers, wrong gate arity, non-integer or
    out-of-range wires, gate-count mismatches — raise ``ValueError``
    with the offending line, and the result is run through the
    structural verifier (:func:`repro.analysis.verify_netlist_strict`:
    topological order, single drivers, const consistency, reachable
    outputs) so a foreign circuit fails HERE with a message instead of
    deep inside ``compile_level_plan`` or, worse, garbling the wrong
    function. ``verify=False`` skips the verifier (not the arity/range
    checks) for callers that deliberately build bad netlists.
    """

    def fail(msg: str, ln: str = "") -> "ValueError":
        where = f" in line {ln!r}" if ln else ""
        return ValueError(f"bristol parse{f' [{name}]' if name else ''}: "
                          f"{msg}{where}")

    def ints(parts: List[str], ln: str) -> List[int]:
        try:
            return [int(p) for p in parts]
        except ValueError:
            raise fail("non-integer field", ln) from None

    const_bits = {}
    lines = []
    for ln in text.splitlines():
        ln = ln.strip()
        if ln.startswith("# const:"):
            body = ln[len("# const:"):]
            if "=" not in body:
                raise fail("malformed '# const:' header", ln)
            wires_s, bits_s = body.split("=", 1)
            wires = ints(wires_s.split(), ln)
            bits = bits_s.strip()
            if len(bits) != len(wires) or set(bits) - {"0", "1"}:
                raise fail("const header bits must be one 0/1 per wire", ln)
            const_bits = {w: int(b) for w, b in zip(wires, bits)}
            continue
        if ln.startswith("#"):
            continue
        lines.append(ln)
    if len(lines) < 3:
        raise fail(f"expected >= 3 header lines, got {len(lines)}")
    hdr = ints(lines[0].split(), lines[0])
    if len(hdr) != 2:
        raise fail("header must be '<num_gates> <num_wires>'", lines[0])
    num_gates, num_wires = hdr
    if num_gates < 0 or num_wires <= 0:
        raise fail(f"bad sizes: {num_gates} gates, {num_wires} wires")
    in_hdr = ints(lines[1].split(), lines[1])
    if not in_hdr or len(in_hdr) != in_hdr[0] + 1:
        raise fail("input header must be '<n> <count_1> ... <count_n>'",
                   lines[1])
    in_counts = in_hdr[1:]
    # wires are assigned to inputs first, in declaration order
    cursor = 0
    groups = []
    for c in in_counts:
        groups.append(list(range(cursor, cursor + c)))
        cursor += c
    g_inputs = groups[0] if len(groups) > 0 else []
    e_inputs = groups[1] if len(groups) > 1 else []
    if len(groups) > 2 and not const_bits:
        const_bits = {w: 0 for w in groups[2]}
    out_hdr = ints(lines[2].split(), lines[2])
    if not out_hdr or len(out_hdr) != out_hdr[0] + 1:
        raise fail("output header must be '<n> <count_1> ... <count_n>'",
                   lines[2])
    n_out = sum(out_hdr[1:])
    if n_out > num_wires:
        raise fail(f"{n_out} output wires > {num_wires} total wires")

    arity = {"INV": (1, OP_INV), "NOT": (1, OP_INV),
             "AND": (2, OP_AND), "XOR": (2, OP_XOR)}
    ops, in0, in1, out = [], [], [], []
    for ln in lines[3:]:
        if not ln:
            continue
        parts = ln.split()
        kind = parts[-1].upper()
        if kind not in arity:
            raise fail(f"unsupported gate {kind!r}", ln)
        n_in, opc = arity[kind]
        fields = ints(parts[:-1], ln)
        if len(fields) != 2 + n_in + 1 or fields[0] != n_in \
                or fields[1] != 1:
            raise fail(f"{kind} gate must read '{n_in} 1 "
                       f"<in...> <out> {kind}'", ln)
        ops.append(opc)
        in0.append(fields[2])
        in1.append(fields[2] if n_in == 1 else fields[3])
        out.append(fields[2 + n_in])
    if len(ops) != num_gates:
        raise fail(f"header promises {num_gates} gates, file has "
                   f"{len(ops)}")
    # Bristol convention: outputs are the last n_out wires
    outputs = list(range(num_wires - n_out, num_wires))
    net = Netlist(
        num_wires=num_wires,
        op=np.asarray(ops, np.uint8),
        in0=np.asarray(in0, np.int32),
        in1=np.asarray(in1, np.int32),
        out=np.asarray(out, np.int32),
        garbler_inputs=np.asarray(g_inputs, np.int32),
        evaluator_inputs=np.asarray(e_inputs, np.int32),
        outputs=np.asarray(outputs, np.int32),
        const_bits=const_bits,
        name=name,
    )
    if verify:
        from repro.analysis.netcheck import verify_netlist_strict
        verify_netlist_strict(net)  # raises NetlistError (a ValueError)
    return net

"""Bristol Fashion netlist emit/parse [Tillich & Smart].

Format:
    <num_gates> <num_wires>
    <n_input_values> <wires_per_value...>
    <n_output_values> <wires_per_value...>
    (blank)
    2 1 <a> <b> <out> AND|XOR
    1 1 <a> <out> INV

Input value 0 = garbler inputs, value 1 = evaluator inputs, value 2
(when present) = constant wires (the format has no constants; we emit them
as a third input bundle and record their bits in a `# const:` header
comment, which our parser understands and foreign parsers skip).
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR


def emit(net: Netlist) -> str:
    """Emit with Bristol wire numbering: inputs first, outputs last.

    Gate order already carries the topology, so renumbering is a pure
    permutation of ids.
    """
    outputs = [int(w) for w in net.outputs]
    assert len(set(outputs)) == len(outputs), "duplicate output wires"
    out_set = set(outputs)
    in_groups = [list(map(int, net.garbler_inputs)),
                 list(map(int, net.evaluator_inputs))]
    const_order = sorted(net.const_bits)
    if const_order:
        in_groups.append(const_order)

    remap = {}
    nxt = 0
    for g in in_groups:
        for w in g:
            remap[w] = nxt
            nxt += 1
    n_out = len(outputs)
    tail_start = net.num_wires - n_out
    for g in range(net.num_gates):
        w = int(net.out[g])
        if w not in remap and w not in out_set:
            remap[w] = nxt
            nxt += 1
    for i, w in enumerate(outputs):
        remap[w] = tail_start + i
    # any untouched wires (dangling inputs of nothing) — fill remaining slots
    for w in range(net.num_wires):
        if w not in remap:
            remap[w] = nxt
            nxt += 1

    lines: List[str] = []
    if const_order:
        bits = "".join(str(net.const_bits[w]) for w in const_order)
        mapped = " ".join(str(remap[w]) for w in const_order)
        lines.append(f"# const: {mapped} = {bits}")
    lines.append(f"{net.num_gates} {net.num_wires}")
    lines.append(
        " ".join([str(len(in_groups))] + [str(len(g)) for g in in_groups])
    )
    lines.append(f"1 {n_out}")
    lines.append("")
    names = {OP_AND: "AND", OP_XOR: "XOR", OP_INV: "INV"}
    for g in range(net.num_gates):
        op = int(net.op[g])
        if op == OP_INV:
            lines.append(
                f"1 1 {remap[int(net.in0[g])]} {remap[int(net.out[g])]} INV"
            )
        else:
            lines.append(
                f"2 1 {remap[int(net.in0[g])]} {remap[int(net.in1[g])]} "
                f"{remap[int(net.out[g])]} {names[op]}"
            )
    return "\n".join(lines) + "\n"


def parse(text: str, name: str = "") -> Netlist:
    const_bits = {}
    lines = []
    for ln in text.splitlines():
        ln = ln.strip()
        if ln.startswith("# const:"):
            body = ln[len("# const:"):]
            wires_s, bits_s = body.split("=")
            wires = [int(w) for w in wires_s.split()]
            bits = bits_s.strip()
            const_bits = {w: int(b) for w, b in zip(wires, bits)}
            continue
        if ln.startswith("#"):
            continue
        lines.append(ln)
    hdr = lines[0].split()
    num_gates, num_wires = int(hdr[0]), int(hdr[1])
    in_hdr = list(map(int, lines[1].split()))
    n_in_vals, in_counts = in_hdr[0], in_hdr[1:]
    # wires are assigned to inputs first, in declaration order
    cursor = 0
    groups = []
    for c in in_counts:
        groups.append(list(range(cursor, cursor + c)))
        cursor += c
    g_inputs = groups[0] if len(groups) > 0 else []
    e_inputs = groups[1] if len(groups) > 1 else []
    if len(groups) > 2 and not const_bits:
        const_bits = {w: 0 for w in groups[2]}
    out_hdr = list(map(int, lines[2].split()))
    n_out = sum(out_hdr[1:])

    ops, in0, in1, out = [], [], [], []
    for ln in lines[3:]:
        if not ln:
            continue
        parts = ln.split()
        kind = parts[-1].upper()
        if kind == "INV" or kind == "NOT":
            ops.append(OP_INV)
            in0.append(int(parts[2]))
            in1.append(int(parts[2]))
            out.append(int(parts[3]))
        elif kind in ("AND", "XOR"):
            ops.append(OP_AND if kind == "AND" else OP_XOR)
            in0.append(int(parts[2]))
            in1.append(int(parts[3]))
            out.append(int(parts[4]))
        else:
            raise ValueError(f"unsupported gate {kind}")
    assert len(ops) == num_gates, (len(ops), num_gates)
    # Bristol convention: outputs are the last n_out wires
    outputs = list(range(num_wires - n_out, num_wires))
    return Netlist(
        num_wires=num_wires,
        op=np.asarray(ops, np.uint8),
        in0=np.asarray(in0, np.int32),
        in1=np.asarray(in1, np.int32),
        out=np.asarray(out, np.int32),
        garbler_inputs=np.asarray(g_inputs, np.int32),
        evaluator_inputs=np.asarray(e_inputs, np.int32),
        outputs=np.asarray(outputs, np.int32),
        const_bits=const_bits,
        name=name,
    )

"""Fixed-point arithmetic circuits over ``Word`` bit-vectors.

AND-gate budgets (the GC cost unit — XOR/INV are free):

  * full adder: 1 AND/bit (carry = ((a^c)&(b^c))^c — MAJ identity)
  * mux: 1 AND/bit
  * conventional k×k multiply: k² partial-product ANDs + (k-1)·k adder ANDs
  * XFBQ multiply (§3.2, [12]): partial products become XNORs (free under
    FreeXOR); only the adder tree pays ANDs, plus optional Q-error
    correction terms (a conditional add per operand LSB).

All words are little-endian two's-complement; arithmetic wraps mod 2^k.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core.circuits.builder import CircuitBuilder, Word


# ---------------------------------------------------------------------------
# addition / subtraction
# ---------------------------------------------------------------------------


def add(cb: CircuitBuilder, a: Word, b: Word, cin: Optional[int] = None,
        width: Optional[int] = None) -> Word:
    """a + b (+cin) mod 2^width; width defaults to len(a)."""
    k = width or len(a)
    c = cin if cin is not None else cb.constant(0)
    out: List[int] = []
    for i in range(k):
        ai = a[i] if i < len(a) else cb.constant(0)
        bi = b[i] if i < len(b) else cb.constant(0)
        axc = cb.XOR(ai, c)
        bxc = cb.XOR(bi, c)
        out.append(cb.XOR(axc, bi))
        if i + 1 < k:  # final carry unused
            c = cb.XOR(cb.AND(axc, bxc), c)
    return Word(tuple(out))


def invert(cb: CircuitBuilder, a: Word) -> Word:
    return Word(tuple(cb.INV(x) for x in a))


def sign_extend(cb: CircuitBuilder, a: Word, new_k: int) -> Word:
    """Free: replicate the sign bit."""
    if new_k <= len(a):
        return Word(a.bits[:new_k])
    return Word(a.bits + tuple(a[-1] for _ in range(new_k - len(a))))


def sub(cb: CircuitBuilder, a: Word, b: Word) -> Word:
    return add(cb, a, invert(cb, b), cin=cb.constant(1))


def neg(cb: CircuitBuilder, a: Word) -> Word:
    zero = cb.const_word(0, len(a))
    return sub(cb, zero, a)


def add_const(cb: CircuitBuilder, a: Word, value: int) -> Word:
    return add(cb, a, cb.const_word(value, len(a)))


# ---------------------------------------------------------------------------
# select / compare / shift
# ---------------------------------------------------------------------------


def mux(cb: CircuitBuilder, sel: int, a: Word, b: Word) -> Word:
    """sel ? a : b."""
    return Word(tuple(cb.MUX(sel, x, y) for x, y in zip(a, b)))


def lt_unsigned(cb: CircuitBuilder, a: Word, b: Word) -> int:
    """1 if a < b (unsigned): borrow chain, 1 AND/bit."""
    # borrow_{i+1} = (~a_i & b_i) | (borrow_i & ~(a_i ^ b_i))
    #             = ((a_i ^ borrow) & (b_i ^ borrow)) ^ borrow with a inverted trick:
    borrow = cb.constant(0)
    for ai, bi in zip(a, b):
        na = cb.INV(ai)
        axc = cb.XOR(na, borrow)
        bxc = cb.XOR(bi, borrow)
        borrow = cb.XOR(cb.AND(axc, bxc), borrow)
    return borrow


def lt_signed(cb: CircuitBuilder, a: Word, b: Word) -> int:
    d = sub(cb, a, b)
    # overflow-aware sign: (a-b)_msb ^ overflow; for |values| << 2^(k-1) the
    # plain msb suffices — inputs are range-limited by the fixed-point format.
    return d[-1]


def eq(cb: CircuitBuilder, a: Word, b: Word) -> int:
    acc = cb.constant(1)
    for ai, bi in zip(a, b):
        acc = cb.AND(acc, cb.INV(cb.XOR(ai, bi)))
    return acc


def max_word(cb: CircuitBuilder, a: Word, b: Word, signed=True) -> Word:
    s = lt_signed(cb, a, b) if signed else lt_unsigned(cb, a, b)
    return mux(cb, s, b, a)


def shift_left_const(cb: CircuitBuilder, a: Word, n: int) -> Word:
    k = len(a)
    zeros = tuple(cb.constant(0) for _ in range(min(n, k)))
    return Word((zeros + a.bits)[:k])


def shift_right_const(cb: CircuitBuilder, a: Word, n: int, arithmetic=False) -> Word:
    k = len(a)
    fill = a[-1] if arithmetic else cb.constant(0)
    bits = a.bits[n:] + tuple(fill for _ in range(min(n, k)))
    return Word(bits[:k])


def shift_right_var(cb: CircuitBuilder, a: Word, amount: Word, arithmetic=False) -> Word:
    """Barrel shifter: log2 stages of muxes; amount little-endian."""
    cur = a
    for s, sel in enumerate(amount):
        shifted = shift_right_const(cb, cur, 1 << s, arithmetic)
        cur = mux(cb, sel, shifted, cur)
    return cur


def shift_left_var(cb: CircuitBuilder, a: Word, amount: Word) -> Word:
    cur = a
    for s, sel in enumerate(amount):
        shifted = shift_left_const(cb, cur, 1 << s)
        cur = mux(cb, sel, shifted, cur)
    return cur


# ---------------------------------------------------------------------------
# multiplication
# ---------------------------------------------------------------------------


def _sum_tree(cb: CircuitBuilder, words: List[Word], width: int) -> Word:
    """Balanced binary adder tree."""
    assert words
    cur = list(words)
    while len(cur) > 1:
        nxt = []
        for i in range(0, len(cur) - 1, 2):
            nxt.append(add(cb, cur[i], cur[i + 1], width=width))
        if len(cur) % 2:
            nxt.append(cur[-1])
        cur = nxt
    return cur[0]


def mul_conventional(cb: CircuitBuilder, a: Word, b: Word,
                     width: Optional[int] = None) -> Word:
    """Schoolbook multiply mod 2^width: k² AND partial products + adder tree."""
    k = width or len(a)
    pps: List[Word] = []
    for j in range(min(len(b), k)):
        row = [cb.constant(0)] * j
        for i in range(k - j):
            row.append(cb.AND(a[i], b[j]))
        pps.append(Word(tuple(row[:k])))
    return _sum_tree(cb, pps, k)


def xfbq_encode(cb: CircuitBuilder, a: Word) -> Word:
    """XFBQ(x) = (x >> 1) with MSB set: digit i represents ±2^i via bit.

    value(x̂) = 2·int(bits) − (2^k − 1);  Q error = INV(LSB(x)) ∈ {0,1}
    (free: pure rewiring).
    """
    k = len(a)
    bits = a.bits[1:] + (cb.constant(1),)
    return Word(bits[:k])


def mul_xfbq(
    cb: CircuitBuilder,
    a: Word,
    b: Word,
    width: Optional[int] = None,
    qerror_terms: bool = False,
) -> Word:
    """Multiply via XFBQ digits: partial products are XNOR (free).

    Given â = XFBQ(a), b̂ = XFBQ(b) with values A = 2ia−M, B = 2ib−M
    (ia := int(â bits), M := 2^k−1):

        A·B = Σ_j 2^j · (2·PP_j − M)·(2 b̂_j−1 sign)  …

    concretely: digit product p_ij = XNOR(â_i, b̂_j) represents ±2^{i+j}, so
        A·B = 2·Σ_j 2^j int(PP_j) · 2 − … ⇒ implemented as
        A·B = 4·Σ_j 2^j int(PP_j) − 2M·Σ_j 2^j b̂ … (constants fold)

    We use the direct form: A·B = Σ_{i,j} (2 p_ij − 1) 2^{i+j}
        = 2·Σ_j 2^j·int(PP_j) − M²  where PP_j = Σ_i p_ij 2^i.
    Only the adder tree costs ANDs. With ``qerror_terms``, the exact product
    a·b = (A−eA)(B−eB) is recovered with two conditional adds + a 1-bit AND.
    """
    k = width or len(a)
    ah, bh = xfbq_encode(cb, a), xfbq_encode(cb, b)
    pps: List[Word] = []
    for j in range(min(len(bh), k)):
        row = [cb.constant(0)] * j
        for i in range(k - j):
            # XNOR — free (XOR + INV)
            row.append(cb.INV(cb.XOR(ah[i], bh[j])))
        pps.append(Word(tuple(row[:k])))
    s = _sum_tree(cb, pps, k)  # Σ_j 2^j int(PP_j)  (mod 2^k)
    prod = shift_left_const(cb, s, 1)  # ×2
    m = (1 << k) - 1
    prod = add_const(cb, prod, (-(m * m)) % (1 << k))  # − M² (free adds)

    if qerror_terms:
        # eA = INV(a0), eB = INV(b0); a·b = ÂB̂ − eA·B̂ − eB·Â + eA·eB
        ea, eb = cb.INV(a[0]), cb.INV(b[0])
        # B̂ value = 2·int(bh) − M: assemble as word (2·bh − M)
        bval = add_const(cb, shift_left_const(cb, Word(bh.bits), 1), (-m) % (1 << k))
        aval = add_const(cb, shift_left_const(cb, Word(ah.bits), 1), (-m) % (1 << k))
        zero = cb.const_word(0, k)
        prod = sub(cb, prod, mux(cb, ea, bval, zero))
        prod = sub(cb, prod, mux(cb, eb, aval, zero))
        ee = cb.AND(ea, eb)
        prod = add(cb, prod, Word((ee,) + tuple(cb.constant(0) for _ in range(k - 1))))
    return prod


def mul(cb: CircuitBuilder, a: Word, b: Word, style: str = "xfbq",
        width: Optional[int] = None, qerror_terms: bool = False) -> Word:
    if style == "xfbq":
        return mul_xfbq(cb, a, b, width, qerror_terms)
    return mul_conventional(cb, a, b, width)


def mul_const(cb: CircuitBuilder, a: Word, value: int,
              width: Optional[int] = None) -> Word:
    """Multiply by a public constant: shift-and-add, no partial-product ANDs."""
    k = width or len(a)
    value %= 1 << k
    terms: List[Word] = []
    i = 0
    while value:
        if value & 1:
            terms.append(shift_left_const(cb, a, i))
        value >>= 1
        i += 1
    if not terms:
        return cb.const_word(0, k)
    return _sum_tree(cb, terms, k)


# ---------------------------------------------------------------------------
# fixed-point helpers (scale = 2^frac)
# ---------------------------------------------------------------------------


def fx_mul(cb: CircuitBuilder, a: Word, b: Word, frac: int, style="xfbq",
           qerror_terms=False) -> Word:
    """Fixed-point multiply with arithmetic right-shift by `frac`.

    The product is formed in a word widened by frac+1 bits so values up to
    the format's full integer range cannot wrap before the shift; the
    result is truncated back to k bits (the protocol's local-truncation
    rule).
    """
    k = len(a)
    kw = k + frac + 1
    aw = sign_extend(cb, a, kw)
    bw = sign_extend(cb, b, kw)
    p = mul(cb, aw, bw, style=style, width=kw, qerror_terms=qerror_terms)
    ps = shift_right_const(cb, p, frac, arithmetic=True)
    return Word(ps.bits[:k])

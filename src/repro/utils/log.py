import logging
import sys

_CONFIGURED = False


def get_logger(name: str = "repro") -> logging.Logger:
    global _CONFIGURED
    if not _CONFIGURED:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(
            logging.Formatter("%(asctime)s %(name)s %(levelname)s %(message)s", "%H:%M:%S")
        )
        root = logging.getLogger("repro")
        root.addHandler(handler)
        root.setLevel(logging.INFO)
        _CONFIGURED = True
    return logging.getLogger(name)

"""Version compatibility shims for the jax API surface we rely on."""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` with the check kwarg spelled for the installed
    version (``check_vma`` post-rename, ``check_rep`` before), falling
    back to ``jax.experimental.shard_map`` when it isn't public yet."""
    import inspect

    if hasattr(jax, "shard_map"):
        impl = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as impl
    kw = ("check_vma"
          if "check_vma" in inspect.signature(impl).parameters
          else "check_rep")
    return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                **{kw: check})

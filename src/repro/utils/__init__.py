from repro.utils.tree import (
    tree_size_bytes,
    tree_num_params,
    tree_zeros_like,
    tree_cast,
    fmt_bytes,
)
from repro.utils.log import get_logger

__all__ = [
    "tree_size_bytes",
    "tree_num_params",
    "tree_zeros_like",
    "tree_cast",
    "fmt_bytes",
    "get_logger",
]

"""Pytree helpers shared by both planes."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_num_params(tree) -> int:
    """Total number of array elements in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_size_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_cast(tree, dtype):
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}EiB"

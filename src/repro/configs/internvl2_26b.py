"""InternVL2-26B [arXiv:2404.16821; hf] — InternViT + InternLM2 backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 (padded to 92672 for
16-way vocab sharding). The InternViT frontend is a stub: input_specs()
provides precomputed patch embeddings prepended to the token sequence.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        input_mode="tokens+image",
        num_image_tokens=256,
        rope_theta=1000000.0,
    )
)

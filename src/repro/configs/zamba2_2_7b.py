"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention block.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
One *shared* attention+MLP block (single weight set) is applied every 6
Mamba2 blocks with per-invocation LoRA adapters (rank 64), following the
Zamba2 design.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_chunk=256,
        attn_every=6,
        shared_attn_lora_rank=64,
        rope_theta=10000.0,
    )
)

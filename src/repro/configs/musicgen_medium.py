"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.
Modality frontend (EnCodec) is a stub: input_specs() provides precomputed
frame embeddings (task spec). LayerNorm + (non-gated) GELU MLP as the
original MusicGen transformer.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        norm_type="layernorm",
        activation="gelu",
        gated_mlp=False,
        input_mode="embeddings",
        rope_theta=10000.0,
    )
)

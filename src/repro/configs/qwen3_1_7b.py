"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family; hf] — qk_norm + GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
    )
)

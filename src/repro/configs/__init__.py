"""One module per assigned architecture (plus the paper's own BERT-base-PiT).

Import side effect: registers the config. ``repro.config.get_config`` loads
all of these lazily.
"""

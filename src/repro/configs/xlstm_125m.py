"""xLSTM-125M [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

12L d_model=768 4H vocab=50304, d_ff=0 (xLSTM blocks carry their own
up/down projections). Every 6th block is sLSTM (ratio ~ xLSTM[5:1]),
rest mLSTM.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50304,
        slstm_every=6,
        norm_type="layernorm",
        causal=True,
    )
)

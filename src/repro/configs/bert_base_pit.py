"""BERT-base — the APINT paper's own evaluation model (Fig 8, 128 tokens).

12L d_model=768 12H d_ff=3072 vocab=30522, bidirectional (encoder), LayerNorm,
GELU. This is the model the privacy-plane benchmarks reproduce the paper's
latency/accuracy breakdowns on.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="bert-base-pit",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=30522,
        causal=False,
        norm_type="layernorm",
        activation="gelu",
        gated_mlp=False,
        rope_theta=0.0,  # BERT uses learned positions; we use absolute-pos table
    )
)

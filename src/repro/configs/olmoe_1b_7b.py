"""OLMoE-1B-7B [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304, MoE 64 experts top-8.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        num_experts=64,
        num_experts_per_token=8,
        moe_impl="a2a",
        # moe_combine stays "psum": the explicit psum_scatter variant was
        # REFUTED by the isolated A/B (§Perf #5) — XLA already converts
        # psum+slice to reduce-scatter, and the manual scatter's transpose
        # costs an extra all-gather in backward.
        qk_norm=True,  # OLMoE uses QK-norm
        rope_theta=10000.0,
    )
)

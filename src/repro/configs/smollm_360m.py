"""SmolLM-360M [hf:HuggingFaceTB/SmolLM family; hf] — small llama arch.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
15 heads not divisible by model=16: sequence-sharded attention fallback.
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49152,
        rope_theta=10000.0,
    )
)

"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1.
40 heads is not divisible by the 16-way model axis: attention activations are
sequence-sharded (see models/sharding.py fallbacks), weights shard on the
flattened head*head_dim dim which IS divisible (5120/16).
"""

from repro.config import ModelConfig, register_config

CONFIG = register_config(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        num_experts=16,
        num_experts_per_token=1,
        moe_impl="a2a",  # moe_combine="psum": see §Perf #5 (scatter refuted)
        rope_theta=500000.0,
    )
)

"""``python -m repro.analysis`` — same CLI as ``scripts/lint.py``."""

import sys

from repro.analysis.cli import main

if __name__ == "__main__":
    sys.exit(main())

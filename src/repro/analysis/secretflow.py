"""AST taint analysis: secret material must not reach the wire or logs.

Sources (secret-typed values):

* attribute reads of known secret fields — the FreeXOR delta
  (``gcirc.r``), zero-labels (``input_zero``/``wire_zero``/``e_zero``),
  output masks (``masks``/``mask_enc``), linear masks (``r1``,
  ``s_mask``, ``he_mask``), the HE secret key (``sk``);
* calls that mint secret material — ``random_delta``, ``random_labels``,
  ``input_zeros``;
* garbling keys (``PRNGKey``/``_next_key`` calls, the ``.key`` attribute):
  a PRG seed that expands to *both* labels of a wire is equivalent to
  the FreeXOR delta — shipping it hands the evaluator every complement
  label (the wire-v2 seed-stream rule);
* draws from a party RNG (``*.rng.integers(...)`` etc.): every RNG draw
  in the protocol is share/mask material by construction.

Sanitizers (the approved masking/opening APIs — their *results* are safe
to transmit by protocol design, whatever went in):

* ``encode_inputs`` / ``choose_labels`` / ``ot_labels`` /
  ``const_wires_labels`` — bits become active labels (masked by the
  unknown wire-zero/delta);
* ``stream_seed`` — the mask-label stream seed (wire v2): it expands
  only to *active* labels the evaluator is entitled to, never a
  complement pair, so the seed itself is transmittable by design.
  Note ``pack_seed_stream`` is deliberately NOT a sanitizer — framing a
  garbling key as a seed-stream record must stay flagged;
* ``respond`` — ``IknpSender.respond``: each label in the masked pair
  is one-time-padded by a correlation-robust hash of the receiver's
  column;
* ``remask_output`` / ``reconstruct_shared`` / ``output_shared`` /
  ``decode_outputs`` — the share-opening identities;
* ``ct_pack`` / ``ct_pack_rows`` — HE encryption (simulated);
* ``deal_matmul_triple`` — Beaver dealing: the ``*1`` halves exist to be
  sent.

Public projections: reading ``.tables`` / ``.output_perm`` / ``.net`` off
a tainted object is clean — garbled tables and permute bits are exactly
the transmittable part of a ``GarbledCircuit``.

Sinks: transport sends (``send``/``sendall``/``_send_control``/
``_send_sim``/``_send_segs``/``write``), log calls (``print``,
``logging``/``logger``/``log``/``warnings`` methods), trace-span
attributes (``obs.span``/``instant``/``timer`` — traces are exported
artifacts, so a span attribute is a log-grade channel), and exception
construction. A separate rule (``exc-to-wire``) flags *any* exception
text or traceback flowing into a send — exception reprs interpolate
values, so shipping them to the peer is an exfiltration channel even
when no tracked secret is syntactically visible.

The analysis is per-function and flow-insensitive (assignment taint is
iterated to a fixpoint, then sinks are scanned), which is the right
cost/precision point for this codebase: protocol functions are short and
single-assignment-ish.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.report import Finding

SECRET_ATTRS = {
    "sk", "r", "input_zero", "wire_zero", "e_zero",
    "masks", "mask_enc", "s_mask", "he_mask", "r1", "delta",
    "key",  # the garbling PRNG key: expands to both labels of every wire
}
SECRET_CALLS = {"random_delta", "random_labels", "input_zeros",
                "PRNGKey", "_next_key"}  # garbling-key mints
RNG_DRAWS = {"integers", "bits", "random", "normal", "uniform", "choice"}
SANITIZERS = {
    "encode_inputs", "choose_labels", "ot_labels", "const_wires_labels",
    "remask_output", "reconstruct_shared", "output_shared",
    "decode_outputs", "ct_pack", "ct_pack_rows", "deal_matmul_triple",
    "share",  # SS.share: x -> (fresh mask, x - mask), both OTP-uniform
    "stream_seed",  # v2 mask-label stream: expands to active labels only
    "respond",  # IknpSender.respond: labels OTP'd by the CRH of t⊕s·u
}
PUBLIC_ATTRS = {"tables", "output_perm", "net", "name", "shape", "dtype"}
SEND_SINKS = {"send", "sendall", "_send_control", "_send_sim",
              "_send_segs", "send_msg", "write"}
LOG_RECEIVERS = {"logging", "logger", "log", "warnings"}
#: tracing sinks (repro.obs): span attributes are exported to trace
#: artifacts, so they are a log-grade exfiltration channel — sizes,
#: tags and counts only, never label/mask/key material
SPAN_SINKS = {"span", "instant", "timer"}

#: files the CI lint covers by default (repo-relative)
DEFAULT_PATHS = (
    "src/repro/core/protocol.py",
    "src/repro/core/session.py",
    "src/repro/net/party.py",
    "src/repro/net/wire.py",
    "src/repro/net/faults.py",
    "src/repro/net/resilience.py",
    "src/repro/serve/__init__.py",
    "src/repro/serve/errors.py",
    "src/repro/serve/gateway.py",
    "src/repro/serve/private_engine.py",
    "src/repro/obs/__init__.py",
    "src/repro/obs/tracer.py",
)


def _call_name(func: ast.expr) -> str:
    """Rightmost name of a call target: ``G.encode_inputs`` -> that."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _attr_chain(node: ast.expr) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


class _FunctionTaint:
    """Taint state for one function body."""

    def __init__(self, fn: ast.AST, path: str, qualname: str):
        self.fn = fn
        self.path = path
        self.qualname = qualname
        self.tainted: Set[str] = set()
        self.exc_names: Set[str] = set()  # `except E as e` bindings
        self.findings: List[Finding] = []
        # parameters named like secret fields carry secrets by convention
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
                if a.arg in SECRET_ATTRS:
                    self.tainted.add(a.arg)

    # -- expression classification -------------------------------------
    def is_tainted(self, node: ast.expr) -> bool:
        """Does this expression carry secret material?"""
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in SECRET_ATTRS:
                return True
            if node.attr in PUBLIC_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            name = _call_name(node.func)
            if name in SANITIZERS:
                return False
            if name in SECRET_CALLS:
                return True
            if name in RNG_DRAWS and isinstance(node.func, ast.Attribute):
                chain = _attr_chain(node.func)
                if any("rng" in part for part in chain[:-1]):
                    return True
            return any(self.is_tainted(a) for a in node.args) or any(
                self.is_tainted(kw.value) for kw in node.keywords
            ) or self.is_tainted(node.func)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(v is not None and self.is_tainted(v)
                       for v in node.values)
        if isinstance(node, ast.JoinedStr):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.FormattedValue):
            return self.is_tainted(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, ast.Compare):
            return False  # booleans of secrets are a different (timing) story
        return False

    def mentions_exc_text(self, node: ast.AST) -> bool:
        """Exception text / traceback reaching this expression?
        ``type(e).__name__`` is allowed — a class name carries none of
        the interpolated values an exception repr does."""
        if isinstance(node, ast.Call):
            name = _call_name(node.func)
            if name == "type":
                return False
            if name in ("format_exc", "format_exception", "print_exc"):
                return True
        if isinstance(node, ast.Name):
            return node.id in self.exc_names
        return any(self.mentions_exc_text(c)
                   for c in ast.iter_child_nodes(node))

    # -- statement walk ------------------------------------------------
    def _bind(self, target: ast.expr, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, tainted)
        elif isinstance(target, ast.Subscript):
            # storing a secret into a container taints the container
            if tainted and isinstance(target.value, ast.Name):
                self.tainted.add(target.value.id)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted)

    def propagate(self) -> None:
        for _ in range(4):  # fixpoint: taint only grows, small bodies
            before = len(self.tainted)
            for node in ast.walk(self.fn):
                if isinstance(node, ast.Assign):
                    t = self.is_tainted(node.value)
                    for tgt in node.targets:
                        self._bind(tgt, t)
                elif isinstance(node, ast.AnnAssign) and node.value:
                    self._bind(node.target, self.is_tainted(node.value))
                elif isinstance(node, ast.AugAssign):
                    if self.is_tainted(node.value):
                        self._bind(node.target, True)
                elif isinstance(node, ast.For):
                    self._bind(node.target, self.is_tainted(node.iter))
                elif isinstance(node, ast.withitem) and node.optional_vars:
                    self._bind(node.optional_vars,
                               self.is_tainted(node.context_expr))
                elif isinstance(node, ast.ExceptHandler) and node.name:
                    self.exc_names.add(node.name)
                elif isinstance(node, (ast.NamedExpr,)):
                    self._bind(node.target, self.is_tainted(node.value))
            if len(self.tainted) == before:
                break

    def scan_sinks(self) -> None:
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Call):
                self._scan_call(node)
            elif isinstance(node, ast.Raise) and node.exc is not None:
                exc = node.exc
                args = exc.args + [kw.value for kw in exc.keywords] \
                    if isinstance(exc, ast.Call) else [exc]
                for a in args:
                    if self.is_tainted(a):
                        self.findings.append(self._finding(
                            "secret-to-exception", node,
                            "secret-derived value interpolated into an "
                            "exception message"))
                        break

    def _scan_call(self, node: ast.Call) -> None:
        name = _call_name(node.func)
        args = list(node.args) + [kw.value for kw in node.keywords]
        if name in SEND_SINKS:
            for a in args:
                if self.is_tainted(a):
                    self.findings.append(self._finding(
                        "secret-to-wire", node,
                        f"secret-derived value reaches transport sink "
                        f"{name}() without an approved masking/opening "
                        f"API"))
                    break
            for a in args:
                if self.mentions_exc_text(a):
                    self.findings.append(self._finding(
                        "exc-to-wire", node,
                        f"exception text/traceback sent to the peer via "
                        f"{name}() — exception reprs interpolate values "
                        f"and can embed secrets"))
                    break
        if name in SPAN_SINKS:
            for a in args:
                if self.is_tainted(a):
                    self.findings.append(self._finding(
                        "secret-to-span", node,
                        f"secret-derived value recorded as a span "
                        f"attribute via {name}() — traces are exported "
                        f"artifacts; record sizes/tags/counts, never "
                        f"payloads"))
                    break
        is_log = name == "print" or (
            isinstance(node.func, ast.Attribute)
            and _attr_chain(node.func)[0] in LOG_RECEIVERS)
        if is_log:
            for a in args:
                if self.is_tainted(a):
                    self.findings.append(self._finding(
                        "secret-to-log", node,
                        f"secret-derived value reaches log sink {name}()"))
                    break

    def _finding(self, rule: str, node: ast.AST, msg: str) -> Finding:
        return Finding("secretflow", rule, self.path,
                       getattr(node, "lineno", 0), self.qualname, msg)


def _functions(tree: ast.Module):
    """Yield (qualname, node) for every function, outermost class-aware."""
    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from walk(child, q + ".")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)
    yield from walk(tree, "")


def lint_file(path: str, rel: Optional[str] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    tree = ast.parse(src, filename=path)
    rel = rel or path
    findings: List[Finding] = []
    for qual, fn in _functions(tree):
        ft = _FunctionTaint(fn, rel, qual)
        ft.propagate()
        ft.scan_sinks()
        findings.extend(ft.findings)
    return findings


def run_secretflow(root: str, paths=None) -> List[Finding]:
    import os

    findings: List[Finding] = []
    for rel in (paths or DEFAULT_PATHS):
        p = rel if os.path.isabs(rel) else os.path.join(root, rel)
        if not os.path.exists(p):
            continue
        findings.extend(lint_file(p, os.path.relpath(p, root)))
    return findings

"""Command line for the static-analysis suite.

Run from the repo root (also available as ``python -m repro.analysis``)::

    python scripts/lint.py --all --baseline analysis/baseline.json
    python scripts/lint.py --netlists              # pillar 1 only
    python scripts/lint.py --secretflow path.py    # lint specific files
    python scripts/lint.py --all --json            # machine-readable
    python scripts/lint.py --all --update-baseline # accept current state

Exit status: 0 when every finding is baselined (or none), 1 when new
findings exist, 2 on usage/internal error. The baseline ratchets counted
findings: a count may shrink freely but any growth fails the lint.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis import jit_hygiene, netcheck, secretflow
from repro.analysis.report import (
    Baseline,
    Finding,
    diff,
    render_json,
    render_text,
)


def _detect_root(start: Optional[str] = None) -> str:
    d = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.isdir(os.path.join(d, "src", "repro")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start or os.getcwd())
        d = parent


def collect_findings(root: str, netlists: bool = False,
                     secret: bool = False, jit: bool = False,
                     paths: Optional[List[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    if netlists:
        findings.extend(netcheck.run_netcheck())
    if secret:
        findings.extend(secretflow.run_secretflow(root, paths or None))
    if jit:
        if paths:
            findings.extend(jit_hygiene.run_jit_hygiene(
                root, jit_paths=paths, proto_paths=paths))
        else:
            findings.extend(jit_hygiene.run_jit_hygiene(root))
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="lint.py", description="PiT static analysis")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (netlists + secretflow + jit)")
    ap.add_argument("--netlists", action="store_true",
                    help="netlist verifier + dataflow over the circuit "
                         "generator inventory")
    ap.add_argument("--secretflow", action="store_true",
                    help="secret-flow taint lint over the protocol files")
    ap.add_argument("--jit", action="store_true",
                    help="jit-hygiene + protocol RNG lint")
    ap.add_argument("--baseline", metavar="PATH",
                    help="baseline JSON of accepted findings")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline to accept current findings")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of text")
    ap.add_argument("--root", metavar="DIR",
                    help="repo root (default: auto-detect from cwd)")
    ap.add_argument("paths", nargs="*",
                    help="restrict secretflow/jit passes to these files")
    args = ap.parse_args(argv)

    if args.all:
        args.netlists = args.secretflow = args.jit = True
    if not (args.netlists or args.secretflow or args.jit):
        ap.error("select at least one pass (--all / --netlists / "
                 "--secretflow / --jit)")
    if args.update_baseline and not args.baseline:
        ap.error("--update-baseline needs --baseline PATH")

    root = _detect_root(args.root)
    try:
        findings = collect_findings(
            root, netlists=args.netlists, secret=args.secretflow,
            jit=args.jit, paths=args.paths or None)
    except SyntaxError as e:
        print(f"lint.py: cannot parse {e.filename}: {e}", file=sys.stderr)
        return 2

    baseline = None
    if args.baseline:
        bp = args.baseline if os.path.isabs(args.baseline) else \
            os.path.join(root, args.baseline)
        if args.update_baseline:
            doc = Baseline.from_findings(findings)
            with open(bp, "w", encoding="utf-8") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"baseline written: {bp} ({len(findings)} finding(s) — "
                  f"fill in each 'reason')")
            return 0
        if os.path.exists(bp):
            baseline = Baseline.load(bp)
        else:
            print(f"lint.py: baseline {bp} not found", file=sys.stderr)
            return 2

    new = diff(findings, baseline)
    print(render_json(findings, new) if args.as_json
          else render_text(findings, new))
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())

"""Findings, baseline diffing and rendering for the static-analysis CLI.

A finding is keyed by ``(tool, rule, path, symbol)`` — deliberately
*without* the line number, so unrelated edits that shift lines don't
invalidate the baseline. Counted findings (e.g. removable-AND totals per
generator) carry a ``count``; a baselined key suppresses the finding as
long as the current count does not exceed the accepted one, so the
baseline doubles as a ratchet: counts may only go down without a
baseline update.

Baseline entries carry a mandatory ``reason`` string — the "explicitly
baselined with a comment" rule: nothing is grandfathered silently.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class Finding:
    tool: str  # "netcheck" | "secretflow" | "jit"
    rule: str  # short rule id, e.g. "secret-to-wire"
    path: str  # repo-relative file, or "netlist:<name>" for circuits
    line: int  # 1-based; 0 when the finding has no source location
    symbol: str  # enclosing function / generator name (baseline key part)
    message: str
    count: int = 1

    @property
    def key(self) -> Tuple[str, str, str, str]:
        return (self.tool, self.rule, self.path, self.symbol)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.tool}/{self.rule}] {self.message}"

    def to_dict(self) -> Dict:
        return asdict(self)


@dataclass
class Baseline:
    """Accepted findings, loaded from / saved to ``analysis/baseline.json``."""

    entries: Dict[Tuple[str, str, str, str], Dict] = field(
        default_factory=dict)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, "r", encoding="utf-8") as f:
            data = json.load(f)
        if data.get("version") != 1:
            raise ValueError(
                f"unsupported baseline version {data.get('version')!r} "
                f"in {path}")
        bl = cls()
        for e in data.get("findings", []):
            missing = [k for k in ("tool", "rule", "path", "symbol", "reason")
                       if k not in e]
            if missing:
                raise ValueError(
                    f"baseline entry {e!r} missing keys {missing} — every "
                    f"accepted finding needs an explicit reason")
            bl.entries[(e["tool"], e["rule"], e["path"], e["symbol"])] = e
        return bl

    @classmethod
    def from_findings(cls, findings: List[Finding],
                      reason: str = "TODO: justify") -> Dict:
        """Serializable baseline doc accepting ``findings`` as-is."""
        return {
            "version": 1,
            "findings": [
                {"tool": f.tool, "rule": f.rule, "path": f.path,
                 "symbol": f.symbol, "count": f.count, "reason": reason}
                for f in findings
            ],
        }

    def accepts(self, f: Finding) -> bool:
        e = self.entries.get(f.key)
        if e is None:
            return False
        return f.count <= int(e.get("count", 1))


def diff(findings: List[Finding],
         baseline: Optional[Baseline]) -> List[Finding]:
    """Findings not covered by the baseline (all of them when no baseline)."""
    if baseline is None:
        return list(findings)
    return [f for f in findings if not baseline.accepts(f)]


def render_text(findings: List[Finding], new: List[Finding]) -> str:
    lines = [f.render() for f in sorted(
        new, key=lambda f: (f.path, f.line, f.rule))]
    n_base = len(findings) - len(new)
    tail = (f"{len(new)} new finding(s), {n_base} baselined"
            if n_base else f"{len(new)} finding(s)")
    lines.append(tail if new or n_base else "clean: no findings")
    return "\n".join(lines)


def render_json(findings: List[Finding], new: List[Finding]) -> str:
    return json.dumps(
        {
            "total": len(findings),
            "new": len(new),
            "baselined": len(findings) - len(new),
            "findings": [f.to_dict() for f in findings],
            "new_findings": [f.to_dict() for f in new],
        },
        indent=2, sort_keys=True)

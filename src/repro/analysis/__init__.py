"""Static analysis for the PiT stack: netlist dataflow + protocol linters.

Two pillars, one CLI (``scripts/lint.py`` / ``python -m repro.analysis``):

* :mod:`repro.analysis.netcheck` — structural verification and dataflow
  passes (constant propagation, dead-gate/dead-wire detection, CSE
  duplicate detection, level histograms) over
  :class:`repro.core.netlist.Netlist`. Its counters feed
  ``Netlist.stats()`` / ``LevelPlan.stats()`` and the ``bench_gc_eval``
  JSON, and are the measurement front-end for the ROADMAP's
  AND-minimization item.
* :mod:`repro.analysis.secretflow` / :mod:`repro.analysis.jit_hygiene` —
  AST linters over the protocol and kernel sources: secret-typed values
  (labels, FreeXOR delta, masks, shares) must not reach a transport
  send, log call or exception message except through an approved
  masking/opening API; jitted bodies must not branch in Python on traced
  values, call host numpy on traced values, or draw from global RNGs.

Findings diff against a checked-in baseline (``analysis/baseline.json``)
so CI fails only on *new* findings; see :mod:`repro.analysis.report`.
"""

from repro.analysis.report import Baseline, Finding  # noqa: F401
from repro.analysis.netcheck import (  # noqa: F401
    NetlistError,
    analyze_netlist,
    dataflow_summary,
    verify_netlist,
    verify_netlist_strict,
)

"""Netlist structural verification and dataflow analysis (pillar 1).

``verify_netlist`` checks the invariants every consumer of a
:class:`~repro.core.netlist.Netlist` assumes but none re-checks:
topological gate order, no duplicate-driven wires, op codes and wire ids
in range, ``const_bits`` consistency (const wires are neither gate
outputs nor party inputs, bits are 0/1), INV arity, no reads of undriven
wires, and outputs that are actually driven and reachable from party
inputs. ``compile_level_plan`` would either crash opaquely or —
worse — silently garble the wrong function on such a netlist; the
Bristol import path routes through :func:`verify_netlist_strict` so
malformed files die with a clear ``ValueError`` instead.

``analyze_netlist`` runs the dataflow passes:

* **constant propagation** — forward walk with an alias lattice
  (wire -> value token; negation is token^1) folding
  XOR/AND/INV over known bits, ``x op x`` and ``x op !x``;
* **duplicate detection (CSE)** — structural hashing over canonical
  input tokens, so a duplicate of a folded gate is caught too;
* **dead-gate / dead-wire detection** — backward reachability from the
  netlist outputs;
* **histograms** — per-level AND population and live-wire counts.

A gate is *removable* when any pass proves it: dead, foldable to a
constant/alias, or a duplicate. ``removable_and`` is the count the
ROADMAP's AND-minimization item optimizes; it is folded into
``Netlist.stats()`` / ``LevelPlan.stats()`` (and from there the
``bench_gc_eval`` JSON) via :func:`dataflow_summary`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR

__all__ = [
    "NetlistError",
    "verify_netlist",
    "verify_netlist_strict",
    "analyze_netlist",
    "dataflow_summary",
    "generator_registry",
    "NetReport",
]


class NetlistError(ValueError):
    """A netlist violates a structural invariant."""


# ---------------------------------------------------------------------------
# structural verification
# ---------------------------------------------------------------------------


def verify_netlist(net: Netlist) -> List[str]:
    """All structural violations in ``net`` (empty list == well-formed)."""
    errs: List[str] = []
    G, W = net.num_gates, net.num_wires
    op, in0, in1, out = net.op, net.in0, net.in1, net.out
    if not (len(in0) == len(in1) == len(out) == G):
        return [f"gate arrays disagree on length: op={G} in0={len(in0)} "
                f"in1={len(in1)} out={len(out)}"]

    bad_op = np.nonzero(~np.isin(op, (OP_XOR, OP_AND, OP_INV)))[0]
    if len(bad_op):
        errs.append(f"gate {bad_op[0]}: op code {int(op[bad_op[0]])} "
                    f"not in {{XOR=0, AND=1, INV=2}}")

    for label, arr in (("in0", in0), ("in1", in1), ("out", out)):
        if G and (arr.min() < 0 or arr.max() >= W):
            g = int(np.nonzero((arr < 0) | (arr >= W))[0][0])
            errs.append(f"gate {g}: {label} wire {int(arr[g])} out of "
                        f"range [0, {W})")
    for label, arr in (("garbler input", net.garbler_inputs),
                       ("evaluator input", net.evaluator_inputs),
                       ("output", net.outputs)):
        a = np.asarray(arr)
        if len(a) and (a.min() < 0 or a.max() >= W):
            errs.append(f"{label} wire out of range [0, {W})")
    for w, b in net.const_bits.items():
        if not (0 <= int(w) < W):
            errs.append(f"const wire {w} out of range [0, {W})")
        if int(b) not in (0, 1):
            errs.append(f"const wire {w}: bit {b!r} is not 0/1")
    if errs:
        return errs  # range errors poison everything below

    inv_bad = np.nonzero((op == OP_INV) & (in0 != in1))[0]
    if len(inv_bad):
        g = int(inv_bad[0])
        errs.append(f"gate {g}: INV requires in1 == in0, got "
                    f"({int(in0[g])}, {int(in1[g])})")

    # exactly one driver per wire; drivers must not hit inputs/consts
    driver = np.full(W, -1, np.int64)
    for g in range(G):
        w = int(out[g])
        if driver[w] >= 0:
            errs.append(f"gate {g}: wire {w} already driven by gate "
                        f"{int(driver[w])} (duplicate driver)")
        driver[w] = g
    inputs = set(map(int, net.garbler_inputs)) | set(
        map(int, net.evaluator_inputs))
    dup_in = set(map(int, net.garbler_inputs)) & set(
        map(int, net.evaluator_inputs))
    for w in sorted(dup_in):
        errs.append(f"wire {w} claimed by both garbler and evaluator inputs")
    for w in sorted(inputs):
        if driver[w] >= 0:
            errs.append(f"input wire {w} is driven by gate {int(driver[w])}")
    for w in sorted(net.const_bits):
        w = int(w)
        if driver[w] >= 0:
            errs.append(f"const wire {w} is driven by gate {int(driver[w])} "
                        f"(conflicting const_bits)")
        if w in inputs:
            errs.append(f"const wire {w} is also a party input "
                        f"(conflicting const_bits)")

    # topological order + no reads of undriven, non-source wires
    defined = np.zeros(W, bool)
    defined[list(inputs)] = True
    defined[[int(w) for w in net.const_bits]] = True
    seen_driven = np.zeros(W, bool)
    for g in range(G):
        for w in ((int(in0[g]),) if op[g] == OP_INV
                  else (int(in0[g]), int(in1[g]))):
            if seen_driven[w] or defined[w]:
                continue
            if driver[w] >= 0:
                errs.append(f"gate {g}: reads wire {w} before gate "
                            f"{int(driver[w])} drives it (not topological)")
            else:
                errs.append(f"gate {g}: reads dangling wire {w} (never "
                            f"driven, not an input or constant)")
            defined[w] = True  # report each wire once
        seen_driven[int(out[g])] = True

    outs = [int(w) for w in net.outputs]
    if len(set(outs)) != len(outs):
        errs.append("duplicate wires in outputs")
    for w in outs:
        if driver[w] < 0 and w not in inputs and w not in net.const_bits:
            errs.append(f"output wire {w} is undriven")

    # outputs reachable from party inputs (a constant-only output computes
    # a public value inside GC — almost certainly a generator bug)
    if inputs:
        reach = np.zeros(W, bool)
        reach[list(inputs)] = True
        for g in range(G):
            r = reach[int(in0[g])]
            if op[g] != OP_INV:
                r = r or reach[int(in1[g])]
            if r:
                reach[int(out[g])] = True
        for w in outs:
            if 0 <= w < W and not reach[w] and w not in inputs \
                    and w not in net.const_bits:
                # declared const outputs are fine (folding can prove an
                # output bit, e.g. XFBQ's low product bit); an *undeclared*
                # input-independent output is a generator bug
                errs.append(f"output wire {w} is not reachable from any "
                            f"party input")
    return errs


def verify_netlist_strict(net: Netlist) -> None:
    """Raise :class:`NetlistError` on the first structural violations."""
    errs = verify_netlist(net)
    if errs:
        name = f" {net.name!r}" if net.name else ""
        head = "; ".join(errs[:4])
        more = f" (+{len(errs) - 4} more)" if len(errs) > 4 else ""
        raise NetlistError(f"malformed netlist{name}: {head}{more}")


# ---------------------------------------------------------------------------
# dataflow passes
# ---------------------------------------------------------------------------


@dataclass
class NetReport:
    """Dataflow counters for one netlist. ``removable_and`` is the count
    of AND gates provably deletable (dead OR const-foldable OR duplicate)
    — each one saves a 32-byte garbled table and two/four hash lanes."""

    name: str
    gates: int
    and_gates: int
    dead_gates: int
    dead_and: int
    foldable_gates: int
    foldable_and: int
    dup_gates: int
    dup_and: int
    removable_and: int
    dead_wires: int
    and_per_level: np.ndarray = field(default_factory=lambda: np.zeros(0))
    live_per_level: np.ndarray = field(default_factory=lambda: np.zeros(0))

    def summary(self) -> Dict[str, int]:
        return {
            "dead_gates": self.dead_gates,
            "dead_and": self.dead_and,
            "foldable_and": self.foldable_and,
            "dup_and": self.dup_and,
            "removable_and": self.removable_and,
            "dead_wires": self.dead_wires,
        }


# alias-lattice tokens: fresh values get even tokens (2*wire), negation is
# token^1, known constants use CONST0/CONST1 and are handled by value
_CONST0, _CONST1, _UNK = -2, -4, -9


def analyze_netlist(net: Netlist, histograms: bool = False) -> NetReport:
    """Run constant propagation + CSE + liveness over ``net``."""
    G, W = net.num_gates, net.num_wires
    op, in0, in1, out = net.op, net.in0, net.in1, net.out

    # value per wire: _CONST0/_CONST1 when known, else an alias token
    tok = np.full(W, _UNK, np.int64)
    src = np.ones(W, bool)
    if G:
        src[out] = False
    for w in np.nonzero(src)[0]:
        tok[w] = 2 * int(w)
    for w, b in net.const_bits.items():
        tok[int(w)] = _CONST1 if int(b) else _CONST0

    def neg(t: int) -> int:
        if t == _CONST0:
            return _CONST1
        if t == _CONST1:
            return _CONST0
        return t ^ 1

    foldable = np.zeros(G, bool)
    dup = np.zeros(G, bool)
    cse: Dict[Tuple[int, int, int], int] = {}
    for g in range(G):
        o = int(op[g])
        ta = int(tok[in0[g]])
        if o == OP_INV:
            r = neg(ta)
            if r in (_CONST0, _CONST1):
                foldable[g] = True
            else:
                key = (OP_INV, ta, ta)
                prev = cse.get(key)
                if prev is not None:
                    dup[g] = True
                    r = prev
                else:
                    cse[key] = r
            tok[out[g]] = r
            continue
        tb = int(tok[in1[g]])
        consts = {_CONST0, _CONST1}
        r = None
        if o == OP_XOR:
            if ta in consts and tb in consts:
                r = _CONST1 if (ta != tb) else _CONST0
            elif ta == _CONST0:
                r = tb
            elif tb == _CONST0:
                r = ta
            elif ta == _CONST1:
                r = neg(tb)
            elif tb == _CONST1:
                r = neg(ta)
            elif ta == tb:
                r = _CONST0
            elif ta == neg(tb):
                r = _CONST1
        else:  # AND
            if ta == _CONST0 or tb == _CONST0:
                r = _CONST0
            elif ta == _CONST1:
                r = tb
            elif tb == _CONST1:
                r = ta
            elif ta == tb:
                r = ta
            elif ta == neg(tb):
                r = _CONST0
        if r is not None:
            foldable[g] = True
            tok[out[g]] = r
            continue
        key = (o, min(ta, tb), max(ta, tb))
        prev = cse.get(key)
        if prev is not None:
            dup[g] = True
            tok[out[g]] = prev
        else:
            r = 2 * int(out[g])
            cse[key] = r
            tok[out[g]] = r

    # backward reachability from outputs (over the original structure)
    needed = np.zeros(W, bool)
    if len(net.outputs):
        needed[np.asarray(net.outputs, np.int64)] = True
    live = np.zeros(G, bool)
    for g in range(G - 1, -1, -1):
        if needed[out[g]]:
            live[g] = True
            needed[in0[g]] = True
            if op[g] != OP_INV:
                needed[in1[g]] = True
    dead = ~live

    read = np.zeros(W, bool)
    if G:
        read[in0] = True
        ni = op != OP_INV
        read[in1[ni]] = True
    is_out = np.zeros(W, bool)
    if len(net.outputs):
        is_out[np.asarray(net.outputs, np.int64)] = True
    driven = np.zeros(W, bool)
    if G:
        driven[out] = True
    dead_wires = int(np.sum(driven & ~read & ~is_out))

    is_and = op == OP_AND
    removable = dead | foldable | dup

    rep = NetReport(
        name=net.name,
        gates=G,
        and_gates=int(is_and.sum()),
        dead_gates=int(dead.sum()),
        dead_and=int((dead & is_and).sum()),
        foldable_gates=int(foldable.sum()),
        foldable_and=int((foldable & is_and).sum()),
        dup_gates=int(dup.sum()),
        dup_and=int((dup & is_and).sum()),
        removable_and=int((removable & is_and).sum()),
        dead_wires=dead_wires,
    )
    if histograms:
        levels = net.levels()
        rep.and_per_level = np.array(
            [int(is_and[lv].sum()) for lv in levels], np.int64)
        # wires written at or before each level and still needed after it
        last_read = np.zeros(W, np.int64)
        gate_lv = np.zeros(G, np.int64)
        for li, lv in enumerate(levels):
            gate_lv[lv] = li
        for g in range(G):
            last_read[in0[g]] = max(last_read[in0[g]], gate_lv[g])
            if op[g] != OP_INV:
                last_read[in1[g]] = max(last_read[in1[g]], gate_lv[g])
        born = np.full(W, -1, np.int64)
        born[np.nonzero(src)[0]] = 0
        if G:
            born[out] = gate_lv + 1
        n_lv = len(levels)
        live_hist = np.zeros(n_lv, np.int64)
        for li in range(n_lv):
            live_hist[li] = int(np.sum(
                (born >= 0) & (born <= li)
                & ((last_read >= li) | is_out)))
        rep.live_per_level = live_hist
    return rep


def dataflow_summary(net: Netlist) -> Dict[str, int]:
    """Scalar dataflow counters, cached on the netlist (cheap for
    ``stats()`` calls inside benchmark loops)."""
    cached = getattr(net, "_dataflow_summary", None)
    if cached is None:
        cached = analyze_netlist(net).summary()
        net._dataflow_summary = cached  # type: ignore[attr-defined]
    return cached


# ---------------------------------------------------------------------------
# generator inventory (what the CLI's --netlists pass sweeps)
# ---------------------------------------------------------------------------


def generator_registry(k: int = 16, frac: int = 6
                       ) -> Dict[str, Callable[[], Netlist]]:
    """Small, fast instantiations of every public ``core/circuits``
    generator — one analyzable netlist per builder. Parameters are kept
    small so the lint sweep costs seconds; the counters are structural
    (per-word-width), so regressions show up at any size."""
    from repro.core.circuits import arith, nonlinear
    from repro.core.circuits.builder import CircuitBuilder, Word

    def binop(name: str, fn) -> Callable[[], Netlist]:
        def build() -> Netlist:
            cb = CircuitBuilder(name)
            a = cb.g_input_word(k)
            b = cb.e_input_word(k)
            cb.output(fn(cb, a, b))
            return cb.build()
        return build

    def mul_style(style: str) -> Callable[[], Netlist]:
        def build() -> Netlist:
            cb = CircuitBuilder(f"mul_{style}{k}")
            a = cb.g_input_word(k)
            b = cb.e_input_word(k)
            cb.output(arith.mul(cb, a, b, style=style))
            return cb.build()
        return build

    def predicate(name: str, fn) -> Callable[[], Netlist]:
        def build() -> Netlist:
            cb = CircuitBuilder(name)
            a = cb.g_input_word(k)
            b = cb.e_input_word(k)
            cb.output(fn(cb, a, b))
            return cb.build()
        return build

    def mux_build() -> Netlist:
        cb = CircuitBuilder(f"mux{k}")
        sel = cb.e_input()
        a = cb.g_input_word(k)
        b = cb.e_input_word(k)
        cb.output(arith.mux(cb, sel, a, b))
        return cb.build()

    def shift_var_build() -> Netlist:
        cb = CircuitBuilder(f"shift_right_var{k}")
        x = cb.e_input_word(k)
        amt = Word(tuple(cb.e_input() for _ in range(4)))
        cb.output(arith.shift_right_var(cb, x, amt, arithmetic=True))
        return cb.build()

    def unary(name: str, fn) -> Callable[[], Netlist]:
        def build() -> Netlist:
            cb = CircuitBuilder(name)
            x = cb.e_input_word(k)
            cb.output(fn(cb, x))
            return cb.build()
        return build

    style = "xfbq"
    return {
        f"add{k}": binop(f"add{k}", arith.add),
        f"sub{k}": binop(f"sub{k}", arith.sub),
        f"mul_conventional{k}": mul_style("conventional"),
        f"mul_xfbq{k}": mul_style("xfbq"),
        f"fx_mul{k}": binop(
            f"fx_mul{k}",
            lambda cb, a, b: arith.fx_mul(cb, a, b, frac, style=style)),
        f"lt_signed{k}": predicate(f"lt_signed{k}", arith.lt_signed),
        f"eq{k}": predicate(f"eq{k}", arith.eq),
        f"max_word{k}": binop(f"max_word{k}", arith.max_word),
        f"mux{k}": mux_build,
        f"shift_right_var{k}": shift_var_build,
        f"exp{k}": unary(
            f"exp{k}",
            lambda cb, x: nonlinear.exp_circuit(cb, x, frac, style)),
        f"reciprocal{k}": unary(
            f"reciprocal{k}",
            lambda cb, x: nonlinear.reciprocal_circuit(cb, x, frac, style)),
        f"rsqrt{k}": unary(
            f"rsqrt{k}",
            lambda cb, x: nonlinear.rsqrt_circuit(cb, x, frac, style)),
        "softmax4": lambda: nonlinear.softmax_circuit(
            4, k=k, frac=frac, style=style).build(),
        "gelu": lambda: nonlinear.gelu_circuit(
            k=k, frac=frac, style=style).build(),
        "silu": lambda: nonlinear.silu_circuit(
            k=k, frac=frac, style=style).build(),
        "layernorm_full4": lambda: nonlinear.layernorm_full_circuit(
            4, k=k, frac=frac, style=style).build(),
        "layernorm_reduced4": lambda: nonlinear.layernorm_reduced_circuit(
            4, k=k, frac=frac, style=style).build(),
    }


def run_netcheck(baseline_reasons: Optional[Dict] = None) -> List:
    """Verify + analyze every generator; return Finding objects."""
    from repro.analysis.report import Finding

    findings: List[Finding] = []
    for gname, build in generator_registry().items():
        path = f"netlist:{gname}"
        try:
            net = build()
        except Exception as e:  # a generator that cannot build is a finding
            findings.append(Finding("netcheck", "build-error", path, 0,
                                    gname, f"generator raised: {e!r}"))
            continue
        for err in verify_netlist(net):
            findings.append(
                Finding("netcheck", "structure", path, 0, gname, err))
        rep = analyze_netlist(net)
        if rep.removable_and:
            findings.append(Finding(
                "netcheck", "removable-and", path, 0, gname,
                f"{rep.removable_and} of {rep.and_gates} AND gates provably "
                f"removable (dead={rep.dead_and}, foldable="
                f"{rep.foldable_and}, duplicate={rep.dup_and})",
                count=rep.removable_and))
        if rep.dead_gates:
            findings.append(Finding(
                "netcheck", "dead-gate", path, 0, gname,
                f"{rep.dead_gates} of {rep.gates} gates dead "
                f"(unreachable from outputs); {rep.dead_wires} dead wires",
                count=rep.dead_gates))
    return findings

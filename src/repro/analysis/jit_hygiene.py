"""AST lints for jitted bodies and protocol-path RNG discipline.

Jit hygiene (over ``kernels/`` and ``core/gc_exec.py``): inside a
function that gets ``jax.jit``-compiled (decorated, wrapped by a
``jax.jit(...)`` call, or used as a ``lax.scan`` body), the *parameters*
are traced values. The pass flags:

* ``jit-py-branch`` — Python ``if``/``while``/ternary/``assert`` whose
  test depends on a traced value (concretization error at trace time, or
  silently baked-in when it happens to be concrete). Branching on static
  Python config (``self.planar``, closure ints, ``static_argnames``) is
  fine and not flagged.
* ``jit-host-np`` — host ``np.*`` calls fed a traced value: the result
  silently leaves the traced graph (constant-folds the tracer or
  errors). ``np.*`` on static plan arrays is idiomatic and not flagged.
* ``jit-host-cast`` — ``int()/float()/bool()/.item()`` on traced values.
* ``jit-time-random`` — ``time.*`` / stdlib ``random.*`` inside a jitted
  body: traced once, frozen forever.

Protocol-path RNG (over ``core/protocol.py``, ``core/session.py``,
``net/party.py``): ``proto-global-rng`` flags draws from the *global*
numpy RNG (``np.random.rand`` etc.) or stdlib ``random`` — protocol
randomness must come from per-party seeded ``Generator`` objects
(``default_rng``/``PRNGKey`` construction is the approved pattern), both
for reproducibility and because the global stream is shared mutable
state across parties in-process, which silently correlates "independent"
masks.
"""

from __future__ import annotations

import ast
import os
from typing import List, Optional, Set

from repro.analysis.report import Finding

#: jit files the CI lint covers by default (repo-relative); directories
#: are swept recursively
DEFAULT_JIT_PATHS = (
    "src/repro/kernels",
    "src/repro/core/gc_exec.py",
)
DEFAULT_PROTO_PATHS = (
    "src/repro/core/protocol.py",
    "src/repro/core/session.py",
    "src/repro/net/party.py",
)

_GLOBAL_RNG_OK = {"default_rng", "PRNGKey", "Generator", "SeedSequence",
                  "BitGenerator", "Philox", "PCG64", "split", "fold_in"}
_CASTS = {"int", "float", "bool", "complex"}


def _call_name(func: ast.expr) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _attr_chain(node: ast.expr) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _is_jax_jit(node: ast.expr) -> bool:
    """Match ``jax.jit``, ``jit``, ``partial(jax.jit, ...)``,
    ``functools.partial(jit, ...)``."""
    chain = _attr_chain(node) if not isinstance(node, ast.Call) else []
    if chain and chain[-1] == "jit":
        return True
    if isinstance(node, ast.Call) and _call_name(node.func) == "partial":
        return any(_is_jax_jit(a) for a in node.args)
    return False


def _static_argnames(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(
                        sub.value, str):
                    names.add(sub.value)
    return names


class _JitBodies(ast.NodeVisitor):
    """Collect function defs that become jitted, with static-arg names."""

    def __init__(self) -> None:
        self.defs = {}  # name -> FunctionDef (last wins; files are small)
        self.jitted = {}  # name -> static argnames

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.defs[node.name] = node
        for dec in node.decorator_list:
            if _is_jax_jit(dec):
                static = _static_argnames(dec) if isinstance(
                    dec, ast.Call) else set()
                self.jitted[node.name] = static
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node.func)
        if name == "jit" and _is_jax_jit(node.func):
            for a in node.args:
                target = _call_name(a) if isinstance(
                    a, (ast.Attribute, ast.Name)) else ""
                if target:
                    self.jitted[target] = _static_argnames(node)
        elif name == "scan":
            # lax.scan(body, ...): the body's params are traced
            if node.args:
                target = _call_name(node.args[0]) if isinstance(
                    node.args[0], (ast.Attribute, ast.Name)) else ""
                if target:
                    self.jitted.setdefault(target, set())
        self.generic_visit(node)


class _JitBodyLint:
    """Taint = 'derived from a traced parameter' within one jitted body."""

    def __init__(self, fn: ast.FunctionDef, path: str, qualname: str,
                 static: Set[str]):
        self.fn = fn
        self.path = path
        self.qualname = qualname
        self.findings: List[Finding] = []
        args = fn.args
        names = [a.arg for a in (
            args.posonlyargs + args.args + args.kwonlyargs)]
        if args.vararg:
            names.append(args.vararg.arg)
        self.traced: Set[str] = {
            n for n in names if n != "self" and n not in static}
        # params of nested defs (scan bodies etc.) are traced too
        for sub in ast.walk(fn):
            if isinstance(sub, ast.FunctionDef) and sub is not fn:
                for a in sub.args.args:
                    if a.arg != "self":
                        self.traced.add(a.arg)

    def is_traced(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            return False  # self.* / closure config is static
        if isinstance(node, ast.Call):
            return any(self.is_traced(a) for a in node.args) or any(
                self.is_traced(kw.value) for kw in node.keywords)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_traced(e) for e in node.elts)
        if isinstance(node, (ast.BinOp,)):
            return self.is_traced(node.left) or self.is_traced(node.right)
        if isinstance(node, ast.BoolOp):
            return any(self.is_traced(v) for v in node.values)
        if isinstance(node, ast.UnaryOp):
            return self.is_traced(node.operand)
        if isinstance(node, ast.Compare):
            return self.is_traced(node.left) or any(
                self.is_traced(c) for c in node.comparators)
        if isinstance(node, ast.Subscript):
            return self.is_traced(node.value)
        if isinstance(node, ast.IfExp):
            return (self.is_traced(node.body) or self.is_traced(node.test)
                    or self.is_traced(node.orelse))
        return False

    def _bind(self, target: ast.expr, traced: bool) -> None:
        if isinstance(target, ast.Name) and traced:
            self.traced.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, traced)

    def run(self) -> List[Finding]:
        for _ in range(4):
            before = len(self.traced)
            for node in ast.walk(self.fn):
                if isinstance(node, ast.Assign):
                    t = self.is_traced(node.value)
                    for tgt in node.targets:
                        self._bind(tgt, t)
                elif isinstance(node, ast.For):
                    self._bind(node.target, self.is_traced(node.iter))
            if len(self.traced) == before:
                break

        for node in ast.walk(self.fn):
            if isinstance(node, (ast.If, ast.While)) and self.is_traced(
                    node.test):
                self._add("jit-py-branch", node,
                          "Python branch on a traced value inside a "
                          "jitted body (use lax.cond/select)")
            elif isinstance(node, ast.IfExp) and self.is_traced(node.test):
                self._add("jit-py-branch", node,
                          "Python ternary on a traced value inside a "
                          "jitted body (use jnp.where)")
            elif isinstance(node, ast.Assert) and self.is_traced(node.test):
                self._add("jit-py-branch", node,
                          "assert on a traced value inside a jitted body")
            elif isinstance(node, ast.Call):
                self._scan_call(node)
        return self.findings

    def _scan_call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        name = _call_name(node.func)
        args = list(node.args) + [kw.value for kw in node.keywords]
        if chain and chain[0] in ("np", "numpy") and any(
                self.is_traced(a) for a in args):
            self._add("jit-host-np", node,
                      f"host numpy call np.{name}() on a traced value "
                      f"inside a jitted body (use jnp)")
        if name in _CASTS and isinstance(node.func, ast.Name) and any(
                self.is_traced(a) for a in args):
            self._add("jit-host-cast", node,
                      f"{name}() concretizes a traced value inside a "
                      f"jitted body")
        if name == "item" and isinstance(node.func, ast.Attribute) and \
                self.is_traced(node.func.value):
            self._add("jit-host-cast", node,
                      ".item() concretizes a traced value inside a "
                      "jitted body")
        if chain and chain[0] in ("time", "random"):
            self._add("jit-time-random", node,
                      f"{chain[0]}.{name}() inside a jitted body is "
                      f"traced once and frozen into the executable")

    def _add(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            "jit", rule, self.path, getattr(node, "lineno", 0),
            self.qualname, msg))


def lint_jit_file(path: str, rel: Optional[str] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    rel = rel or path
    coll = _JitBodies()
    coll.visit(tree)
    findings: List[Finding] = []
    for name, static in coll.jitted.items():
        fn = coll.defs.get(name)
        if fn is not None:
            findings.extend(_JitBodyLint(fn, rel, name, static).run())
    return findings


def lint_proto_rng(path: str, rel: Optional[str] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    rel = rel or path
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        name = _call_name(node.func)
        if name in _GLOBAL_RNG_OK:
            continue
        hit = None
        if len(chain) >= 2 and chain[0] in ("np", "numpy") and \
                chain[1] == "random":
            hit = f"np.random.{name}"
        elif len(chain) == 2 and chain[0] == "random":
            hit = f"random.{name}"
        if hit:
            findings.append(Finding(
                "jit", "proto-global-rng", rel,
                getattr(node, "lineno", 0), hit,
                f"{hit}() draws from a global RNG in a protocol path — "
                f"use a per-party seeded Generator"))
    return findings


def run_jit_hygiene(root: str, jit_paths=None,
                    proto_paths=None) -> List[Finding]:
    findings: List[Finding] = []
    for rel in (jit_paths or DEFAULT_JIT_PATHS):
        p = rel if os.path.isabs(rel) else os.path.join(root, rel)
        if os.path.isdir(p):
            for dirpath, _dirnames, filenames in os.walk(p):
                for fname in sorted(filenames):
                    if fname.endswith(".py"):
                        fp = os.path.join(dirpath, fname)
                        findings.extend(
                            lint_jit_file(fp, os.path.relpath(fp, root)))
        elif os.path.exists(p):
            findings.extend(lint_jit_file(p, os.path.relpath(p, root)))
    for rel in (proto_paths or DEFAULT_PROTO_PATHS):
        p = rel if os.path.isabs(rel) else os.path.join(root, rel)
        if os.path.exists(p):
            findings.extend(lint_proto_rng(p, os.path.relpath(p, root)))
    return findings

from repro.sched.schedulers import (
    depth_first_order,
    full_reorder,
    segment_reorder,
    fine_grained_order,
    coarse_grained_partition,
)

__all__ = [
    "depth_first_order",
    "full_reorder",
    "segment_reorder",
    "fine_grained_order",
    "coarse_grained_partition",
]

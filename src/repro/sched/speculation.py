"""Compiler speculation (§3.4.2): cycle-accurate address pre-assignment.

Given a per-core gate order and a wire-memory capacity, simulate the Wire
Memory and assign, per instruction:

  * write address (blank slot, else evict the LBUW — the Last-to-Be-Used
    Wire, i.e. Belady-optimal replacement),
  * read addresses (in-memory hit or an OoRW fetch from DRAM),
  * Live bit   (an evicted-but-still-needed wire must go to DRAM),
  * OoRW-fetch / WEN bits (transfer timing + overwrite protection).

Two policies:
  * "apint": LBUW eviction; fetched OoRWs are installed in Wire Memory and
    reused by later reads.
  * "haac":  sequential (round-robin) write addresses ignoring reusability;
    fetched OoRWs are consumed once (queue-style) — every out-of-memory
    read is a fresh DRAM fetch. (HAAC §3.4 critique.)
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.netlist import Netlist, OP_INV

INF = 1 << 60


@dataclass
class SpecStats:
    instructions: int = 0
    oorw_fetches: int = 0
    dram_wire_reads: int = 0
    dram_wire_writes: int = 0  # Live-bit writes
    hits: int = 0
    evictions: int = 0

    @property
    def dram_wire_bytes(self) -> int:
        return 16 * (self.dram_wire_reads + self.dram_wire_writes)


@dataclass
class SpecProgram:
    """Instruction stream annotations for the accelerator model."""

    order: np.ndarray
    is_oorw_read0: np.ndarray  # bool per instr: in0 comes from DRAM
    is_oorw_read1: np.ndarray
    live: np.ndarray  # bool: output must be written to DRAM
    stats: SpecStats = field(default_factory=SpecStats)


def _next_uses(net: Netlist, order: np.ndarray) -> Dict[int, List[int]]:
    uses: Dict[int, List[int]] = {}
    for pos, g in enumerate(order):
        gi = int(g)
        uses.setdefault(int(net.in0[gi]), []).append(pos)
        if net.op[gi] != OP_INV:
            uses.setdefault(int(net.in1[gi]), []).append(pos)
    return uses


def speculate(
    net: Netlist,
    order: np.ndarray,
    capacity_wires: int,
    policy: str = "apint",
) -> SpecProgram:
    assert policy in ("apint", "haac")
    n = len(order)
    uses = _next_uses(net, order)
    use_ptr: Dict[int, int] = {w: 0 for w in uses}

    def next_use(w: int, after: int) -> int:
        lst = uses.get(w)
        if not lst:
            return INF
        i = use_ptr.get(w, 0)
        while i < len(lst) and lst[i] <= after:
            i += 1
        use_ptr[w] = i
        return lst[i] if i < len(lst) else INF

    in_mem: Dict[int, int] = {}  # wire -> slot
    free: List[int] = list(range(capacity_wires))
    heap: List[Tuple[int, int]] = []  # (-next_use, wire) lazy
    in_dram: set = set()
    rr = [0]  # haac round-robin pointer
    slot_wire: Dict[int, Optional[int]] = {}

    st = SpecStats(instructions=n)
    o0 = np.zeros(n, bool)
    o1 = np.zeros(n, bool)
    live = np.zeros(n, bool)
    producer_pos: Dict[int, int] = {}

    def evict_for(pos: int, protect: set) -> int:
        """Free one slot; returns slot id."""
        if free:
            return free.pop()
        st.evictions += 1
        if policy == "apint":
            skipped = []
            while True:
                nu_neg, w = heapq.heappop(heap)
                if w not in in_mem:
                    continue  # stale entry for an evicted wire
                if w in protect:
                    skipped.append((nu_neg, w))
                    continue
                # lazy check: stale next-use?
                actual = next_use(w, pos - 1)
                if -nu_neg != actual:
                    heapq.heappush(heap, (-actual, w))
                    continue
                break
            for item in skipped:
                heapq.heappush(heap, item)
        else:  # haac: sequential overwrite, reusability ignored
            cap = capacity_wires
            for _ in range(cap + 1):
                slot = rr[0] % cap
                rr[0] += 1
                w = slot_wire.get(slot)
                if w is None or w not in protect:
                    break
            if w is None:
                return slot
        slot = in_mem.pop(w)
        # Live: evicted wire still needed later -> must persist to DRAM
        if next_use(w, pos - 1) < INF and w not in in_dram:
            in_dram.add(w)
            st.dram_wire_writes += 1
            p = producer_pos.get(w)
            if p is not None:
                live[p] = True
        return slot

    def install(w: int, slot: int, pos: int):
        in_mem[w] = slot
        slot_wire[slot] = w
        if policy == "apint":
            heapq.heappush(heap, (-next_use(w, pos), w))

    # inputs/constants arrive over the wire into DRAM; the compiler preloads
    # Wire Memory "as much as possible with operable input wires" (§3.4.2),
    # earliest-used first.
    inputs = [int(w) for w in list(net.garbler_inputs)
              + list(net.evaluator_inputs) + list(net.const_bits)]
    for w in inputs:
        in_dram.add(w)
    by_first_use = sorted(
        (uses[w][0], w) for w in inputs if w in uses
    )
    for _, w in by_first_use[:capacity_wires]:
        slot = free.pop()
        install(w, slot, -1)

    for pos in range(n):
        g = int(order[pos])
        ins = [int(net.in0[g])]
        if net.op[g] != OP_INV:
            ins.append(int(net.in1[g]))
        protect = set(ins) | {int(net.out[g])}
        for j, w in enumerate(ins):
            if w in in_mem:
                st.hits += 1
                if policy == "apint":
                    heapq.heappush(heap, (-next_use(w, pos), w))
            else:
                st.oorw_fetches += 1
                st.dram_wire_reads += 1
                (o0 if j == 0 else o1)[pos] = True
                if policy == "apint":
                    slot = evict_for(pos, protect)
                    install(w, slot, pos)
                # haac: consumed once, not installed
        wout = int(net.out[g])
        slot = evict_for(pos, protect)
        install(wout, slot, pos)
        producer_pos[wout] = pos

    return SpecProgram(order=order, is_oorw_read0=o0, is_oorw_read1=o1,
                       live=live, stats=st)

"""Netlist scheduling (§3.3): gate orderings fed to the accelerator model.

  depth_first_order   — EMP-tool style (the builder's natural emission order)
  full_reorder        — HAAC FR: global BFS levelization
  segment_reorder     — HAAC SR: DF segments (half wire-memory each) with FR
                        applied inside every segment
  fine_grained_order  — APINT: DF segments + Critical-Path-First-Execution
                        (recursive critical-path priorities [34, 35]) +
                        cycle-accurate list scheduling inside each segment
  coarse_grained_partition — APINT coarse scheduling: one independent unit
                        operation (e.g. a softmax row) per core

All return gate-index permutations of the netlist (and per-core lists for
the coarse partition); correctness = every permutation is topological.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR

# cycle weights (the paper's PE latencies, evaluation)
GATE_CYCLES = {OP_AND: 18, OP_XOR: 1, OP_INV: 1}


def depth_first_order(net: Netlist) -> np.ndarray:
    return np.arange(net.num_gates, dtype=np.int64)


def full_reorder(net: Netlist) -> np.ndarray:
    levels = net.levels()
    if not levels:
        return np.empty(0, np.int64)
    return np.concatenate(levels).astype(np.int64)


def _segments(net: Netlist, seg_gates: int) -> List[np.ndarray]:
    order = np.arange(net.num_gates, dtype=np.int64)
    return [order[i: i + seg_gates] for i in range(0, len(order), seg_gates)]


def segment_reorder(net: Netlist, seg_gates: int) -> np.ndarray:
    """HAAC SR: FR (levelize) within each DF segment."""
    out = []
    for seg in _segments(net, seg_gates):
        out.append(_levelize_subset(net, seg))
    return np.concatenate(out) if out else np.empty(0, np.int64)


def _levelize_subset(net: Netlist, seg: np.ndarray) -> np.ndarray:
    """BFS levels of the sub-DAG induced by `seg` (external inputs ready)."""
    in_seg = {int(g): i for i, g in enumerate(seg)}
    prod = {}  # wire -> producing gate within segment
    for g in seg:
        prod[int(net.out[g])] = int(g)
    level: Dict[int, int] = {}
    order = []
    for g in seg:
        gi = int(g)
        lv = 0
        for w in (net.in0[gi], net.in1[gi]):
            pw = prod.get(int(w))
            if pw is not None:
                lv = max(lv, level[pw] + 1)
        level[gi] = lv
    segl = sorted((level[int(g)], int(g)) for g in seg)
    return np.array([g for _, g in segl], dtype=np.int64)


# ---------------------------------------------------------------------------
# CPFE (fine-grained)
# ---------------------------------------------------------------------------


def _cpfe_priorities(net: Netlist, seg: np.ndarray) -> Dict[int, int]:
    """Recursive critical-path priorities within one segment.

    Lower rank = scheduled first among operable gates.
    """
    seg = [int(g) for g in seg]
    seg_set = set(seg)
    prod = {int(net.out[g]): g for g in seg}
    children: Dict[int, List[int]] = {g: [] for g in seg}
    parents: Dict[int, List[int]] = {g: [] for g in seg}
    for g in seg:
        for w in (int(net.in0[g]), int(net.in1[g])):
            p = prod.get(w)
            if p is not None and p != g:
                parents[g].append(p)
                children[p].append(g)

    weight = {g: GATE_CYCLES[int(net.op[g])] for g in seg}
    rank: Dict[int, int] = {}
    counter = [0]

    def longest_path(nodes: List[int]) -> List[int]:
        """Critical (max-weight) path within `nodes` (already topological)."""
        nset = set(nodes)
        dist: Dict[int, int] = {}
        pred: Dict[int, int] = {}
        best, best_d = None, -1
        for g in nodes:  # nodes kept in topological (emission) order
            d = weight[g]
            for p in parents[g]:
                if p in nset and dist.get(p, -1) + weight[g] > d:
                    d = dist[p] + weight[g]
                    pred[g] = p
            dist[g] = d
            if d > best_d:
                best, best_d = g, d
        path = []
        cur = best
        while cur is not None:
            path.append(cur)
            cur = pred.get(cur)
        return list(reversed(path))

    def descendants(g: int, allowed: set) -> List[int]:
        out, stack, seen = [], [c for c in children[g]], set()
        while stack:
            n = stack.pop()
            if n in seen or n not in allowed or n in rank:
                continue
            seen.add(n)
            out.append(n)
            stack.extend(children[n])
        return sorted(out)  # emission order = topological

    def assign(nodes: List[int]):
        nodes = [n for n in nodes if n not in rank]
        if not nodes:
            return
        path = longest_path(nodes)
        for g in path:
            if g not in rank:
                rank[g] = counter[0]
                counter[0] += 1
        allowed = set(nodes)
        for g in path:
            sub = descendants(g, allowed)
            assign(sub)

    assign(seg)
    for g in seg:  # stragglers (disconnected)
        if g not in rank:
            rank[g] = counter[0]
            counter[0] += 1
    return rank


def fine_grained_order(net: Netlist, seg_gates: int) -> np.ndarray:
    """Segmentation + CPFE + cycle-accurate list scheduling (§3.3.2)."""
    out = []
    for seg in _segments(net, seg_gates):
        rank = _cpfe_priorities(net, seg)
        order = _list_schedule(net, seg, rank)
        out.append(order)
    return np.concatenate(out) if out else np.empty(0, np.int64)


def _list_schedule(net: Netlist, seg: np.ndarray, rank: Dict[int, int]) -> np.ndarray:
    """Pick the operable gate with the best CPFE rank each issue slot,
    modeling the PE latency: a gate's output is ready `GATE_CYCLES` after
    issue; a gate is operable when both in-segment producers are done."""
    import heapq

    seg = [int(g) for g in seg]
    prod = {int(net.out[g]): g for g in seg}
    remaining: Dict[int, int] = {}
    children: Dict[int, List[int]] = {g: [] for g in seg}
    for g in seg:
        deps = 0
        for w in (int(net.in0[g]), int(net.in1[g])):
            p = prod.get(w)
            if p is not None and p != g:
                deps += 1
                children[p].append(g)
        if int(net.op[g]) == OP_INV:
            # single input counted twice when in1 == in0
            pass
        remaining[g] = deps

    ready = [(rank[g], g) for g in seg if remaining[g] == 0]
    heapq.heapify(ready)
    # events: (completion_time, gate)
    t = 0
    order = []
    pending: List[Tuple[int, int]] = []
    done = set()
    while ready or pending:
        if ready:
            _, g = heapq.heappop(ready)
            t += 1  # one issue slot per cycle
            fin = t + GATE_CYCLES[int(net.op[g])]
            heapq.heappush(pending, (fin, g))
            order.append(g)
        else:
            # stall until next completion
            fin, g = heapq.heappop(pending)
            t = max(t, fin)
            done.add(g)
            for c in children[g]:
                remaining[c] -= 1
                if remaining[c] == 0:
                    heapq.heappush(ready, (rank[c], c))
            continue
        # retire completions at current time
        while pending and pending[0][0] <= t:
            fin, g2 = heapq.heappop(pending)
            done.add(g2)
            for c in children[g2]:
                remaining[c] -= 1
                if remaining[c] == 0:
                    heapq.heappush(ready, (rank[c], c))
    return np.array(order, dtype=np.int64)


# ---------------------------------------------------------------------------
# coarse-grained partition
# ---------------------------------------------------------------------------


def coarse_grained_partition(nets: Sequence[Netlist], num_cores: int
                             ) -> List[List[int]]:
    """Map independent unit operations (row circuits) onto cores
    round-robin: core i gets rows i, i+C, ... (§3.3.1)."""
    assign: List[List[int]] = [[] for _ in range(num_cores)]
    for i in range(len(nets)):
        assign[i % num_cores].append(i)
    return assign


def check_topological(net: Netlist, order: np.ndarray) -> bool:
    pos = {int(net.out[g]): i for i, g in enumerate(order)}
    for i, g in enumerate(order):
        for w in (int(net.in0[g]), int(net.in1[g])):
            if w in pos and pos[w] > i:
                return False
    return True

"""Netlist scheduling (§3.3): gate orderings fed to the accelerator model.

  depth_first_order   — EMP-tool style (the builder's natural emission order)
  full_reorder        — HAAC FR: global BFS levelization
  segment_reorder     — HAAC SR: DF segments (half wire-memory each) with FR
                        applied inside every segment
  fine_grained_order  — APINT: DF segments + Critical-Path-First-Execution
                        (recursive critical-path priorities [34, 35]) +
                        cycle-accurate list scheduling inside each segment
  coarse_grained_partition — APINT coarse scheduling: one independent unit
                        operation (e.g. a softmax row) per core

All return gate-index permutations of the netlist (and per-core lists for
the coarse partition); correctness = every permutation is topological.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR

# the paper's PE latencies; values must match accel/sim.py (which cannot
# be imported here — accel.sim already imports repro.sched)
HALFGATE_EVAL_CY = 18
HALFGATE_GARBLE_CY = 21


def gate_cycles(garbling: bool = False) -> Dict[int, int]:
    """Per-op cycle weights for schedule costing.

    Garbling pays 21 cy per Half-Gate AND (4 hash lanes) vs 18 cy for
    evaluation (2 lanes) — a garble-side schedule costed with the eval
    table underestimates every AND on the critical path by ~17%.
    """
    return {
        OP_AND: HALFGATE_GARBLE_CY if garbling else HALFGATE_EVAL_CY,
        OP_XOR: 1,
        OP_INV: 1,
    }


# compatibility view: the evaluation-side table (pre-garbling-aware API)
GATE_CYCLES = gate_cycles(garbling=False)


def schedule_cost(net: Netlist, garbling: bool = False) -> int:
    """Total PE compute cycles of a schedule under :func:`gate_cycles`.

    Schedule-independent (every topological order issues each gate once);
    what matters is the latency table. The 21 cy/AND garble constant
    assumes a *dense* table write — exactly 2 rows per real AND gate.
    That assumption now matches the device executor bit for bit: packed
    table emission writes ``table_base[k] + lane`` rows, one per valid
    AND lane (the old ys-stack emission amortized K×and_width padded
    rows per walk, i.e. MORE than 2 rows per AND at preprocessing
    scale, which this costing never modeled). ``accel/sim.py`` prices
    the same dense write per AND (TABLE_BYTES streamed out);
    ``test_sched`` pins the two models to each other.
    """
    cyc = gate_cycles(garbling)
    ops = net.op
    return int(sum(int(np.sum(ops == op)) * c for op, c in cyc.items()))


def depth_first_order(net: Netlist) -> np.ndarray:
    return np.arange(net.num_gates, dtype=np.int64)


def full_reorder(net: Netlist) -> np.ndarray:
    levels = net.levels()
    if not levels:
        return np.empty(0, np.int64)
    return np.concatenate(levels).astype(np.int64)


def _segments(net: Netlist, seg_gates: int) -> List[np.ndarray]:
    order = np.arange(net.num_gates, dtype=np.int64)
    return [order[i: i + seg_gates] for i in range(0, len(order), seg_gates)]


def segment_reorder(net: Netlist, seg_gates: int) -> np.ndarray:
    """HAAC SR: FR (levelize) within each DF segment."""
    out = []
    for seg in _segments(net, seg_gates):
        out.append(_levelize_subset(net, seg))
    return np.concatenate(out) if out else np.empty(0, np.int64)


def _levelize_subset(net: Netlist, seg: np.ndarray) -> np.ndarray:
    """BFS levels of the sub-DAG induced by `seg` (external inputs ready)."""
    in_seg = {int(g): i for i, g in enumerate(seg)}
    prod = {}  # wire -> producing gate within segment
    for g in seg:
        prod[int(net.out[g])] = int(g)
    level: Dict[int, int] = {}
    order = []
    for g in seg:
        gi = int(g)
        lv = 0
        for w in (net.in0[gi], net.in1[gi]):
            pw = prod.get(int(w))
            if pw is not None:
                lv = max(lv, level[pw] + 1)
        level[gi] = lv
    segl = sorted((level[int(g)], int(g)) for g in seg)
    return np.array([g for _, g in segl], dtype=np.int64)


# ---------------------------------------------------------------------------
# CPFE (fine-grained)
# ---------------------------------------------------------------------------


def _cpfe_priorities(net: Netlist, seg: np.ndarray,
                     cycles: Dict[int, int] = None) -> Dict[int, int]:
    """Recursive critical-path priorities within one segment.

    Lower rank = scheduled first among operable gates. ``cycles`` is the
    PE latency table (:func:`gate_cycles`); defaults to evaluation.
    """
    cycles = cycles if cycles is not None else GATE_CYCLES
    seg = [int(g) for g in seg]
    seg_set = set(seg)
    prod = {int(net.out[g]): g for g in seg}
    children: Dict[int, List[int]] = {g: [] for g in seg}
    parents: Dict[int, List[int]] = {g: [] for g in seg}
    for g in seg:
        for w in (int(net.in0[g]), int(net.in1[g])):
            p = prod.get(w)
            if p is not None and p != g:
                parents[g].append(p)
                children[p].append(g)

    weight = {g: cycles[int(net.op[g])] for g in seg}
    rank: Dict[int, int] = {}
    counter = [0]

    def longest_path(nodes: List[int]) -> List[int]:
        """Critical (max-weight) path within `nodes` (already topological)."""
        nset = set(nodes)
        dist: Dict[int, int] = {}
        pred: Dict[int, int] = {}
        best, best_d = None, -1
        for g in nodes:  # nodes kept in topological (emission) order
            d = weight[g]
            for p in parents[g]:
                if p in nset and dist.get(p, -1) + weight[g] > d:
                    d = dist[p] + weight[g]
                    pred[g] = p
            dist[g] = d
            if d > best_d:
                best, best_d = g, d
        path = []
        cur = best
        while cur is not None:
            path.append(cur)
            cur = pred.get(cur)
        return list(reversed(path))

    def descendants(g: int, allowed: set) -> List[int]:
        out, stack, seen = [], [c for c in children[g]], set()
        while stack:
            n = stack.pop()
            if n in seen or n not in allowed or n in rank:
                continue
            seen.add(n)
            out.append(n)
            stack.extend(children[n])
        return sorted(out)  # emission order = topological

    def assign(nodes: List[int]):
        nodes = [n for n in nodes if n not in rank]
        if not nodes:
            return
        path = longest_path(nodes)
        for g in path:
            if g not in rank:
                rank[g] = counter[0]
                counter[0] += 1
        allowed = set(nodes)
        for g in path:
            sub = descendants(g, allowed)
            assign(sub)

    assign(seg)
    for g in seg:  # stragglers (disconnected)
        if g not in rank:
            rank[g] = counter[0]
            counter[0] += 1
    return rank


def fine_grained_order(net: Netlist, seg_gates: int,
                       garbling: bool = False) -> np.ndarray:
    """Segmentation + CPFE + cycle-accurate list scheduling (§3.3.2).

    ``garbling=True`` costs the schedule with the garble-side PE latency
    (21 cy per AND, matching ``accel/sim.py``) so offline/preprocessing
    schedules are priced correctly; the default is evaluation (18 cy).
    """
    cycles = gate_cycles(garbling)
    out = []
    for seg in _segments(net, seg_gates):
        rank = _cpfe_priorities(net, seg, cycles)
        order = _list_schedule(net, seg, rank, cycles)
        out.append(order)
    return np.concatenate(out) if out else np.empty(0, np.int64)


def _list_schedule(net: Netlist, seg: np.ndarray, rank: Dict[int, int],
                   cycles: Dict[int, int] = None) -> np.ndarray:
    """Pick the operable gate with the best CPFE rank each issue slot,
    modeling the PE latency: a gate's output is ready ``cycles[op]`` after
    issue; a gate is operable when both in-segment producers are done."""
    import heapq

    cycles = cycles if cycles is not None else GATE_CYCLES

    seg = [int(g) for g in seg]
    prod = {int(net.out[g]): g for g in seg}
    remaining: Dict[int, int] = {}
    children: Dict[int, List[int]] = {g: [] for g in seg}
    for g in seg:
        deps = 0
        for w in (int(net.in0[g]), int(net.in1[g])):
            p = prod.get(w)
            if p is not None and p != g:
                deps += 1
                children[p].append(g)
        if int(net.op[g]) == OP_INV:
            # single input counted twice when in1 == in0
            pass
        remaining[g] = deps

    ready = [(rank[g], g) for g in seg if remaining[g] == 0]
    heapq.heapify(ready)
    # events: (completion_time, gate)
    t = 0
    order = []
    pending: List[Tuple[int, int]] = []
    done = set()
    while ready or pending:
        if ready:
            _, g = heapq.heappop(ready)
            t += 1  # one issue slot per cycle
            fin = t + cycles[int(net.op[g])]
            heapq.heappush(pending, (fin, g))
            order.append(g)
        else:
            # stall until next completion
            fin, g = heapq.heappop(pending)
            t = max(t, fin)
            done.add(g)
            for c in children[g]:
                remaining[c] -= 1
                if remaining[c] == 0:
                    heapq.heappush(ready, (rank[c], c))
            continue
        # retire completions at current time
        while pending and pending[0][0] <= t:
            fin, g2 = heapq.heappop(pending)
            done.add(g2)
            for c in children[g2]:
                remaining[c] -= 1
                if remaining[c] == 0:
                    heapq.heappush(ready, (rank[c], c))
    return np.array(order, dtype=np.int64)


# ---------------------------------------------------------------------------
# coarse-grained partition
# ---------------------------------------------------------------------------


def coarse_grained_partition(nets: Sequence[Netlist], num_cores: int
                             ) -> List[List[int]]:
    """Map independent unit operations (row circuits) onto cores
    round-robin: core i gets rows i, i+C, ... (§3.3.1)."""
    assign: List[List[int]] = [[] for _ in range(num_cores)]
    for i in range(len(nets)):
        assign[i % num_cores].append(i)
    return assign


def check_topological(net: Netlist, order: np.ndarray) -> bool:
    pos = {int(net.out[g]): i for i, g in enumerate(order)}
    for i, g in enumerate(order):
        for w in (int(net.in0[g]), int(net.in1[g])):
            if w in pos and pos[w] > i:
                return False
    return True

"""Per-arch reduced-config smoke: one forward/train step on CPU, output
shapes + finiteness (task deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config, reduced_config
from repro.launch.steps import build_train_step
from repro.models.transformer import forward, init_caches, init_params
from repro.train.optimizer import init_opt_state

ASSIGNED = [
    "olmoe-1b-7b", "llama4-scout-17b-a16e", "llama3.2-1b", "deepseek-67b",
    "qwen3-1.7b", "smollm-360m", "musicgen-medium", "xlstm-125m",
    "zamba2-2.7b", "internvl2-26b",
]


def make_batch(cfg, B, S, kind, rng):
    out = {}
    if cfg.input_mode == "embeddings":
        s = 1 if kind == "decode" else S
        out["embeddings"] = jnp.asarray(
            rng.standard_normal((B, s, cfg.d_model)), jnp.float32)
    elif cfg.input_mode == "tokens+image":
        n = cfg.num_image_tokens
        if kind == "decode":
            out["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        else:
            out["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S - n)), jnp.int32)
            out["image_embeds"] = jnp.asarray(
                rng.standard_normal((B, n, cfg.d_model)), jnp.float32)
    else:
        s = 1 if kind == "decode" else S
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, s)), jnp.int32)
    if kind == "train":
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return out


@pytest.mark.parametrize("arch", ASSIGNED + ["bert-base-pit"])
def test_train_step_smoke(arch, rng):
    cfg = reduced_config(get_config(arch))
    B, S = 2, 64
    params = init_params(cfg, jax.random.PRNGKey(0))
    tc = TrainConfig()
    step, _, _, _ = build_train_step(cfg, tc)
    state = {"params": params, "opt": init_opt_state(params),
             "step": jnp.int32(0)}
    batch = make_batch(cfg, B, S, "train", rng)
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))), state2["params"], 0.0
    )
    assert np.isfinite(delta)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_smoke(arch, rng):
    cfg = reduced_config(get_config(arch))
    B, S = 2, 64
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S, "prefill", rng)
    logits, caches = forward(cfg, params, batch, mode="prefill")
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    dbatch = make_batch(cfg, B, S, "decode", rng)
    caches2 = init_caches(cfg, B, S + 4, dtype=jnp.dtype(cfg.dtype))
    logits2, caches3 = forward(cfg, params, dbatch, mode="decode",
                               caches=caches2)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(caches3["len"]) == 1

"""Multi-client gateway: session mux over one accept loop, per-session
ledgers, shared garbling cache, admission control, and teardown.

The acceptance bar (ISSUE 7): >= 4 concurrent TCP client sessions behind
ONE listener, outputs bit-identical to the single-client in-process
``PiTSession.run``, exactly one garbled slab per distinct netlist across
all sessions, bounded pools shedding with retry-after hints, and a
mid-session kill that returns its bundles without touching anyone else.
"""

import threading
import time

import numpy as np
import pytest

from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from repro.net import InProcPipe, TcpListener
from repro.serve import BundlePoolEmpty, NetPrivateServeEngine, PitGateway, \
    gateway_client

D, HEADS, DFF, S = 8, 2, 16, 4


def _model(seed=0):
    rng = np.random.default_rng(seed)
    weights = random_weights(rng, D, DFF, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=6)
    return PrivateTransformer(pcfg, D, HEADS, DFF, weights, seed=seed)


def _inproc_engine(gw, *, seed, pool_target=2, timeout=120):
    """One pipelined client (offline + online pair) over InProc pipes."""
    off_c, off_s = InProcPipe.make_pair()
    on_c, on_s = InProcPipe.make_pair()
    gw.serve_transport(off_s, timeout=timeout)
    gw.serve_transport(on_s, timeout=timeout)
    return NetPrivateServeEngine(off_c, on_c, pool_target=pool_target,
                                 seed=seed, timeout=timeout)


def _wait(pred, timeout=20.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.02)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# accept loop
# ---------------------------------------------------------------------------


def test_accept_loop_serves_many_and_stops():
    from repro.net import TcpTransport

    lst = TcpListener()
    seen = []
    loop = lst.accept_loop(seen.append, accept_timeout=0.1)
    clis = [TcpTransport.connect("127.0.0.1", lst.port) for _ in range(3)]
    assert loop.wait_accepted(3, timeout=10)
    assert loop.accepted == 3 and loop.error is None
    loop.stop()
    loop.join(timeout=5)
    assert not loop.alive
    for c in clis + seen:
        c.close()
    lst.close()


def test_accept_loop_max_accepts():
    from repro.net import TcpTransport

    lst = TcpListener()
    seen = []
    loop = lst.accept_loop(seen.append, accept_timeout=0.1, max_accepts=1)
    c1 = TcpTransport.connect("127.0.0.1", lst.port)
    assert loop.wait_accepted(1, timeout=10)
    loop.join(timeout=5)  # exits on its own once the bound is reached
    assert not loop.alive and loop.accepted == 1
    for c in seen + [c1]:
        c.close()
    lst.close()


# ---------------------------------------------------------------------------
# the acceptance-criteria test: 4 concurrent TCP sessions, one listener
# ---------------------------------------------------------------------------


def test_gateway_four_tcp_sessions_bit_identical():
    model = _model(seed=11)
    gw = PitGateway(model, S, impl="ref", max_sessions=8, pool_cap=4)
    lst = TcpListener()
    loop = gw.serve_listener(lst, accept_timeout=0.2, timeout=120)

    rng = np.random.default_rng(12)
    xs = [rng.normal(0, 1, (S, D)) for _ in range(4)]
    engines = [None] * 4
    outs = [None] * 4
    errs = []

    def client(i):
        try:
            eng = gateway_client("127.0.0.1", lst.port, seed=100 + i,
                                 timeout=120)
            engines[i] = eng
            eng.preprocess(1)
            outs[i] = eng.run(xs[i])
        except Exception as e:  # surfaced below — threads swallow raises
            errs.append((i, e))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=240)
    assert not errs, errs
    assert loop.accepted == 8  # 4 clients x (offline + online)

    # bit-identical to the single-client in-process session
    sess = model.compile_session(S, impl="ref")
    for i, x in enumerate(xs):
        assert np.array_equal(outs[i], sess.run(x, sess.preprocess(1)[0])), i

    st = gw.stats()
    assert st["sessions_active"] == 4 and st["sessions_admitted"] == 4
    sids = [s["sid"] for s in st["sessions"]]
    assert len(set(sids)) == 4  # one SessionState per client

    # per-session ledgers: each session metered its own full transcript,
    # and the client-side ledger agrees tag-for-tag with the server side
    by_token = {s["client"]: s for s in st["sessions"]}
    for eng in engines:
        srv_side = by_token[eng._shared.client_token]
        assert srv_side["offline_by_tag"] == dict(eng.ledger.offline.by_tag)
        assert srv_side["online_by_tag"] == dict(eng.ledger.online.by_tag)
        assert srv_side["offline_bytes"] > 0 and srv_side["online_bytes"] > 0

    # shared garbling cache: one slab per distinct netlist across ALL
    # sessions — 1 miss each on the first prep, hits from the other 3
    cache = st["garbling_cache"]
    assert cache["slabs"] == cache["distinct_netlists"] > 0
    assert cache["misses"] == cache["slabs"]
    assert cache["hits"] == 3 * cache["slabs"]

    for eng in engines:
        eng.close()
    loop.stop()
    gw.close()
    lst.close()


# ---------------------------------------------------------------------------
# teardown: a killed client returns its bundles, others are untouched
# ---------------------------------------------------------------------------


def test_gateway_kill_mid_session_returns_bundles():
    model = _model(seed=21)
    gw = PitGateway(model, S, impl="ref", max_sessions=4, pool_cap=4)
    rng = np.random.default_rng(22)

    victim = _inproc_engine(gw, seed=1)
    survivor = _inproc_engine(gw, seed=2)
    victim.preprocess(2)
    survivor.preprocess(1)
    x = rng.normal(0, 1, (S, D))
    victim.run(x)  # consumes 1 of its 2 bundles

    # kill: close both transports with no bye — the server sees the
    # peer vanish mid-session with a bundle still outstanding
    victim.offline.transport.close()
    victim.online.transport.close()
    _wait(lambda: gw.stats()["sessions_active"] == 1,
          what="victim session teardown")

    st = gw.stats()
    assert st["bundles_returned"] == 1  # the unconsumed one came back
    dead = [s for s in st["sessions"] if s["bundles_returned"] == 1]
    assert len(dead) == 1 and dead[0]["bundles_outstanding"] == 0

    # the surviving session is unaffected: its bundle is intact and runs
    y = survivor.run(x)
    sess = model.compile_session(S, impl="ref")
    assert np.array_equal(y, sess.run(x, sess.preprocess(1)[0]))
    survivor.close()
    gw.close()


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_gateway_kill_during_pipelined_refill_reclaims_exactly():
    """The kill lands *inside* a pipelined ``refill_async`` prep stream
    (not between requests): the in-flight prep must vanish without a
    trace — neither side committed it — and the reclaim returns exactly
    the prior unconsumed bundles. The refill thread dies loudly on the
    injected reset, hence the warning filter."""
    from repro.net import Fault, FaultyTransport
    from repro.serve import NetPrivateServeEngine

    model = _model(seed=23)
    gw = PitGateway(model, S, impl="ref", max_sessions=4, pool_cap=8)
    rng = np.random.default_rng(24)

    # the victim's offline leg runs through a FaultyTransport so the
    # kill can be armed deterministically relative to the op counter
    off_c, off_s = InProcPipe.make_pair()
    on_c, on_s = InProcPipe.make_pair()
    gw.serve_transport(off_s, timeout=120)
    gw.serve_transport(on_s, timeout=120)
    ft = FaultyTransport(off_c)
    victim = NetPrivateServeEngine(ft, on_c, pool_target=2, seed=1,
                                   timeout=120)
    survivor = _inproc_engine(gw, seed=2)

    victim.preprocess(2)
    survivor.preprocess(1)
    x = rng.normal(0, 1, (S, D))
    victim.run(x)  # consumes 1 of the victim's 2 bundles

    ft.arm(Fault(ft.op + 4, "reset"))  # fires mid-prep-stream
    refill = victim.refill_async(1)
    refill.join(timeout=120)
    assert not refill.is_alive(), "refill thread hung on the kill"
    assert victim.pool_size() == 1, "failed refill must not grow the pool"

    # finish the crash: the online leg vanishes too, no bye
    victim.online.transport.close()
    _wait(lambda: gw.stats()["sessions_active"] == 1,
          what="victim session teardown")

    st = gw.stats()
    # exactly the unconsumed prior bundle came back; the interrupted
    # prep was never committed on either side (no phantom bundle, no
    # burn — only a mid-RUN interrupt burns)
    assert st["bundles_prepped"] == 3  # victim 2 + survivor 1
    assert st["bundles_returned"] == 1
    assert st["bundles_burned"] == 0
    assert st["bundles_consumed"] == 1
    assert st["bundles_prepped"] == (
        st["bundles_consumed"] + st["bundles_outstanding"]
        + st["bundles_returned"] + st["bundles_burned"])

    # the survivor is untouched and bit-identical
    y = survivor.run(x)
    sess = model.compile_session(S, impl="ref")
    assert np.array_equal(y, sess.run(x, sess.preprocess(1)[0]))
    survivor.close()
    gw.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_gateway_session_cap_sheds_with_hint():
    model = _model(seed=31)
    gw = PitGateway(model, S, impl="ref", max_sessions=1)
    lst = TcpListener()
    loop = gw.serve_listener(lst, accept_timeout=0.2, timeout=60)

    eng = gateway_client("127.0.0.1", lst.port, seed=1, timeout=60)
    with pytest.raises(BundlePoolEmpty) as ei:
        gateway_client("127.0.0.1", lst.port, seed=2, timeout=60)
    assert ei.value.scope == "session"
    assert ei.value.retry_after_s is not None and ei.value.retry_after_s > 0
    assert gw.stats()["sessions_shed"] == 1

    # the admitted session keeps working after the shed
    rng = np.random.default_rng(32)
    x = rng.normal(0, 1, (S, D))
    eng.preprocess(1)
    y = eng.run(x)
    sess = model.compile_session(S, impl="ref")
    assert np.array_equal(y, sess.run(x, sess.preprocess(1)[0]))

    eng.close()
    loop.stop()
    gw.close()
    lst.close()


# ---------------------------------------------------------------------------
# stats()/metrics() thread-safety: consistent snapshots under churn
# ---------------------------------------------------------------------------


def test_gateway_stats_metrics_consistent_under_hammer():
    """N client threads mutate the gateway (admits, preps, runs,
    teardowns) while a reader polls ``stats()``/``metrics()`` in a tight
    loop. Every snapshot must be internally consistent (counters taken
    under the gateway lock, per-session summaries under each session
    lock) and the metrics counters monotonic across polls — a torn read
    shows up as a violated identity or a counter going backwards."""
    model = _model(seed=51)
    gw = PitGateway(model, S, impl="ref", max_sessions=8, pool_cap=8)
    stop = threading.Event()
    problems = []
    polls = [0]
    counter_keys = {"sessions_admitted", "sessions_shed", "prep_sheds",
                    "sessions_resumed", "leases_expired",
                    "bundles_prepped", "bundles_consumed",
                    "bundles_returned", "bundles_burned",
                    "garbling_cache_hits", "garbling_cache_misses"}
    gauge_keys = {"sessions_active", "sessions_parked",
                  "bundles_outstanding", "prep_inflight",
                  "prep_ewma_s", "bundles_per_s", "elapsed_s"}

    def reader():
        last = None
        while not stop.is_set():
            st = gw.stats()
            m = gw.metrics()
            try:
                assert m["schema"] == "pit.gateway.v1"
                assert set(m["counters"]) == counter_keys  # stable schema
                assert set(m["gauges"]) == gauge_keys
                assert isinstance(m["spans"], dict)
                assert st["sessions_active"] <= st["sessions_admitted"]
                # every prepped bundle is outstanding, consumed,
                # returned, or burned — an identity only a consistent
                # snapshot keeps (burn accounting holds it mid-run too)
                assert st["bundles_prepped"] == (
                    st["bundles_consumed"] + st["bundles_outstanding"]
                    + st["bundles_burned"]
                    + sum(s["bundles_returned"] for s in st["sessions"]))
                if last is not None:
                    for k in counter_keys:
                        assert m["counters"][k] >= last[k], \
                            f"counter {k} went backwards"
                last = m["counters"]
                polls[0] += 1
            except AssertionError as e:
                problems.append(str(e))
                stop.set()
                return

    rd = threading.Thread(target=reader)
    rd.start()

    rng = np.random.default_rng(52)
    xs = [rng.normal(0, 1, (S, D)) for _ in range(3)]
    errs = []

    def client(i):
        try:
            eng = _inproc_engine(gw, seed=60 + i)
            eng.preprocess(1)
            eng.run(xs[i])
            eng.close()  # clean teardown churns the session table too
        except Exception as e:
            errs.append((i, e))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(3)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=240)
    stop.set()
    rd.join(timeout=30)
    assert not errs, errs
    assert not problems, problems
    assert polls[0] > 10, "reader barely ran — hammer proved nothing"
    st = gw.stats()
    assert st["sessions_admitted"] == 3
    assert st["bundles_consumed"] == 3
    gw.close()


def test_gateway_bounded_pool_sheds_before_garbling():
    model = _model(seed=41)
    gw = PitGateway(model, S, impl="ref", max_sessions=2, pool_cap=1)
    eng = _inproc_engine(gw, seed=1)

    assert eng.preprocess(1) == 1  # at the cap
    c2s_after_first = eng.ledger.offline.client_to_server
    with pytest.raises(BundlePoolEmpty) as ei:
        eng.preprocess(1)  # would exceed pool_cap=1 -> typed shed
    assert ei.value.scope == "prep"
    assert ei.value.retry_after_s is not None and ei.value.retry_after_s > 0
    # shed before the expensive work: no offline PROTO bytes moved (the
    # refused prep cost one CONTROL round trip, nothing garbled)
    assert eng.ledger.offline.client_to_server == c2s_after_first
    assert gw.stats()["prep_sheds"] == 1

    # consuming the outstanding bundle frees capacity again
    rng = np.random.default_rng(42)
    eng.run(rng.normal(0, 1, (S, D)))
    assert eng.preprocess(1) == 1
    eng.close()
    gw.close()

"""Tiny offline stand-in for the ``hypothesis`` API surface these tests
use (``given``/``settings``/``st.integers``/``st.tuples``/``st.lists``).

When hypothesis is installed the real library is used (see the guarded
imports in the test modules); otherwise this shim runs each property test
on ``max_examples`` deterministic pseudo-random draws so the suite still
collects and exercises the properties without the dependency.
"""

from __future__ import annotations

import inspect
import random


class _Strategy:
    def example(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def example(self, rng):
        return rng.randint(self.lo, self.hi)


class _Tuples(_Strategy):
    def __init__(self, parts):
        self.parts = parts

    def example(self, rng):
        return tuple(p.example(rng) for p in self.parts)


class _Lists(_Strategy):
    def __init__(self, elem, min_size=0, max_size=None):
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rng):
        size = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(size)]


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def tuples(*parts):
        return _Tuples(parts)

    @staticmethod
    def lists(elem, min_size=0, max_size=None):
        return _Lists(elem, min_size=min_size, max_size=max_size)


def settings(max_examples=10, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**drawn):
    """Run the test on N deterministic draws; fixture args pass through."""

    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", 10)
            rng = random.Random(f"shim:{fn.__module__}.{fn.__name__}")
            for _ in range(n):
                vals = {k: s.example(rng) for k, s in drawn.items()}
                fn(*args, **kwargs, **vals)

        # pytest must only see the fixture parameters, not the drawn ones
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items() if name not in drawn]
        wrapper.__signature__ = sig.replace(parameters=params)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._shim_max_examples = getattr(fn, "_shim_max_examples", 10)
        return wrapper

    return deco


st = strategies

"""Config registry + assigned architecture invariants."""

import pytest

from repro.config import (
    SHAPES,
    assigned_shapes,
    get_config,
    list_configs,
    reduced_config,
)

ASSIGNED = [
    "olmoe-1b-7b", "llama4-scout-17b-a16e", "llama3.2-1b", "deepseek-67b",
    "qwen3-1.7b", "smollm-360m", "musicgen-medium", "xlstm-125m",
    "zamba2-2.7b", "internvl2-26b",
]

# (layers, d_model, heads, kv, d_ff, vocab) from the assignment table
EXPECTED = {
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
    "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
    "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
    "smollm-360m": (32, 960, 15, 5, 2560, 49152),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
}


def test_all_assigned_registered():
    names = list_configs()
    for a in ASSIGNED:
        assert a in names


@pytest.mark.parametrize("arch", ASSIGNED)
def test_exact_dims(arch):
    c = get_config(arch)
    assert (
        c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
        c.vocab_size,
    ) == EXPECTED[arch]


@pytest.mark.parametrize("arch", ASSIGNED)
def test_padded_vocab_shards(arch):
    c = get_config(arch)
    assert c.padded_vocab % 128 == 0
    assert c.padded_vocab >= c.vocab_size


def test_long_500k_only_subquadratic():
    for arch in ASSIGNED:
        c = get_config(arch)
        names = [s.name for s in assigned_shapes(c)]
        if c.family in ("ssm", "hybrid"):
            assert "long_500k" in names, arch
        else:
            assert "long_500k" not in names, arch


def test_total_cells():
    # 10 archs x 4 shapes = 40 cells; 8 long_500k skips are documented
    total = sum(len(assigned_shapes(get_config(a))) for a in ASSIGNED)
    assert total == 32
    assert 10 * len(SHAPES) == 40


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_config_preserves_structure(arch):
    c = get_config(arch)
    r = reduced_config(c)
    assert r.family == c.family
    assert (r.num_experts > 0) == (c.num_experts > 0)
    assert r.qk_norm == c.qk_norm
    assert r.input_mode == c.input_mode
    assert r.num_heads % r.num_kv_heads == 0


def test_param_counts_sane():
    assert abs(get_config("deepseek-67b").num_params() / 67e9 - 1) < 0.05
    assert abs(get_config("smollm-360m").num_params() / 0.41e9 - 1) < 0.15
    assert abs(get_config("olmoe-1b-7b").num_params() / 6.9e9 - 1) < 0.1

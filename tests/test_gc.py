"""Garbled-circuit correctness: hypothesis property tests on random
circuits + arithmetic circuit properties + Bristol roundtrip."""

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline CI: deterministic fallback shim
    from _hyp_fallback import given, settings, strategies as st

from repro.core.circuits import arith, bristol
from repro.core.circuits.builder import CircuitBuilder
from repro.core.garble import run_garbled
from repro.core.netlist import OP_AND, OP_INV, OP_XOR


def _rand_circuit(draw_ops, n_g=4, n_e=4):
    cb = CircuitBuilder("h")
    g = [cb.g_input() for _ in range(n_g)]
    e = [cb.e_input() for _ in range(n_e)]
    pool = g + e + [cb.constant(0), cb.constant(1)]
    for op, a, b in draw_ops:
        a %= len(pool)
        b %= len(pool)
        if op == 0:
            pool.append(cb.AND(pool[a], pool[b]))
        elif op == 1:
            pool.append(cb.XOR(pool[a], pool[b]))
        else:
            pool.append(cb.INV(pool[a]))
    cb.output(pool[-min(8, len(pool)):])
    return cb.build()


@settings(max_examples=15, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 1000), st.integers(0, 1000)),
        min_size=5, max_size=60,
    ),
    seed=st.integers(0, 2**31 - 1),
)
def test_garbled_equals_plaintext(ops, seed):
    net = _rand_circuit(ops)
    rng = np.random.default_rng(seed)
    I = 3
    gb = rng.integers(0, 2, (I, len(net.garbler_inputs)))
    eb = rng.integers(0, 2, (I, len(net.evaluator_inputs)))
    want = net.eval_plain(gb, eb)
    got = run_garbled(net, jax.random.PRNGKey(seed), gb, eb, impl="ref")
    assert np.array_equal(want, got)


@settings(max_examples=20, deadline=None)
@given(a=st.integers(0, 2**16 - 1), b=st.integers(0, 2**16 - 1))
def test_adder_property(a, b):
    cb = CircuitBuilder()
    wa = cb.g_input_word(16)
    wb = cb.e_input_word(16)
    cb.output(arith.add(cb, wa, wb))
    net = cb.build()
    bits = lambda v: [(v >> i) & 1 for i in range(16)]
    out = net.eval_plain([bits(a)], [bits(b)])
    got = sum(int(x) << i for i, x in enumerate(out[0]))
    assert got == (a + b) % (1 << 16)
    assert net.and_count == 15  # optimal ripple adder


@settings(max_examples=20, deadline=None)
@given(a=st.integers(0, 2**12 - 1), b=st.integers(0, 2**12 - 1))
def test_xfbq_identity_property(a, b):
    """XFBQ(x) represents x + INV(lsb x); the product identity holds."""
    k = 12
    cb = CircuitBuilder()
    wa = cb.g_input_word(k)
    wb = cb.e_input_word(k)
    cb.output(arith.mul_xfbq(cb, wa, wb, qerror_terms=True))
    net = cb.build()
    bits = lambda v: [(v >> i) & 1 for i in range(k)]
    out = net.eval_plain([bits(a)], [bits(b)])
    got = sum(int(x) << i for i, x in enumerate(out[0]))
    assert got == (a * b) % (1 << k)


@settings(max_examples=10, deadline=None)
@given(a=st.integers(0, 2**10 - 1), b=st.integers(0, 2**10 - 1))
def test_comparator_mux(a, b):
    cb = CircuitBuilder()
    wa = cb.g_input_word(10)
    wb = cb.e_input_word(10)
    lt = arith.lt_unsigned(cb, wa, wb)
    cb.output(arith.mux(cb, lt, wb, wa))  # max(a, b)
    net = cb.build()
    bits = lambda v: [(v >> i) & 1 for i in range(10)]
    out = net.eval_plain([bits(a)], [bits(b)])
    got = sum(int(x) << i for i, x in enumerate(out[0]))
    assert got == max(a, b)


def test_and_reduction_xfbq_64b():
    """Fig. 5(b): XFBQ cuts 64-bit multiplier ANDs by ~39-50%."""
    k = 64
    counts = {}
    for style, qe in [("conventional", False), ("xfbq", False), ("xfbq", True)]:
        cb = CircuitBuilder()
        a = cb.g_input_word(k)
        b = cb.e_input_word(k)
        cb.output(arith.mul(cb, a, b, style=style, qerror_terms=qe))
        counts[(style, qe)] = cb.build().and_count
    base = counts[("conventional", False)]
    red_noq = 1 - counts[("xfbq", False)] / base
    red_q = 1 - counts[("xfbq", True)] / base
    assert 0.35 < red_noq < 0.60, red_noq
    assert 0.30 < red_q < 0.55, red_q
    assert red_q < red_noq  # q-error terms cost extra ANDs


def test_garble_batched_instances(rng):
    """Instance batching (coarse-grained rows) garbles independently."""
    cb = CircuitBuilder()
    a = cb.g_input_word(8)
    b = cb.e_input_word(8)
    cb.output(arith.add(cb, a, b))
    net = cb.build()
    I = 16
    av = rng.integers(0, 256, I)
    bv = rng.integers(0, 256, I)
    gb = (av[:, None] >> np.arange(8)) & 1
    eb = (bv[:, None] >> np.arange(8)) & 1
    out = run_garbled(net, jax.random.PRNGKey(7), gb, eb, impl="ref")
    got = (out.astype(np.int64) << np.arange(8)).sum(1)
    assert np.array_equal(got, (av + bv) % 256)


def test_bristol_roundtrip(rng):
    cb = CircuitBuilder("rt")
    a = cb.g_input_word(6)
    b = cb.e_input_word(6)
    s = arith.add(cb, a, b)
    m = arith.mux(cb, arith.lt_unsigned(cb, a, b), s, a)
    cb.output(m)
    net = cb.build()
    text = bristol.emit(net)
    net2 = bristol.parse(text, "rt2")
    assert net2.and_count == net.and_count
    assert net2.num_gates == net.num_gates
    for _ in range(5):
        av, bv = rng.integers(0, 64, 2)
        bits = lambda v: [(int(v) >> i) & 1 for i in range(6)]
        o1 = net.eval_plain([bits(av)], [bits(bv)])
        o2 = net2.eval_plain([bits(av)], [bits(bv)])
        assert np.array_equal(o1, o2)


def test_inv_and_const_are_free():
    cb = CircuitBuilder()
    a = cb.g_input()
    x = cb.INV(a)
    y = cb.XOR(x, cb.constant(1))  # == a, folded
    z = cb.AND(y, cb.constant(1))  # == y, folded
    cb.output(z)
    net = cb.build()
    assert net.and_count == 0
    out = net.eval_plain([[1]], np.zeros((1, 0)))
    assert out[0][0] == 1

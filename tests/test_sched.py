"""Scheduler + speculation + accelerator-model invariants."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline CI: deterministic fallback shim
    from _hyp_fallback import given, settings, strategies as st

from repro.core.circuits import nonlinear as NL
from repro.core.circuits.builder import CircuitBuilder
from repro.sched import schedulers as SC
from repro.sched.speculation import speculate
from repro.accel.sim import AccelConfig, simulate_core


@pytest.fixture(scope="module")
def net():
    return NL.softmax_circuit(4, k=20, frac=8, style="xfbq").build()


def _rand_net(ops):
    cb = CircuitBuilder()
    ins = [cb.g_input() for _ in range(4)] + [cb.e_input() for _ in range(4)]
    pool = list(ins)
    for op, a, b in ops:
        a %= len(pool)
        b %= len(pool)
        pool.append(
            cb.AND(pool[a], pool[b]) if op == 0 else cb.XOR(pool[a], pool[b])
        )
    cb.output(pool[-4:])
    return cb.build()


@settings(max_examples=10, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.integers(0, 1), st.integers(0, 999), st.integers(0, 999)),
        min_size=10, max_size=80,
    )
)
def test_all_orders_topological(ops):
    net = _rand_net(ops)
    for fn in (
        SC.depth_first_order,
        SC.full_reorder,
        lambda n: SC.segment_reorder(n, 16),
        lambda n: SC.fine_grained_order(n, 16),
    ):
        order = fn(net)
        assert len(order) == net.num_gates
        assert len(set(order.tolist())) == net.num_gates
        assert SC.check_topological(net, order)


def test_speculation_no_spill_when_memory_big(net):
    order = SC.fine_grained_order(net, 10**9)
    prog = speculate(net, order, capacity_wires=net.num_wires + 10,
                     policy="apint")
    assert prog.stats.oorw_fetches == 0
    assert prog.stats.dram_wire_writes == 0


def test_speculation_lbuw_beats_haac(net):
    cap = 1024
    order = SC.segment_reorder(net, cap // 2)
    apint = speculate(net, order, cap, policy="apint")
    haac = speculate(net, order, cap, policy="haac")
    assert apint.stats.oorw_fetches < haac.stats.oorw_fetches
    assert apint.stats.dram_wire_bytes < haac.stats.dram_wire_bytes


def test_fig10_progression(net):
    """HAAC -> +coarse -> +fine -> +speculation strictly improves latency;
    APINT end point cuts memory stalls by >80% (paper: 86.1-99.4%)."""
    cap = 1024
    sr = SC.segment_reorder(net, cap // 2)
    fine = SC.fine_grained_order(net, cap // 2)
    results = {}
    for name, order, policy, coal in [
        ("haac", sr, "haac", False),
        ("coarse", sr, "haac", True),
        ("fine", fine, "haac", True),
        ("apint", fine, "apint", True),
    ]:
        prog = speculate(net, order, cap, policy=policy)
        cfg = AccelConfig(coalesced=coal)
        results[name] = simulate_core(net, prog, cfg, cfg.dram_burst_latency)
    assert results["coarse"].cycles < results["haac"].cycles
    assert results["apint"].cycles < results["coarse"].cycles
    mem_red = 1 - results["apint"].memory_stall_cycles / max(
        results["haac"].memory_stall_cycles, 1)
    assert mem_red > 0.8, mem_red
    assert results["apint"].oorw_count < results["haac"].oorw_count


def test_coarse_partition():
    nets = [object()] * 37
    parts = SC.coarse_grained_partition(nets, 16)
    assert sum(len(p) for p in parts) == 37
    assert max(len(p) for p in parts) - min(len(p) for p in parts) <= 1


def test_garble_cycle_table(net):
    """Garble-side costing uses 21 cy per AND (matching accel/sim.py);
    the garbling schedule stays a valid topological order."""
    from repro.accel import sim as AS

    assert SC.gate_cycles(garbling=False)[1] == AS.HALFGATE_EVAL_CY == 18
    assert SC.gate_cycles(garbling=True)[1] == AS.HALFGATE_GARBLE_CY == 21
    assert SC.GATE_CYCLES == SC.gate_cycles(garbling=False)  # compat view
    order = SC.fine_grained_order(net, 1024, garbling=True)
    assert len(order) == net.num_gates
    assert SC.check_topological(net, order)


def test_schedule_cost_parity_with_accel_sim(net):
    """The scheduler's costing and the accelerator model price a netlist
    identically in both phases — the 21 cy/AND garble constant (4 hash
    lanes + a dense 2-row table write) now matches the packed-emission
    device executor, which writes exactly 2 table rows per real AND
    (pad-lane spill is overwritten in place, never amortized per AND)."""
    from repro.accel import sim as AS
    from repro.core.netlist import OP_AND

    for garbling in (False, True):
        assert SC.schedule_cost(net, garbling=garbling) == \
            AS.program_compute_cycles(net, garbling=garbling)
    n_and = int(np.sum(net.op == OP_AND))
    diff = SC.schedule_cost(net, garbling=True) - \
        SC.schedule_cost(net, garbling=False)
    assert diff == n_and * (AS.HALFGATE_GARBLE_CY - AS.HALFGATE_EVAL_CY)
    # the device executor's packed layout keeps the dense-write premise:
    # exactly one packed table row pair per real AND gate
    from repro.core.netlist import compile_level_plan
    plan = compile_level_plan(net)
    assert len(plan.and_rows) == n_and
    assert sorted(plan.and_rows) == list(range(n_and))


def test_cpfe_prioritizes_critical_path():
    # chain of ANDs (critical) + independent XORs: chain must rank first
    cb = CircuitBuilder()
    a = cb.g_input()
    b = cb.e_input()
    chain = a
    for _ in range(5):
        chain = cb.AND(chain, b)
    xors = [cb.XOR(a, b)]
    for _ in range(4):
        xors.append(cb.XOR(xors[-1], b))
    cb.output([chain, xors[-1]])
    net = cb.build()
    rank = SC._cpfe_priorities(net, np.arange(net.num_gates))
    and_ranks = [rank[g] for g in range(net.num_gates)
                 if net.op[g] == 1]
    xor_ranks = [rank[g] for g in range(net.num_gates)
                 if net.op[g] == 0]
    assert max(and_ranks) < min(xor_ranks)

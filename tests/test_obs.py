"""Tracing + metrics subsystem (``repro.obs``): tracer unit behavior,
Chrome trace_event export validity, the span-backed ``Stats.phase``
unification, and the ledger <-> trace reconciliation contract — on a real
two-party TCP run, the per-(phase, tag) byte sums of the ``wire:seg``
trace events must equal the :class:`~repro.net.party.WireLedger` per-tag
totals *exactly*, on both wire versions.
"""

import importlib.util
import json
import threading
import time
from collections import defaultdict
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from repro.core.protocol import Stats

ROOT = Path(__file__).resolve().parents[1]
D, HEADS, DFF, S = 8, 2, 16, 4


def _load_trace_check():
    """The CI artifact validator, loaded from scripts/ (not a package)."""
    spec = importlib.util.spec_from_file_location(
        "trace_check", ROOT / "scripts" / "trace_check.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _model(seed=0):
    rng = np.random.default_rng(seed)
    weights = random_weights(rng, D, DFF, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=6)
    return PrivateTransformer(pcfg, D, HEADS, DFF, weights, seed=seed)


@pytest.fixture
def tracer():
    tr = obs.enable()
    yield tr
    obs.disable()


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_span_nesting_builds_paths(tracer):
    with obs.span("a"):
        with obs.span("b", n=1):
            with obs.span("c"):
                pass
        with obs.span("b"):
            pass
    paths = [sp.path for sp in tracer.finished_spans()]
    assert sorted(paths) == ["a", "a/b", "a/b", "a/b/c"]
    rep = tracer.report()
    assert rep["a/b"]["count"] == 2
    assert rep["a"]["count"] == 1
    assert rep["a"]["total_s"] >= rep["a/b"]["total_s"] > 0
    assert rep["a/b"]["max_s"] >= rep["a/b"]["mean_s"]


def test_span_attrs_reject_payloads(tracer):
    with pytest.raises(TypeError):
        obs.span("x", labels=np.arange(3))
    with pytest.raises(TypeError):
        obs.span("x", data=b"\x00\x01")
    with pytest.raises(TypeError):
        obs.instant("x", seg=[1, 2])
    with obs.span("x") as sp:
        with pytest.raises(TypeError):
            sp.set(arr=np.zeros(2))
        sp.set(bytes=16, tag="shares", ok=True, frac=0.5)  # scalars pass
    assert tracer.finished_spans()[-1].attrs["bytes"] == 16


def test_null_tracer_is_shared_noop():
    assert obs.current() is obs.NULL_TRACER
    s1, s2 = obs.span("a", n=1), obs.span("b")
    assert s1 is s2  # one preallocated object, no per-call allocation
    assert s1.elapsed_s == 0.0
    assert s1.set(x=1) is s1 and s1.close() is s1
    with obs.span("c"):
        pass
    obs.instant("i", n=2)
    assert obs.current().finished_spans() == []
    assert obs.current().report() == {}
    with pytest.raises(RuntimeError):
        obs.current().export("/tmp/never.json")


def test_timer_measures_with_tracing_off_and_on(tracer):
    obs.disable()
    sp = obs.timer("t", n=1)
    time.sleep(0.01)
    assert sp.close().elapsed_s >= 0.01  # real measurement, unrecorded
    assert obs.current().finished_spans() == []

    obs.install(tracer)
    with obs.timer("t2") as sp2:
        time.sleep(0.001)
    assert sp2.elapsed_s > 0
    assert [s.name for s in tracer.finished_spans()] == ["t2"]


def test_stats_phase_is_span_backed(tracer):
    """The Stats.phase timing path and the trace are the same clock:
    one outermost block == one recorded span == one t_s accumulation."""
    st = Stats()
    with st.phase("offline"):
        with st.phase("offline"):  # re-entrant: inner block is free
            time.sleep(0.005)
        with obs.span("op:linear"):
            pass
    assert st.t_offline_s >= 0.005
    rep = tracer.report()
    assert rep["offline"]["count"] == 1
    assert abs(rep["offline"]["total_s"] - st.t_offline_s) < 1e-9
    assert rep["offline/op:linear"]["count"] == 1  # ops nest under phase


def test_tracer_threads_isolated_stacks(tracer):
    barrier = threading.Barrier(8)  # all 8 alive at once: distinct tids

    def worker(i):
        barrier.wait(timeout=30)
        with obs.span("outer", worker=i):
            with obs.span("inner"):
                pass

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    spans = tracer.finished_spans()
    assert len(spans) == 16
    # per-thread stacks: every inner nested under ITS thread's outer
    for sp in spans:
        if sp.name == "inner":
            assert sp.path == "outer/inner"
    assert len({sp._tid for sp in spans}) == 8


def test_export_chrome_schema(tracer, tmp_path):
    with obs.span("parent", n=2):
        with obs.span("child"):
            obs.instant("tick", bytes=4)
    out = tmp_path / "t.json"
    tracer.export(str(out))
    doc = json.loads(out.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert [e["ph"] for e in evs].count("B") == 2
    assert [e["ph"] for e in evs].count("E") == 2
    inst = [e for e in evs if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["s"] == "t"
    assert all(evs[i]["ts"] <= evs[i + 1]["ts"] for i in range(len(evs) - 1))
    # the artifact validator CI runs must agree it is clean + balanced
    assert _load_trace_check().check_events(doc) == []


def test_trace_check_catches_bad_traces():
    tc = _load_trace_check()
    base = {"cat": "x", "ts": 1.0, "pid": 1, "tid": 1}
    # unbalanced: B without E
    doc = {"traceEvents": [{"name": "a", "ph": "B", **base}]}
    assert any("unclosed" in p for p in tc.check_events(doc))
    # mismatched close
    doc = {"traceEvents": [{"name": "a", "ph": "B", **base},
                           {"name": "b", "ph": "E", **base}]}
    assert any("closes" in p for p in tc.check_events(doc))
    # secret-looking attribute key
    doc = {"traceEvents": [{"name": "a", "ph": "i", **base,
                            "args": {"input_labels": 3}}]}
    assert any("secret-looking" in p for p in tc.check_events(doc))
    # payload-shaped attribute value
    doc = {"traceEvents": [{"name": "a", "ph": "i", **base,
                            "args": {"v": [1, 2, 3]}}]}
    assert any("payload-shaped" in p for p in tc.check_events(doc))
    assert tc.check_events({"traceEvents": []}) == []


# ---------------------------------------------------------------------------
# the reconciliation contract: trace wire:seg sums == WireLedger, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire_version", [1, 2])
def test_trace_reconciles_with_wire_ledger_tcp(tracer, tmp_path,
                                               wire_version):
    """Full two-party TCP run with tracing on: for every (phase, tag),
    the byte attrs of the ``wire:seg`` trace events sum to the
    :class:`WireLedger` per-tag totals exactly — once over the sender's
    ``wire.send`` emissions and once over the receiver's ``wire.recv``
    emissions (both parties share the process-global tracer here)."""
    from repro.net import (GarblerEndpoint, PitNetServer, TcpListener,
                           TcpTransport)

    model = _model(seed=17)
    rng = np.random.default_rng(18)
    x = rng.normal(0, 1, (S, D))
    srv = PitNetServer(model, S, impl="ref")
    lst = TcpListener()
    loop = srv.serve_tcp(lst, timeout=300)
    cli = GarblerEndpoint(TcpTransport.connect("127.0.0.1", lst.port),
                          seed=19, impl="ref", timeout=300,
                          wire_version=wire_version)
    assert loop.wait_accepted(1, timeout=30)
    cli.preprocess(1)
    y = cli.run(x)
    assert cli.shared.negotiated_version == wire_version
    assert np.isfinite(y).all()
    cli.close()
    lst.close()

    sent = {"offline": defaultdict(int), "online": defaultdict(int)}
    rcvd = {"offline": defaultdict(int), "online": defaultdict(int)}
    for name, _ts, _tid, attrs in tracer.finished_instants():
        if name != "wire:seg":
            continue
        side = sent if attrs["dir"] == "send" else rcvd
        side[attrs["phase"]][attrs["tag"]] += attrs["bytes"]

    led = cli.shared.ledger
    for phase, chan in (("offline", led.offline), ("online", led.online)):
        want = dict(chan.by_tag)
        assert dict(sent[phase]) == want, f"v{wire_version} {phase} send"
        assert dict(rcvd[phase]) == want, f"v{wire_version} {phase} recv"
    # and the server-side ledger tells the same story
    sled = srv.shared.ledger
    assert dict(sent["offline"]) == dict(sled.offline.by_tag)
    assert dict(sent["online"]) == dict(sled.online.by_tag)

    # structural nesting: protocol op spans live under the phase spans
    paths = {sp.path for sp in tracer.finished_spans()}
    assert "offline" in paths and "online" in paths
    assert any(p.startswith("online/op:") for p in paths), sorted(paths)
    assert "offline/garble" in paths  # client-side garbling under offline
    assert any(p.startswith("online/wire.") for p in paths)

    # the exported artifact passes the CI validator end to end
    out = tmp_path / f"recon_v{wire_version}.json"
    tracer.export(str(out))
    doc = json.loads(out.read_text())
    assert _load_trace_check().check_events(doc) == []
    n_segs = sum(1 for e in doc["traceEvents"]
                 if e["ph"] == "i" and e["name"] == "wire:seg")
    assert n_segs == sum(1 for nm, *_ in tracer.finished_instants()
                         if nm == "wire:seg")

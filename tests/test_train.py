"""Trainer substrate: resume bitwise-equality, checkpoint atomicity,
straggler watchdog, gradient compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config, reduced_config
from repro.train import checkpoint as CK
from repro.train.compression import ErrorFeedback, _quant, _dequant
from repro.train.fault import StragglerWatchdog, elastic_info
from repro.train.trainer import Trainer


def _mk_trainer(d, **kw):
    cfg = reduced_config(get_config("smollm-360m"))
    tc = TrainConfig(total_steps=10, warmup_steps=2, checkpoint_every=4,
                     checkpoint_dir=d, seed=0, **kw)
    return Trainer(cfg, tc, global_batch=4, seq_len=32)


def test_resume_bitwise_identical():
    with tempfile.TemporaryDirectory() as d1:
        tr = _mk_trainer(d1)
        tr.init_or_resume(resume=False)
        full = tr.run(8, with_guard=False)["losses"]
    with tempfile.TemporaryDirectory() as d2:
        tr1 = _mk_trainer(d2)
        tr1.init_or_resume(resume=False)
        part1 = tr1.run(4, with_guard=False)["losses"]
        tr2 = _mk_trainer(d2)
        assert tr2.init_or_resume(resume=True) == 4
        part2 = tr2.run(4, with_guard=False)["losses"]
    assert np.array_equal(np.array(full), np.array(part1 + part2))


def test_checkpoint_atomic_and_gc():
    with tempfile.TemporaryDirectory() as d:
        state = {"a": {"w": np.arange(6).reshape(2, 3)}, "step": np.int32(3)}
        for s in (1, 2, 3, 4, 5):
            CK.save(d, s, state, keep=2)
        assert CK.latest_step(d) == 5
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert kept == ["step_4", "step_5"]
        step, restored = CK.restore(d)
        assert step == 5
        assert np.array_equal(restored["a"]["w"], state["a"]["w"])


def test_straggler_watchdog():
    wd = StragglerWatchdog(k=3.0, warmup=3)
    for i in range(20):
        wd.observe(i, 0.1 + 0.001 * (i % 3))
    assert wd.flagged == []
    assert wd.observe(100, 1.5) is True
    assert 100 in wd.flagged


def test_elastic_info():
    info = elastic_info()
    assert info["devices"] >= 1
    assert info["mesh"][0] * info["mesh"][1] <= info["devices"]


def test_int8_quant_roundtrip(rng):
    x = jnp.asarray(rng.normal(0, 3, (128,)), jnp.float32)
    q, s = _quant(x)
    back = _dequant(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 1.01


def test_error_feedback_reduces_bias(rng):
    g = jnp.asarray(rng.normal(0, 1, (256,)), jnp.float32)
    ef = ErrorFeedback({"g": g})
    total_plain = jnp.zeros_like(g)
    total_ef = jnp.zeros_like(g)
    ident = lambda x: x
    for _ in range(50):
        q, s = _quant(g)
        total_plain = total_plain + _dequant(q, s)
        red = ef.apply({"g": g}, ident)
        total_ef = total_ef + red["g"]
    err_plain = float(jnp.linalg.norm(total_plain - 50 * g))
    err_ef = float(jnp.linalg.norm(total_ef - 50 * g))
    assert err_ef < err_plain * 0.5  # error feedback kills accumulated bias


def test_compressed_ring_allreduce_multi_device():
    """Ring int8 all-reduce ~= psum (runs on 8 fake devices, subprocess)."""
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.train.compression import _ring_allreduce_int8
from repro.utils.compat import shard_map
if jax.device_count() < 8:
    # host platform override not honored (e.g. a real accelerator backend
    # won the platform pick); the 8-way mesh below can't be built
    print("SKIP: fewer than 8 devices")
    raise SystemExit(0)
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
parts = rng.normal(0, 1, (8, 1, 64)).astype(np.float32)  # distinct per rank
fn = shard_map(
    lambda x: _ring_allreduce_int8(x[0], "data")[None],
    mesh=mesh, in_specs=P("data"), out_specs=P("data"), check=False,
)
res = np.asarray(fn(jnp.asarray(parts)))  # (8, 1, 64): each rank's result
want = parts.sum(0)[0]
for rnk in range(8):
    err = np.abs(res[rnk, 0] - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 0.08, (rnk, err)
print("OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        env={**os.environ, "PYTHONPATH": "src"},
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))),
    )
    if "SKIP" in r.stdout:
        pytest.skip("fewer than 8 jax devices available in subprocess")
    assert "OK" in r.stdout, r.stdout + r.stderr

"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.halfgate import ref as HR
from repro.kernels.halfgate import ref_np as HN
from repro.kernels.halfgate.halfgate import eval_pallas, garble_pallas
from repro.kernels.label_select import ref as LR
from repro.kernels.label_select.label_select import select_labels_pallas
from repro.kernels.ntt import ref as NR
from repro.kernels.ntt.ntt import ntt_pallas


def _labels(key, g):
    return jax.random.bits(key, (g, 4), dtype=jnp.uint32)


@pytest.mark.parametrize("g", [1, 7, 64, 513, 4096])
def test_halfgate_garble_sweep(g):
    ks = jax.random.split(jax.random.PRNGKey(g), 4)
    a0, b0, r = _labels(ks[0], g), _labels(ks[1], g), _labels(ks[2], g)
    tw = jnp.arange(g, dtype=jnp.uint32)
    ref = HR.garble_and_gates(a0, b0, r, tw)
    pal = garble_pallas(a0, b0, r, tw, interpret=True)
    for x, y in zip(ref, pal):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("g", [1, 65, 2048])
def test_halfgate_eval_sweep(g):
    ks = jax.random.split(jax.random.PRNGKey(g + 99), 4)
    a0, b0, r = _labels(ks[0], g), _labels(ks[1], g), _labels(ks[2], g)
    tw = jnp.arange(g, dtype=jnp.uint32)
    _, tg, te = HR.garble_and_gates(a0, b0, r, tw)
    ref = HR.eval_and_gates(a0, b0, tg, te, tw)
    pal = eval_pallas(a0, b0, tg, te, tw, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))


def test_halfgate_numpy_mirror():
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    g = 777
    a0, b0, r = _labels(ks[0], g), _labels(ks[1], g), _labels(ks[2], g)
    tw = jnp.arange(g, dtype=jnp.uint32)
    jr = HR.garble_and_gates(a0, b0, r, tw)
    nr = HN.garble_and_gates(np.asarray(a0), np.asarray(b0), np.asarray(r),
                             np.asarray(tw))
    for x, y in zip(jr, nr):
        np.testing.assert_array_equal(np.asarray(x), y)


def test_halfgate_correctness_semantics():
    """Evaluated label equals the garbler's label for a AND b."""
    g = 256
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    a0, b0 = _labels(ks[0], g), _labels(ks[1], g)
    r = jax.random.bits(ks[2], (1, 4), dtype=jnp.uint32)
    r = r.at[..., 0].set(r[..., 0] | jnp.uint32(1))
    r = jnp.broadcast_to(r, (g, 4))
    tw = jnp.arange(g, dtype=jnp.uint32)
    c0, tg, te = HR.garble_and_gates(a0, b0, r, tw)
    for abit in (0, 1):
        for bbit in (0, 1):
            a = a0 ^ (r * abit)
            b = b0 ^ (r * bbit)
            c = HR.eval_and_gates(a, b, tg, te, tw)
            want = c0 ^ (r * (abit & bbit))
            np.testing.assert_array_equal(np.asarray(c), np.asarray(want))


@pytest.mark.parametrize("n", [64, 256, 1024, 4096])
def test_ntt_pallas_sweep(n):
    q = NR.find_ntt_primes(16, 1, n, max_q=NR.INT32_PRODUCT_BOUND)[0]
    a = np.random.default_rng(n).integers(0, q, (3, n)).astype(np.int64)
    fwd_ref = np.asarray(NR.ntt_forward(jnp.asarray(a.astype(np.uint64)), q, n))
    fwd_pal = np.asarray(
        ntt_pallas(jnp.asarray(a, jnp.int32), q, n, interpret=True)
    ).astype(np.uint64)
    np.testing.assert_array_equal(fwd_ref, fwd_pal)
    back = np.asarray(
        ntt_pallas(jnp.asarray(fwd_pal.astype(np.int64), jnp.int32), q, n,
                   inverse=True, interpret=True)
    ).astype(np.uint64)
    np.testing.assert_array_equal(back, a.astype(np.uint64))


@pytest.mark.parametrize("n,q_bits", [(256, 13), (256, 14), (1024, 14)])
def test_ntt_convolution_theorem(n, q_bits):
    q = NR.find_ntt_primes(q_bits, 1, n)[0]
    rng = np.random.default_rng(1)
    a = rng.integers(0, q, n).astype(np.uint64)
    b = rng.integers(0, q, n).astype(np.uint64)
    fast = np.asarray(NR.negacyclic_mul(jnp.asarray(a), jnp.asarray(b), q, n))
    naive = NR.negacyclic_mul_naive(a, b, q, n)
    np.testing.assert_array_equal(fast, naive)


@pytest.mark.parametrize("g", [3, 100, 4097])
def test_label_select_sweep(g):
    key = jax.random.PRNGKey(g)
    ks = jax.random.split(key, 3)
    w0 = _labels(ks[0], g)
    r = _labels(ks[1], g)
    bits = jax.random.bits(ks[2], (g,), dtype=jnp.uint32) & 1
    ref = LR.select_labels(w0, r, bits)
    pal = select_labels_pallas(w0, r, bits, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))

"""End-to-end behaviour: private transformer inference parity (the paper's
accuracy claim, Fig. 8a analog) and the serving path."""

import numpy as np
import pytest

from repro.config import PrivacyConfig


@pytest.mark.slow
def test_private_inference_matches_float(rng):
    from repro.core.engine import PrivateTransformer, random_weights

    d, heads, d_ff, S = 16, 2, 32, 8
    weights = random_weights(rng, d, d_ff, 1)
    x = rng.normal(0, 1, (S, d))
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=7)
    eng = PrivateTransformer(pcfg, d, heads, d_ff, weights, seed=0)
    got = eng.forward_private(x)
    want = eng.forward_float(x)
    # fixed-point + LUT approximation error through a full block
    assert np.abs(got - want).max() < 0.25
    assert np.abs(got - want).mean() < 0.05
    st = eng.p.stats
    assert st.gc_instances_ands > 0
    assert st.channel_offline.total > st.channel_online.total  # DELPHI shape


@pytest.mark.slow
def test_apint_reduces_layernorm_gc_end_to_end(rng):
    """Whole-block workload with vs without the LayerNorm offload."""
    from repro.core.engine import PrivateTransformer, random_weights

    d, heads, d_ff, S = 16, 2, 32, 8
    weights = random_weights(rng, d, d_ff, 1)
    x = rng.normal(0, 1, (S, d))
    ands = {}
    for off in (True, False):
        pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                             frac_bits=7, layernorm_offload=off)
        eng = PrivateTransformer(pcfg, d, heads, d_ff, weights, seed=0)
        eng.forward_private(x)
        ands[off] = sum(
            v["and"] * v["instances"]
            for k, v in eng.p.stats.per_fn.items()
            if "layernorm" in k
        )
    assert ands[True] < 0.7 * ands[False]


def test_serve_decode_matches_prefill(rng):
    """Greedy decode tokens from the cache path == argmax from the full
    forward at each position."""
    import jax
    import jax.numpy as jnp

    from repro.config import get_config, reduced_config
    from repro.models.transformer import forward, init_params
    from repro.serve import ServeEngine
    from repro.serve.engine import Request
    import dataclasses

    cfg = dataclasses.replace(
        reduced_config(get_config("llama3.2-1b"), attn_chunk=16),
        dtype="float32",
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    eng = ServeEngine(cfg, params, capacity=32, batch=1)
    out = eng.generate([Request(prompt=prompt, max_new_tokens=4)])[0]

    # oracle: recompute each step with a full prefill over the grown prompt
    toks = list(prompt)
    want = []
    for _ in range(4):
        logits, _ = forward(
            cfg, params, {"tokens": jnp.asarray(np.array(toks)[None])},
            mode="prefill",
        )
        nxt = int(np.argmax(np.asarray(logits)[0, : cfg.vocab_size]))
        want.append(nxt)
        toks.append(nxt)
    assert out.out_tokens == want

"""Device-resident GC executor: bit-exact parity with the numpy oracle
across netlist shapes, executable-cache behaviour, and the single-dispatch
guarantee (one jitted call per evaluate — no per-level host round trips).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import PrivacyConfig
from repro.core import garble as G
from repro.core.circuits import arith
from repro.core.circuits.builder import CircuitBuilder
from repro.core.garble import run_garbled
from repro.core.gc_exec import get_executor
from repro.core.netlist import compile_level_plan
from repro.core.protocol import PiTProtocol
from repro.kernels.dispatch import resolve_impl

DEVICE_IMPL = resolve_impl("auto")  # "jit" on CPU CI, "pallas" on TPU


def _adder_net():
    cb = CircuitBuilder("adder8")
    a = cb.g_input_word(8)
    b = cb.e_input_word(8)
    cb.output(arith.add(cb, a, b))
    return cb.build()


def _comparator_net():
    cb = CircuitBuilder("cmpmux")
    a = cb.g_input_word(6)
    b = cb.e_input_word(6)
    s = arith.add(cb, a, b)
    cb.output(arith.mux(cb, arith.lt_unsigned(cb, a, b), s, a))
    return cb.build()


def _inv_levels_net():
    """Chains of INVs make whole levels with zero AND/XOR lanes."""
    cb = CircuitBuilder("invchain")
    x = cb.g_input()
    y = cb.e_input()
    for _ in range(5):
        x = cb.INV(x)
        y = cb.INV(y)
    cb.output([x, cb.XOR(x, y), cb.AND(x, y)])
    return cb.build()


def _const_net():
    cb = CircuitBuilder("consts")
    a = cb.g_input_word(4)
    b = cb.e_input_word(4)
    c = cb.const_word(0b1010, 4)
    s = arith.add(cb, a, arith.add(cb, b, c))
    cb.output(s)
    return cb.build()


@pytest.fixture(scope="module")
def softmax_row_net():
    """A real (tiny) protocol softmax-row netlist: share reconstruct ->
    max/exp/reciprocal -> remask, with garbler+evaluator+const wires."""
    pcfg = PrivacyConfig(he_poly_n=64, he_num_primes=2, he_t_bits=12,
                         frac_bits=4, layernorm_offload=True)
    return PiTProtocol(pcfg, seed=0).softmax_net(2, 4)


SHAPES = {
    "adder": _adder_net,
    "comparator": _comparator_net,
    "inv_levels": _inv_levels_net,
    "const_wires": _const_net,
}


@pytest.mark.parametrize("impl", [DEVICE_IMPL, "pallas_interpret"])
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_executor_matches_plaintext(shape, impl, rng):
    net = SHAPES[shape]()
    I = 3
    gb = rng.integers(0, 2, (I, len(net.garbler_inputs)))
    eb = rng.integers(0, 2, (I, len(net.evaluator_inputs)))
    want = net.eval_plain(gb, eb)
    got = run_garbled(net, jax.random.PRNGKey(7), gb, eb, impl=impl)
    assert np.array_equal(want, got)


@pytest.mark.parametrize("impl", [DEVICE_IMPL, "pallas_interpret"])
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_garble_bit_exact_vs_ref(shape, impl):
    """Same key stream -> identical tables/labels/permute bits: the
    device executor is a drop-in for the numpy walk, not just
    semantically equivalent."""
    net = SHAPES[shape]()
    key = jax.random.PRNGKey(11)
    g_ref = G.garble(net, key, 2, impl="ref", keep_wires=True)
    g_dev = G.garble(net, key, 2, impl=impl, keep_wires=True)
    assert np.array_equal(np.asarray(g_ref.tables), np.asarray(g_dev.tables))
    assert np.array_equal(np.asarray(g_ref.input_zero),
                          np.asarray(g_dev.input_zero))
    assert np.array_equal(np.asarray(g_ref.output_perm),
                          np.asarray(g_dev.output_perm))
    assert np.array_equal(np.asarray(g_ref.wire_zero),
                          np.asarray(g_dev.wire_zero))


@pytest.mark.parametrize("impl", [DEVICE_IMPL, "pallas_interpret"])
def test_softmax_row_parity(softmax_row_net, impl, rng):
    net = softmax_row_net
    I = 2
    gb = rng.integers(0, 2, (I, len(net.garbler_inputs)))
    eb = rng.integers(0, 2, (I, len(net.evaluator_inputs)))
    want = net.eval_plain(gb, eb)
    got = run_garbled(net, jax.random.PRNGKey(3), gb, eb, impl=impl)
    assert np.array_equal(want, got)


def test_slice_instances_bands(rng):
    """Batch-garble once, hand each consumer an instance band: the band's
    device evaluate matches the ref oracle decode bit for bit."""
    net = _comparator_net()
    I, lo, hi = 6, 2, 5
    gb = rng.integers(0, 2, (I, len(net.garbler_inputs)))
    eb = rng.integers(0, 2, (I, len(net.evaluator_inputs)))
    gc = G.garble(net, jax.random.PRNGKey(5), I, impl=DEVICE_IMPL)
    band = G.slice_instances(gc, lo, hi)
    ids = np.concatenate([np.asarray(net.garbler_inputs, np.int64),
                          np.asarray(net.evaluator_inputs, np.int64)])
    labs = np.concatenate(
        [np.asarray(G.encode_inputs(band, net.garbler_inputs, gb[lo:hi])),
         np.asarray(G.encode_inputs(band, net.evaluator_inputs, eb[lo:hi]))],
        axis=1)
    out = G.evaluate(net, band.tables, (ids, labs), impl=DEVICE_IMPL)
    got = G.decode_outputs(band, out)
    assert np.array_equal(got, net.eval_plain(gb, eb)[lo:hi])


def test_executor_cache_and_single_dispatch(rng):
    """One executable per (netlist, instances, impl); repeated evaluates
    reuse it without retracing — i.e. the whole netlist walk stays inside
    a single cached jit call (zero per-level dispatches)."""
    net = _adder_net()
    I = 12  # > 8: exercises the throughput-regime plan
    gb = rng.integers(0, 2, (I, 8))
    eb = rng.integers(0, 2, (I, 8))
    gc = G.garble(net, jax.random.PRNGKey(1), I, impl=DEVICE_IMPL)
    ids = np.concatenate([np.asarray(net.garbler_inputs, np.int64),
                          np.asarray(net.evaluator_inputs, np.int64)])
    labs = np.concatenate(
        [np.asarray(G.encode_inputs(gc, net.garbler_inputs, gb)),
         np.asarray(G.encode_inputs(gc, net.evaluator_inputs, eb))], axis=1)

    exe = get_executor(net, I, DEVICE_IMPL)
    assert get_executor(net, I, DEVICE_IMPL) is exe  # cache hit
    calls0, traces0 = exe.n_eval_calls, exe.n_traces
    for _ in range(3):
        G.evaluate(net, gc.tables, (ids, labs), impl=DEVICE_IMPL)
    assert exe.n_eval_calls == calls0 + 3
    # the body traced at most once across all three calls: the walk is a
    # single compiled dispatch, never a per-level loop
    assert exe.n_traces <= traces0 + 1
    G.evaluate(net, gc.tables, (ids, labs), impl=DEVICE_IMPL)
    assert exe.n_traces <= traces0 + 1

    # a different batch size is a different executable, same cache
    gc2 = G.garble(net, jax.random.PRNGKey(1), 2, impl=DEVICE_IMPL)
    exe2 = get_executor(net, 2, DEVICE_IMPL)
    assert exe2 is not exe
    assert get_executor(net, 2, DEVICE_IMPL) is exe2


def test_auto_never_uses_host_loop(rng):
    """``impl="auto"`` resolves to the device-resident path everywhere —
    the numpy walk only runs when "ref" is requested explicitly."""
    assert resolve_impl("auto") in ("jit", "pallas")
    net = _const_net()
    I = 2
    gb = rng.integers(0, 2, (I, 4))
    eb = rng.integers(0, 2, (I, 4))
    got = run_garbled(net, jax.random.PRNGKey(2), gb, eb, impl="auto")
    assert np.array_equal(got, net.eval_plain(gb, eb))
    plan = compile_level_plan(net, instances=I)
    assert any(impl != "ref" for (_, impl) in plan._executors), \
        "auto dropped to the host loop"


def test_width_regimes_both_correct(rng):
    """Small batches get the wide latency plan, large ones the tight
    throughput plan — same netlist, both bit-correct."""
    net = _adder_net()
    lat = compile_level_plan(net, instances=2)
    thr = compile_level_plan(net, instances=64)
    assert lat.and_width >= thr.and_width
    assert lat.free_width >= thr.free_width
    assert lat.n_chunks <= thr.n_chunks
    for I in (2, 64):
        gb = rng.integers(0, 2, (I, 8))
        eb = rng.integers(0, 2, (I, 8))
        got = run_garbled(net, jax.random.PRNGKey(I), gb, eb,
                          impl=DEVICE_IMPL)
        assert np.array_equal(got, net.eval_plain(gb, eb))


def test_level_plan_invariants_append_only():
    """compact=False escape hatch: append-only numbering — every chunk
    reads strictly below its own output block, writes land contiguously,
    and the store holds exactly one live row per gate."""
    net = _comparator_net()
    plan = compile_level_plan(net, compact=False)
    assert not plan.compact
    K = plan.n_chunks
    stride = plan.and_width + plan.free_width
    n_src = len(plan.source_ids)
    assert plan.n_rows == n_src + net.num_gates + stride + 1
    assert plan.n_rows == plan.store_rows_naive
    valid = plan.and_valid + plan.free_valid
    assert plan.base[0] == n_src
    assert np.array_equal(np.diff(plan.base), valid[:-1])
    assert int(valid.sum()) == net.num_gates
    dummy = plan.n_rows - 1
    for k in range(K):
        for arr in (plan.and_in0[k], plan.and_in1[k],
                    plan.free_in0[k], plan.free_in1[k]):
            real = arr[arr != dummy]
            assert real.max(initial=-1) < plan.base[k]
        assert sorted(plan.perm[k]) == list(range(stride))
    # every original wire resolves to a live row
    assert plan.wire_rows.max() <= dummy
    out_rows = plan.wire_rows[np.asarray(net.outputs)]
    assert np.array_equal(out_rows, plan.out_rows)


def test_level_plan_invariants_compact():
    """Liveness-compacted numbering: the store shrinks below one row per
    gate, write windows stay clear of sources and the dummy row, and the
    packed table layout is exactly the cumsum of valid AND lanes."""
    net = _comparator_net()
    plan = compile_level_plan(net)  # compact is the default
    assert plan.compact
    assert plan.n_rows < plan.store_rows_naive  # reuse actually happened
    stride = plan.and_width + plan.free_width
    n_src = len(plan.source_ids)
    dummy = plan.n_rows - 1
    # windows never overlap pinned rows (sources below, dummy above);
    # the read-liveness invariant itself ("no row rewritten while live")
    # is simulated and asserted by compile_level_plan's validator
    assert plan.base.min() >= n_src
    assert (plan.base + stride <= dummy).all()
    for k in range(plan.n_chunks):
        assert sorted(plan.perm[k]) == list(range(stride))
    # outputs stay pinned: every output row is where wire_rows says
    assert np.array_equal(plan.wire_rows[np.asarray(net.outputs)],
                          plan.out_rows)
    # packed tables: chunk-major cumsum layout, one row per real AND
    assert np.array_equal(np.diff(plan.table_base), plan.and_valid[:-1])
    assert plan.n_table_rows == net.and_count + plan.and_width
    assert len(plan.and_rows) == net.and_count
    assert sorted(plan.and_rows) == list(range(net.and_count))


def test_liveness_adversarial_long_lived_row():
    """A wire produced early and read only at the very end: a naive
    renumber that recycles rows by production order would clobber it.
    The liveness pass must keep it pinned across the whole chain — the
    compile-time plan validator fails otherwise, and the executor output
    must stay bit-exact.

    (Private generator, not the session-scoped ``rng`` fixture: new
    tests must not shift the shared stream consumed by later modules.)
    """
    rng = np.random.default_rng(71)
    cb = CircuitBuilder("longlived")
    a = cb.g_input_word(4)
    b = cb.e_input_word(4)
    keep = [cb.AND(a[i], b[i]) for i in range(4)]  # early, read last
    chain = arith.add(cb, a, b)
    for _ in range(40):  # long filler chain that churns through rows
        chain = arith.add(cb, chain, b)
    tail = [cb.AND(keep[i], chain[i]) for i in range(4)]  # late reads
    cb.output(list(chain) + keep + tail)
    net = cb.build()
    plan = compile_level_plan(net)  # compile-time validator runs here
    assert plan.compact
    assert plan.n_rows < plan.store_rows_naive, \
        "no reuse happened — the adversarial case was not exercised"
    I = 3
    gb = rng.integers(0, 2, (I, len(net.garbler_inputs)))
    eb = rng.integers(0, 2, (I, len(net.evaluator_inputs)))
    want = net.eval_plain(gb, eb)
    got = run_garbled(net, jax.random.PRNGKey(9), gb, eb, impl=DEVICE_IMPL)
    assert np.array_equal(want, got)


@pytest.mark.parametrize("impl", [DEVICE_IMPL, "pallas_interpret"])
@pytest.mark.parametrize("instances", [1, 64])
def test_packed_table_parity(impl, instances):
    """Packed table emission (dense carry at table_base offsets, no
    ys-stack padding) stays bit-exact with the numpy oracle across the
    latency (I=1) and preprocessing (I=64) regimes."""
    net = _comparator_net()
    key = jax.random.PRNGKey(21)
    g_ref = G.garble(net, key, instances, impl="ref")
    g_dev = G.garble(net, key, instances, impl=impl)
    assert np.array_equal(np.asarray(g_ref.tables), np.asarray(g_dev.tables))
    assert np.array_equal(np.asarray(g_ref.input_zero),
                          np.asarray(g_dev.input_zero))
    assert np.array_equal(np.asarray(g_ref.output_perm),
                          np.asarray(g_dev.output_perm))


def test_compact_false_fallback_parity():
    """The compact=False escape hatch is a full drop-in: same tables,
    same labels, same end-to-end bits as the compacted default."""
    rng = np.random.default_rng(72)  # private: keep the shared stream
    net = _adder_net()
    key = jax.random.PRNGKey(31)
    I = 5
    g_compact = G.garble(net, key, I, impl=DEVICE_IMPL)
    exe = get_executor(net, I, DEVICE_IMPL, compact=False)
    assert not exe.plan.compact
    from repro.core import labels as LB
    k_r, k_w = jax.random.split(key)
    r = LB.random_delta(k_r, (I,))
    src = LB.random_labels(k_w, (I, len(exe.plan.source_ids)))
    in_zero, tables, out_perm = exe.garble(src, r)
    assert np.array_equal(np.asarray(g_compact.tables), np.asarray(tables))
    assert np.array_equal(np.asarray(g_compact.output_perm),
                          np.asarray(out_perm))
    gb = rng.integers(0, 2, (I, 8))
    eb = rng.integers(0, 2, (I, 8))
    got = run_garbled(net, jax.random.PRNGKey(41), gb, eb, impl=DEVICE_IMPL)
    assert np.array_equal(got, net.eval_plain(gb, eb))


def test_keep_wires_requires_append_only():
    """keep_wires garbling routes to the compact=False plan (the compacted
    store recycles rows, so a full wire snapshot is impossible there)."""
    net = _adder_net()
    exe = get_executor(net, 2, DEVICE_IMPL, compact=True)
    src = jnp.zeros((2, len(exe.plan.source_ids), 4), jnp.uint32)
    r = jnp.ones((2, 4), jnp.uint32)
    with pytest.raises(ValueError, match="keep_wires"):
        exe.garble(src, r, keep_wires=True)
    # the public API routes around it
    gc = G.garble(net, jax.random.PRNGKey(1), 2, impl=DEVICE_IMPL,
                  keep_wires=True)
    assert gc.wire_zero is not None


def test_garble_width_plan_interop():
    """AND-rich netlists garble on a tighter-AND-width plan than they
    evaluate on (4 hash lanes per padded AND lane garbler-side vs 2).
    Tables are dense-slot ordered, so the two plans interoperate — and
    stay bit-exact with the oracle."""
    cb = CircuitBuilder("andrich")
    a = cb.g_input_word(96)
    b = cb.e_input_word(96)
    cb.output([cb.AND(a[i], b[i]) for i in range(96)])
    net = cb.build()
    I = 16  # throughput regime
    eplan = compile_level_plan(net, instances=I)
    gplan = compile_level_plan(net, instances=I, garbling=True)
    assert gplan.and_width < eplan.and_width  # distinct plans engaged
    key = jax.random.PRNGKey(13)
    g_ref = G.garble(net, key, I, impl="ref")
    g_dev = G.garble(net, key, I, impl=DEVICE_IMPL)
    assert np.array_equal(np.asarray(g_ref.tables), np.asarray(g_dev.tables))
    rng = np.random.default_rng(73)  # private: keep the shared stream
    gb = rng.integers(0, 2, (I, 96))
    eb = rng.integers(0, 2, (I, 96))
    got = run_garbled(net, key, gb, eb, impl=DEVICE_IMPL)
    assert np.array_equal(got, net.eval_plain(gb, eb))


@pytest.mark.parametrize("impl", [DEVICE_IMPL, "pallas_interpret"])
def test_prefetch_parity(impl):
    """The double-buffered speculative gather (prefetch=True) is purely a
    scheduling change: garble and evaluate outputs are bit-identical to
    the default path — including the forwarding patch for lanes the
    current chunk itself just produced."""
    from repro.core import labels as LB
    from repro.core.gc_exec import LevelExecutor

    net = _comparator_net()
    I = 4
    plan = compile_level_plan(net, instances=I)
    exe_pf = LevelExecutor(plan, I, impl, prefetch=True)
    exe_np = LevelExecutor(plan, I, impl, prefetch=False)
    assert exe_pf.prefetch and not exe_np.prefetch
    key = jax.random.PRNGKey(17)
    k_r, k_w = jax.random.split(key)
    r = LB.random_delta(k_r, (I,))
    src = LB.random_labels(k_w, (I, len(plan.source_ids)))
    z_pf, tab_pf, perm_pf = exe_pf.garble(src, r)
    z_np, tab_np, perm_np = exe_np.garble(src, r)
    assert np.array_equal(np.asarray(tab_pf), np.asarray(tab_np))
    assert np.array_equal(np.asarray(z_pf), np.asarray(z_np))
    assert np.array_equal(np.asarray(perm_pf), np.asarray(perm_np))
    active = LB.random_labels(jax.random.PRNGKey(5),
                              (I, len(plan.source_ids)))
    o_pf = exe_pf.evaluate(active, tab_pf)
    o_np = exe_np.evaluate(active, tab_np)
    assert np.array_equal(np.asarray(o_pf), np.asarray(o_np))


def test_plan_stats_report_reuse():
    """stats() surfaces the liveness and packed-table wins per netlist."""
    net = _comparator_net()
    s = compile_level_plan(net).stats()
    assert s["compact"] and s["store_rows"] < s["store_rows_naive"]
    assert s["store_row_reduction"] > 1.0
    assert s["table_rows_real"] == net.and_count
    assert s["table_rows_padded"] >= s["table_rows_real"]
    s_naive = compile_level_plan(net, compact=False).stats()
    assert s_naive["store_rows"] == s_naive["store_rows_naive"]
